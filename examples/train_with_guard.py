"""End-to-end driver: train a ~100M-class model for a few hundred steps
with the real Guard pipeline in the loop: per-step wall times flow
through ``GuardStepHook`` into telemetry Frames, the peer-relative
detector and tiered policy run on them, and a (synthetically injected)
stall triggers the IMMEDIATE-restart path — the health manager swaps the
host's node for a spare and the trainer resumes from the fastest
checkpoint tier: the ``TieredCheckpointManager``'s in-memory peer
replica (hot-spare promotion) rather than a cold restart from durable
storage. The hook publishes the incident as a ``RecoveryEvent`` with
the tier used and re-tunes the fast-snapshot cadence from the session's
live MTTF estimate at every checkpoint boundary.

This is the single-host version of the production loop; on a fleet, each
host reports its barrier time and the same session runs fleet-side.

Run:  PYTHONPATH=src python examples/train_with_guard.py [--steps 300]
"""
import argparse
import tempfile
import time


from repro.configs import get_config, reduced
from repro.guard import GuardStepHook, NodeSwapped, RecoveryEvent
from repro.models.model import Model
from repro.train import (AdamWConfig, DataConfig, SyntheticLM,
                         TieredCheckpointManager, TrainConfig, Trainer)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--big", action="store_true",
                    help="~100M-param config (use on real accelerators; "
                         "the default ~20M fits a single CPU core)")
    args = ap.parse_args()

    if args.big:   # the ~100M-class driver for real hardware
        cfg = reduced(get_config(args.arch), num_layers=8, d_model=768,
                      num_heads=12, num_kv_heads=12, d_ff=2304, head_dim=64,
                      vocab_size=16384)
    else:
        cfg = reduced(get_config(args.arch), num_layers=6, d_model=384,
                      num_heads=6, num_kv_heads=6, d_ff=1024, head_dim=64,
                      vocab_size=4096)
    print(f"[example] {cfg.name} reduced: "
          f"{cfg.param_count()/1e6:.0f}M params")

    model = Model(cfg)
    data = SyntheticLM(DataConfig(cfg.vocab_size, seq_len=128,
                                  global_batch=8 if args.big else 4))

    # the real Fig.-1 loop: measured step times -> Frames -> detector ->
    # tiered policy -> manager swap + trainer rewind. The injected stall
    # scales this host's *measured* wall time mid-run (a deterministic
    # stand-in for a stuck collective), so detection is genuine.
    hook = GuardStepHook(window_steps=4, n_peers=15)
    hook.inject_stall(at_step=args.steps // 2, factor=8.0, steps=4)
    hook.session.bus.subscribe(NodeSwapped, lambda ev: print(
        f"  [guard] node {ev.old} swapped for spare {ev.new} ({ev.reason}) "
        f"-> immediate restart"))
    hook.session.bus.subscribe(RecoveryEvent, lambda ev: print(
        f"  [guard] recovered from {ev.ckpt_tier} tier at step {ev.step} "
        f"({'hot-spare promotion' if ev.hot_spare else 'restart'}, "
        f"{ev.replay_steps} steps to replay)"))

    # fresh checkpoint dir per run: a stale checkpoint at/after --steps
    # would make restore() skip training entirely
    ckpt_dir = tempfile.mkdtemp(
        prefix=f"guard_example_ckpt_{cfg.d_model}x{cfg.num_layers}_")
    # tiered checkpointing: durable tier every ckpt_interval steps plus
    # peer-replica/local-shard fast snapshots on the MTTF-tuned cadence
    # (min_interval floors it to seconds here — CPU steps are slow)
    ckpt = TieredCheckpointManager(ckpt_dir, node_id=hook.node_id,
                                   fast_interval_s=5.0)
    hook.bind_checkpoint(ckpt)
    trainer = Trainer(
        model, data,
        TrainConfig(steps=args.steps, ckpt_interval=50,
                    opt=AdamWConfig(peak_lr=6e-4, warmup_steps=20,
                                    total_steps=args.steps)),
        ckpt=ckpt,
        hook=hook)

    t0 = time.perf_counter()
    out = trainer.run(on_metrics=lambda s, m: print(
        f"  step {s:4d} loss {m['loss']:.3f}") if s % 25 == 0 else None)
    dt = time.perf_counter() - t0
    losses = [h["loss"] for h in out["history"]]
    flags = [e for e in hook.session.events() if e.kind == "straggler_flagged"]
    recoveries = [e for e in hook.session.events() if e.kind == "recovery"]
    print(f"[example] {out['final_step']} steps in {dt:.0f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"{len(flags)} detector flag(s), "
          f"{hook.restarts_requested} guard restart(s), "
          f"{len(recoveries)} recovery event(s), "
          f"{ckpt.snapshots_taken} fast snapshot(s), "
          f"{hook.frames_fed} telemetry frames")
    assert hook.restarts_requested >= 1, "stall was not detected"
    assert recoveries and any(e.hot_spare for e in recoveries), \
        "restart did not resume from the peer-replica tier"
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()
