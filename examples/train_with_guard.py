"""End-to-end driver: train a ~100M-class model for a few hundred steps
with the real Guard pipeline in the loop: per-step wall times flow
through ``GuardStepHook`` into telemetry Frames, the peer-relative
detector and tiered policy run on them, and a (synthetically injected)
stall triggers the IMMEDIATE-restart path — the health manager swaps the
host's node for a spare and the trainer rewinds to its last checkpoint.

This is the single-host version of the production loop; on a fleet, each
host reports its barrier time and the same session runs fleet-side.

Run:  PYTHONPATH=src python examples/train_with_guard.py [--steps 300]
"""
import argparse
import tempfile
import time


from repro.configs import get_config, reduced
from repro.guard import GuardStepHook, NodeSwapped
from repro.models.model import Model
from repro.train import (AdamWConfig, CheckpointManager, DataConfig,
                         SyntheticLM, TrainConfig, Trainer)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--big", action="store_true",
                    help="~100M-param config (use on real accelerators; "
                         "the default ~20M fits a single CPU core)")
    args = ap.parse_args()

    if args.big:   # the ~100M-class driver for real hardware
        cfg = reduced(get_config(args.arch), num_layers=8, d_model=768,
                      num_heads=12, num_kv_heads=12, d_ff=2304, head_dim=64,
                      vocab_size=16384)
    else:
        cfg = reduced(get_config(args.arch), num_layers=6, d_model=384,
                      num_heads=6, num_kv_heads=6, d_ff=1024, head_dim=64,
                      vocab_size=4096)
    print(f"[example] {cfg.name} reduced: "
          f"{cfg.param_count()/1e6:.0f}M params")

    model = Model(cfg)
    data = SyntheticLM(DataConfig(cfg.vocab_size, seq_len=128,
                                  global_batch=8 if args.big else 4))

    # the real Fig.-1 loop: measured step times -> Frames -> detector ->
    # tiered policy -> manager swap + trainer rewind. The injected stall
    # scales this host's *measured* wall time mid-run (a deterministic
    # stand-in for a stuck collective), so detection is genuine.
    hook = GuardStepHook(window_steps=4, n_peers=15)
    hook.inject_stall(at_step=args.steps // 2, factor=8.0, steps=4)
    hook.session.bus.subscribe(NodeSwapped, lambda ev: print(
        f"  [guard] node {ev.old} swapped for spare {ev.new} ({ev.reason}) "
        f"-> immediate restart from last checkpoint"))

    # fresh checkpoint dir per run: a stale checkpoint at/after --steps
    # would make restore() skip training entirely
    ckpt_dir = tempfile.mkdtemp(
        prefix=f"guard_example_ckpt_{cfg.d_model}x{cfg.num_layers}_")
    trainer = Trainer(
        model, data,
        TrainConfig(steps=args.steps, ckpt_interval=50,
                    opt=AdamWConfig(peak_lr=6e-4, warmup_steps=20,
                                    total_steps=args.steps)),
        ckpt=CheckpointManager(ckpt_dir),
        hook=hook)

    t0 = time.perf_counter()
    out = trainer.run(on_metrics=lambda s, m: print(
        f"  step {s:4d} loss {m['loss']:.3f}") if s % 25 == 0 else None)
    dt = time.perf_counter() - t0
    losses = [h["loss"] for h in out["history"]]
    flags = [e for e in hook.session.events() if e.kind == "straggler_flagged"]
    print(f"[example] {out['final_step']} steps in {dt:.0f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"{len(flags)} detector flag(s), "
          f"{hook.restarts_requested} guard restart(s), "
          f"{hook.frames_fed} telemetry frames")
    assert hook.restarts_requested >= 1, "stall was not detected"
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()
