"""End-to-end driver: train a ~100M-class model for a few hundred steps
with the Guard step hook, checkpointing, and a mid-run restart.

This is the single-host version of the production loop: the trainer's
per-step wall time streams into the online monitor, checkpoints are saved
asynchronously, and a (manually injected) stall triggers the
IMMEDIATE-restart path, which rewinds to the last checkpoint.

Run:  PYTHONPATH=src python examples/train_with_guard.py [--steps 300]
"""
import argparse
import time


from repro.configs import get_config, reduced
from repro.models.model import Model
from repro.train import (AdamWConfig, CheckpointManager, DataConfig,
                         SyntheticLM, TrainConfig, Trainer)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--big", action="store_true",
                    help="~100M-param config (use on real accelerators; "
                         "the default ~20M fits a single CPU core)")
    args = ap.parse_args()

    if args.big:   # the ~100M-class driver for real hardware
        cfg = reduced(get_config(args.arch), num_layers=8, d_model=768,
                      num_heads=12, num_kv_heads=12, d_ff=2304, head_dim=64,
                      vocab_size=16384)
    else:
        cfg = reduced(get_config(args.arch), num_layers=6, d_model=384,
                      num_heads=6, num_kv_heads=6, d_ff=1024, head_dim=64,
                      vocab_size=4096)
    print(f"[example] {cfg.name} reduced: "
          f"{cfg.param_count()/1e6:.0f}M params")

    model = Model(cfg)
    data = SyntheticLM(DataConfig(cfg.vocab_size, seq_len=128,
                                  global_batch=8 if args.big else 4))

    stall = {"at": args.steps // 2, "armed": True}

    def hook(step, wall_s, metrics):
        # simulate a node stall mid-run: Guard fires an immediate restart
        if stall["armed"] and step == stall["at"]:
            stall["armed"] = False
            print(f"  [guard] stall detected at step {step} -> "
                  f"immediate restart from last checkpoint")
            return True
        return False

    ckpt_dir = f"/tmp/guard_example_ckpt_{cfg.d_model}x{cfg.num_layers}"
    trainer = Trainer(
        model, data,
        TrainConfig(steps=args.steps, ckpt_interval=50,
                    opt=AdamWConfig(peak_lr=6e-4, warmup_steps=20,
                                    total_steps=args.steps)),
        ckpt=CheckpointManager(ckpt_dir),
        hook=hook)

    t0 = time.perf_counter()
    out = trainer.run(on_metrics=lambda s, m: print(
        f"  step {s:4d} loss {m['loss']:.3f}") if s % 25 == 0 else None)
    dt = time.perf_counter() - t0
    losses = [h["loss"] for h in out["history"]]
    print(f"[example] {out['final_step']} steps in {dt:.0f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(incl. one checkpoint-rewind restart)")
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()
