"""Offline node sweep on REAL hardware (this host), driven through the
``repro.guard`` control plane — the deployable path of §5.2.

The sweep hardware is the ``LocalJaxSweepBackend``: the MXU-aligned
sustained-matmul Pallas burn kernel (repro/kernels/sweep_burn) on the
local JAX device(s) plus pairwise bandwidth timing. Instead of wiring
``single_node_sweep`` by hand, the demo builds a NODE_SWEEP-tier
``GuardSession`` over that backend: the operator pulls the node for
verification (``replace_node``), the non-blocking scheduler runs the
sweep -> (if needed) triage -> sweep qualification loop, and every state
transition — quarantine, sweep start/finish, triage stages, the final
verdict — arrives as typed events on the session bus. On a real TPU
host, drop interpret=True for the compiled kernel.

Run:  PYTHONPATH=src python examples/node_sweep_demo.py
"""

from repro.core.sweep import SweepConfig
from repro.core.triage import ErrorSignals
from repro.guard import GuardSession, LocalHostControl, SweepFinished
from repro.kernels.sweep_burn import LocalJaxSweepBackend, measure_tflops


class PrintSink:
    """Event-bus sink: every control-plane transition, as it happens."""

    def emit(self, ev) -> None:
        fields = {k: v for k, v in ev.to_dict().items()
                  if k not in ("kind", "t", "step") and v not in ("", ())}
        detail = ", ".join(f"{k}={v}" for k, v in fields.items())
        print(f"  [bus] {ev.kind:14s} {detail}")


def main():
    print("[sweep] building the NODE_SWEEP-tier session over the local "
          "JAX backend...")
    control = LocalHostControl()
    # the operator pulled this node for COMPUTE verification: a failed
    # sweep should walk the GPU remediation lane, not hit triage's
    # no-evidence early termination (which would RMA the host)
    control.signals_provider = lambda nid: ErrorSignals(
        gpu_errors=True, detail="operator-reported compute suspicion")
    backend = LocalJaxSweepBackend(interpret=True)
    ref = backend.reference()
    print(f"[sweep] reference: {ref.device_tflops:.3f} TFLOP/s "
          f"(interpret-mode on CPU; compiled on TPU), "
          f"{ref.intra_bw_gbps:.1f} GB/s")

    cfg = SweepConfig(burn_seconds=16.0, compute_tolerance=0.25,
                      symmetry_tolerance=0.25, bw_tolerance=0.8,
                      inflation_tolerance=2.0)
    session = GuardSession.node_sweep(control, backend, sweep_cfg=cfg)
    session.add_sink(PrintSink())
    session.register_active([0])
    session.register_spares([1])

    # the operator path: pull node 0 for offline verification; a spare
    # takes its place and the sweep scheduler picks it up event-driven
    print("[sweep] pulling node 0 for offline qualification...")
    session.replace_node(0, "operator-requested verification", step=0)
    control.t += 1.0
    session.advance(control.t)              # starts the queued sweep
    finish = session.scheduler.next_finish_t()
    control.t = (finish or control.t) + 1.0
    session.advance(control.t)              # lands the verdict

    done = [e for e in session.events() if isinstance(e, SweepFinished)]
    assert done, "qualification did not complete"
    verdict = done[-1]
    print(f"[sweep] node0 verdict: {verdict.outcome.upper()} "
          f"after {verdict.sweeps} sweep(s), "
          f"{verdict.duration_s:.0f}s simulated bench time")
    for f in verdict.failures:
        print("   failure:", f)
    print(f"[sweep] healthy spares now: {session.spare_ids()}")

    print("\n[sweep] sustained vs burst throughput (the §5.1 gap "
          "burn-in tests miss):")
    short = measure_tflops(iters=8, repeats=2)
    long = measure_tflops(iters=64, repeats=2)
    print(f"   8-iter burst: {short:.3f} TFLOP/s | "
          f"64-iter sustained: {long:.3f} TFLOP/s")


if __name__ == "__main__":
    main()
