"""Offline node sweep on REAL hardware (this host), via the Pallas burn
kernel — the deployable path of §5.2.

The LocalJaxSweepBackend runs the MXU-aligned sustained-matmul probe
(repro/kernels/sweep_burn) on the local JAX device(s), measures pairwise
bandwidth, and applies the same conservative verdict logic the simulator
uses. On a real TPU host, drop interpret=True for the compiled kernel.

Run:  PYTHONPATH=src python examples/node_sweep_demo.py
"""

from repro.core.sweep import SweepConfig, single_node_sweep
from repro.kernels.sweep_burn import LocalJaxSweepBackend, measure_tflops


def main():
    print("[sweep] calibrating reference on local device...")
    backend = LocalJaxSweepBackend(interpret=True)
    ref = backend.reference()
    print(f"[sweep] reference: {ref.device_tflops:.3f} TFLOP/s "
          f"(interpret-mode on CPU; compiled on TPU), "
          f"{ref.intra_bw_gbps:.1f} GB/s")

    cfg = SweepConfig(burn_seconds=16.0, compute_tolerance=0.25,
                      symmetry_tolerance=0.25, bw_tolerance=0.8)
    rep = single_node_sweep(backend, node_id=0, cfg=cfg)
    tf = rep.measurements["tflops"]
    print(f"[sweep] node0: {'PASS' if rep.passed else 'FAIL'}")
    for d, t in enumerate(tf):
        print(f"   device {d}: {t:.3f} TFLOP/s "
              f"({t / ref.device_tflops:.0%} of reference)")
    for f in rep.failures:
        print("   failure:", f)

    print("\n[sweep] sustained vs burst throughput (the §5.1 gap "
          "burn-in tests miss):")
    short = measure_tflops(iters=8, repeats=2)
    long = measure_tflops(iters=64, repeats=2)
    print(f"   8-iter burst: {short:.3f} TFLOP/s | "
          f"64-iter sustained: {long:.3f} TFLOP/s")


if __name__ == "__main__":
    main()
