"""Multi-day cluster simulation, fleet-first: two concurrent jobs
sharing one Guard control plane.

The default run drives TWO concurrent simulated jobs — an ENHANCED-tier
production job and an ONLINE-tier research job — through one
``FleetController``: both lease replacement capacity from the shared
global spare pool (cross-job transfers when a home fleet runs dry),
queue offline qualification on the shared sweep bench, and stream their
Guard events into the fleet-wide cursor-replayable log. The summary
shows the per-job ladder plus the fleet-level accounting: grants,
transfers, the healthscan's background campaigns, and the node census
conservation check.

``--tiers`` restores the classic single-job Table-4 ladder: the same
fleet/fault environment run under each management tier side by side,
with the MTTF / MFU / human-time columns the paper reports.

``--correlated`` (tiers mode) layers declarative fault scenarios on top
of the background Poisson wear: a rack-level cooling incident, a
leaf-switch failure and a fabric congestion storm (see
``repro.simcluster.scenarios``) — the incident mix that separates the
tiers hardest.

Run:  PYTHONPATH=src python examples/cluster_simulation.py [--hours 24]
          [--tiers] [--correlated]
"""
import argparse
from collections import Counter


from repro.guard import Tier
from repro.simcluster import (CongestionStorm, FleetJobSpec, FleetRunConfig,
                              RackThermal, RunConfig, SwitchFailure,
                              simulate_fleet, simulate_run)


def run_fleet(args):
    cfg = FleetRunConfig(
        jobs=(
            FleetJobSpec(name="prod", tier=Tier.ENHANCED,
                         n_nodes=args.nodes, n_spare=4, seed=0),
            FleetJobSpec(name="research", tier=Tier.ONLINE,
                         n_nodes=args.nodes, n_spare=4, seed=1),
        ),
        duration_h=args.hours,
        bench_slots=8,
        healthscan_period_s=3600.0,
        spare_target=8,
        seed=0)
    res = simulate_fleet(cfg)

    print(f"{'job':>10s}{'tier':>6s}{'steps':>8s}{'crashes':>9s}"
          f"{'restarts':>10s}{'leases':>8s}{'xfers':>7s}{'human':>8s}")
    for j in res.jobs:
        print(f"{j['name']:>10s}{j['tier']:6d}{j['steps']:8d}"
              f"{j['crashes']:9d}{j['restarts']:10d}{j['leases']:8d}"
              f"{j['transfers']:7d}{j['human_hours']:7.1f}h")
    cen = res.census
    print(f"\nshared pool: {res.pool['grants']} grants "
          f"({res.pool['transfers']} transfers, "
          f"{res.pool['provisions']} provisioned), "
          f"max wait {res.max_wait_s:.0f}s, "
          f"{res.starvation_events} starvation events")
    print(f"healthscan: {res.healthscan.get('campaigns', 0)} background "
          f"campaigns, {res.healthscan.get('scanned', 0)} spares scanned, "
          f"{res.healthscan.get('failed', 0)} pulled to quarantine")
    print(f"census: accounted {cen['accounted']} == expected "
          f"{cen['expected']} -> conserved={res.census_ok}")
    print(f"fleet log: {res.events_logged} events streamed "
          f"(cursor-replayable); control plane "
          f"{res.overhead_frac * 100:.2f}% of sim wall")


def run_tiers(args):
    scenarios = ()
    if args.correlated:
        scenarios = (
            RackThermal(at_h=args.hours * 0.2, rack_size=8),
            SwitchFailure(at_h=args.hours * 0.5, group_size=16),
            CongestionStorm(at_h=args.hours * 0.7, duration_h=1.0),
        )

    print(f"{'tier':22s}{'MTTF':>8s}{'MFU':>8s}{'human/inc':>11s}"
          f"{'mean step':>11s}{'crashes':>9s}{'restarts':>10s}  events")
    for tier in Tier:
        r = simulate_run(RunConfig(
            tier=tier, n_nodes=args.nodes, n_spare=8,
            duration_h=args.hours, initial_grey_p=0.2, seed=0,
            scenarios=scenarios))
        kinds = Counter(e["kind"] for e in r.events
                        if e["kind"] != "checkpoint")
        top = ", ".join(f"{k}:{n}" for k, n in kinds.most_common(3))
        print(f"T{int(tier)} {tier.name:18s}"
              f"{r.mttf_h:7.1f}h{r.mfu:8.1%}"
              f"{r.human_h_per_incident:10.2f}h"
              f"{r.mean_step_s:10.1f}s"
              f"{r.crashes:9d}{r.guard_restarts:10d}  {top}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hours", type=float, default=24.0)
    ap.add_argument("--nodes", type=int, default=64)
    ap.add_argument("--tiers", action="store_true",
                    help="single-job Table-4 tier ladder instead of the "
                         "two-job fleet demo")
    ap.add_argument("--correlated", action="store_true",
                    help="tiers mode: add rack/switch/congestion "
                         "scenario events")
    args = ap.parse_args()

    if args.tiers:
        run_tiers(args)
    else:
        run_fleet(args)


if __name__ == "__main__":
    main()
