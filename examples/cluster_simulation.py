"""Multi-day cluster simulation: the four Table-4 tiers side by side.

Runs the same fleet/fault environment under each management tier and
prints the MTTF / MFU / human-time ladder the paper reports — the
cluster-scale counterpart of quickstart.py.

Run:  PYTHONPATH=src python examples/cluster_simulation.py [--hours 24]
"""
import argparse


from repro.simcluster import RunConfig, Tier, simulate_run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hours", type=float, default=24.0)
    ap.add_argument("--nodes", type=int, default=64)
    args = ap.parse_args()

    print(f"{'tier':22s}{'MTTF':>8s}{'MFU':>8s}{'human/inc':>11s}"
          f"{'mean step':>11s}{'crashes':>9s}{'restarts':>10s}")
    for tier in Tier:
        r = simulate_run(RunConfig(
            tier=tier, n_nodes=args.nodes, n_spare=8,
            duration_h=args.hours, initial_grey_p=0.2, seed=0))
        print(f"T{int(tier)} {tier.name:18s}"
              f"{r.mttf_h:7.1f}h{r.mfu:8.1%}"
              f"{r.human_h_per_incident:10.2f}h"
              f"{r.mean_step_s:10.1f}s"
              f"{r.crashes:9d}{r.guard_restarts:10d}")


if __name__ == "__main__":
    main()
