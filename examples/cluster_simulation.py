"""Multi-day cluster simulation: the four Table-4 tiers side by side.

Runs the same fleet/fault environment under each management tier —
``GuardSession.from_tier`` builds the matching control plane inside
``simulate_run`` — and prints the MTTF / MFU / human-time ladder the
paper reports, plus the typed-event totals from each run's Guard trace.

``--correlated`` layers declarative fault scenarios on top of the
background Poisson wear: a rack-level cooling incident, a leaf-switch
failure and a fabric congestion storm (see
``repro.simcluster.scenarios``) — the incident mix that separates the
tiers hardest.

Run:  PYTHONPATH=src python examples/cluster_simulation.py [--hours 24]
          [--correlated]
"""
import argparse
from collections import Counter


from repro.guard import Tier
from repro.simcluster import (CongestionStorm, RackThermal, RunConfig,
                              SwitchFailure, simulate_run)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hours", type=float, default=24.0)
    ap.add_argument("--nodes", type=int, default=64)
    ap.add_argument("--correlated", action="store_true",
                    help="add rack/switch/congestion scenario events")
    args = ap.parse_args()

    scenarios = ()
    if args.correlated:
        scenarios = (
            RackThermal(at_h=args.hours * 0.2, rack_size=8),
            SwitchFailure(at_h=args.hours * 0.5, group_size=16),
            CongestionStorm(at_h=args.hours * 0.7, duration_h=1.0),
        )

    print(f"{'tier':22s}{'MTTF':>8s}{'MFU':>8s}{'human/inc':>11s}"
          f"{'mean step':>11s}{'crashes':>9s}{'restarts':>10s}  events")
    for tier in Tier:
        r = simulate_run(RunConfig(
            tier=tier, n_nodes=args.nodes, n_spare=8,
            duration_h=args.hours, initial_grey_p=0.2, seed=0,
            scenarios=scenarios))
        kinds = Counter(e["kind"] for e in r.events
                        if e["kind"] != "checkpoint")
        top = ", ".join(f"{k}:{n}" for k, n in kinds.most_common(3))
        print(f"T{int(tier)} {tier.name:18s}"
              f"{r.mttf_h:7.1f}h{r.mfu:8.1%}"
              f"{r.human_h_per_incident:10.2f}h"
              f"{r.mean_step_s:10.1f}s"
              f"{r.crashes:9d}{r.guard_restarts:10d}  {top}")


if __name__ == "__main__":
    main()
