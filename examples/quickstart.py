"""Quickstart: Guard in ~60 lines.

Builds a simulated 32-node training job, injects a thermally-degrading
node and a dead NIC, and watches the online monitor detect, classify, and
the health manager mitigate — the paper's Fig. 1 loop end to end.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (DetectorConfig, HealthManager, NodeState,
                        OnlineMonitor, PolicyConfig)
from repro.simcluster import FaultKind, FaultRates, SimCluster

QUIET = FaultRates(thermal=0, power=0, mem_ecc=0, nic_down=0,
                   nic_degraded=0, host_cpu=0, congestion=0, fail_stop=0,
                   admission_grey_p=0)


def main():
    cluster = SimCluster(n_active=32, n_spare=4, rates=QUIET, seed=0)
    monitor = OnlineMonitor(DetectorConfig(), PolicyConfig())
    manager = HealthManager(cluster, cluster, monitor)
    for nid in cluster.active:
        manager.register(nid, NodeState.ACTIVE)
    for nid in cluster.spares:
        manager.register(nid, NodeState.HEALTHY_SPARE)

    print("injecting: severe thermal fault on node 5, dead NIC on node 9")
    cluster.injector.inject(FaultKind.THERMAL, 5, severity=0.9)
    cluster.injector.inject(FaultKind.NIC_DOWN, 9, device=7)

    for step in range(1, 601):
        rec = cluster.run_step()
        if step % cluster.window_steps == 0:
            frame = cluster.collect()
            if frame is None:
                continue
            for ev in monitor.observe(frame):
                print(f"  t={rec['t']:7.0f}s step={step:4d} node "
                      f"{ev.decision.node_id}: {ev.decision.action.value} "
                      f"({ev.decision.reason})")
                manager.handle(ev)
        if step % 90 == 0:                   # checkpoint boundary
            if manager.on_checkpoint():
                print(f"  t={rec['t']:7.0f}s checkpoint: deferred swaps "
                      f"applied")
            manager.qualify_all_quarantined()

    times = cluster.node_barrier_times()
    print(f"\nfinal mean step {np.mean([cluster.run_step()['step_time'] for _ in range(20)]):.2f}s "
          f"(healthy = {cluster.workload.healthy_step_s:.2f}s)")
    print(f"node states: 5 -> {manager.state[5].value}, "
          f"9 -> {manager.state[9].value}")
    print(f"stats: {manager.stats}")


if __name__ == "__main__":
    main()
