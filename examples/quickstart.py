"""Quickstart: Guard in ~60 lines.

Builds a simulated 32-node training job, injects a thermally-degrading
node and a dead NIC, and watches one ``GuardSession`` — online detection,
tiered mitigation, and overlapped offline qualification behind a single
facade — close the paper's Fig. 1 loop end to end. Every state
transition lands on the session's typed event bus; this script just
tails the trace.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.guard import GuardSession, StragglerFlagged, SweepFinished, Tier
from repro.simcluster import FaultKind, FaultRates, SimCluster

QUIET = FaultRates(thermal=0, power=0, mem_ecc=0, nic_down=0,
                   nic_degraded=0, host_cpu=0, congestion=0, fail_stop=0,
                   admission_grey_p=0)


def main():
    cluster = SimCluster(n_active=32, n_spare=4, rates=QUIET, seed=0)
    session = GuardSession.from_tier(Tier.ENHANCED, control=cluster,
                                     sweep_backend=cluster)
    session.register_active(cluster.active)
    session.register_spares(cluster.spares)
    session.bus.subscribe(StragglerFlagged, lambda ev: print(
        f"  t={ev.t:7.0f}s step={ev.step:4d} node {ev.node_id}: "
        f"{ev.action} ({ev.reason})"))
    session.bus.subscribe(SweepFinished, lambda ev: print(
        f"  t={ev.t:7.0f}s offline qualification of node {ev.node_id}: "
        f"{ev.outcome} after {ev.duration_s:.0f}s on the sweep bench"))

    print("injecting: severe thermal fault on node 5, dead NIC on node 9")
    cluster.injector.inject(FaultKind.THERMAL, 5, severity=0.9)
    cluster.injector.inject(FaultKind.NIC_DOWN, 9, device=7)

    for step in range(1, 601):
        cluster.run_step()
        if step % cluster.window_steps == 0:
            frame = cluster.collect()
            if frame is not None:
                session.observe(frame)
        if step % 90 == 0:                   # checkpoint boundary
            ck = session.on_checkpoint()
            if ck.applied_swaps:
                print(f"  checkpoint at step {step}: "
                      f"{ck.applied_swaps} deferred swap(s) applied")
        session.advance(cluster.t)           # sweeps overlap the job

    session.scheduler.drain(cluster.t)       # land in-flight qualifications

    print(f"\nfinal mean step "
          f"{np.mean([cluster.run_step()['step_time'] for _ in range(20)]):.2f}s "
          f"(healthy = {cluster.workload.healthy_step_s:.2f}s)")
    print(f"node states: 5 -> {session.node_state(5).value}, "
          f"9 -> {session.node_state(9).value}")
    print(f"stats: {session.stats}")
    kinds = {}
    for ev in session.events():
        kinds[ev.kind] = kinds.get(ev.kind, 0) + 1
    print(f"event trace: {kinds}")


if __name__ == "__main__":
    main()
