"""Oracle for the WKV6 kernel: the model's own XLA chunked implementation
(repro.models.rwkv6.wkv_chunked), plus a naive O(S) sequential recurrence
for double-checking both."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.rwkv6 import wkv_chunked


def wkv6_ref(r, k, v, logw, u, s0):
    """Kernel layout (B, H, S, hd) -> model layout and back."""
    to_model = lambda x: jnp.moveaxis(x, 1, 2)     # (B, S, H, hd)
    y, s = wkv_chunked(to_model(r), to_model(k), to_model(v),
                       to_model(logw), u, s0)
    return jnp.moveaxis(y, 2, 1).astype(jnp.float32), s


def wkv6_naive(r, k, v, logw, u, s0):
    """Token-by-token recurrence (the mathematical definition)."""
    B, H, S, hd = r.shape
    rf, kf, vf, lw = (t.astype(jnp.float32) for t in (r, k, v, logw))
    uf = u.astype(jnp.float32)

    def step(state, xs):
        rt, kt, vt, lwt = xs                       # (B, H, hd)
        att = state + uf[None, :, :, None] * kt[..., None] * vt[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv", rt, att)
        state = jnp.exp(lwt)[..., None] * state + \
            kt[..., None] * vt[..., None, :]
        return state, y

    xs = tuple(jnp.moveaxis(t, 2, 0) for t in (rf, kf, vf, lw))
    state, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 2), state
