"""Jitted public wrapper for the WKV6 Pallas kernel (model layout)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.wkv6.wkv6 import wkv6_chunked


def wkv6(r, k, v, logw, u, s0, *, chunk: int = 64, interpret: bool = True):
    """Model layout r/k/v/logw (B, S, H, hd), u (H, hd), s0 (B, H, hd, hd)
    -> (y (B, S, H, hd) fp32, state (B, H, hd, hd) fp32)."""
    to_k = lambda x: jnp.moveaxis(x, 1, 2)
    y, s = wkv6_chunked(to_k(r), to_k(k), to_k(v),
                        to_k(logw.astype(jnp.float32)), u,
                        s0.astype(jnp.float32), chunk=chunk,
                        interpret=interpret)
    return jnp.moveaxis(y, 1, 2), s
