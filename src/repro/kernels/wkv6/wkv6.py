"""Pallas TPU kernel for the chunked RWKV6 (Finch) WKV recurrence.

The chunked formulation is parallel inside a chunk of C tokens (dense
(C, C) intra-chunk matmuls — MXU work) and sequential across chunks through
the (hd, hd) state. Grid (B, H, S/C): the chunk axis is the innermost grid
dimension, executed sequentially on TPU, so the running state lives in VMEM
scratch and persists chunk-to-chunk — the state never round-trips to HBM
(the same insight flash-attention applies to softmax statistics, applied
here to a linear-attention recurrence).

VMEM working set per program: 4×(C, hd) inputs + (C, C) intra-chunk matrix
+ (hd, hd) state — hardware-aligned for C, hd multiples of 128 (hd=64 runs
under lane packing; fine for the assigned rwkv6-7b head_dim=64).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_CLAMP = 25.0


def _clip_exp(x):
    return jnp.exp(jnp.clip(x, -_CLAMP, _CLAMP))


def _wkv6_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref,
                 y_ref, sout_ref, state, *, chunk: int):
    c = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(c == 0)
    def _init():
        state[...] = s0_ref[0, 0].astype(jnp.float32)

    rr = r_ref[0, 0].astype(jnp.float32)          # (C, hd)
    kk = k_ref[0, 0].astype(jnp.float32)
    vv = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)
    uf = u_ref[0].astype(jnp.float32)             # (hd,)
    S_prev = state[...]

    clw = jnp.cumsum(lw, axis=0)                  # inclusive
    ecl = clw - lw                                # exclusive
    q_ = rr * _clip_exp(ecl)
    k_ = kk * _clip_exp(-clw)
    A = jax.lax.dot_general(q_, k_, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (C, C)
    t_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    A = jnp.where(s_i < t_i, A, 0.0)              # strictly causal
    diag = jnp.sum(rr * uf[None] * kk, axis=1)    # (C,)
    y = jax.lax.dot_general(A, vv, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y = y + diag[:, None] * vv
    y = y + jax.lax.dot_general(q_, S_prev, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    total = clw[-1]                               # (hd,)
    kdecay = kk * _clip_exp(total[None, :] - clw)
    S_new = _clip_exp(total)[:, None] * S_prev + jax.lax.dot_general(
        kdecay, vv, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    state[...] = S_new

    @pl.when(c == nc - 1)
    def _final():
        sout_ref[0, 0] = S_new


def wkv6_chunked(r, k, v, logw, u, s0, *, chunk: int = 64,
                 interpret: bool = True):
    """r/k/v (B, H, S, hd); logw (B, H, S, hd) fp32; u (H, hd);
    s0 (B, H, hd, hd) fp32 -> (y (B, H, S, hd) fp32, s (B, H, hd, hd))."""
    B, H, S, hd = r.shape
    C = min(chunk, S)
    assert S % C == 0, (S, C)
    grid = (B, H, S // C)
    blk = lambda b, h, c: (b, h, c, 0)
    sblk = lambda b, h, c: (b, h, 0, 0)

    kernel = functools.partial(_wkv6_kernel, chunk=C)
    y, sout = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, C, hd), blk),
            pl.BlockSpec((1, 1, C, hd), blk),
            pl.BlockSpec((1, 1, C, hd), blk),
            pl.BlockSpec((1, 1, C, hd), blk),
            pl.BlockSpec((1, hd), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, 1, hd, hd), sblk),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, C, hd), blk),
            pl.BlockSpec((1, 1, hd, hd), sblk),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, H, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u, s0)
    return y, sout
