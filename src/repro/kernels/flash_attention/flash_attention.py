"""Pallas TPU flash-attention (forward): tiled online-softmax causal/full
attention with GQA, adapted for the TPU memory hierarchy.

Tiling: grid (batch, q_heads, S/BQ, T/BK); the innermost (KV) grid dimension
executes sequentially on TPU, so the running max/denominator/accumulator
live in VMEM scratch and persist across KV blocks. Block shapes are
MXU-aligned (multiples of 128 on the contracting/lane dims); the (BQ, BK)
score tile and the (BQ, hd) accumulator bound the VMEM working set
regardless of sequence length — this is the paper-independent hot-spot
kernel for the prefill path.

Causal handling: a KV block entirely in the future is skipped via pl.when
(no MXU work, no VMEM traffic); the diagonal block applies an iota mask.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 256
DEFAULT_BK = 256
_NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               causal: bool, scale: float, bq: int, bk: int,
               q_offset: int, kv_len: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = q_offset + qi * bq
    k_start = kj * bk
    # block is live unless every key is in the future of every query
    live = (not causal) or (k_start <= q_start + bq - 1)

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)            # (BQ, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (BK, hd)
        v = v_ref[0, 0].astype(jnp.float32)            # (BK, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (BQ, BK)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < kv_len                            # padded keys
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            mask &= kpos <= qpos
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_scr[:, 0]                           # (BQ,)
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])                # (BQ, BK)
        l_new = alpha * l_scr[:, 0] + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new[:, None], m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new[:, None], l_scr.shape)

    @pl.when(kj == nk - 1)
    def _final():
        l = jnp.maximum(l_scr[:, 0], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: int = DEFAULT_BQ, block_k: int = DEFAULT_BK,
                    q_offset: int = 0, kv_len: int = 0,
                    interpret: bool = True):
    """q (B, Hq, S, hd); k/v (B, Hkv, T, hd) -> (B, Hq, S, hd).

    GQA: query head h reads kv head h // (Hq // Hkv). Requires S % block_q
    == 0 and T % block_k == 0 (ops.py pads otherwise); ``kv_len`` is the
    unpadded key count (0 -> T).
    """
    B, Hq, S, hd = q.shape
    _, Hkv, T, _ = k.shape
    G = Hq // Hkv
    bq = min(block_q, S)
    bk = min(block_k, T)
    assert S % bq == 0 and T % bk == 0, (S, bq, T, bk)
    scale = 1.0 / math.sqrt(hd)
    grid = (B, Hq, S // bq, T // bk)

    kernel = functools.partial(_fa_kernel, causal=causal, scale=scale,
                               bq=bq, bk=bk, q_offset=q_offset,
                               kv_len=kv_len or T)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),     # running max
            pltpu.VMEM((bq, 128), jnp.float32),     # running denominator
            pltpu.VMEM((bq, hd), jnp.float32),      # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
