"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import math

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, q_offset: int = 0):
    """q (B, Hq, S, hd); k/v (B, Hkv, T, hd) -> (B, Hq, S, hd), fp32 math."""
    B, Hq, S, hd = q.shape
    _, Hkv, T, _ = k.shape
    G = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, S, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bhgsd,bhtd->bhgst", qf, kf) / math.sqrt(hd)
    if causal:
        qpos = q_offset + jnp.arange(S)
        mask = jnp.arange(T)[None, :] <= qpos[:, None]
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgst,bhtd->bhgsd", p, vf)
    return o.reshape(B, Hq, S, hd).astype(q.dtype)
