"""Jitted public wrapper around the flash-attention Pallas kernel.

``attention(q, k, v)`` takes the model's (B, S, H, hd) layout, handles
padding to block multiples, and differentiates via a custom VJP whose
backward recomputes attention with the XLA reference (the standard
recompute-backward pairing for a forward-optimized kernel)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def _pad_to(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _attn(q, k, v, causal, block_q, block_k, interpret):
    qp, S = _pad_to(q, 2, block_q)
    kp, T = _pad_to(k, 2, block_k)
    vp, _ = _pad_to(v, 2, block_k)
    out = flash_attention(qp, kp, vp, causal=causal, block_q=block_q,
                          block_k=block_k, kv_len=T, interpret=interpret)
    return out[:, :, :S]


def _fwd(q, k, v, causal, block_q, block_k, interpret):
    return _attn(q, k, v, causal, block_q, block_k, interpret), (q, k, v)


def _bwd(causal, block_q, block_k, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q, k, v: attention_ref(q, k, v, causal=causal), q, k, v)
    return vjp(g)


_attn.defvjp(_fwd, _bwd)


def attention(q, k, v, *, causal: bool = True, block_q: int = 256,
              block_k: int = 256, interpret: bool = True):
    """Model layout q (B, S, Hq, hd), k/v (B, T, Hkv, hd) -> (B, S, Hq, hd).

    ``interpret=True`` executes the kernel body in Python on CPU (this
    container); on TPU pass interpret=False for the compiled path."""
    qt = jnp.moveaxis(q, 1, 2)
    kt = jnp.moveaxis(k, 1, 2)
    vt = jnp.moveaxis(v, 1, 2)
    out = _attn(qt, kt, vt, causal, block_q, block_k, interpret)
    return jnp.moveaxis(out, 1, 2)
