# guardlint: hot  (fleet-sized arrays live here: float32, no per-node loops)
"""NumPy oracle for the fleet-score kernel: batched peer-relative
scoring of ring-buffer rows, float32 end-to-end.

This is the detector's semantics (``StragglerDetector`` §4.2) lifted out
of the per-row loop into one ``(R, M, N)`` pass: for each of R history
rows and M metrics, score all N nodes against their peer baseline —
median, MAD, robust z, directional threshold — and derive the
step-time relative excess and its deviation-masked contribution.

Medians use ``np.partition`` order statistics (identical result to
``np.median``: even N averages the two middle order statistics as
``(a + b) / 2``). Every constant is an explicit ``np.float32`` so the
arithmetic is bit-reproducible against the jax/pallas implementations,
which perform the same correctly-rounded single-precision ops.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

F32 = np.float32


def median_lastdim_ref(x: np.ndarray) -> np.ndarray:
    """(..., N) -> (..., 1) median along the last axis via one partition.

    NaNs order last (``np.partition`` total order), matching the
    bit-space bisection used by the jax path. Even N recovers the lower
    middle statistic as the max of the left partition — numpy's
    multi-kth introselect is ~7x slower than single-kth, and the max is
    the identical element (including NaN rows: a NaN reaches the left
    half only when fewer than h finite values exist, exactly when the
    (h-1)-th statistic is NaN too)."""
    n = x.shape[-1]
    h = n // 2
    p = np.partition(x, h, axis=-1)
    if n % 2:
        return p[..., h:h + 1]
    lo = np.max(p[..., :h], axis=-1, keepdims=True)
    return (lo + p[..., h:h + 1]) / 2.0


def score_rows_ref(
    mats: np.ndarray,
    dirs: Sequence[float],
    st_j: Optional[int],
    *,
    z_threshold: float = 3.0,
    slowdown_floor: float = 0.025,
    mad_floor_frac: float = 0.01,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Score R ring-buffer rows in one pass.

    Args:
      mats: (R, M, N) float32 — R history rows x M metrics x N nodes.
      dirs: (M,) unhealthy-deviation directions (+1 higher-is-bad).
      st_j: metric index of ``step_time`` (None: no primary signal).

    Returns ``(dev, rel, contrib)``:
      dev     (R, M, N) bool — peer-relative deviation verdicts; the
              step_time row additionally requires the relative excess
              to clear ``slowdown_floor``.
      rel     (R, N) float32 — step-time excess over the peer median.
      contrib (R, N) float32 — ``rel`` where step-deviant, else 0.
    """
    mats = np.ascontiguousarray(mats, dtype=F32)
    assert mats.ndim == 3, mats.shape
    _, m, n = mats.shape
    d = np.asarray(dirs, F32).reshape(1, m, 1)
    med = median_lastdim_ref(mats)                        # (R, M, 1)
    diff = mats - med
    mad = median_lastdim_ref(np.abs(diff))
    floor = np.maximum(np.abs(med) * F32(mad_floor_frac), F32(1e-9))
    scale = np.maximum(mad / F32(0.6745), floor)
    z = (diff / scale) * d
    dev = z > F32(z_threshold)
    rel = np.zeros((mats.shape[0], n), F32)
    contrib = np.zeros((mats.shape[0], n), F32)
    if st_j is not None:
        med_st = np.maximum(med[:, st_j], F32(1e-9))      # (R, 1)
        rel = mats[:, st_j] / med_st - F32(1.0)
        sdev = dev[:, st_j] & (rel > F32(slowdown_floor))
        dev[:, st_j] = sdev
        contrib = np.where(sdev, rel, F32(0.0))
    return dev, rel, contrib


__all__ = ["median_lastdim_ref", "score_rows_ref"]
