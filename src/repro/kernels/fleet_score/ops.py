# guardlint: hot  (fleet-sized arrays live here: float32, no per-node loops)
"""Public fleet-score entry point: one call scores R ring-buffer rows.

``score_rows`` dispatches between three interchangeable backends:

  numpy    vectorized partition-based reference (``ref.py``) — the
           single-host production path (no device round trip).
  jax      ``score_rows_jnp`` under ``jax.jit`` — the shardable path.
           When a ``repro.dist`` mesh context is active the input is
           constrained over the ``fleet_node`` logical axis, so the
           peer-median rank counts psum across node shards and the
           elementwise verdicts stay fully partitioned.
  pallas   the fused Pallas kernel (interpret-mode CPU fallback), lane
           dim NaN-padded to the 128 tile.

All three agree bit-for-bit on the verdict masks and, for non-degenerate
inputs, on the continuous outputs (same correctly-rounded float32 ops in
the same order) — the golden sweep in ``tests/test_detector_golden.py``
pins that contract.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.kernels.fleet_score.ref import score_rows_ref

BACKENDS = ("numpy", "jax", "pallas")
_LANE = 128          # f32 TPU lane tile; pallas inputs pad to multiples


@functools.lru_cache(maxsize=64)
def _compiled(backend: str, shape: Tuple[int, int, int],
              dirs: Tuple[float, ...], st_j: Optional[int],
              z_threshold: float, slowdown_floor: float,
              mad_floor_frac: float, n_valid: Optional[int],
              ctx) -> object:
    """Jitted scorer for one (backend, shape, config, mesh) signature.

    ``ctx`` is the active DistContext (or None) — part of the cache key
    so a sharded trace is never reused outside its mesh."""
    import jax

    from repro.dist import constraint
    from repro.kernels.fleet_score.fleet_score import (fleet_score,
                                                       score_rows_jnp)
    kw = dict(z_threshold=z_threshold, slowdown_floor=slowdown_floor,
              mad_floor_frac=mad_floor_frac, n_valid=n_valid)

    if backend == "jax":
        def run(mats):
            mats = constraint(mats, None, None, "fleet_node")
            return score_rows_jnp(mats, dirs, st_j, **kw)
    else:
        def run(mats):
            return fleet_score(mats, dirs, st_j, interpret=True, **kw)
    return jax.jit(run)


def score_rows(
    mats: np.ndarray,
    dirs: Sequence[float],
    st_j: Optional[int],
    *,
    z_threshold: float = 3.0,
    slowdown_floor: float = 0.025,
    mad_floor_frac: float = 0.01,
    backend: str = "numpy",
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Score R history rows of M metrics over N nodes in one fused pass.

    Returns ``(dev, rel, contrib)``: (R, M, N) bool verdicts, (R, N)
    float32 step-time relative excess, (R, N) float32 deviation-masked
    contribution. See ``ref.score_rows_ref`` for exact semantics.
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown fleet_score backend {backend!r}; "
                         f"expected one of {BACKENDS}")
    mats = np.ascontiguousarray(mats, dtype=np.float32)
    assert mats.ndim == 3, mats.shape
    if backend == "numpy":
        return score_rows_ref(
            mats, dirs, st_j, z_threshold=z_threshold,
            slowdown_floor=slowdown_floor, mad_floor_frac=mad_floor_frac)

    from repro.dist import current
    n = mats.shape[2]
    n_valid = None
    if backend == "pallas" and n % _LANE:
        pad = _LANE - n % _LANE
        mats = np.pad(mats, ((0, 0), (0, 0), (0, pad)), mode="constant",
                      constant_values=np.float32(np.nan))
        n_valid = n
    fn = _compiled(backend, mats.shape, tuple(float(v) for v in dirs),
                   None if st_j is None else int(st_j),
                   float(z_threshold), float(slowdown_floor),
                   float(mad_floor_frac), n_valid, current())
    dev, rel, contrib = (np.asarray(o) for o in fn(mats))
    return (dev[..., :n] > 0, rel[:, 0, :n], contrib[:, 0, :n])


__all__ = ["BACKENDS", "score_rows"]
