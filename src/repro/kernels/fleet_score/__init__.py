"""Fused fleet-score kernel: peer-median / MAD / robust-z / threshold
verdicts over circular (depth, N) detector buffers in one float32 pass,
with numpy / jax (shardable) / pallas backends."""
from repro.kernels.fleet_score.fleet_score import (fleet_score,
                                                   median_lastdim,
                                                   score_rows_jnp)
from repro.kernels.fleet_score.ops import BACKENDS, score_rows
from repro.kernels.fleet_score.ref import median_lastdim_ref, score_rows_ref

__all__ = ["BACKENDS", "fleet_score", "median_lastdim",
           "median_lastdim_ref", "score_rows", "score_rows_jnp",
           "score_rows_ref"]
