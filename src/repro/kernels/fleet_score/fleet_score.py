# guardlint: hot  (fleet-sized arrays live here: float32, no per-node loops)
"""Fused fleet-score kernel: peer-median, MAD, robust-z and threshold
verdicts over ``(R, M, N)`` ring-buffer rows in one pass, float32.

The interesting part is the median. ``jax.lax.sort`` does not lower
inside Pallas TPU kernels and a sort is not shardable anyway, so order
statistics are found by *bisection in the key space*: float32 bit
patterns map through the standard monotonic transform

    u    = bitcast(x, uint32)
    key  = ~u            if sign bit set  (negatives reverse)
           u | 0x8000..  otherwise        (positives above negatives)

into uint32 keys whose integer order equals IEEE-754 total order (NaNs
above +inf, exactly where ``np.partition`` places them). A 32-round
binary search then pins the k-th smallest key: each round counts
``sum(key <= mid)`` along the node axis and halves the interval. The
count is the ONLY cross-node operation — an elementwise compare plus a
sum reduction — which makes the whole scorer a shardable reduction over
a ``repro.dist`` node axis (the counts psum across shards under GSPMD)
and TPU-lowerable inside Pallas (no gather, no sort network).

The recovered order statistic is the exact element bit pattern, so the
median — ``(a + b) / 2`` of the two middle statistics for even N — is
bit-identical to the ``np.partition`` reference in ``ref.py``.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# numpy scalars (not jnp 0-d arrays): they inline as jaxpr literals, so
# the Pallas trace captures no constants
_SIGN = np.uint32(0x80000000)


def float_key(x: jnp.ndarray) -> jnp.ndarray:
    """float32 -> uint32 keys in IEEE total order (NaNs largest)."""
    u = jax.lax.bitcast_convert_type(x, jnp.uint32)
    return jnp.where(u & _SIGN != 0, ~u, u | _SIGN)


def key_float(k: jnp.ndarray) -> jnp.ndarray:
    """Inverse of ``float_key`` — exact bit pattern round trip."""
    u = jnp.where(k & _SIGN != 0, k ^ _SIGN, ~k)
    return jax.lax.bitcast_convert_type(u, jnp.float32)


def kth_smallest_key(keys: jnp.ndarray, k: int) -> jnp.ndarray:
    """(..., N) uint32 -> (..., 1): the k-th smallest key per row.

    32 bisection rounds over the key space; the per-round rank count is
    the shardable node-axis reduction."""
    shape = keys.shape[:-1] + (1,)
    target = np.int32(k + 1)

    def body(_, lohi):
        lo, hi = lohi
        mid = lo + ((hi - lo) >> 1)
        rank = jnp.sum((keys <= mid).astype(jnp.int32), axis=-1,
                       keepdims=True)
        take = rank >= target
        return (jnp.where(take, lo, mid + np.uint32(1)),
                jnp.where(take, mid, hi))

    lo, _ = jax.lax.fori_loop(
        0, 32, body, (jnp.zeros(shape, jnp.uint32),
                      jnp.full(shape, 0xFFFFFFFF, jnp.uint32)))
    return lo


def median_lastdim(x: jnp.ndarray, n_valid: Optional[int] = None
                   ) -> jnp.ndarray:
    """(..., N) -> (..., 1) median, bit-identical to the np.partition
    reference. ``n_valid`` restricts the order statistics to the first
    ``n_valid`` logical elements when the lane dim is padded — pads must
    sort above every real value (use float32 NaN)."""
    n = x.shape[-1] if n_valid is None else int(n_valid)
    keys = float_key(x)
    h = n // 2
    if n % 2:
        return key_float(kth_smallest_key(keys, h))
    a = key_float(kth_smallest_key(keys, h - 1))
    b = key_float(kth_smallest_key(keys, h))
    return (a + b) / 2.0


def score_rows_jnp(
    mats: jnp.ndarray,
    dirs: Union[Sequence[float], jnp.ndarray],
    st_j: Optional[int],
    *,
    z_threshold: float,
    slowdown_floor: float,
    mad_floor_frac: float,
    n_valid: Optional[int] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """The fused scorer on (R, M, N) float32 — the single implementation
    shared by the jitted jax backend (full array, shardable) and the
    Pallas kernel (one (1, M, N) block per grid step, ``dirs`` arriving
    as an operand ref so the kernel trace stays constant-free).

    Returns ``(dev, rel, contrib)`` with dev as a float32 0/1 mask
    (uniform tiling on TPU; the ops layer casts to bool) and rel/contrib
    shaped (R, 1, N)."""
    f32 = np.float32
    x = mats.astype(jnp.float32)
    m = x.shape[1]
    d = jnp.asarray(dirs, jnp.float32).reshape(1, m, 1)
    med = median_lastdim(x, n_valid)                       # (R, M, 1)
    diff = x - med
    mad = median_lastdim(jnp.abs(diff), n_valid)
    floor = jnp.maximum(jnp.abs(med) * f32(mad_floor_frac), f32(1e-9))
    scale = jnp.maximum(mad / f32(0.6745), floor)
    z = (diff / scale) * d
    dev = (z > f32(z_threshold)).astype(jnp.float32)
    if st_j is None:
        zero = jnp.zeros((x.shape[0], 1, x.shape[2]), jnp.float32)
        return dev, zero, zero
    xs = x[:, st_j:st_j + 1]                               # (R, 1, N)
    ms = jnp.maximum(med[:, st_j:st_j + 1], f32(1e-9))     # (R, 1, 1)
    rel = xs / ms - f32(1.0)
    sdev = (dev[:, st_j:st_j + 1] > 0) & (rel > f32(slowdown_floor))
    dev = dev.at[:, st_j:st_j + 1].set(sdev.astype(jnp.float32))
    contrib = jnp.where(sdev, rel, f32(0.0))
    return dev, rel, contrib


def _fleet_score_kernel(mats_ref, dirs_ref, dev_ref, rel_ref,
                        contrib_ref, *, st_j, n_valid, z_threshold,
                        slowdown_floor, mad_floor_frac):
    dev, rel, contrib = score_rows_jnp(
        mats_ref[...], dirs_ref[...].reshape(-1), st_j,
        z_threshold=z_threshold, slowdown_floor=slowdown_floor,
        mad_floor_frac=mad_floor_frac, n_valid=n_valid)
    dev_ref[...] = dev
    rel_ref[...] = rel
    contrib_ref[...] = contrib


def fleet_score(
    mats: jnp.ndarray,
    dirs: Sequence[float],
    st_j: Optional[int],
    *,
    z_threshold: float,
    slowdown_floor: float,
    mad_floor_frac: float,
    n_valid: Optional[int] = None,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Pallas entry point: grid over R rows, one fused (M, N) block per
    step resident in VMEM (8 metrics x 131k nodes fp32 ~ 4 MB). The lane
    dim should be padded to the 128-lane tile with float32 NaN and the
    true node count passed as ``n_valid``."""
    r, m, n = mats.shape
    dirs_arr = np.asarray(dirs, np.float32).reshape(m, 1)
    kernel = functools.partial(
        _fleet_score_kernel,
        st_j=st_j, n_valid=n_valid, z_threshold=float(z_threshold),
        slowdown_floor=float(slowdown_floor),
        mad_floor_frac=float(mad_floor_frac))
    return pl.pallas_call(
        kernel,
        grid=(r,),
        in_specs=[pl.BlockSpec((1, m, n), lambda i: (i, 0, 0)),
                  pl.BlockSpec((m, 1), lambda i: (0, 0))],
        out_specs=[pl.BlockSpec((1, m, n), lambda i: (i, 0, 0)),
                   pl.BlockSpec((1, 1, n), lambda i: (i, 0, 0)),
                   pl.BlockSpec((1, 1, n), lambda i: (i, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((r, m, n), jnp.float32),
                   jax.ShapeDtypeStruct((r, 1, n), jnp.float32),
                   jax.ShapeDtypeStruct((r, 1, n), jnp.float32)],
        interpret=interpret,
    )(mats, dirs_arr)


__all__ = ["fleet_score", "float_key", "key_float", "kth_smallest_key",
           "median_lastdim", "score_rows_jnp"]
