"""Pallas TPU kernels for the compute hot-spots, validated in interpret mode:

  flash_attention  tiled online-softmax causal/full GQA attention (prefill)
  wkv6             chunked RWKV6 linear-attention recurrence
  sweep_burn       MXU-aligned sustained-matmul probe (the §5.2 offline
                   sweep's compute workload)
  fleet_score      fused peer-median/MAD/robust-z/threshold scorer over
                   the detector's circular (depth, N) buffers (§4.2)
"""
