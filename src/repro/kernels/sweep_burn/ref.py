"""Oracle for the burn kernel: the same chained-rescaled matmul in jnp."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def burn_ref(a, b, *, iters: int = 64):
    def body(_, x):
        y = (x @ b).astype(jnp.float32)
        scale = jax.lax.rsqrt(jnp.mean(jnp.square(y)) + 1e-12)
        return y * scale

    return jax.lax.fori_loop(0, iters, body, a.astype(jnp.float32))
