from repro.kernels.sweep_burn.ops import LocalJaxSweepBackend, measure_tflops
from repro.kernels.sweep_burn.ref import burn_ref
from repro.kernels.sweep_burn.sweep_burn import burn, burn_flops

__all__ = ["LocalJaxSweepBackend", "burn", "burn_flops", "burn_ref",
           "measure_tflops"]
