"""Public wrapper: timed sustained-throughput measurement + a local-JAX
SweepBackend so the offline sweep (§5.2) runs for real on whatever
accelerator hosts this process — the deployable counterpart of the
simulator's probe."""
from __future__ import annotations

import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sweep import SweepReference
from repro.kernels.sweep_burn.sweep_burn import burn, burn_flops


def measure_tflops(m: int = 512, k: int = 512, iters: int = 64,
                   repeats: int = 3, interpret: bool = True,
                   seed: int = 0) -> float:
    """Median sustained TFLOP/s of the burn chain on the local device."""
    key = jax.random.key(seed)
    a = jax.random.normal(key, (m, k), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(key, 1), (k, k), jnp.float32)
    f = jax.jit(lambda a, b: burn(a, b, iters=iters, interpret=interpret))
    f(a, b).block_until_ready()                   # compile/warm
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        f(a, b).block_until_ready()
        ts.append(time.perf_counter() - t0)
    return burn_flops(m, k, iters) / np.median(ts) / 1e12


class LocalJaxSweepBackend:
    """SweepBackend over the local JAX device(s): compute probes run the
    Pallas burn kernel; bandwidth probes time a device round-trip copy.
    Used by examples/node_sweep_demo.py."""

    def __init__(self, reference: Optional[SweepReference] = None,
                 interpret: bool = True):
        self._interpret = interpret
        self._ref = reference

    def device_count(self, node_id: int) -> int:
        return jax.local_device_count()

    def compute_probe(self, node_id: int, device: int,
                      seconds: float) -> float:
        iters = max(8, min(int(seconds), 64))
        return measure_tflops(iters=iters, interpret=self._interpret,
                              seed=device)

    def intra_bw_probe(self, node_id: int, dev_a: int, dev_b: int) -> float:
        x = jnp.ones((4 << 20,), jnp.float32)      # 16 MB
        f = jax.jit(lambda x: x + 1)
        f(x).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(4):
            x = f(x)
        x.block_until_ready()
        dt = time.perf_counter() - t0
        return 4 * 2 * x.nbytes / dt / 1e9

    def multi_node_probe(self, node_ids: Sequence[int],
                         steps: int) -> np.ndarray:
        # single-host stand-in: time a psum-shaped reduction
        x = jnp.ones((1 << 20,), jnp.float32)
        f = jax.jit(lambda x: jnp.sum(x) + x)
        f(x).block_until_ready()
        ts = []
        for _ in range(min(steps, 10)):
            t0 = time.perf_counter()
            f(x).block_until_ready()
            ts.append(time.perf_counter() - t0)
        return np.asarray(ts)

    def reference(self) -> SweepReference:
        if self._ref is None:
            # self-calibrate: current device defines "healthy"
            tf = measure_tflops(interpret=self._interpret)
            bw = self.intra_bw_probe(0, 0, 1)
            st = float(np.median(self.multi_node_probe([0, 1], 5)))
            self._ref = SweepReference(tf, bw, st)
        return self._ref
