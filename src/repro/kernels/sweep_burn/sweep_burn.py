"""MXU-aligned sustained-matmul burn kernel — the single-node sweep's
compute probe (§5.2).

Unlike a burn-in correctness test, the probe measures *sustained* matmul
throughput: ``iters`` back-to-back (M, K) @ (K, N) products whose operands
stay resident in VMEM (no HBM traffic after the first load), so the
measured rate is pure MXU + thermal behaviour. Tiles default to 512³ —
multiples of the 128×128 systolic array with a VMEM footprint (3 MB fp32)
that fits comfortably alongside double-buffering.

A data-dependent chain (each product feeds the next through a cheap
rescale) prevents the compiler from collapsing the loop; the scalar
checksum output also serves as a numerical-health check: one flaky MAC
shows up as a checksum mismatch across devices running the same seed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _burn_kernel(a_ref, b_ref, o_ref, acc, *, iters_per_block: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc[...] = a_ref[...]

    def body(_, x):
        y = jax.lax.dot_general(x, b_ref[...], (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        # renormalize so the chain neither explodes nor denorms
        scale = jax.lax.rsqrt(jnp.mean(jnp.square(y)) + 1e-12)
        return y * scale

    acc[...] = jax.lax.fori_loop(0, iters_per_block, body, acc[...])

    @pl.when(i == pl.num_programs(0) - 1)
    def _final():
        o_ref[...] = acc[...]


def burn(a, b, *, iters: int = 64, iters_per_block: int = 8,
         interpret: bool = True):
    """a (M, K), b (K, N) fp32 -> (M, N) chained product.

    FLOPs executed = 2 * M * K * N * iters (requires K == N for chaining).
    """
    M, K = a.shape
    K2, N = b.shape
    assert K == K2 == N, "burn chain needs square b"
    assert iters % iters_per_block == 0
    grid = (iters // iters_per_block,)
    kernel = functools.partial(_burn_kernel,
                               iters_per_block=iters_per_block)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((M, K), lambda i: (0, 0)),
                  pl.BlockSpec((K, N), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((M, N), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((M, N), jnp.float32)],
        interpret=interpret,
    )(a, b)


def burn_flops(M: int, K: int, iters: int) -> float:
    return 2.0 * M * K * K * iters
