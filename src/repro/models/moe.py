"""Mixture-of-Experts block with expert parallelism.

Design (production path, DeepSeek/GShard-style with capacity):

  1. Router (GSPMD, replicated weights): softmax top-k + Switch-style
     load-balance aux loss, computed *outside* the manual region so the aux
     loss is an ordinary traced scalar.
  2. Dispatch (shard_map, manual over the whole mesh): tokens are sorted by
     expert id, packed into a (E, C, D) capacity buffer per chip, and
     exchanged with the expert owners over the 'model' axis via
     ``lax.all_to_all`` — the same token-dispatch / result-combine
     synchronization points §3.2 of the paper calls out as the MoE straggler
     amplifier.
  3. Expert FFN: grouped gated-MLP einsum over the local experts; expert
     weights arrive FSDP-sharded on d_model and are all-gathered over 'data'
     (ZeRO-3 style) just-in-time.
  4. Combine: inverse all_to_all, unsort, weighted sum over k.

Shared experts (always-on) run as a plain dense GSPMD FFN outside the manual
region and are added to the routed output.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist import api as dist
from repro.models import common as cm
from repro.models.layers import apply_mlp, init_mlp


def init_moe(keys, cfg):
    m = cfg.moe
    d = cfg.d_model
    p = {
        "router": cm.dense(next(keys), d, m.num_experts, (None, None)),
        # stacked expert mats: (E, D, 2F) and (E, F, D). Master weights +
        # moments stay (expert x fsdp) 2-D sharded; the bf16 compute copy
        # is gathered over fsdp ONCE per layer pass by the model's ZeRO-3
        # JIT gather (dist.gather_fsdp) BEFORE the shard_map, so the manual
        # region sees (expert-sharded, replicated-d) weights with no
        # in-region all-gather (§Perf iteration 5)
        "wi": cm.Annot(
            jax.random.normal(next(keys), (m.num_experts, d, 2 * m.expert_d_ff),
                              jnp.float32) / math.sqrt(d),
            ("expert", "fsdp", None)),
        "wo": cm.Annot(
            jax.random.normal(next(keys), (m.num_experts, m.expert_d_ff, d),
                              jnp.float32) / math.sqrt(m.expert_d_ff),
            ("expert", "fsdp", None)),
    }
    if m.num_shared_experts:
        p["shared"] = init_mlp(keys, d, m.num_shared_experts * m.expert_d_ff,
                               cfg.act)
    return p


def _route(router_w, x, num_experts: int, top_k: int):
    """Returns (weights (B,S,k) fp32, idx (B,S,k) int32, aux_loss scalar)."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, top_k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # Switch-style aux: E * sum_e( frac_tokens_e * mean_prob_e )
    B, S, E = probs.shape
    onehot_top1 = jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32)
    frac = jnp.mean(onehot_top1, axis=(0, 1))
    mean_p = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac * mean_p)
    return w, idx, aux


def _dispatch_compute_combine(x, idx, w, wi, wo, *, act: str, capacity: int,
                              num_experts: int, top_k: int,
                              ep_axis: Optional[str]):
    """Manual (per-shard) MoE body. x (B,S,D) local; idx/w (B,S,k) local."""
    B, S, D = x.shape
    T = B * S
    E, C, K = num_experts, capacity, top_k
    xf = x.reshape(T, D)
    flat_e = idx.reshape(T * K)                       # expert of each slot
    tok_of_slot = jnp.repeat(jnp.arange(T), K)

    # stable sort by expert -> position-within-expert via sorted cumcount
    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    # rank within equal-expert runs: i - first_index_of(se[i])
    first_idx = jnp.searchsorted(se, jnp.arange(E), side="left")
    rank = jnp.arange(T * K) - first_idx[se]
    keep = rank < C
    dest = jnp.where(keep, se * C + rank, E * C)      # E*C = dropped sentinel

    # src[e*C + c] = flat token slot feeding that capacity cell (T*K = empty)
    src = jnp.full((E * C + 1,), T, jnp.int32)
    src = src.at[dest].set(tok_of_slot[order].astype(jnp.int32), mode="drop")
    src = src[:-1]
    xpad = jnp.concatenate([xf, jnp.zeros((1, D), xf.dtype)], axis=0)
    xd = xpad[src].reshape(E, C, D)                   # dispatch buffer

    if ep_axis is not None:
        # (E, C, D) -> (E/tp, tp*C, D): tokens for my local experts
        xd = jax.lax.all_to_all(xd, ep_axis, split_axis=0, concat_axis=1,
                                tiled=True)
    wi = wi.astype(x.dtype)
    wo = wo.astype(x.dtype)

    h = jnp.einsum("ecd,edf->ecf", xd, wi)
    g, u = jnp.split(h, 2, axis=-1)
    g = jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)
    y = jnp.einsum("ecf,efd->ecd", g * u, wo)

    if ep_axis is not None:
        y = jax.lax.all_to_all(y, ep_axis, split_axis=1, concat_axis=0,
                               tiled=True)            # back to (E, C, D)

    # combine: slot s reads y[flat_e[s]*C + rank[s]] if kept
    ypad = jnp.concatenate([y.reshape(E * C, D),
                            jnp.zeros((1, D), y.dtype)], axis=0)
    slot_src = jnp.where(keep, se * C + rank, E * C)
    gathered = ypad[slot_src]                         # (T*K, D) in sorted order
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(T * K))
    gathered = gathered[inv].reshape(T, K, D)
    wk = w.reshape(T, K, 1).astype(gathered.dtype)
    out = jnp.sum(gathered * wk, axis=1).reshape(B, S, D)
    return out


def apply_moe(p, cfg, x):
    """x (B,S,D) -> (B,S,D), aux_loss. Chooses manual EP when a mesh context
    maps 'act_expert' onto >1 devices; otherwise runs the same math locally.
    """
    m = cfg.moe
    w, idx, aux = _route(p["router"], x, m.num_experts, m.top_k)
    idx = idx.astype(jnp.int32)

    ctx = dist.current()
    ep_axes = ctx.mesh_axes("act_expert") if ctx else ()
    ep = len(ep_axes) == 1 and ctx.mesh.shape[ep_axes[0]] > 1 \
        and m.num_experts % ctx.mesh.shape[ep_axes[0]] == 0

    if not ep:
        T = x.shape[0] * x.shape[1]
        cap = max(int(math.ceil(T * m.top_k * m.capacity_factor
                                / m.num_experts)), m.top_k)
        routed = _dispatch_compute_combine(
            x, idx, w, p["wi"], p["wo"], act=cfg.act, capacity=cap,
            num_experts=m.num_experts, top_k=m.top_k, ep_axis=None)
    else:
        mesh = ctx.mesh
        ep_axis = ep_axes[0]
        tp = mesh.shape[ep_axis]
        dp = [a for a in ("pod", "data") if a in mesh.axis_names]
        dp_size = math.prod(mesh.shape[a] for a in dp) if dp else 1
        B, S, D = x.shape
        bspec = tuple(dp) if (dp and B % dp_size == 0) else None
        seq_shard = tp if S % tp == 0 else 1
        sspec = ep_axis if seq_shard > 1 else None
        T_local = (B // (dp_size if bspec else 1)) * (S // seq_shard)
        cap = max(int(math.ceil(T_local * m.top_k * m.capacity_factor
                                / m.num_experts)), m.top_k)

        fn = dist.shard_map(
            functools.partial(
                _dispatch_compute_combine, act=cfg.act, capacity=cap,
                num_experts=m.num_experts, top_k=m.top_k, ep_axis=ep_axis),
            mesh=mesh,
            in_specs=(P(bspec, sspec, None), P(bspec, sspec, None),
                      P(bspec, sspec, None),
                      P(ep_axis, None, None), P(ep_axis, None, None)),
            out_specs=P(bspec, sspec, None),
            # when S doesn't shard over EP (decode: S=1), every EP shard
            # dispatches the same tokens and the inverse all_to_all returns
            # identical combines on every shard — replicated in value, but
            # the varying-manual-axes checker can't see through all_to_all
            check_vma=False,
        )
        routed = fn(x, idx, w.astype(x.dtype), p["wi"], p["wo"])

    if m.num_shared_experts:
        routed = routed + apply_mlp(p["shared"], x, cfg.act)
    return routed, aux
