"""RG-LRU recurrent block + Griffin/RecurrentGemma layer pattern.

The recurrent block is: two input projections (gate branch + recurrence
branch), a short temporal conv, the Real-Gated LRU
    a_t = exp(-c * softplus(Λ) * sigmoid(W_a x_t)),
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t),
computed over the sequence with `lax.associative_scan` (O(log S) depth — no
sequential dependency in the HLO), and an output projection gated by
GeLU(gate branch). Pattern per config: ("rec", "rec", "attn") repeating.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.dist import api as dist
from repro.models import common as cm

_C = 8.0  # Griffin's fixed scaling inside the decay exponent


def init_rec_block(keys, cfg):
    d, w = cfg.d_model, (cfg.lru_width or cfg.d_model)
    p = {
        "wx": cm.dense(next(keys), d, w, ("fsdp", "lru")),   # recurrence branch
        "wg": cm.dense(next(keys), d, w, ("fsdp", "lru")),   # gate branch
        "conv": cm.normal(next(keys), (cfg.conv_width, w), (None, "lru"),
                          scale=1.0 / math.sqrt(cfg.conv_width)),
        "conv_b": cm.zeros((w,), ("lru",)),
        "wa": cm.normal(next(keys), (w,), ("lru",), scale=0.1),  # input gate W_a (diag)
        "wi": cm.normal(next(keys), (w,), ("lru",), scale=0.1),  # input gate W_i (diag)
        "lam": cm.Annot(jnp.full((w,), 0.65), ("lru",)),      # Λ init: a ≈ .9
        "wo": cm.dense(next(keys), w, d, ("lru", "fsdp")),
    }
    return p


def _conv1d(x, kernel, bias, state=None):
    """Causal depthwise temporal conv. x (B,S,W); kernel (K,W).

    state (B,K-1,W) carries the last K-1 inputs for decode; returns
    (y, new_state) when state is given."""
    K = kernel.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * kernel[i].astype(x.dtype)
            for i in range(K))
    y = y + bias.astype(x.dtype)
    if state is None:
        return y
    return y, xp[:, -(K - 1):].astype(jnp.float32)


def _gates(p, xb):
    """log-decay (fp32, <0) and input gate for the LRU. xb (B,S,W)."""
    xf = xb.astype(jnp.float32)
    ra = jax.nn.sigmoid(xf * p["wa"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * ra
    gate_i = jax.nn.sigmoid(xf * p["wi"].astype(jnp.float32))
    return log_a, gate_i


def rg_lru(p, xb, h0):
    """xb (B,S,W) conv output; h0 (B,W) fp32 carry. Associative scan over S."""
    log_a, gate_i = _gates(p, xb)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    bx = beta * gate_i * xb.astype(jnp.float32)
    # fold the incoming state into the first element
    bx = bx.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    A, H = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return H.astype(xb.dtype), H[:, -1]


def rg_lru_step(p, xb, h0):
    """One decode step. xb (B,W); h0 (B,W) fp32."""
    log_a, gate_i = _gates(p, xb[:, None])
    log_a, gate_i = log_a[:, 0], gate_i[:, 0]
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    h = a * h0 + beta * gate_i * xb.astype(jnp.float32)
    return h.astype(xb.dtype), h


def rec_block(p, cfg, x, h0, *, collect_state: bool = False):
    """Recurrent temporal block (train/prefill). x (B,S,D).

    Returns (out, h_last, conv_state) — conv_state is the last K-1 raw conv
    inputs (decode seed; None unless ``collect_state``)."""
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["wg"]))
    xb = jnp.einsum("bsd,dw->bsw", x, p["wx"])
    xb = dist.constraint(xb, "act_batch", None, "act_ff")
    conv_state = None
    if collect_state:
        K = p["conv"].shape[0]
        conv_state = xb[:, -(K - 1):].astype(jnp.float32)
    xbc = _conv1d(xb, p["conv"], p["conv_b"])
    y, h_last = rg_lru(p, xbc, h0)
    out = jnp.einsum("bsw,wd->bsd", y * gate, p["wo"])
    return out, h_last, conv_state


def rec_block_step(p, cfg, x, state):
    """Decode step. x (B,D); state dict(h (B,W) fp32, conv (B,K-1,W) fp32)."""
    gate = jax.nn.gelu(jnp.einsum("bd,dw->bw", x, p["wg"]))
    xb = jnp.einsum("bd,dw->bw", x, p["wx"])
    xb2, conv_state = _conv1d(xb[:, None], p["conv"], p["conv_b"],
                              state["conv"])
    y, h = rg_lru_step(p, xb2[:, 0], state["h"])
    out = jnp.einsum("bw,wd->bd", y * gate, p["wo"])
    return out, {"h": h, "conv": conv_state}
