"""Param-tree utilities: annotated initialization with logical sharding axes.

Every parameter leaf is created as ``Annot(value, axes)`` where ``axes`` is a
tuple of logical axis names (see ``repro.dist.api.DEFAULT_RULES``). A single
``split`` call at the end of ``init`` separates the value tree from the axes
tree, so values and sharding metadata can never drift apart.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class Annot:
    """A param leaf annotated with logical sharding axes.

    Registered as a pytree node whose *child* is the value and whose
    *aux data* is the axes tuple — so jax transforms (vmap for layer
    stacking, eval_shape for the allocation-free dry-run) pass through it
    while the sharding metadata rides along statically.
    """
    __slots__ = ("value", "axes")

    def __init__(self, value, axes: Tuple[Optional[str], ...]):
        self.value = value
        self.axes = tuple(axes)

    def __repr__(self):
        return f"Annot({self.value!r}, axes={self.axes})"


jax.tree_util.register_pytree_node(
    Annot,
    lambda a: ((a.value,), a.axes),
    lambda aux, ch: Annot(ch[0], aux),
)


def is_annot(x) -> bool:
    return isinstance(x, Annot)


def dense(key, in_dim: int, out_dim: int, axes, *, scale: Optional[float] = None,
          dtype=jnp.float32) -> Annot:
    scale = scale if scale is not None else 1.0 / np.sqrt(in_dim)
    v = jax.random.normal(key, (in_dim, out_dim), dtype) * jnp.asarray(scale, dtype)
    return Annot(v, tuple(axes))


def zeros(shape, axes, dtype=jnp.float32) -> Annot:
    return Annot(jnp.zeros(shape, dtype), tuple(axes))


def ones(shape, axes, dtype=jnp.float32) -> Annot:
    return Annot(jnp.ones(shape, dtype), tuple(axes))


def normal(key, shape, axes, *, scale=0.02, dtype=jnp.float32) -> Annot:
    return Annot(jax.random.normal(key, shape, dtype) * jnp.asarray(scale, dtype),
                 tuple(axes))


def split(tree):
    """Annotated tree -> (values, axes). Trees share one treedef."""
    values = jax.tree.map(lambda a: a.value, tree, is_leaf=is_annot)
    axes = jax.tree.map(lambda a: a.axes, tree, is_leaf=is_annot)
    return values, axes


def stack_layers(tree):
    """Mark every Annot in a vmap-stacked layer tree with a leading
    (unsharded) 'layer' axis."""
    return jax.tree.map(
        lambda a: Annot(a.value, ("layer",) + a.axes),
        tree, is_leaf=is_annot)


def keygen(key):
    """Infinite stream of fresh PRNG keys."""
    while True:
        key, sub = jax.random.split(key)
        yield sub


def cast(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree)


@dataclasses.dataclass(frozen=True)
class Policy:
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16

    def c(self, tree):
        return cast(tree, self.compute_dtype)


def pad_vocab(vocab_size: int, multiple: int = 128) -> int:
    """Megatron-style vocab padding: TPU-lane friendly and TP-divisible."""
    return ((vocab_size + multiple - 1) // multiple) * multiple
