"""Attention-transformer blocks: decoder layer (dense or MoE FFN), encoder
layer, and cross-attention decoder layer (whisper). Layer params are
scan-stacked; bodies are remat'd by the model assembly."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist import api as dist
from repro.models import attention as attn
from repro.models import common as cm
from repro.models import moe as moe_mod
from repro.models.layers import apply_mlp, init_mlp, layer_norm, rms_norm


def init_decoder_layer(keys, cfg, *, moe_layer: bool, dense_d_ff: int = 0,
                       cross: bool = False):
    p = {
        "ln_attn": cm.zeros((cfg.d_model,), (None,)),
        "attn": attn.init_attention(keys, cfg),
        "ln_mlp": cm.zeros((cfg.d_model,), (None,)),
    }
    if moe_layer:
        p["moe"] = moe_mod.init_moe(keys, cfg)
    else:
        p["mlp"] = init_mlp(keys, cfg.d_model,
                            dense_d_ff or cfg.d_ff, cfg.act)
    if cross:
        p["ln_cross"] = cm.zeros((cfg.d_model,), (None,))
        p["cross"] = attn.init_attention(keys, cfg, cross=True)
    return p


def _norm(cfg, x, scale):
    if cfg.family == "audio":  # whisper uses LayerNorm
        return layer_norm(x, 1.0 + scale, jnp.zeros_like(scale), cfg.norm_eps)
    return rms_norm(x, scale, cfg.norm_eps)


def project_cross_kv(p, cfg, enc_out):
    """Per-layer cross-attention K/V from encoder output, cache layout
    (B,Hkv,T,hd)."""
    B, T, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = jnp.einsum("btd,dh->bth", enc_out, p["wk"]) \
        .reshape(B, T, cfg.num_kv_heads, hd)
    v = jnp.einsum("btd,dh->bth", enc_out, p["wv"]) \
        .reshape(B, T, cfg.num_kv_heads, hd)
    return jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2)


def decoder_layer(p, cfg, x, positions, *, causal=True, window=0,
                  enc_out=None, collect_cache=False):
    """Train/prefill decoder layer. x (B,S,D). Returns (x, aux, cache_kv)."""
    h = _norm(cfg, x, p["ln_attn"])
    q, k, v = attn.project_qkv(p["attn"], cfg, h, positions,
                               rope=not cfg.learned_pos_emb)
    if window:
        o = attn.local_attention(q, k, v, window=window)
    else:
        o = attn.full_attention(q, k, v, causal=causal)
    x = x + attn.out_projection(p["attn"], o)

    cross_cache = None
    if enc_out is not None:
        h = _norm(cfg, x, p["ln_cross"])
        qc = jnp.einsum("bsd,dh->bsh", h, p["cross"]["wq"])
        B, S, _ = h.shape
        qc = qc.reshape(B, S, cfg.num_heads, cfg.resolved_head_dim)
        kc, vc = project_cross_kv(p["cross"], cfg, enc_out)
        oc = attn.cross_attention(qc, jnp.moveaxis(kc, 1, 2),
                                  jnp.moveaxis(vc, 1, 2))
        x = x + attn.out_projection(p["cross"], oc)
        cross_cache = (kc, vc)

    h = _norm(cfg, x, p["ln_mlp"])
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        d, aux = moe_mod.apply_moe(p["moe"], cfg, h)
    else:
        d = apply_mlp(p["mlp"], h, cfg.act)
    x = x + d
    x = dist.constraint(x, "act_batch", "act_seq_ckpt", "act_embed")
    cache = None
    if collect_cache:
        # (B,S,Hkv,hd) -> (B,Hkv,S,hd) cache layout
        cache = (jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2))
        if cross_cache is not None:
            cache = cache + cross_cache
    return x, aux, cache


def decoder_layer_step(p, cfg, x, kcache, vcache, pos, *, window=0,
                       enc_kv=None):
    """Decode-step layer. x (B,D); caches (B,Hkv,Sc,hd). Returns
    (x, kcache, vcache)."""
    B, d = x.shape
    hd = cfg.resolved_head_dim
    h = _norm(cfg, x, p["ln_attn"])[:, None]          # (B,1,D)
    if cfg.mrope_sections:
        positions = jnp.broadcast_to(pos, (3, B, 1)).astype(jnp.int32)
    else:
        positions = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)
    q, k, v = attn.project_qkv(p["attn"], cfg, h, positions,
                               rope=not cfg.learned_pos_emb)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]               # (B,H*,hd)
    if window:
        o, kcache, vcache = _ring_decode(q, kcache, vcache, k, v, pos, window)
    else:
        o, kcache, vcache = attn.decode_attention(q, kcache, vcache, k, v, pos)
    x = x + jnp.einsum("bh,hd->bd", o.reshape(B, -1), p["attn"]["wo"])

    if enc_kv is not None:
        hc = _norm(cfg, x, p["ln_cross"])
        qc = jnp.einsum("bd,dh->bh", hc, p["cross"]["wq"]) \
            .reshape(B, cfg.num_heads, hd)
        oc = _plain_decode_attn(qc, enc_kv[0], enc_kv[1])
        x = x + jnp.einsum("bh,hd->bd", oc.reshape(B, -1), p["cross"]["wo"])

    h = _norm(cfg, x, p["ln_mlp"])
    if "moe" in p:
        dlt, _ = moe_mod.apply_moe(p["moe"], cfg, h[:, None])
        dlt = dlt[:, 0]
    else:
        dlt = _mlp_step(p["mlp"], h, cfg.act)
    return x + dlt, kcache, vcache


def _mlp_step(p, x, act):
    if act in ("swiglu", "geglu"):
        g = jnp.einsum("bd,df->bf", x, p["wg"])
        u = jnp.einsum("bd,df->bf", x, p["wu"])
        g = jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)
        h = g * u
    else:
        h = jnp.einsum("bd,df->bf", x, p["wi"])
        h = jax.nn.gelu(h) if act == "gelu" else jnp.square(jax.nn.relu(h))
    return jnp.einsum("bf,fd->bd", h, p["wo"])


def _plain_decode_attn(q, k, v):
    """q (B,Hq,hd), fixed k/v (B,Hkv,T,hd) (cross attention, no mask)."""
    B, Hq, hd = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, hd)
    s = jnp.einsum("bhgd,bhtd->bhgt", qg, k,
                   preferred_element_type=jnp.float32) / jnp.sqrt(float(hd))
    p_ = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bhgt,bhtd->bhgd", p_, v).reshape(B, Hq, hd)


def _ring_decode(q, kc, vc, knew, vnew, pos, window):
    """Sliding-window ring-buffer decode attention (griffin local attn)."""
    B, Hkv, W, hd = kc.shape
    slot = jnp.mod(pos, W)

    def ins(c, new):
        return jax.lax.dynamic_update_slice(c, new[:, :, None, :],
                                            (0, 0, slot, 0))
    kc, vc = ins(kc, knew), ins(vc, vnew)
    Hq = q.shape[1]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, hd)
    s = jnp.einsum("bhgd,bhsd->bhgs", qg, kc,
                   preferred_element_type=jnp.float32) / jnp.sqrt(float(hd))
    valid = jnp.arange(W) <= pos                      # warmup masking
    s = jnp.where(valid[None, None, None], s, -1e30)
    p_ = jax.nn.softmax(s, axis=-1).astype(vc.dtype)
    o = jnp.einsum("bhgs,bhsd->bhgd", p_, vc).reshape(B, Hq, hd)
    return o, kc, vc


def init_encoder_layer(keys, cfg):
    return {
        "ln_attn": cm.zeros((cfg.d_model,), (None,)),
        "attn": attn.init_attention(keys, cfg),
        "ln_mlp": cm.zeros((cfg.d_model,), (None,)),
        "mlp": init_mlp(keys, cfg.d_model, cfg.d_ff, cfg.act),
    }


def encoder_layer(p, cfg, x):
    h = _norm(cfg, x, p["ln_attn"])
    q, k, v = attn.project_qkv(p["attn"], cfg, h, rope=False)
    o = attn.full_attention(q, k, v, causal=False, chunk=2048)
    x = x + attn.out_projection(p["attn"], o)
    h = _norm(cfg, x, p["ln_mlp"])
    return x + apply_mlp(p["mlp"], h, cfg.act)
