"""Attention: GQA projections, chunked causal/local attention (bounded
activation memory, scan-based), cross-attention, and decode-step attention
with KV-cache *sequence sharding* (flash-decoding style partial attention
combined via psum/pmax inside shard_map).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist import api as dist
from repro.models import common as cm
from repro.models.layers import apply_mrope, apply_rope, rms_norm


# ---------------------------------------------------------------- params


def init_attention(keys, cfg, cross: bool = False):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    p = {
        "wq": cm.dense(next(keys), d, nq * hd, ("fsdp", "heads")),
        "wk": cm.dense(next(keys), d, nkv * hd, ("fsdp", "kv_heads")),
        "wv": cm.dense(next(keys), d, nkv * hd, ("fsdp", "kv_heads")),
        "wo": cm.dense(next(keys), nq * hd, d, ("heads", "fsdp")),
    }
    if cfg.qkv_bias:
        p["bq"] = cm.zeros((nq * hd,), ("heads",))
        p["bk"] = cm.zeros((nkv * hd,), ("kv_heads",))
        p["bv"] = cm.zeros((nkv * hd,), ("kv_heads",))
    if cfg.qk_norm:
        p["q_norm"] = cm.zeros((hd,), (None,))
        p["k_norm"] = cm.zeros((hd,), (None,))
    return p


def project_qkv(p, cfg, x, positions=None, *, rope: bool = True):
    """x (B,S,D) -> q (B,S,Hq,hd), k/v (B,S,Hkv,hd) with RoPE applied."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = q.reshape(B, S, cfg.num_heads, hd)
    k = k.reshape(B, S, cfg.num_kv_heads, hd)
    v = v.reshape(B, S, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    # constrain BEFORE RoPE: the rotate-half split/concat on an
    # unconstrained layout makes GSPMD shard head_dim and reshard through
    # all-to-alls; pinning heads-sharded/hd-replicated here keeps the
    # rotation entirely local
    q = dist.constraint(q, "act_batch", None, "act_heads", None)
    k = dist.constraint(k, "act_batch", None, "act_kv_heads", None)
    v = dist.constraint(v, "act_batch", None, "act_kv_heads", None)
    if rope:
        if positions is None:
            positions = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
        if cfg.mrope_sections:
            q = apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
            k = apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        q = dist.constraint(q, "act_batch", None, "act_heads", None)
        k = dist.constraint(k, "act_batch", None, "act_kv_heads", None)
    return q, k, v


def out_projection(p, attn_out):
    """attn_out (B,S,Hq,hd) -> (B,S,D)."""
    B, S, H, hd = attn_out.shape
    return jnp.einsum("bsh,hd->bsd", attn_out.reshape(B, S, H * hd), p["wo"])


# ---------------------------------------------------------------- core math


def _grouped_scores(qc, k):
    """qc (B,C,Hkv,G,hd) x k (B,T,Hkv,hd) -> (B,Hkv,G,C,T) fp32 logits."""
    return jnp.einsum("bchgd,bthd->bhgct", qc, k,
                      preferred_element_type=jnp.float32)


def _grouped_out(probs, v):
    """probs (B,Hkv,G,C,T) x v (B,T,Hkv,hd) -> (B,C,Hkv,G,hd)."""
    return jnp.einsum("bhgct,bthd->bchgd", probs.astype(v.dtype), v)


def _softmax_masked(scores, mask):
    scores = jnp.where(mask, scores, -1e30)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - jax.lax.stop_gradient(m))
    return e / jnp.sum(e, axis=-1, keepdims=True)


def _seq_shard_fallback(Hq: int, chunk: int) -> bool:
    """True when attention heads can NOT be sharded over the TP axis (e.g.
    llama4's 40 or whisper's 12 heads on a 16-wide axis) but the query
    chunk can — context-parallel attention instead of replicated attention
    (16x the FLOPs/memory otherwise)."""
    ctx = dist.current()
    if ctx is None:
        return False
    size = ctx.axis_size("act_heads")
    return size > 1 and Hq % size != 0 and chunk % size == 0


def full_attention(q, k, v, *, causal: bool, chunk: int = 2048,
                   q_offset: int = 0):
    """Query-chunked attention with bounded score memory.

    q (B,S,Hq,hd), k/v (B,T,Hkv,hd). ``lax.scan`` over query chunks keeps the
    HLO compact and the live score tensor at (B,Hkv,G,chunk,T).
    """
    B, S, Hq, hd = q.shape
    T = k.shape[1]
    Hkv = k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)
    chunk = min(chunk, S)
    if S % chunk:
        chunk = S  # irregular small seq: single chunk
    nc = S // chunk
    seq_fallback = _seq_shard_fallback(Hq, chunk)
    qg = q.reshape(B, nc, chunk, Hkv, G, hd)
    qg = jnp.moveaxis(qg, 1, 0)                     # (nc,B,C,Hkv,G,hd)
    kpos = jnp.arange(T)

    def body(_, args):
        ci, qc = args
        if seq_fallback:
            qc = dist.constraint(qc, "act_batch", "act_seq_ckpt",
                                 None, None, None)
        scores = _grouped_scores(qc, k) * scale     # (B,Hkv,G,C,T)
        qpos = q_offset + ci * chunk + jnp.arange(chunk)
        mask = jnp.ones((chunk, T), bool) if not causal else (
            kpos[None, :] <= qpos[:, None])
        probs = _softmax_masked(scores, mask[None, None, None])
        o = _grouped_out(probs, v)
        if seq_fallback:
            o = dist.constraint(o, "act_batch", "act_seq_ckpt",
                                None, None, None)
        return None, o

    _, out = jax.lax.scan(body, None, (jnp.arange(nc), qg))
    out = jnp.moveaxis(out, 0, 1).reshape(B, S, Hq, hd)
    return dist.constraint(out, "act_batch", None, "act_heads", None)


def local_attention(q, k, v, *, window: int):
    """Sliding-window causal attention, O(S·W): each window-sized query chunk
    attends to itself + the previous chunk (covers all offsets < window)."""
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    W = window
    scale = 1.0 / math.sqrt(hd)
    if S <= W:
        return full_attention(q, k, v, causal=True, chunk=min(2048, S))
    assert S % W == 0, (S, W)
    nc = S // W
    seq_fallback = _seq_shard_fallback(Hq, W)
    qg = jnp.moveaxis(q.reshape(B, nc, W, Hkv, G, hd), 1, 0)
    # left-pad keys with one window so chunk i slices [(i-1)W, (i+1)W)
    kp = jnp.pad(k, ((0, 0), (W, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (W, 0), (0, 0), (0, 0)))

    def body(_, args):
        ci, qc = args
        if seq_fallback:
            qc = dist.constraint(qc, "act_batch", "act_seq_ckpt",
                                 None, None, None)
        ks = jax.lax.dynamic_slice_in_dim(kp, ci * W, 2 * W, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(vp, ci * W, 2 * W, axis=1)
        scores = _grouped_scores(qc, ks) * scale    # (B,Hkv,G,W,2W)
        qpos = ci * W + jnp.arange(W)
        kpos = (ci - 1) * W + jnp.arange(2 * W)
        mask = ((kpos[None, :] <= qpos[:, None]) &
                (kpos[None, :] > qpos[:, None] - W) &
                (kpos[None, :] >= 0))
        probs = _softmax_masked(scores, mask[None, None, None])
        o = _grouped_out(probs, vs)
        if seq_fallback:
            o = dist.constraint(o, "act_batch", "act_seq_ckpt",
                                None, None, None)
        return None, o

    _, out = jax.lax.scan(body, None, (jnp.arange(nc), qg))
    out = jnp.moveaxis(out, 0, 1).reshape(B, S, Hq, hd)
    return dist.constraint(out, "act_batch", None, "act_heads", None)


def cross_attention(q, k, v):
    return full_attention(q, k, v, causal=False, chunk=2048)


# ---------------------------------------------------------------- decode


def _decode_inner(q, kc, vc, k_new, v_new, pos, *, axis: Optional[str],
                  window_offset=0):
    """Partial attention over a (possibly sequence-sharded) KV cache.

    q (B,Hq,hd); kc/vc (B,Hkv,Sl,hd) local shard; k_new/v_new (B,Hkv,hd);
    pos scalar int32 (global position to write + last visible position).
    Combines across `axis` shards with pmax/psum (flash-decoding).
    """
    B, Hq, hd = q.shape
    Hkv, Sl = kc.shape[1], kc.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)
    shard = jax.lax.axis_index(axis) if axis else 0
    offset = shard * Sl + window_offset

    # --- predicated cache insert (small read-modify-write, no full copy)
    loc = jnp.clip(pos - offset, 0, Sl - 1)
    ok = ((pos - offset) >= 0) & ((pos - offset) < Sl)

    def insert(cache, new):
        cur = jax.lax.dynamic_slice(cache, (0, 0, loc, 0), (B, Hkv, 1, hd))
        val = jnp.where(ok, new[:, :, None, :], cur)
        return jax.lax.dynamic_update_slice(cache, val, (0, 0, loc, 0))

    kc = insert(kc, k_new)
    vc = insert(vc, v_new)

    qg = q.reshape(B, Hkv, G, hd)
    scores = jnp.einsum("bhgd,bhsd->bhgs", qg, kc,
                        preferred_element_type=jnp.float32) * scale
    kpos = offset + jnp.arange(Sl)
    mask = (kpos <= pos)[None, None, None, :]
    scores = jnp.where(mask, scores, -1e30)

    m_loc = jnp.max(scores, axis=-1)                       # (B,Hkv,G)
    if axis:
        m = jax.lax.pmax(m_loc, axis)
    else:
        m = m_loc
    e = jnp.exp(scores - m[..., None])
    l_loc = jnp.sum(e, axis=-1)
    o_loc = jnp.einsum("bhgs,bhsd->bhgd", e.astype(vc.dtype), vc)
    if axis:
        l = jax.lax.psum(l_loc, axis)
        o = jax.lax.psum(o_loc, axis)
    else:
        l, o = l_loc, o_loc
    out = (o / jnp.maximum(l[..., None], 1e-30).astype(o.dtype)).reshape(B, Hq, hd)
    return out.astype(q.dtype), kc, vc


def decode_attention(q, kcache, vcache, k_new, v_new, pos, *,
                     window_offset=0):
    """Decode-step attention w/ cache insert. Uses shard_map sequence-parallel
    partial attention when a mesh context shards 'act_kv_seq'; otherwise runs
    locally. Returns (out (B,Hq,hd), kcache, vcache).
    """
    ctx = dist.current()
    seq_axes = ctx.mesh_axes("act_kv_seq") if ctx else ()
    Sl = kcache.shape[2]
    use_shard = bool(seq_axes) and dist.current().axis_size("act_kv_seq") > 1 \
        and Sl % dist.current().axis_size("act_kv_seq") == 0
    if not use_shard:
        return _decode_inner(q, kcache, vcache, k_new, v_new, pos, axis=None,
                             window_offset=window_offset)

    assert len(seq_axes) == 1, seq_axes
    axis = seq_axes[0]
    mesh = ctx.mesh
    B = q.shape[0]
    dp = [a for a in ("pod", "data") if a in mesh.axis_names]
    dp_size = math.prod(mesh.shape[a] for a in dp) if dp else 1
    bspec = tuple(dp) if (dp and B % dp_size == 0) else None

    fn = dist.shard_map(
        functools.partial(_decode_inner, axis=axis,
                          window_offset=window_offset),
        mesh=mesh,
        in_specs=(P(bspec, None, None), P(bspec, None, axis, None),
                  P(bspec, None, axis, None), P(bspec, None, None),
                  P(bspec, None, None), P()),
        out_specs=(P(bspec, None, None), P(bspec, None, axis, None),
                   P(bspec, None, axis, None)),
    )
    return fn(q, kcache, vcache, k_new, v_new, pos)
