"""RWKV6 (Finch): attention-free linear recurrence with data-dependent decay.

Training/prefill use a *chunked* formulation (parallel within chunks of
``CHUNK`` tokens, `lax.scan` carrying the (B,H,dk,dv) wkv state across
chunks) — the same algorithm the Pallas kernel (`repro.kernels.wkv6`)
implements with VMEM tiling; this module is the XLA path and the kernel's
reference semantics. Decode is a single O(1) state update, which is why this
arch runs the long_500k cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist import api as dist
from repro.models import common as cm
from repro.models.layers import group_norm, layer_norm

CHUNK = 32
_CLAMP = 25.0   # exponent clamp for intra-chunk relative decays (fp32-safe)
LORA_MIX = 32
LORA_DECAY = 64


def init_block(keys, cfg):
    d = cfg.d_model
    H = d // cfg.rwkv_head_dim
    hd = cfg.rwkv_head_dim
    p = {
        "ln1": cm.zeros((d,), (None,)),
        "ln2": cm.zeros((d,), (None,)),
        "tm": {
            "mu_x": cm.normal(next(keys), (d,), (None,), scale=0.1),
            "mu_rkvgw": cm.normal(next(keys), (5, d), (None, None), scale=0.1),
            "w1": cm.dense(next(keys), d, 5 * LORA_MIX, ("fsdp", None)),
            "w2": cm.normal(next(keys), (5, LORA_MIX, d), (None, None, "fsdp"),
                            scale=0.01),
            "dw0": cm.Annot(jnp.full((d,), -3.0), (None,)),   # decay ~ .95
            "dw1": cm.dense(next(keys), d, LORA_DECAY, ("fsdp", None)),
            "dw2": cm.normal(next(keys), (LORA_DECAY, d), (None, "fsdp"),
                             scale=0.01),
            "u": cm.normal(next(keys), (H, hd), ("heads", None), scale=0.1),
            "wr": cm.dense(next(keys), d, d, ("fsdp", "heads")),
            "wk": cm.dense(next(keys), d, d, ("fsdp", "heads")),
            "wv": cm.dense(next(keys), d, d, ("fsdp", "heads")),
            "wg": cm.dense(next(keys), d, d, ("fsdp", "heads")),
            "wo": cm.dense(next(keys), d, d, ("heads", "fsdp")),
            "ln_x_s": cm.ones((d,), (None,)),
            "ln_x_b": cm.zeros((d,), (None,)),
        },
        "cm": {
            "mu_k": cm.normal(next(keys), (d,), (None,), scale=0.1),
            "mu_r": cm.normal(next(keys), (d,), (None,), scale=0.1),
            "wk": cm.dense(next(keys), d, cfg.d_ff, ("fsdp", "ff")),
            "wv": cm.dense(next(keys), cfg.d_ff, d, ("ff", "fsdp")),
            "wr": cm.dense(next(keys), d, d, ("fsdp", None)),
        },
    }
    return p


def _ddlerp(tm, x, sx):
    """Data-dependent token-shift interpolation -> (xr, xk, xv, xg, xw)."""
    xxx = x + sx * tm["mu_x"].astype(x.dtype)
    t = jnp.tanh(jnp.einsum("bsd,dk->bsk", xxx, tm["w1"]))
    B, S, _ = t.shape
    t = t.reshape(B, S, 5, LORA_MIX)
    mix = jnp.einsum("bsrk,rkd->bsrd", t, tm["w2"].astype(x.dtype))
    mus = tm["mu_rkvgw"].astype(x.dtype)               # (5, d)
    outs = [x + sx * (mus[i] + mix[:, :, i]) for i in range(5)]
    return outs  # r, k, v, g, w order


def _decay_logw(tm, xw):
    """Data-dependent per-channel log-decay (negative, fp32)."""
    lo = jnp.einsum("bsd,dk->bsk", xw.astype(jnp.float32),
                    tm["dw1"].astype(jnp.float32))
    dd = jnp.einsum("bsk,kd->bsd", jnp.tanh(lo), tm["dw2"].astype(jnp.float32))
    return -jnp.exp(tm["dw0"].astype(jnp.float32) + dd)   # (B,S,D) < 0


def wkv_chunked(r, k, v, logw, u, state):
    """Chunked WKV6. r/k/v (B,S,H,hd) compute dtype; logw (B,S,H,hd) fp32;
    u (H,hd); state (B,H,hd,hd) fp32. Returns (y (B,S,H,hd), state)."""
    B, S, H, hd = r.shape
    C = CHUNK if S % CHUNK == 0 else S
    nc = S // C

    def to_chunks(x):
        return jnp.moveaxis(x.reshape(B, nc, C, H, hd), 1, 0)

    rc, kc, vc, lwc = map(to_chunks, (r.astype(jnp.float32),
                                      k.astype(jnp.float32),
                                      v.astype(jnp.float32), logw))
    uf = u.astype(jnp.float32)

    def body(S_prev, args):
        rr, kk, vv, lw = args                          # (B,C,H,hd)
        clw = jnp.cumsum(lw, axis=1)                   # inclusive
        ecl = clw - lw                                 # exclusive
        q_ = rr * jnp.exp(jnp.clip(ecl, -_CLAMP, _CLAMP))
        k_ = kk * jnp.exp(jnp.clip(-clw, -_CLAMP, _CLAMP))
        A = jnp.einsum("bthk,bshk->bhts", q_, k_)      # (B,H,C,C)
        mask = jnp.tril(jnp.ones((C, C), bool), -1)
        A = jnp.where(mask[None, None], A, 0.0)
        diag = jnp.einsum("bthk,bthk->bth", rr, uf[None, None] * kk)
        y = jnp.einsum("bhts,bshv->bthv", A, vv)
        y = y + diag[..., None] * vv
        y = y + jnp.einsum("bthk,bhkv->bthv",
                           rr * jnp.exp(jnp.clip(ecl, -_CLAMP, _CLAMP)), S_prev)
        total = clw[:, -1]                             # (B,H,hd)
        kdecay = kk * jnp.exp(jnp.clip(total[:, None] - clw, -_CLAMP, _CLAMP))
        S_new = (jnp.exp(jnp.clip(total, -_CLAMP, _CLAMP))[..., None] * S_prev
                 + jnp.einsum("bshk,bshv->bhkv", kdecay, vv))
        return S_new, y

    state, ys = jax.lax.scan(body, state.astype(jnp.float32),
                             (rc, kc, vc, lwc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, hd)
    return y, state


def wkv_step(r, k, v, logw, u, state):
    """Single decode step. r/k/v (B,H,hd); logw (B,H,hd) fp32; state fp32."""
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    y = jnp.einsum("bhk,bhkv->bhv", rf, state) + \
        jnp.sum(rf * u.astype(jnp.float32)[None] * kf, -1, keepdims=True) * vf
    state = jnp.exp(logw)[..., None] * state + kf[..., None] * vf[:, :, None]
    return y, state


def time_mix(p, cfg, x, sx, state):
    """x (B,S,D) train/prefill (sx = shifted-x minus x); state (B,H,hd,hd)."""
    B, S, d = x.shape
    H = d // cfg.rwkv_head_dim
    hd = cfg.rwkv_head_dim
    tm = p["tm"]
    xr, xk, xv, xg, xw = _ddlerp(tm, x, sx)
    r = jnp.einsum("bsd,dh->bsh", xr, tm["wr"]).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,dh->bsh", xk, tm["wk"]).reshape(B, S, H, hd)
    v = jnp.einsum("bsd,dh->bsh", xv, tm["wv"]).reshape(B, S, H, hd)
    g = jax.nn.silu(jnp.einsum("bsd,dh->bsh", xg, tm["wg"]))
    logw = _decay_logw(tm, xw).reshape(B, S, H, hd)
    r = dist.constraint(r, "act_batch", None, "act_heads", None)
    k = dist.constraint(k, "act_batch", None, "act_heads", None)
    v = dist.constraint(v, "act_batch", None, "act_heads", None)
    y, state = wkv_chunked(r, k, v, logw, tm["u"], state)
    y = y.reshape(B, S, d).astype(x.dtype)
    y = group_norm(y, tm["ln_x_s"], tm["ln_x_b"], num_groups=H) * g
    out = jnp.einsum("bsh,hd->bsd", y, tm["wo"])
    return out, state


def channel_mix(p, x, sx, act_unused=None):
    pc = p["cm"]
    xk = x + sx * pc["mu_k"].astype(x.dtype)
    xr = x + sx * pc["mu_r"].astype(x.dtype)
    k = jnp.einsum("bsd,df->bsf", xk, pc["wk"])
    k = jnp.square(jax.nn.relu(k))
    k = dist.constraint(k, "act_batch", None, "act_ff")
    kv = jnp.einsum("bsf,fd->bsd", k, pc["wv"])
    return jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, pc["wr"])) * kv


def shift(x):
    """Token shift: x_{t-1} (zeros at t=0)."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def block(p, cfg, x, wkv_state, *, collect_last: bool = False):
    """One RWKV6 block (train/prefill).

    Returns (x, new_state, last) where ``last`` is the (x_tm, x_cm) pair of
    last-token post-norm activations needed to seed the decode token shift
    (None unless ``collect_last``)."""
    h = layer_norm(x, 1.0 + p["ln1"], jnp.zeros_like(p["ln1"]), cfg.norm_eps)
    sx = shift(h) - h
    dt, wkv_state = time_mix(p, cfg, h, sx, wkv_state)
    x = x + dt
    h2 = layer_norm(x, 1.0 + p["ln2"], jnp.zeros_like(p["ln2"]), cfg.norm_eps)
    sx2 = shift(h2) - h2
    x = x + channel_mix(p, h2, sx2)
    last = None
    if collect_last:
        last = (h[:, -1].astype(jnp.float32), h2[:, -1].astype(jnp.float32))
    return x, wkv_state, last


def block_step(p, cfg, x, state):
    """One decode step. x (B,D); state dict(wkv, x_tm, x_cm)."""
    B, d = x.shape
    H = d // cfg.rwkv_head_dim
    hd = cfg.rwkv_head_dim
    tm = p["tm"]
    h = layer_norm(x, 1.0 + p["ln1"], jnp.zeros_like(p["ln1"]), cfg.norm_eps)
    sx = state["x_tm"].astype(h.dtype) - h
    h3, sx3 = h[:, None], sx[:, None]
    xr, xk, xv, xg, xw = _ddlerp(tm, h3, sx3)
    r = jnp.einsum("bsd,dh->bsh", xr, tm["wr"]).reshape(B, H, hd)
    k = jnp.einsum("bsd,dh->bsh", xk, tm["wk"]).reshape(B, H, hd)
    v = jnp.einsum("bsd,dh->bsh", xv, tm["wv"]).reshape(B, H, hd)
    g = jax.nn.silu(jnp.einsum("bsd,dh->bsh", xg, tm["wg"]))[:, 0]
    logw = _decay_logw(tm, xw).reshape(B, H, hd)
    y, wkv = wkv_step(r, k, v, logw, tm["u"], state["wkv"])
    y = y.reshape(B, d).astype(x.dtype)
    y = group_norm(y, tm["ln_x_s"], tm["ln_x_b"], num_groups=H) * g
    x = x + jnp.einsum("bh,hd->bd", y, tm["wo"])

    h2 = layer_norm(x, 1.0 + p["ln2"], jnp.zeros_like(p["ln2"]), cfg.norm_eps)
    sx2 = state["x_cm"].astype(h2.dtype) - h2
    pc = p["cm"]
    xk2 = h2 + sx2 * pc["mu_k"].astype(h2.dtype)
    xr2 = h2 + sx2 * pc["mu_r"].astype(h2.dtype)
    kk = jnp.square(jax.nn.relu(jnp.einsum("bd,df->bf", xk2, pc["wk"])))
    x = x + jax.nn.sigmoid(jnp.einsum("bd,de->be", xr2, pc["wr"])) * \
        jnp.einsum("bf,fd->bd", kk, pc["wv"])
    new_state = {"wkv": wkv, "x_tm": h.astype(jnp.float32),
                 "x_cm": h2.astype(jnp.float32)}
    return x, new_state
