"""Unified model API over all assigned architecture families.

``Model(cfg)`` exposes:

  init(key)                 -> annotated param tree (values carry logical axes)
  train_loss(params, batch) -> (loss, metrics)        [train_4k]
  prefill(params, batch)    -> (last_logits, cache)   [prefill_32k]
  decode_step(params, tokens, cache) -> (logits, cache)  [decode_32k/long_500k]
  init_cache(batch, cache_len) -> cache pytree (zeros)

Families: dense/vlm (RoPE/M-RoPE GQA transformer), moe (GQA + routed
experts), ssm (RWKV6), hybrid (Griffin RG-LRU + local attention), audio
(whisper encoder-decoder; mel frontend is a stub — precomputed frames).

Layers are scan-stacked (one traced body per layer kind -> compact HLO that
partitions quickly on the 512-device dry-run mesh) and remat'd according to
``Model.remat`` ("full" | "none").
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.dist import api as dist
from repro.models import attention as attn
from repro.models import common as cm
from repro.models import rglru
from repro.models import rwkv6
from repro.models import transformer as tfm
from repro.models.layers import (apply_mlp, cross_entropy, embed_tokens,
                                 init_embed, init_mlp, layer_norm,
                                 logits_from_hidden, rms_norm)

MAX_DECODE_LEN = 32_768       # learned-pos-emb table length (whisper decode)


def _stack_inits(fn, n: int):
    """Run an init fn n times and stack the Annot trees on a 'layer' axis."""
    trees = [fn() for _ in range(n)]
    if n == 1:
        return jax.tree.map(
            lambda a: cm.Annot(a.value[None], ("layer",) + a.axes),
            trees[0], is_leaf=cm.is_annot)

    def stack(*leaves):
        vals = jnp.stack([l.value for l in leaves])
        return cm.Annot(vals, ("layer",) + leaves[0].axes)

    return jax.tree.map(stack, *trees, is_leaf=cm.is_annot)


def _maybe_remat(fn, mode: str):
    if mode == "full":
        return jax.checkpoint(fn)
    return fn


# =====================================================================
# Model
# =====================================================================


class Model:
    def __init__(self, cfg: ArchConfig, policy: Optional[cm.Policy] = None,
                 remat: str = "full", fsdp_gather: bool = True):
        self.cfg = cfg
        self.policy = policy or cm.Policy()
        self.remat = remat
        # ZeRO-3 JIT weight gather before use (see dist.gather_fsdp); can
        # be disabled to reproduce the naive GSPMD baseline in §Perf
        self.fsdp_gather = fsdp_gather
        self.vocab_padded = cm.pad_vocab(cfg.vocab_size)
        self._axes_cache = None

    def _axes(self, key: str, strip_layer: bool = False):
        """Logical-axes subtree for one param group (lazy, eval_shape)."""
        if self._axes_cache is None:
            self._axes_cache = self.param_axes()
        sub = self._axes_cache[key]
        if not strip_layer:
            return sub
        return jax.tree.map(
            lambda ax: ax[1:] if ax and ax[0] == "layer" else ax,
            sub, is_leaf=dist._is_axes_leaf)

    def _gather(self, lp, key: str, strip_layer: bool = False):
        if not self.fsdp_gather or dist.current() is None:
            return lp
        return dist.gather_fsdp(lp, self._axes(key, strip_layer))

    # ------------------------------------------------------------ init

    def init(self, key):
        cfg = self.cfg
        keys = cm.keygen(key)
        p: Dict[str, Any] = {
            "embed": init_embed(keys, self.vocab_padded, cfg.d_model,
                                cfg.tie_embeddings),
            "ln_f": cm.zeros((cfg.d_model,), (None,)),
        }
        if cfg.family == "ssm":
            p["ln0"] = cm.zeros((cfg.d_model,), (None,))
            p["layers"] = _stack_inits(
                lambda: rwkv6.init_block(keys, cfg), cfg.num_layers)
        elif cfg.family == "hybrid":
            n_units, rem = self._hybrid_units()
            p["units"] = _stack_inits(
                lambda: self._init_hybrid_unit(keys), n_units)
            if rem:
                p["tail"] = [self._init_hybrid_layer(keys, kind)
                             for kind in self._hybrid_tail_kinds()]
        elif cfg.is_encoder_decoder:
            p["enc_layers"] = _stack_inits(
                lambda: tfm.init_encoder_layer(keys, cfg), cfg.encoder_layers)
            p["ln_enc"] = cm.zeros((cfg.d_model,), (None,))
            p["dec_layers"] = _stack_inits(
                lambda: tfm.init_decoder_layer(keys, cfg, moe_layer=False,
                                               cross=True), cfg.num_layers)
            if cfg.learned_pos_emb:
                p["pos_enc"] = cm.normal(keys.__next__(),
                                         (cfg.encoder_seq_len, cfg.d_model),
                                         (None, "fsdp"), scale=0.02)
                p["pos_dec"] = cm.normal(keys.__next__(),
                                         (MAX_DECODE_LEN, cfg.d_model),
                                         (None, "fsdp"), scale=0.02)
        elif cfg.moe:
            n_dense = 1 if cfg.moe.first_layer_dense else 0
            if n_dense:
                p["dense0"] = tfm.init_decoder_layer(
                    keys, cfg, moe_layer=False, dense_d_ff=cfg.moe.dense_d_ff)
            p["layers"] = _stack_inits(
                lambda: tfm.init_decoder_layer(keys, cfg, moe_layer=True),
                cfg.num_layers - n_dense)
        else:  # dense / vlm
            p["layers"] = _stack_inits(
                lambda: tfm.init_decoder_layer(keys, cfg, moe_layer=False),
                cfg.num_layers)
        return p

    def init_params(self, key):
        """init + split -> (values, axes)."""
        return cm.split(self.init(key))

    def param_axes(self):
        """Axes tree without materializing values (via eval_shape)."""
        tree = jax.eval_shape(self.init, jax.random.key(0))
        return jax.tree.map(lambda a: a.axes, tree, is_leaf=cm.is_annot)

    def param_shapes(self):
        tree = jax.eval_shape(self.init, jax.random.key(0))
        return jax.tree.map(lambda a: a.value, tree, is_leaf=cm.is_annot)

    # ------------------------------------------------------ hybrid helpers

    def _hybrid_units(self) -> Tuple[int, int]:
        pat = len(self.cfg.block_pattern)
        return self.cfg.num_layers // pat, self.cfg.num_layers % pat

    def _hybrid_tail_kinds(self):
        pat = self.cfg.block_pattern
        _, rem = self._hybrid_units()
        return [pat[i % len(pat)] for i in range(rem)]

    def _init_hybrid_layer(self, keys, kind: str):
        cfg = self.cfg
        if kind == "attn":
            return tfm.init_decoder_layer(keys, cfg, moe_layer=False)
        return {
            "ln_rec": cm.zeros((cfg.d_model,), (None,)),
            "rec": rglru.init_rec_block(keys, cfg),
            "ln_mlp": cm.zeros((cfg.d_model,), (None,)),
            "mlp": init_mlp(keys, cfg.d_model, cfg.d_ff, cfg.act),
        }

    def _init_hybrid_unit(self, keys):
        return {f"l{i}_{kind}": self._init_hybrid_layer(keys, kind)
                for i, kind in enumerate(self.cfg.block_pattern)}

    # --------------------------------------------------------- embedding

    def _embed(self, p, tokens, batch=None):
        cfg = self.cfg
        x = embed_tokens(self._gather(p["embed"], "embed"), tokens,
                         self.policy.compute_dtype)
        if cfg.family == "hybrid":                       # gemma convention
            x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
        if batch is not None and "patch_embeds" in batch:
            pe = batch["patch_embeds"].astype(x.dtype)   # (B, P, D) stub
            x = jax.lax.dynamic_update_slice(x, pe, (0, 0, 0))
        return dist.constraint(x, "act_batch", "act_seq", "act_embed")

    def _positions(self, batch, B, S):
        if self.cfg.mrope_sections:
            return batch["positions"]                     # (3, B, S)
        pos = batch.get("positions") if batch else None
        if pos is None:
            pos = jnp.arange(S, dtype=jnp.int32)[None].repeat(B, 0)
        return pos

    # =================================================================
    # forward (train / prefill)
    # =================================================================

    def _forward(self, params, batch, *, collect_cache: bool):
        """Shared train/prefill body -> (hidden (B,S,D), aux, cache)."""
        cfg = self.cfg
        p = self.policy.c(params)
        tokens = batch["tokens"]
        B, S = tokens.shape
        fam = cfg.family

        if fam == "ssm":
            return self._forward_rwkv(p, tokens, collect_cache)
        if fam == "hybrid":
            return self._forward_hybrid(p, tokens, collect_cache)
        if cfg.is_encoder_decoder:
            return self._forward_encdec(p, batch, collect_cache)

        x = self._embed(p, tokens, batch)
        positions = self._positions(batch, B, S)
        aux_total = jnp.zeros((), jnp.float32)
        caches = []

        if cfg.moe and cfg.moe.first_layer_dense:
            x, _, c = tfm.decoder_layer(self._gather(p["dense0"], "dense0"),
                                        cfg, x, positions,
                                        collect_cache=collect_cache)
            caches.append(c)

        def body(x, lp):
            lp = self._gather(lp, "layers", strip_layer=True)
            x, aux, c = tfm.decoder_layer(lp, cfg, x, positions,
                                          collect_cache=collect_cache)
            return x, (aux, c)

        x, (auxs, scanned_cache) = jax.lax.scan(
            _maybe_remat(body, self.remat if not collect_cache else "none"),
            x, p["layers"])
        aux_total = aux_total + jnp.sum(auxs)

        cache = None
        if collect_cache:
            cache = {"layers": scanned_cache}
            if caches:
                cache["dense0"] = caches[0]
        return x, aux_total, cache

    def _forward_rwkv(self, p, tokens, collect_cache):
        cfg = self.cfg
        B, S = tokens.shape
        x = self._embed(p, tokens)
        x = layer_norm(x, 1.0 + p["ln0"], jnp.zeros_like(p["ln0"]),
                       cfg.norm_eps)
        H = cfg.d_model // cfg.rwkv_head_dim
        hd = cfg.rwkv_head_dim

        def body(x, lp):
            lp = self._gather(lp, "layers", strip_layer=True)
            s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
            x, st, last = rwkv6.block(lp, cfg, x, s0,
                                      collect_last=collect_cache)
            return x, (st, last) if collect_cache else None

        x, ys = jax.lax.scan(
            _maybe_remat(body, self.remat if not collect_cache else "none"),
            x, p["layers"])
        cache = None
        if collect_cache:
            states, lasts = ys
            cache = {"wkv": states, "x_tm": lasts[0], "x_cm": lasts[1]}
        return x, jnp.zeros((), jnp.float32), cache

    def _forward_hybrid(self, p, tokens, collect_cache):
        cfg = self.cfg
        B, S = tokens.shape
        x = self._embed(p, tokens)
        positions = jnp.arange(S, dtype=jnp.int32)[None].repeat(B, 0)
        W = cfg.window
        w = cfg.lru_width or cfg.d_model
        K = cfg.conv_width

        def one_layer(lp, kind, x):
            if kind == "attn":
                x, _, c = tfm.decoder_layer(lp, cfg, x, positions,
                                            window=W,
                                            collect_cache=collect_cache)
                if collect_cache:
                    k, v = c

                    def to_ring(t):
                        # ring layout: slot i holds position pos with
                        # pos % W == i.  S % W == 0 keeps slots aligned;
                        # S < W right-pads (warmup masking covers the rest).
                        if t.shape[2] < W:
                            return jnp.pad(
                                t, ((0, 0), (0, 0),
                                    (0, W - t.shape[2]), (0, 0)))
                        assert t.shape[2] % W == 0, (t.shape, W)
                        return t[:, :, -W:]
                    c = (to_ring(k), to_ring(v))
                return x, c
            h = rms_norm(x, lp["ln_rec"], cfg.norm_eps)
            h0 = jnp.zeros((B, w), jnp.float32)
            out, h_last, conv_st = rglru.rec_block(
                lp["rec"], cfg, h, h0, collect_state=collect_cache)
            x = x + out
            h2 = rms_norm(x, lp["ln_mlp"], cfg.norm_eps)
            x = x + apply_mlp(lp["mlp"], h2, cfg.act)
            c = {"h": h_last, "conv": conv_st} if collect_cache else None
            return x, c

        def unit_body(x, up):
            up = self._gather(up, "units", strip_layer=True)
            cs = {}
            for i, kind in enumerate(cfg.block_pattern):
                x, c = one_layer(up[f"l{i}_{kind}"], kind, x)
                if collect_cache:
                    cs[f"l{i}_{kind}"] = c
            return x, cs if collect_cache else None

        x, unit_caches = jax.lax.scan(
            _maybe_remat(unit_body, self.remat if not collect_cache
                         else "none"), x, p["units"])
        tail_caches = []
        tail_p = self._gather(p["tail"], "tail") if "tail" in p else []
        for lp, kind in zip(tail_p, self._hybrid_tail_kinds()):
            x, c = one_layer(lp, kind, x)
            tail_caches.append(c)
        cache = None
        if collect_cache:
            cache = {"units": unit_caches, "tail": tail_caches}
        return x, jnp.zeros((), jnp.float32), cache

    def _forward_encdec(self, p, batch, collect_cache):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        frames = batch["enc_frames"].astype(self.policy.compute_dtype)
        enc = frames + p["pos_enc"].astype(frames.dtype)[None]

        def enc_body(x, lp):
            lp = self._gather(lp, "enc_layers", strip_layer=True)
            return tfm.encoder_layer(lp, cfg, x), None

        enc, _ = jax.lax.scan(_maybe_remat(enc_body, self.remat),
                              enc, p["enc_layers"])
        enc = layer_norm(enc, 1.0 + p["ln_enc"], jnp.zeros_like(p["ln_enc"]),
                         cfg.norm_eps)

        x = self._embed(p, tokens)
        x = x + p["pos_dec"].astype(x.dtype)[None, :S]
        positions = jnp.arange(S, dtype=jnp.int32)[None].repeat(B, 0)

        def dec_body(x, lp):
            lp = self._gather(lp, "dec_layers", strip_layer=True)
            x, aux, c = tfm.decoder_layer(lp, cfg, x, positions,
                                          enc_out=enc,
                                          collect_cache=collect_cache)
            return x, c

        x, caches = jax.lax.scan(
            _maybe_remat(dec_body, self.remat if not collect_cache
                         else "none"), x, p["dec_layers"])
        cache = {"layers": caches} if collect_cache else None
        return x, jnp.zeros((), jnp.float32), cache

    # ----------------------------------------------------------- train

    def train_loss(self, params, batch):
        cfg = self.cfg
        x, aux, _ = self._forward(params, batch, collect_cache=False)
        x = rms_norm(x, params["ln_f"].astype(x.dtype), cfg.norm_eps) \
            if cfg.family != "audio" else \
            layer_norm(x, 1.0 + params["ln_f"].astype(x.dtype),
                       jnp.zeros_like(params["ln_f"]).astype(x.dtype),
                       cfg.norm_eps)
        x = dist.constraint(x, "act_batch", "act_seq", "act_embed")
        logits = logits_from_hidden(
            self._gather(self.policy.c(params["embed"]), "embed"), x,
            cfg.vocab_size, cfg.tie_embeddings)
        logits = dist.constraint(logits, "act_batch", "act_seq", "act_vocab")
        ce = cross_entropy(logits, batch["labels"], cfg.vocab_size)
        aux_w = cfg.moe.router_aux_loss if cfg.moe else 0.0
        loss = ce + aux_w * aux
        return loss, {"loss": loss, "ce": ce, "aux": aux}

    # --------------------------------------------------------- prefill

    def _final_logits(self, params, x_last):
        cfg = self.cfg
        lnf = params["ln_f"].astype(x_last.dtype)
        if cfg.family == "audio":
            x_last = layer_norm(x_last, 1.0 + lnf, jnp.zeros_like(lnf),
                                cfg.norm_eps)
        else:
            x_last = rms_norm(x_last, lnf, cfg.norm_eps)
        return logits_from_hidden(
            self._gather(self.policy.c(params["embed"]), "embed"), x_last,
            cfg.vocab_size, cfg.tie_embeddings)

    def prefill(self, params, batch):
        """-> (last-token logits (B, Vp), cache)."""
        tokens = batch["tokens"]
        B, S = tokens.shape
        x, _, cache = self._forward(params, batch, collect_cache=True)
        logits = self._final_logits(params, x[:, -1])
        cache = dict(cache)
        cache["pos"] = jnp.full((), S, jnp.int32)
        return logits, cache

    # ---------------------------------------------------------- decode

    def init_cache(self, batch_size: int, cache_len: int):
        """Zero-filled decode cache (also the dry-run ShapeDtypeStruct via
        eval_shape)."""
        cfg = self.cfg
        B = batch_size
        hd = cfg.resolved_head_dim
        nkv = cfg.num_kv_heads
        d = cfg.d_model
        zero = functools.partial(jnp.zeros)

        if cfg.family == "ssm":
            H = d // cfg.rwkv_head_dim
            rhd = cfg.rwkv_head_dim
            L = cfg.num_layers
            return {
                "wkv": zero((L, B, H, rhd, rhd), jnp.float32),
                "x_tm": zero((L, B, d), jnp.float32),
                "x_cm": zero((L, B, d), jnp.float32),
                "pos": jnp.zeros((), jnp.int32),
            }
        kv_dtype = self.policy.compute_dtype
        if cfg.family == "hybrid":
            W = cfg.window
            w = cfg.lru_width or d
            K = cfg.conv_width
            n_units, _ = self._hybrid_units()

            def layer_cache(kind, lead=()):
                if kind == "attn":
                    return (zero(lead + (B, nkv, W, hd), kv_dtype),
                            zero(lead + (B, nkv, W, hd), kv_dtype))
                return {"h": zero(lead + (B, w), jnp.float32),
                        "conv": zero(lead + (B, K - 1, w), jnp.float32)}

            units = {f"l{i}_{kind}": layer_cache(kind, (n_units,))
                     for i, kind in enumerate(cfg.block_pattern)}
            tail = [layer_cache(kind) for kind in self._hybrid_tail_kinds()]
            return {"units": units, "tail": tail,
                    "pos": jnp.zeros((), jnp.int32)}
        if cfg.is_encoder_decoder:
            L = cfg.num_layers
            T = cfg.encoder_seq_len
            return {"layers": (
                        zero((L, B, nkv, cache_len, hd), kv_dtype),
                        zero((L, B, nkv, cache_len, hd), kv_dtype),
                        zero((L, B, nkv, T, hd), kv_dtype),
                        zero((L, B, nkv, T, hd), kv_dtype)),
                    "pos": jnp.zeros((), jnp.int32)}
        L = cfg.num_layers - (1 if cfg.moe and cfg.moe.first_layer_dense
                              else 0)
        cache = {"layers": (zero((L, B, nkv, cache_len, hd), kv_dtype),
                            zero((L, B, nkv, cache_len, hd), kv_dtype)),
                 "pos": jnp.zeros((), jnp.int32)}
        if cfg.moe and cfg.moe.first_layer_dense:
            cache["dense0"] = (zero((B, nkv, cache_len, hd), kv_dtype),
                               zero((B, nkv, cache_len, hd), kv_dtype))
        return cache

    def cache_dims(self):
        """Logical sharding dims for every cache leaf, mirroring the
        init_cache structure. Decode distribution strategy: batch over
        (pod, data); KV cache *sequence* over 'model' (flash-decoding
        partial attention — small-kv-head GQA can't head-shard); recurrent
        state width over 'model'."""
        cfg = self.cfg
        B = ("act_batch",)
        if cfg.family == "ssm":
            return {
                "wkv": (None, "act_batch", "act_heads", None, None),
                "x_tm": (None, "act_batch", None),
                "x_cm": (None, "act_batch", None),
                "pos": (),
            }
        kv = (None, "act_batch", None, "act_kv_seq", None)
        if cfg.family == "hybrid":
            def layer_dims(kind, lead):
                pre = (None,) * lead
                if kind == "attn":
                    return (pre + ("act_batch", None, "act_kv_seq", None),) * 2
                return {"h": pre + ("act_batch", "act_ff"),
                        "conv": pre + ("act_batch", None, "act_ff")}

            units = {f"l{i}_{kind}": layer_dims(kind, 1)
                     for i, kind in enumerate(cfg.block_pattern)}
            tail = [layer_dims(kind, 0)
                    for kind in self._hybrid_tail_kinds()]
            return {"units": units, "tail": tail, "pos": ()}
        if cfg.is_encoder_decoder:
            return {"layers": (kv, kv, kv, kv), "pos": ()}
        out = {"layers": (kv, kv), "pos": ()}
        if cfg.moe and cfg.moe.first_layer_dense:
            out["dense0"] = (kv[1:], kv[1:])
        return out

    def decode_step(self, params, tokens, cache):
        """tokens (B,) int32 -> (logits (B, Vp), new cache)."""
        cfg = self.cfg
        p = self.policy.c(params)
        B = tokens.shape[0]
        pos = cache["pos"]
        x = embed_tokens(p["embed"], tokens, self.policy.compute_dtype)
        if cfg.family == "hybrid":
            x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
        x = dist.constraint(x, "act_batch", "act_embed")

        if cfg.family == "ssm":
            def body(x, xs):
                lp, st = xs
                x, st = rwkv6.block_step(lp, cfg, x, st)
                return x, st

            state = {"wkv": cache["wkv"], "x_tm": cache["x_tm"],
                     "x_cm": cache["x_cm"]}
            x, new_state = jax.lax.scan(body, x, (p["layers"], state))
            new_cache = dict(new_state)
        elif cfg.family == "hybrid":
            def one_step(lp, kind, x, c):
                if kind == "attn":
                    x, kc, vc = tfm.decoder_layer_step(
                        lp, cfg, x, c[0], c[1], pos, window=cfg.window)
                    return x, (kc, vc)
                h = rms_norm(x, lp["ln_rec"], cfg.norm_eps)
                out, st = rglru.rec_block_step(lp["rec"], cfg, h, c)
                x = x + out
                h2 = rms_norm(x, lp["ln_mlp"], cfg.norm_eps)
                x = x + _mlp_step_act(lp["mlp"], h2, cfg.act)
                return x, st

            def unit_body(x, xs):
                up, ucache = xs
                new = {}
                for i, kind in enumerate(cfg.block_pattern):
                    key = f"l{i}_{kind}"
                    x, new[key] = one_step(up[key], kind, x, ucache[key])
                return x, new

            x, new_units = jax.lax.scan(unit_body, x,
                                        (p["units"], cache["units"]))
            new_tail = []
            for lp, kind, c in zip(p.get("tail", []),
                                   self._hybrid_tail_kinds(), cache["tail"]):
                x, nc = one_step(lp, kind, x, c)
                new_tail.append(nc)
            new_cache = {"units": new_units, "tail": new_tail}
        elif cfg.is_encoder_decoder:
            x = x + p["pos_dec"].astype(x.dtype)[pos]

            def body(x, xs):
                lp, kc, vc, ck, cv = xs
                x, kc, vc = tfm.decoder_layer_step(lp, cfg, x, kc, vc, pos,
                                                   enc_kv=(ck, cv))
                return x, (kc, vc)

            kc, vc, ck, cv = cache["layers"]
            x, (nk, nv) = jax.lax.scan(body, x,
                                       (p["dec_layers"], kc, vc, ck, cv))
            new_cache = {"layers": (nk, nv, ck, cv)}
        else:
            new_cache = {}
            if cfg.moe and cfg.moe.first_layer_dense:
                kc, vc = cache["dense0"]
                x, kc, vc = tfm.decoder_layer_step(p["dense0"], cfg, x,
                                                   kc, vc, pos)
                new_cache["dense0"] = (kc, vc)

            def body(x, xs):
                lp, kc, vc = xs
                x, kc, vc = tfm.decoder_layer_step(lp, cfg, x, kc, vc, pos)
                return x, (kc, vc)

            kc, vc = cache["layers"]
            x, new_kv = jax.lax.scan(body, x, (p["layers"], kc, vc))
            new_cache["layers"] = new_kv

        logits = self._final_logits(params, x)
        new_cache["pos"] = pos + 1
        return logits, new_cache


def _mlp_step_act(p, x, act):
    from repro.models.transformer import _mlp_step
    return _mlp_step(p, x, act)


# =====================================================================
# input specs (dry-run stand-ins; no allocation)
# =====================================================================


def input_specs(cfg: ArchConfig, shape: ShapeConfig,
                policy: Optional[cm.Policy] = None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train/prefill -> {"batch": {...}}; decode -> {"tokens", "cache"}.
    """
    policy = policy or cm.Policy()
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct

    def lm_batch(with_labels: bool):
        b = {"tokens": sds((B, S), jnp.int32)}
        if with_labels:
            b["labels"] = sds((B, S), jnp.int32)
        if cfg.mrope_sections:
            b["positions"] = sds((3, B, S), jnp.int32)
            b["patch_embeds"] = sds((B, min(256, S), cfg.d_model),
                                    policy.compute_dtype)
        if cfg.is_encoder_decoder:
            b["enc_frames"] = sds((B, cfg.encoder_seq_len, cfg.d_model),
                                  policy.compute_dtype)
        return b

    if shape.kind in ("train", "prefill"):
        return {"batch": lm_batch(with_labels=shape.kind == "train")}

    # decode: one new token against a cache of length seq_len
    model = Model(cfg, policy)
    cache = jax.eval_shape(
        functools.partial(model.init_cache, B, S))
    if cfg.mrope_sections:
        # decode positions are derived from cache["pos"]; nothing extra
        pass
    return {"tokens": sds((B,), jnp.int32), "cache": cache}
