"""Shared neural-net layers: norms, RoPE / M-RoPE, gated MLPs, embeddings."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.dist.api import constraint
from repro.models import common as cm


# ---------------------------------------------------------------- norms


def rms_norm(x, scale, eps=1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x, scale, bias, eps=1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def group_norm(x, scale, bias, num_groups, eps=1e-5):
    """GroupNorm over the last dim (used by RWKV's ln_x)."""
    dt = x.dtype
    *lead, d = x.shape
    xg = x.astype(jnp.float32).reshape(*lead, num_groups, d // num_groups)
    mu = jnp.mean(xg, axis=-1, keepdims=True)
    var = jnp.var(xg, axis=-1, keepdims=True)
    y = ((xg - mu) * jax.lax.rsqrt(var + eps)).reshape(*lead, d)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------- RoPE


def _rope_angles(positions, head_dim: int, theta: float):
    """positions (...,) -> cos/sin (..., head_dim//2), fp32."""
    half = head_dim // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta: float):
    """x (B, S, H, hd); positions (B, S). GPT-NeoX half-split convention."""
    hd = x.shape[-1]
    cos, sin = _rope_angles(positions, hd, theta)   # (B, S, hd/2)
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, sections: Tuple[int, ...], theta: float):
    """M-RoPE (qwen2-vl): positions3 (3, B, S); sections sum == head_dim//2.

    Frequency slots are assigned to (temporal, height, width) sections; each
    slot rotates by the position of its section.
    """
    hd = x.shape[-1]
    half = hd // 2
    assert sum(sections) == half, (sections, half)
    sec_id = jnp.repeat(
        jnp.arange(len(sections)), jnp.array(sections), total_repeat_length=half
    )                                                  # (half,)
    # per-slot position: select section's position stream
    pos = positions3.astype(jnp.float32)               # (3, B, S)
    pos_sel = pos[sec_id, :, :]                        # (half, B, S)
    pos_sel = jnp.moveaxis(pos_sel, 0, -1)             # (B, S, half)
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = pos_sel * freq                               # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- MLPs


def init_mlp(keys, d_model: int, d_ff: int, act: str):
    """Gated MLPs keep gate/up as SEPARATE matrices: splitting a fused
    (2F) projection whose output dim is TP-sharded forces a
    collective-permute reshard of the halves (each half lives on the other
    half of the TP group) — 0.65 TB/chip/step on the 110B cell."""
    p = {}
    if act in ("swiglu", "geglu"):
        p["wg"] = cm.dense(next(keys), d_model, d_ff, ("fsdp", "ff"))
        p["wu"] = cm.dense(next(keys), d_model, d_ff, ("fsdp", "ff"))
    else:
        p["wi"] = cm.dense(next(keys), d_model, d_ff, ("fsdp", "ff"))
    p["wo"] = cm.dense(next(keys), d_ff, d_model, ("ff", "fsdp"))
    return p


def apply_mlp(p, x, act: str):
    """x (B, S, D) -> (B, S, D)."""
    if act in ("swiglu", "geglu"):
        g = jnp.einsum("bsd,df->bsf", x, p["wg"])
        u = jnp.einsum("bsd,df->bsf", x, p["wu"])
        g = jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)
        h = g * u
    else:
        h = jnp.einsum("bsd,df->bsf", x, p["wi"])
        if act == "gelu":
            h = jax.nn.gelu(h)
        elif act == "relu_sq":
            h = jnp.square(jax.nn.relu(h))
        else:
            raise ValueError(act)
    h = constraint(h, "act_batch", None, "act_ff")
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])


# ---------------------------------------------------------------- embeddings


def init_embed(keys, vocab_padded: int, d_model: int, tie: bool):
    p = {"tok": cm.normal(next(keys), (vocab_padded, d_model),
                          ("vocab", "fsdp"), scale=0.02)}
    if not tie:
        p["head"] = cm.dense(next(keys), d_model, vocab_padded,
                             ("fsdp", "vocab"))
    return p


def embed_tokens(p, tokens, compute_dtype):
    out = jnp.take(p["tok"].astype(compute_dtype), tokens, axis=0)
    return out


def logits_from_hidden(p, x, vocab_size: int, tie: bool):
    """x (..., D) -> logits (..., V_padded) with padded slots masked."""
    if tie:
        w = p["tok"].astype(x.dtype).T
    else:
        w = p["head"].astype(x.dtype)
    logits = x @ w
    vp = logits.shape[-1]
    if vp != vocab_size:
        pad_mask = (jnp.arange(vp) >= vocab_size)
        logits = logits + (pad_mask * jnp.asarray(-1e9, x.dtype))
    return logits


def cross_entropy(logits, labels, vocab_size: int):
    """Streaming-safe CE over a (possibly vocab-sharded) logits tensor.

    logits (B, S, Vp) any float dtype; labels (B, S) int32, -1 = masked.
    Avoids materializing fp32 logits or a one-hot: the correct-class logit is
    an iota-compare reduction and logsumexp reduces in fp32 accumulators.
    """
    vp = logits.shape[-1]
    lmax = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = (logits - lmax).astype(jnp.float32)
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + lmax[..., 0].astype(jnp.float32)
    onehot_sel = (jnp.arange(vp)[None, None, :] == labels[..., None])
    correct = jnp.sum(jnp.where(onehot_sel, logits.astype(jnp.float32), 0.0), axis=-1)
    nll = lse - correct
    mask = (labels >= 0) & (labels < vocab_size)
    nll = jnp.where(mask, nll, 0.0)
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)
