"""Logical-axis sharding layer: the substrate every sharded code path sits on.

Model code never mentions mesh axes. Parameters and activations carry
*logical* axis names ("fsdp", "heads", "act_batch", ...) and this module
resolves them against the active mesh through a rule table:

    logical name  ->  tuple of mesh axes (resolved left to right)

Resolution for one tensor dimension (``DistContext.spec``):

  1. ``None`` (or a name with no rule / no mapped axis present in the
     mesh) -> the dim is replicated.
  2. Duplicate suppression: a dim whose mapped mesh axes intersect the
     axes already used by an earlier dim of the same spec replicates —
     a mesh axis can shard at most one dim of a tensor.
  3. Divisibility fallback: when the dim size is known and does not
     divide the mapped mesh-axis product, the dim replicates (e.g.
     whisper's 12 heads on a 16-wide "model" axis).

Context management is module-level so the same model code runs sharded
inside ``use_mesh(...)`` and unsharded outside it: ``constraint`` is the
single choke point — identity without a context, a
``jax.lax.with_sharding_constraint`` inside one.

The param-tree helpers implement the ZeRO-3 flavour used by the models:
master weights live "fsdp"-sharded (``param_sharding``) and the bf16
compute copy is all-gathered just-in-time (``gather_fsdp`` drops the
"fsdp" entry from each leaf's axes and re-constrains, which XLA turns
into an all-gather right before use).
"""
from __future__ import annotations

import contextlib
import inspect
import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

Axes = Tuple[Optional[str], ...]
Rules = Dict[str, Tuple[str, ...]]

# Logical axis -> mesh axes. Mesh axes missing from the active mesh are
# skipped at resolution time, so one table covers the single-pod
# ("data", "model") and multi-pod ("pod", "data", "model") meshes.
DEFAULT_RULES: Rules = {
    # -- parameter axes -------------------------------------------------
    "fsdp": ("data",),          # ZeRO-3 weight/moment sharding
    "tp": ("model",),           # generic tensor-parallel dim
    "heads": ("model",),        # attention Q heads (TP)
    "kv_heads": ("model",),     # attention KV heads (TP)
    "ff": ("model",),           # MLP hidden dim (TP)
    "vocab": ("model",),        # embedding / logits vocab dim (TP)
    "expert": ("model",),       # MoE expert dim (EP)
    "layer": (),                # scan-stacked layer dim: never sharded
    # -- activation axes ------------------------------------------------
    "act_batch": ("pod", "data"),   # batch: pure DP across pods + data
    "act_seq": (),                  # sequence: replicated by default
    "act_seq_ckpt": ("model",),     # context-parallel fallback chunks
    "act_embed": (),                # d_model: replicated (norms are local)
    "act_vocab": ("model",),        # logits vocab dim
    "act_heads": ("model",),        # Q-head activations
    "act_kv_heads": ("model",),     # KV-head activations
    "act_kv_seq": ("model",),       # decode KV-cache sequence (flash-decode)
    "act_ff": ("model",),           # MLP hidden activations
    "act_expert": ("model",),       # MoE expert-parallel axis
    # -- fleet-monitoring axes -------------------------------------------
    "fleet_node": ("data",),        # detector node axis: peer-median rank
                                    # counts psum across node shards
}


class DistContext:
    """A mesh plus the rule table resolving logical axes onto it."""

    def __init__(self, mesh, rules: Optional[Rules] = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES) if rules is None else dict(rules)

    # ------------------------------------------------------- resolution

    def mesh_axes(self, name: Optional[str]) -> Tuple[str, ...]:
        """Mesh axes a logical name maps to, restricted to this mesh.

        Unknown names (and ``None``) resolve to () — replicated — so
        model code can use new logical names before a rule exists.
        """
        if name is None:
            return ()
        present = self.mesh.axis_names
        return tuple(a for a in self.rules.get(name, ()) if a in present)

    def axis_size(self, name: Optional[str]) -> int:
        """Total shard count of a logical axis on this mesh (1 = unmapped)."""
        return math.prod(self.mesh.shape[a] for a in self.mesh_axes(name))

    def spec(self, logical_axes: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None) -> P:
        """Resolve per-dim logical names to a PartitionSpec.

        ``shape`` (when given) enables the divisibility fallback; it must
        have the same rank as ``logical_axes``.
        """
        logical_axes = tuple(logical_axes)
        if shape is not None and len(shape) != len(logical_axes):
            raise ValueError(
                f"rank mismatch: axes {logical_axes} vs shape {tuple(shape)}")
        used: set = set()
        entries = []
        for i, name in enumerate(logical_axes):
            axes = self.mesh_axes(name)
            if not axes or used & set(axes):
                entries.append(None)
                continue
            size = math.prod(self.mesh.shape[a] for a in axes)
            if shape is not None and shape[i] % size != 0:
                entries.append(None)        # doesn't divide: replicate
                continue
            used.update(axes)
            entries.append(axes[0] if len(axes) == 1 else axes)
        return P(*entries)

    def sharding(self, logical_axes: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]] = None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical_axes, shape))


# ----------------------------------------------------------------- context

_context: Optional[DistContext] = None


def set_context(ctx: Optional[DistContext]) -> None:
    global _context
    _context = ctx


def current() -> Optional[DistContext]:
    return _context


@contextlib.contextmanager
def use_mesh(mesh, rules: Optional[Rules] = None):
    """Install a DistContext for the dynamic extent of the block.

    The prior context is restored on exit — including on exception — so
    nested meshes and failing tests can't leak sharding state.
    """
    prev = current()
    ctx = DistContext(mesh, rules)
    set_context(ctx)
    try:
        yield ctx
    finally:
        set_context(prev)


def constraint(x, *logical_axes: Optional[str]):
    """Apply a sharding constraint when a mesh context is active.

    Identity (returns ``x`` itself) without a context, so model code is
    unconditional; the divisibility fallback means a constraint can never
    make a layout invalid, only unconstrained.
    """
    ctx = current()
    if ctx is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, ctx.sharding(logical_axes, x.shape))


# Top-level jax.shard_map (and its check_vma kwarg) only exist on newer
# jax; 0.4.x has jax.experimental.shard_map.shard_map with the same
# semantics under check_rep. Resolve both once at import time.
_shard_map_fn = getattr(jax, "shard_map", None)
if _shard_map_fn is None:
    from jax.experimental.shard_map import shard_map as _shard_map_fn
_CHECK_KWARG = ("check_vma" if "check_vma" in
                inspect.signature(_shard_map_fn).parameters else "check_rep")


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-portable ``jax.shard_map``."""
    return _shard_map_fn(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, **{_CHECK_KWARG: check_vma})


# ------------------------------------------------------------ param trees


def _is_axes_leaf(x: Any) -> bool:
    """True for a logical-axes tuple like ("fsdp", None, "heads") or ().

    Distinguishes axes leaves from structural tuples (whose elements are
    themselves containers) in ``jax.tree.map`` traversals.
    """
    return isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)


def param_sharding(axes_tree, params_tree, ctx: Optional[DistContext] = None):
    """Axes tree + matching value tree -> tree of NamedShardings."""
    ctx = ctx if ctx is not None else current()
    if ctx is None:
        raise RuntimeError("param_sharding requires an active mesh context")
    return jax.tree.map(
        lambda ax, p: ctx.sharding(ax, p.shape),
        axes_tree, params_tree, is_leaf=_is_axes_leaf)


def gather_fsdp(tree, axes_tree):
    """ZeRO-3 just-in-time gather: re-constrain with "fsdp" dropped.

    Inside jit this compiles to an all-gather over the "data" axis right
    before the weights are consumed; other logical axes (TP/EP) keep
    their sharding. No-op without a context.
    """
    ctx = current()
    if ctx is None:
        return tree

    def gather(x, ax):
        gathered = tuple(None if a == "fsdp" else a for a in ax)
        return jax.lax.with_sharding_constraint(
            x, ctx.sharding(gathered, x.shape))

    return jax.tree.map(gather, tree, axes_tree)
