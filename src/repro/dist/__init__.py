"""Distribution layer: logical-axis sharding over jax meshes."""
from repro.dist.api import (DEFAULT_RULES, DistContext, constraint, current,
                            gather_fsdp, param_sharding, set_context,
                            shard_map, use_mesh)

__all__ = [
    "DEFAULT_RULES", "DistContext", "constraint", "current", "gather_fsdp",
    "param_sharding", "set_context", "shard_map", "use_mesh",
]
