"""Tiered response policy (§4.2).

Training step time — the user-visible signal — decides the response tier;
hardware metrics only ever *support* a verdict. The tiers trade mitigation
urgency against operational disruption:

  PENDING      no observable step impact (hardware signals only): keep the
               node in the job, mark pending-verification, watch closely.
  DEFER        moderate sustained slowdown (~10%): actionable, not urgent —
               mitigate at the NEXT CHECKPOINT to confirm the diagnosis
               without an extra restart.
  IMMEDIATE    severe (>=20%) degradation or a stall: restart now with a
               healthy replacement; the node leaves service for remediation.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional

from repro.core.detector import NodeAssessment


class Action(enum.Enum):
    NONE = "none"
    PENDING_VERIFICATION = "pending_verification"
    DEFER_TO_CHECKPOINT = "defer_to_checkpoint"
    IMMEDIATE_RESTART = "immediate_restart"


@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    moderate_slowdown: float = 0.10   # §4.2 "~10%"
    severe_slowdown: float = 0.20     # §4.2 ">=20%"


@dataclasses.dataclass
class Decision:
    node_id: int
    action: Action
    reason: str
    slowdown: float


class TieredPolicy:
    def __init__(self, cfg: Optional[PolicyConfig] = None):
        self.cfg = cfg or PolicyConfig()

    def decide(self, assessments: List[NodeAssessment]) -> List[Decision]:
        out = []
        for a in assessments:
            if not a.flagged:
                continue
            if a.stalled or a.slowdown >= self.cfg.severe_slowdown:
                act = Action.IMMEDIATE_RESTART
                why = "stall" if a.stalled else \
                    f"severe slowdown {a.slowdown:.0%}"
            elif a.slowdown >= self.cfg.moderate_slowdown:
                act = Action.DEFER_TO_CHECKPOINT
                why = f"moderate sustained slowdown {a.slowdown:.0%}"
            else:
                act = Action.PENDING_VERIFICATION
                why = ("hardware signals: " + ",".join(a.support)
                       if a.support else "marginal step deviation")
            out.append(Decision(a.node_id, act, why, a.slowdown))
        return out
