"""Tiered response policy (§4.2).

Training step time — the user-visible signal — decides the response tier;
hardware metrics only ever *support* a verdict. The tiers trade mitigation
urgency against operational disruption:

  PENDING      no observable step impact (hardware signals only): keep the
               node in the job, mark pending-verification, watch closely.
  DEFER        moderate sustained slowdown (~10%): actionable, not urgent —
               mitigate at the NEXT CHECKPOINT to confirm the diagnosis
               without an extra restart.
  IMMEDIATE    severe (>=20%) degradation or a stall: restart now with a
               healthy replacement; the node leaves service for remediation.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional, Sequence, Union

from repro.core.detector import FleetAssessment, NodeAssessment


class Action(enum.Enum):
    NONE = "none"
    PENDING_VERIFICATION = "pending_verification"
    DEFER_TO_CHECKPOINT = "defer_to_checkpoint"
    IMMEDIATE_RESTART = "immediate_restart"


@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    moderate_slowdown: float = 0.10   # §4.2 "~10%"
    severe_slowdown: float = 0.20     # §4.2 ">=20%"


@dataclasses.dataclass
class Decision:
    node_id: int
    action: Action
    reason: str
    slowdown: float


class TieredPolicy:
    def __init__(self, cfg: Optional[PolicyConfig] = None):
        self.cfg = cfg or PolicyConfig()

    def decide(self, assessments: Union[FleetAssessment,
                                        Sequence[NodeAssessment]]
               ) -> List[Decision]:
        if isinstance(assessments, FleetAssessment):
            return self._decide_fleet(assessments)
        return [self._decide_one(a.node_id, a.slowdown, a.stalled, a.support)
                for a in assessments if a.flagged]

    def _decide_fleet(self, fleet: FleetAssessment) -> List[Decision]:
        """Vectorized tier classification over the assessment arrays:
        only the flagged rows ever become Python objects."""
        idx = fleet.flagged_indices()
        if not idx.size:
            return []
        slowdown = fleet.slowdown[idx]
        stalled = fleet.stalled[idx]
        # tier codes for all flagged rows in one pass
        immediate = stalled | (slowdown >= self.cfg.severe_slowdown)
        deferred = ~immediate & (slowdown >= self.cfg.moderate_slowdown)
        out = []
        for j, i in enumerate(idx):
            support = None if immediate[j] or deferred[j] \
                else fleet.support_of(int(i))
            out.append(self._decide_one(
                int(fleet.node_ids[i]), float(slowdown[j]),
                bool(stalled[j]), support))
        return out

    def _decide_one(self, node_id: int, slowdown: float, stalled: bool,
                    support) -> Decision:
        if stalled or slowdown >= self.cfg.severe_slowdown:
            act = Action.IMMEDIATE_RESTART
            why = "stall" if stalled else f"severe slowdown {slowdown:.0%}"
        elif slowdown >= self.cfg.moderate_slowdown:
            act = Action.DEFER_TO_CHECKPOINT
            why = f"moderate sustained slowdown {slowdown:.0%}"
        else:
            act = Action.PENDING_VERIFICATION
            why = ("hardware signals: " + ",".join(support)
                   if support else "marginal step deviation")
        return Decision(node_id, act, why, slowdown)
