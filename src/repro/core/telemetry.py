# guardlint: hot  (fleet-sized arrays live here: float32, no per-node loops)
"""Telemetry schema, ring buffers and the Collector protocol (§4.1).

Guard consumes fleet telemetry through a single narrow interface — a
``Collector`` that yields one ``Frame`` per evaluation window. A Frame is a
set of named, vectorized per-node metric arrays; the detector never touches
the substrate underneath. On hardware the collector wraps the platform
monitoring agent (DCGM-equivalent) at a 30–60 s cadence; in this repo the
simulated fleet (``repro.simcluster``) implements the same protocol, so the
detection stack is deployable unchanged.

Metric catalogue (paper §4.1) — all per-node reductions over the node's
``devices_per_node`` accelerators / NICs:

  step_time     seconds this node took to reach the sync barrier (PRIMARY)
  gpu_temp      hottest device temperature, °C
  gpu_util      mean device utilization, [0, 1]
  gpu_freq      slowest device clock, GHz
  gpu_power     lowest device power draw, W
  nic_errors    summed NIC error counters over the window (retx, retries)
  nic_tx_rate   lowest per-NIC effective transmit rate, Gb/s
  nic_up        fraction of this node's NICs that are up, [0, 1]
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Protocol

import numpy as np

# Canonical metric names + the direction in which deviation is UNHEALTHY.
#   +1: higher-than-peers is bad      -1: lower-than-peers is bad
METRIC_DIRECTION: Dict[str, int] = {
    "step_time": +1,
    "gpu_temp": +1,
    "gpu_util": -1,
    "gpu_freq": -1,
    "gpu_power": -1,
    "nic_errors": +1,
    "nic_tx_rate": -1,
    "nic_up": -1,
}
METRICS: tuple = tuple(METRIC_DIRECTION)
HARDWARE_METRICS: tuple = tuple(m for m in METRICS if m != "step_time")


@dataclasses.dataclass
class Frame:
    """One evaluation window of fleet telemetry.

    Every metric is a float array of shape (num_nodes,) aligned with
    ``node_ids``. ``valid`` masks nodes that reported (False = no heartbeat,
    treated as a stall by the monitor)."""

    t: float                                 # sim/wall time, seconds
    step: int                                # global training step index
    node_ids: np.ndarray                     # (N,) int64
    metrics: Dict[str, np.ndarray]           # name -> (N,) float64
    valid: np.ndarray                        # (N,) bool

    def __post_init__(self):
        n = len(self.node_ids)
        for k, v in self.metrics.items():
            assert k in METRIC_DIRECTION, f"unknown metric {k}"
            assert v.shape == (n,), (k, v.shape, n)
        assert self.valid.shape == (n,)


class Collector(Protocol):
    """The substrate interface: one Frame per evaluation window."""

    def collect(self) -> Optional[Frame]:
        """Next telemetry frame, or None if the job has stopped."""
        ...


class RingHistory:
    """Fixed-depth per-metric history of fleet frames (vectorized).

    Preallocated circular ``(depth, N)`` buffers per metric: each ``push``
    writes one row in place instead of re-stacking frame lists, so the
    steady-state cost of keeping a 16k-node window is one row-copy per
    metric per evaluation window. Used by the detector for temporal
    (K-of-N window) filtering — those reductions are order-invariant, so
    the hot path reads the raw buffers via ``rows`` and only ``stacked``
    pays for chronological ordering."""

    def __init__(self, depth: int):
        self.depth = depth
        self._bufs: Dict[str, np.ndarray] = {}   # metric -> (depth, N)
        self._valid: Optional[np.ndarray] = None  # (depth, N) bool
        self._ids: Optional[np.ndarray] = None    # (N,) current node ids
        self._used = 0          # rows filled so far (<= depth)
        self._head = 0          # next row to (over)write
        self._last: Optional[Frame] = None
        self.generation = 0     # bumped on every (re)allocation
        self.last_backfill: Optional[np.ndarray] = None  # cols changed by
        # the most recent push's replacement backfill (None if none)

    def _alloc(self, frame: Frame) -> None:
        n = len(frame.node_ids)
        # float32: halves the resident window at 100k nodes and matches
        # the fleet_score kernel's end-to-end f32 contract
        self._bufs = {m: np.empty((self.depth, n), np.float32)
                      for m in frame.metrics}
        self._valid = np.empty((self.depth, n), bool)
        self._ids = frame.node_ids.copy()
        self._used = 0
        self._head = 0
        self.generation += 1

    def push(self, frame: Frame) -> None:
        self.last_backfill = None
        ids = self._ids
        if ids is None or len(frame.node_ids) != len(ids) or \
                set(frame.metrics) != set(self._bufs):
            # fleet resized (or metric schema changed): history no longer
            # aligns — restart.
            self._alloc(frame)
        elif not np.array_equal(frame.node_ids, ids):
            # node replacement: the new node must NOT inherit its
            # predecessor's history column (otherwise every freshly
            # swapped-in spare is instantly "sustained deviant" and a
            # replacement cascade follows). Backfill changed columns
            # with the new node's current readings; everyone else keeps
            # their window.
            changed = frame.node_ids != ids
            for m, buf in self._bufs.items():
                buf[:, changed] = frame.metrics[m][changed]
            self._valid[:, changed] = True
            self._ids = ids.copy()
            self._ids[changed] = frame.node_ids[changed]
            self.last_backfill = changed
        row = self._head
        for m, v in frame.metrics.items():
            self._bufs[m][row] = v
        self._valid[row] = frame.valid
        self._head = (row + 1) % self.depth
        self._used = min(self._used + 1, self.depth)
        self._last = frame

    @property
    def last_row(self) -> int:
        """Buffer row index the most recent push wrote."""
        return (self._head - 1) % self.depth

    @property
    def nbytes(self) -> int:
        """Resident bytes of the circular buffers (memory report)."""
        total = sum(b.nbytes for b in self._bufs.values())
        if self._valid is not None:
            total += self._valid.nbytes
        return total

    def __len__(self) -> int:
        return self._used

    @property
    def full(self) -> bool:
        return self._used == self.depth

    def rows(self, metric: str) -> np.ndarray:
        """(depth_used, N) raw buffer rows, in ARBITRARY window order.

        Zero-copy view for order-invariant temporal reductions (counts,
        sums, medians over the window axis). Callers must not mutate."""
        return self._bufs[metric][:self._used]

    def rows_raw(self, metric: str) -> np.ndarray:
        """(depth, N) full backing buffer (rows beyond ``len(self)`` are
        uninitialized). For row-indexed score caches; do not mutate."""
        return self._bufs[metric]

    def metric_names(self) -> tuple:
        return tuple(self._bufs)

    def rows_valid(self) -> np.ndarray:
        return self._valid[:self._used]

    def stacked(self, metric: str) -> np.ndarray:
        """(depth_used, N) history for one metric, oldest row first."""
        return self._bufs[metric][self._order()]

    def stacked_valid(self) -> np.ndarray:
        return self._valid[self._order()]

    def _order(self) -> np.ndarray:
        if self._used < self.depth:
            return np.arange(self._used)
        return (self._head + np.arange(self.depth)) % self.depth

    def last(self) -> Frame:
        if self._last is None:
            raise IndexError("empty history")
        return self._last

    def clear(self) -> None:
        self._used = 0
        self._head = 0
        self._last = None


def reduce_device_metrics(
    temps: np.ndarray, utils: np.ndarray, freqs: np.ndarray,
    powers: np.ndarray, nic_err: np.ndarray, nic_tx: np.ndarray,
    nic_up: np.ndarray,
) -> Dict[str, np.ndarray]:
    """Per-device (N, D) arrays -> per-node (N,) metric dict.

    Reductions pick the WORST device per node (hottest / slowest / weakest),
    because a single degraded device gates the node's collectives (§3.3)."""
    return {
        "gpu_temp": temps.max(axis=1),
        "gpu_util": utils.mean(axis=1),
        "gpu_freq": freqs.min(axis=1),
        "gpu_power": powers.min(axis=1),
        "nic_errors": nic_err.sum(axis=1),
        "nic_tx_rate": nic_tx.min(axis=1),
        "nic_up": nic_up.mean(axis=1),
    }
