"""Telemetry schema, ring buffers and the Collector protocol (§4.1).

Guard consumes fleet telemetry through a single narrow interface — a
``Collector`` that yields one ``Frame`` per evaluation window. A Frame is a
set of named, vectorized per-node metric arrays; the detector never touches
the substrate underneath. On hardware the collector wraps the platform
monitoring agent (DCGM-equivalent) at a 30–60 s cadence; in this repo the
simulated fleet (``repro.simcluster``) implements the same protocol, so the
detection stack is deployable unchanged.

Metric catalogue (paper §4.1) — all per-node reductions over the node's
``devices_per_node`` accelerators / NICs:

  step_time     seconds this node took to reach the sync barrier (PRIMARY)
  gpu_temp      hottest device temperature, °C
  gpu_util      mean device utilization, [0, 1]
  gpu_freq      slowest device clock, GHz
  gpu_power     lowest device power draw, W
  nic_errors    summed NIC error counters over the window (retx, retries)
  nic_tx_rate   lowest per-NIC effective transmit rate, Gb/s
  nic_up        fraction of this node's NICs that are up, [0, 1]
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, Optional, Protocol

import numpy as np

# Canonical metric names + the direction in which deviation is UNHEALTHY.
#   +1: higher-than-peers is bad      -1: lower-than-peers is bad
METRIC_DIRECTION: Dict[str, int] = {
    "step_time": +1,
    "gpu_temp": +1,
    "gpu_util": -1,
    "gpu_freq": -1,
    "gpu_power": -1,
    "nic_errors": +1,
    "nic_tx_rate": -1,
    "nic_up": -1,
}
METRICS: tuple = tuple(METRIC_DIRECTION)
HARDWARE_METRICS: tuple = tuple(m for m in METRICS if m != "step_time")


@dataclasses.dataclass
class Frame:
    """One evaluation window of fleet telemetry.

    Every metric is a float array of shape (num_nodes,) aligned with
    ``node_ids``. ``valid`` masks nodes that reported (False = no heartbeat,
    treated as a stall by the monitor)."""

    t: float                                 # sim/wall time, seconds
    step: int                                # global training step index
    node_ids: np.ndarray                     # (N,) int64
    metrics: Dict[str, np.ndarray]           # name -> (N,) float64
    valid: np.ndarray                        # (N,) bool

    def __post_init__(self):
        n = len(self.node_ids)
        for k, v in self.metrics.items():
            assert k in METRIC_DIRECTION, f"unknown metric {k}"
            assert v.shape == (n,), (k, v.shape, n)
        assert self.valid.shape == (n,)


class Collector(Protocol):
    """The substrate interface: one Frame per evaluation window."""

    def collect(self) -> Optional[Frame]:
        """Next telemetry frame, or None if the job has stopped."""
        ...


class RingHistory:
    """Fixed-depth per-metric history of fleet frames (vectorized).

    Stores the last ``depth`` frames as stacked (depth, N) arrays per metric;
    used by the detector for temporal (K-of-N window) filtering."""

    def __init__(self, depth: int):
        self.depth = depth
        self._frames: Deque[Frame] = deque(maxlen=depth)

    def push(self, frame: Frame) -> None:
        if self._frames:
            last_ids = self._frames[-1].node_ids
            if len(frame.node_ids) != len(last_ids):
                # fleet resized: history no longer aligns — restart.
                self._frames.clear()
            elif not np.array_equal(frame.node_ids, last_ids):
                # node replacement: the new node must NOT inherit its
                # predecessor's history column (otherwise every freshly
                # swapped-in spare is instantly "sustained deviant" and a
                # replacement cascade follows). Backfill changed columns
                # with the new node's current readings; everyone else keeps
                # their window.
                changed = frame.node_ids != last_ids
                for f in self._frames:
                    for m, vals in f.metrics.items():
                        if m in frame.metrics:
                            vals[changed] = frame.metrics[m][changed]
                    f.valid[changed] = True
                    f.node_ids = f.node_ids.copy()
                    f.node_ids[changed] = frame.node_ids[changed]
        self._frames.append(frame)

    def __len__(self) -> int:
        return len(self._frames)

    @property
    def full(self) -> bool:
        return len(self._frames) == self.depth

    def stacked(self, metric: str) -> np.ndarray:
        """(depth_used, N) history for one metric."""
        return np.stack([f.metrics[metric] for f in self._frames])

    def stacked_valid(self) -> np.ndarray:
        return np.stack([f.valid for f in self._frames])

    def last(self) -> Frame:
        return self._frames[-1]

    def clear(self) -> None:
        self._frames.clear()


def reduce_device_metrics(
    temps: np.ndarray, utils: np.ndarray, freqs: np.ndarray,
    powers: np.ndarray, nic_err: np.ndarray, nic_tx: np.ndarray,
    nic_up: np.ndarray,
) -> Dict[str, np.ndarray]:
    """Per-device (N, D) arrays -> per-node (N,) metric dict.

    Reductions pick the WORST device per node (hottest / slowest / weakest),
    because a single degraded device gates the node's collectives (§3.3)."""
    return {
        "gpu_temp": temps.max(axis=1),
        "gpu_util": utils.mean(axis=1),
        "gpu_freq": freqs.min(axis=1),
        "gpu_power": powers.min(axis=1),
        "nic_errors": nic_err.sum(axis=1),
        "nic_tx_rate": nic_tx.min(axis=1),
        "nic_up": nic_up.mean(axis=1),
    }
