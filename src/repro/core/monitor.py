"""Online node-health monitoring loop (§4).

``OnlineMonitor`` consumes telemetry Frames (from any Collector), runs the
peer-relative detector and the tiered policy, and emits ``HealthEvent``s for
the health manager to act on. It is deliberately thin: all intelligence lives
in the detector/policy so this loop stays lightweight and non-intrusive —
the paper's requirement for running it against production jobs. The whole
window is processed on the detector's ``FleetAssessment`` arrays; per-node
records are materialized only for the nodes that generated decisions.

An optional ``Diagnoser`` (``repro.diagnose``) sits BETWEEN the detector
and the policy: it attributes each flagged node to a root cause via
what-if counterfactual replay, and mitigation decisions against nodes it
holds (cascade victims stalled behind a culprit, transient congestion)
are downgraded to pending-verification — watched, not evicted.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from repro.core.detector import (DetectorConfig, FleetAssessment,
                                 NodeAssessment, StragglerDetector)
from repro.core.policy import Action, Decision, PolicyConfig, TieredPolicy
from repro.core.telemetry import Frame


@dataclasses.dataclass
class HealthEvent:
    t: float
    step: int
    decision: Decision
    assessment: NodeAssessment


class OnlineMonitor:
    def __init__(self,
                 detector_cfg: Optional[DetectorConfig] = None,
                 policy_cfg: Optional[PolicyConfig] = None,
                 on_event: Optional[Callable[[HealthEvent], None]] = None,
                 diagnoser=None):
        self.detector = StragglerDetector(detector_cfg)
        self.policy = TieredPolicy(policy_cfg)
        self.on_event = on_event
        # optional repro.diagnose.Diagnoser (duck-typed so repro.core
        # keeps zero dependency on the diagnosis package)
        self.diagnoser = diagnoser
        self.events: List[HealthEvent] = []
        # nodes currently marked pending-verification (watched closely)
        self.pending: Dict[int, float] = {}
        self.last_assessment: Optional[FleetAssessment] = None
        self.last_diagnosis = None

    def observe(self, frame: Frame) -> List[HealthEvent]:
        """Process one evaluation window; returns new events."""
        fleet = self.detector.update(frame)
        self.last_assessment = fleet
        diag = None
        if self.diagnoser is not None:
            diag = self.diagnoser.diagnose(frame, fleet)
        self.last_diagnosis = diag
        new: List[HealthEvent] = []
        for d in self.policy.decide(fleet):
            if diag is not None:
                d = diag.reroute(d)
            if d.action == Action.PENDING_VERIFICATION:
                # record once; re-emit only on escalation
                if d.node_id in self.pending:
                    continue
                self.pending[d.node_id] = frame.t
            else:
                self.pending.pop(d.node_id, None)
            idx = fleet.index_of(d.node_id)
            ev = HealthEvent(frame.t, frame.step, d, fleet.node(idx))
            new.append(ev)
            self.events.append(ev)
            if self.on_event:
                self.on_event(ev)
        # drop pending marks for nodes that cleared
        for nid in list(self.pending):
            cleared = fleet.flagged_of(nid)
            if cleared is False:          # None = node left the frame
                del self.pending[nid]
        return new

    def node_replaced(self, node_id: int) -> None:
        self.detector.reset_node(node_id)
        self.pending.pop(node_id, None)
        if self.diagnoser is not None:
            self.diagnoser.node_replaced(node_id)
