"""Online node-health monitoring loop (§4).

``OnlineMonitor`` consumes telemetry Frames (from any Collector), runs the
peer-relative detector and the tiered policy, and emits ``HealthEvent``s for
the health manager to act on. It is deliberately thin: all intelligence lives
in the detector/policy so this loop stays lightweight and non-intrusive —
the paper's requirement for running it against production jobs. The whole
window is processed on the detector's ``FleetAssessment`` arrays; per-node
records are materialized only for the nodes that generated decisions.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from repro.core.detector import (DetectorConfig, FleetAssessment,
                                 NodeAssessment, StragglerDetector)
from repro.core.policy import Action, Decision, PolicyConfig, TieredPolicy
from repro.core.telemetry import Frame


@dataclasses.dataclass
class HealthEvent:
    t: float
    step: int
    decision: Decision
    assessment: NodeAssessment


class OnlineMonitor:
    def __init__(self,
                 detector_cfg: Optional[DetectorConfig] = None,
                 policy_cfg: Optional[PolicyConfig] = None,
                 on_event: Optional[Callable[[HealthEvent], None]] = None):
        self.detector = StragglerDetector(detector_cfg)
        self.policy = TieredPolicy(policy_cfg)
        self.on_event = on_event
        self.events: List[HealthEvent] = []
        # nodes currently marked pending-verification (watched closely)
        self.pending: Dict[int, float] = {}
        self.last_assessment: Optional[FleetAssessment] = None

    def observe(self, frame: Frame) -> List[HealthEvent]:
        """Process one evaluation window; returns new events."""
        fleet = self.detector.update(frame)
        self.last_assessment = fleet
        new: List[HealthEvent] = []
        for d in self.policy.decide(fleet):
            if d.action == Action.PENDING_VERIFICATION:
                # record once; re-emit only on escalation
                if d.node_id in self.pending:
                    continue
                self.pending[d.node_id] = frame.t
            else:
                self.pending.pop(d.node_id, None)
            idx = fleet.index_of(d.node_id)
            ev = HealthEvent(frame.t, frame.step, d, fleet.node(idx))
            new.append(ev)
            self.events.append(ev)
            if self.on_event:
                self.on_event(ev)
        # drop pending marks for nodes that cleared
        for nid in list(self.pending):
            cleared = fleet.flagged_of(nid)
            if cleared is False:          # None = node left the frame
                del self.pending[nid]
        return new

    def node_replaced(self, node_id: int) -> None:
        self.detector.reset_node(node_id)
        self.pending.pop(node_id, None)
