"""Peer-relative, multi-signal, temporally-filtered straggler detection (§4.2).

The detector never uses absolute thresholds. Every metric is scored against
the *peer baseline* of nodes in the same job via robust statistics
(median / MAD z-scores), which adapts to workload characteristics and
hardware heterogeneity for free. A node is flagged only when

  1. its PRIMARY signal (step_time) shows a sustained relative slowdown, OR
  2. multiple SUPPORTING hardware signals deviate together (pending
     verification tier — no step impact yet),

and the deviation persists for >= K of the last N evaluation windows
(temporal filter). Hysteresis: once flagged, a node needs ``clear_windows``
consecutive clean windows to unflag, preventing oscillation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.telemetry import (HARDWARE_METRICS, METRIC_DIRECTION, Frame,
                                  RingHistory)


@dataclasses.dataclass(frozen=True)
class DetectorConfig:
    """Defaults are the paper's 'moderately conservative' operating point
    (§6.1): aggressive enough to catch mild greys early (low FNR) at the
    price of a double-digit FPR — acceptable because early remediation
    stages are lightweight and reversible (Table 3: FPR 12.4%, FNR 7.8%)."""
    window: int = 6              # N: evaluation windows kept for filtering
    persistence: int = 3         # K: windows (of N) a signal must deviate
    z_threshold: float = 3.0     # robust z beyond which a signal deviates
    min_support: int = 2         # hardware signals required for hw-only flag
    slowdown_floor: float = 0.025  # relative step-time excess that counts
    stall_factor: float = 5.0    # step_time > stall_factor x median = stall
    clear_windows: int = 3       # hysteresis: clean windows to unflag
    mad_floor_frac: float = 0.01 # MAD floor as a fraction of the median


@dataclasses.dataclass
class NodeAssessment:
    """Detector verdict for one node in one evaluation window."""
    node_id: int
    slowdown: float              # sustained relative step-time excess (>=0)
    stalled: bool
    support: List[str]           # hardware metrics in sustained deviation
    step_deviant: bool           # primary signal sustained deviation
    flagged: bool                # overall verdict after temporal filtering


def robust_z(values: np.ndarray, axis: int = -1,
             mad_floor: float = 1e-9) -> np.ndarray:
    """Median/MAD z-score along ``axis`` (peer axis). 0.6745 ~ Φ⁻¹(3/4)."""
    med = np.median(values, axis=axis, keepdims=True)
    mad = np.median(np.abs(values - med), axis=axis, keepdims=True)
    scale = np.maximum(mad / 0.6745, mad_floor)
    return (values - med) / scale


class StragglerDetector:
    """Stateful fleet-wide detector; feed one Frame per evaluation window."""

    def __init__(self, cfg: Optional[DetectorConfig] = None):
        self.cfg = cfg or DetectorConfig()
        self.history = RingHistory(self.cfg.window)
        self._clean_streak: Dict[int, int] = {}
        self._latched: Dict[int, bool] = {}

    # ------------------------------------------------------------ core

    def _deviation_matrix(self, metric: str) -> np.ndarray:
        """(depth, N) bool: windows where node deviates unhealthily."""
        cfg = self.cfg
        hist = self.history.stacked(metric)              # (depth, N)
        direction = METRIC_DIRECTION[metric]
        med = np.median(hist, axis=1, keepdims=True)
        floor = np.maximum(np.abs(med) * cfg.mad_floor_frac, 1e-9)
        z = robust_z(hist, axis=1, mad_floor=floor) * direction
        return z > cfg.z_threshold

    def update(self, frame: Frame) -> List[NodeAssessment]:
        cfg = self.cfg
        self.history.push(frame)
        n = len(frame.node_ids)
        depth = len(self.history)
        # "sustained" requires a full persistence window of history; until
        # then only stalls can flag (fresh jobs / post-replacement re-baseline)
        warmed = depth >= cfg.persistence
        need = cfg.persistence if warmed else depth + 1  # unattainable early

        # --- primary signal: sustained relative step-time excess
        st_hist = self.history.stacked("step_time")      # (depth, N)
        med = np.median(st_hist, axis=1, keepdims=True)
        rel = st_hist / np.maximum(med, 1e-9) - 1.0
        step_dev_w = self._deviation_matrix("step_time") & \
            (rel > cfg.slowdown_floor)
        dev_count = step_dev_w.sum(0)
        step_deviant = dev_count >= need
        # sustained slowdown magnitude: mean over deviant windows
        slow_sum = np.where(step_dev_w, rel, 0.0).sum(0)
        slowdown = np.where(step_deviant,
                            slow_sum / np.maximum(dev_count, 1), 0.0)

        # --- stalls: no heartbeat or grossly inflated latest step
        last = self.history.last()
        stalled = (~last.valid) | (
            last.metrics["step_time"] >
            cfg.stall_factor * np.median(last.metrics["step_time"]))

        # --- supporting hardware signals (sustained)
        support_masks = {}
        for m in HARDWARE_METRICS:
            if m in last.metrics:
                dev = self._deviation_matrix(m)
                support_masks[m] = dev.sum(0) >= need

        support_count = np.zeros(n, dtype=int)
        for mask in support_masks.values():
            support_count += mask.astype(int)

        raw_flag = stalled | step_deviant | (support_count >= cfg.min_support)

        out: List[NodeAssessment] = []
        for i, nid in enumerate(frame.node_ids):
            nid = int(nid)
            latched = self._latched.get(nid, False)
            if raw_flag[i]:
                self._clean_streak[nid] = 0
                latched = True
            elif latched:
                streak = self._clean_streak.get(nid, 0) + 1
                self._clean_streak[nid] = streak
                if streak >= cfg.clear_windows:
                    latched = False
            self._latched[nid] = latched
            out.append(NodeAssessment(
                node_id=nid,
                slowdown=float(slowdown[i]),
                stalled=bool(stalled[i]),
                support=[m for m, msk in support_masks.items() if msk[i]],
                step_deviant=bool(step_deviant[i]),
                flagged=latched,
            ))
        return out

    def is_latched(self, node_id: int) -> bool:
        """Public latch query: is this node currently flagged (with
        hysteresis)? The health manager's deferred-swap confirmation and
        any external trace/UI consumer must use this instead of reaching
        into detector internals."""
        return self._latched.get(node_id, False)

    def latched_nodes(self) -> List[int]:
        """All currently latched node ids (sorted, for stable iteration)."""
        return sorted(n for n, v in self._latched.items() if v)

    def reset_node(self, node_id: int) -> None:
        """Forget latch state (node replaced/repaired)."""
        self._latched.pop(node_id, None)
        self._clean_streak.pop(node_id, None)
