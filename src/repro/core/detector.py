# guardlint: hot  (fleet-sized arrays live here: float32, no per-node loops)
"""Peer-relative, multi-signal, temporally-filtered straggler detection (§4.2).

The detector never uses absolute thresholds. Every metric is scored against
the *peer baseline* of nodes in the same job via robust statistics
(median / MAD z-scores), which adapts to workload characteristics and
hardware heterogeneity for free. A node is flagged only when

  1. its PRIMARY signal (step_time) shows a sustained relative slowdown, OR
  2. multiple SUPPORTING hardware signals deviate together (pending
     verification tier — no step impact yet),

and the deviation persists for >= K of the last N evaluation windows
(temporal filter). Hysteresis: once flagged, a node needs ``clear_windows``
consecutive clean windows to unflag, preventing oscillation.

The hot path is array-native: ``StragglerDetector.update`` returns a
struct-of-arrays ``FleetAssessment`` whose latch / clean-streak state is
held as node-indexed arrays, so one 16k-node evaluation window costs a
fixed number of numpy reductions and O(flagged) Python objects — per-node
``NodeAssessment`` records are materialized lazily, and only for the
consumers that ask for them.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.core.telemetry import (HARDWARE_METRICS, METRIC_DIRECTION, Frame,
                                  RingHistory)
from repro.kernels.fleet_score import score_rows


@dataclasses.dataclass(frozen=True)
class DetectorConfig:
    """Defaults are the paper's 'moderately conservative' operating point
    (§6.1): aggressive enough to catch mild greys early (low FNR) at the
    price of a double-digit FPR — acceptable because early remediation
    stages are lightweight and reversible (Table 3: FPR 12.4%, FNR 7.8%)."""
    window: int = 6              # N: evaluation windows kept for filtering
    persistence: int = 3         # K: windows (of N) a signal must deviate
    z_threshold: float = 3.0     # robust z beyond which a signal deviates
    min_support: int = 2         # hardware signals required for hw-only flag
    slowdown_floor: float = 0.025  # relative step-time excess that counts
    stall_factor: float = 5.0    # step_time > stall_factor x median = stall
    clear_windows: int = 3       # hysteresis: clean windows to unflag
    mad_floor_frac: float = 0.01 # MAD floor as a fraction of the median
    scorer: str = "numpy"        # fleet_score backend: numpy | jax | pallas


@dataclasses.dataclass
class NodeAssessment:
    """Detector verdict for one node in one evaluation window."""
    node_id: int
    slowdown: float              # sustained relative step-time excess (>=0)
    stalled: bool
    support: List[str]           # hardware metrics in sustained deviation
    step_deviant: bool           # primary signal sustained deviation
    flagged: bool                # overall verdict after temporal filtering


class FleetAssessment:
    """One evaluation window's verdicts for the whole fleet, as arrays.

    Every field is aligned with ``node_ids``; per-node ``NodeAssessment``
    objects exist only when a consumer materializes them (``node``,
    ``flagged_assessments``, or the sequence protocol, which older
    callers use transparently). ``materialized`` counts how many were
    built — the scale benchmark asserts it stays O(flagged)."""

    __slots__ = ("node_ids", "slowdown", "stalled", "step_deviant",
                 "support_masks", "flagged", "materialized", "_index")

    def __init__(self, node_ids: np.ndarray, slowdown: np.ndarray,
                 stalled: np.ndarray, step_deviant: np.ndarray,
                 support_masks: Dict[str, np.ndarray],
                 flagged: np.ndarray):
        self.node_ids = node_ids
        self.slowdown = slowdown
        self.stalled = stalled
        self.step_deviant = step_deviant
        self.support_masks = support_masks
        self.flagged = flagged
        self.materialized = 0
        self._index: Optional[Dict[int, int]] = None

    # ------------------------------------------------------ array queries

    def flagged_indices(self) -> np.ndarray:
        return np.flatnonzero(self.flagged)

    def flagged_ids(self) -> np.ndarray:
        return self.node_ids[self.flagged]

    def index_of(self, node_id: int) -> Optional[int]:
        # vectorized scan: callers look up O(flagged) ids per window, so a
        # full dict build would dwarf the lookups themselves
        hit = np.flatnonzero(self.node_ids == node_id)
        return int(hit[0]) if hit.size else None

    def flagged_of(self, node_id: int) -> Optional[bool]:
        """Latched verdict for one node id; None if not in this frame."""
        i = self.index_of(node_id)
        return None if i is None else bool(self.flagged[i])

    # ---------------------------------------------- lazy materialization

    def support_of(self, i: int) -> List[str]:
        return [m for m, msk in self.support_masks.items() if msk[i]]

    def node(self, i: int) -> NodeAssessment:
        """Materialize the per-node record for row ``i``."""
        self.materialized += 1
        return NodeAssessment(
            node_id=int(self.node_ids[i]),
            slowdown=float(self.slowdown[i]),
            stalled=bool(self.stalled[i]),
            support=self.support_of(i),
            step_deviant=bool(self.step_deviant[i]),
            flagged=bool(self.flagged[i]),
        )

    def flagged_assessments(self) -> List[NodeAssessment]:
        return [self.node(int(i)) for i in self.flagged_indices()]

    # -------------------------------------------------- sequence protocol
    # Compatibility with the pre-vectorization API, where update()
    # returned List[NodeAssessment]: indexing/iteration materialize
    # records on demand, so old-style consumers keep working while the
    # hot path stays allocation-free.

    def __len__(self) -> int:
        return len(self.node_ids)

    def __getitem__(self, i: int) -> NodeAssessment:
        return self.node(i)

    def __iter__(self) -> Iterator[NodeAssessment]:
        # guardlint: disable=GL003 reason=compat sequence protocol for
        # old-style consumers; the hot path reads the arrays directly
        for i in range(len(self.node_ids)):
            yield self.node(i)


def robust_z(values: np.ndarray, axis: int = -1,
             mad_floor: float = 1e-9) -> np.ndarray:
    """Median/MAD z-score along ``axis`` (peer axis). 0.6745 ~ Φ⁻¹(3/4)."""
    med = np.median(values, axis=axis, keepdims=True)
    mad = np.median(np.abs(values - med), axis=axis, keepdims=True)
    scale = np.maximum(mad / 0.6745, mad_floor)
    return (values - med) / scale


class StragglerDetector:
    """Stateful fleet-wide detector; feed one Frame per evaluation window."""

    def __init__(self, cfg: Optional[DetectorConfig] = None):
        self.cfg = cfg or DetectorConfig()
        self.history = RingHistory(self.cfg.window)
        # latch / clean-streak state as node-indexed arrays aligned with
        # the last frame's node_ids; ids that left the frame park their
        # state in _off until reset_node forgets them (same semantics as
        # the old per-id dicts, without per-window dict traffic)
        self._state_ids: Optional[np.ndarray] = None
        self._latched: Optional[np.ndarray] = None
        self._clean: Optional[np.ndarray] = None
        self._off: Dict[int, tuple] = {}   # id -> (latched, clean_streak)
        # per-row score caches aligned with the ring buffers: each history
        # row's peer-relative deviation verdicts never change once scored
        # (peer medians are within-row), so one window costs one new row of
        # medians instead of depth x metrics of them. Replacement backfill
        # and reallocation rescore everything (rare). All caches are
        # float32 end-to-end — the fleet_score kernel contract.
        self._gen = -1                      # history generation scored
        self._dev3: Optional[np.ndarray] = None  # (M, depth, N) bool
        self._rel: Optional[np.ndarray] = None  # (depth, N) step_time rel
        self._contrib: Optional[np.ndarray] = None  # (depth, N) masked rel
        self._metric_list: List[str] = []
        self._dirs: tuple = ()
        self._st_j: Optional[int] = None
        self._rows_mat: Optional[np.ndarray] = None  # (depth, M, N) scratch

    # ------------------------------------------------------------ core

    def _score_rows(self, rows: np.ndarray) -> None:
        """Score ring-buffer rows (peer-relative robust-z deviation +
        step-time relative excess) in one fused (R, M, N) pass through
        ``repro.kernels.fleet_score`` — every op reduces along the peer
        axis independently, so batching rows changes no verdict."""
        cfg = self.cfg
        mats = self._rows_mat[:len(rows)]          # (R, M, N) f32 scratch
        for j, m in enumerate(self._metric_list):
            mats[:, j] = self.history.rows_raw(m)[rows]
        dev, rel, contrib = score_rows(
            mats, self._dirs, self._st_j,
            z_threshold=cfg.z_threshold, slowdown_floor=cfg.slowdown_floor,
            mad_floor_frac=cfg.mad_floor_frac, backend=cfg.scorer)
        self._dev3[:, rows] = np.swapaxes(dev, 0, 1)
        if self._st_j is not None:
            self._rel[rows] = rel
            self._contrib[rows] = contrib

    def _sync_scores(self) -> None:
        """Bring the per-row caches up to date after a push."""
        hist = self.history
        if hist.generation != self._gen:
            self._gen = hist.generation
            n = len(hist.last().node_ids)
            m = len(hist.metric_names())
            self._metric_list = list(hist.metric_names())
            self._dirs = tuple(float(METRIC_DIRECTION[k])
                               for k in self._metric_list)
            self._metric_idx = {k: j
                                for j, k in enumerate(self._metric_list)}
            self._st_j = self._metric_idx.get("step_time")
            self._rows_mat = np.empty((hist.depth, m, n), np.float32)
            self._dev3 = np.empty((m, hist.depth, n), bool)
            self._rel = np.empty((hist.depth, n), np.float32)
            self._contrib = np.empty((hist.depth, n), np.float32)
            rows = np.arange(len(hist))
        elif hist.last_backfill is not None:
            rows = np.arange(len(hist))      # backfill rescored everything
        else:
            rows = np.asarray([hist.last_row])
        self._score_rows(rows)

    def _realign_state(self, node_ids: np.ndarray) -> None:
        """Carry latch state over a fleet membership change by id."""
        old_ids, old_latch, old_clean = \
            self._state_ids, self._latched, self._clean
        n = len(node_ids)
        self._latched = np.zeros(n, bool)
        self._clean = np.zeros(n, np.int64)
        if old_ids is not None and len(old_ids) == n:
            # typical case: a few replaced columns — bulk-copy the rest
            same = old_ids == node_ids
            self._latched[same] = old_latch[same]
            self._clean[same] = old_clean[same]
            moved = np.flatnonzero(~same)
        elif old_ids is not None:
            moved = np.arange(len(old_ids))
        else:
            moved = np.arange(0)
        for i in moved:                       # departing ids park in _off
            self._off[int(old_ids[i])] = (bool(old_latch[i]),
                                          int(old_clean[i]))
        if self._off:
            joins = moved if old_ids is not None and len(old_ids) == n \
                else np.arange(n)
            for i in joins:                   # rejoining ids resume state
                st = self._off.pop(int(node_ids[i]), None)
                if st is not None:
                    self._latched[i], self._clean[i] = st
        self._state_ids = node_ids.copy()

    def update(self, frame: Frame) -> FleetAssessment:
        cfg = self.cfg
        self.history.push(frame)
        self._sync_scores()
        depth = len(self.history)
        used = slice(0, depth)
        # "sustained" requires a full persistence window of history; until
        # then only stalls can flag (fresh jobs / post-replacement re-baseline)
        warmed = depth >= cfg.persistence
        need = cfg.persistence if warmed else depth + 1  # unattainable early

        # --- primary signal: sustained relative step-time excess
        # (one stacked reduction covers every metric's deviation counts)
        all_counts = self._dev3[:, used].sum(1)          # (M, N)
        dev_count = all_counts[self._st_j]
        step_deviant = dev_count >= need
        # sustained slowdown magnitude: mean over deviant windows. The
        # masked sum runs in chronological window order so it is
        # bit-stable against the ring buffer's write position.
        order = self.history._order()
        slow_sum = self._contrib[order].sum(0)
        slowdown = np.where(
            step_deviant,
            slow_sum / np.maximum(dev_count, 1).astype(np.float32),
            np.float32(0.0))

        # --- stalls: no heartbeat or grossly inflated latest step
        last = self.history.last()
        stalled = (~last.valid) | (
            last.metrics["step_time"] >
            cfg.stall_factor * np.median(last.metrics["step_time"]))

        # --- supporting hardware signals (sustained)
        support_masks = {}
        support_count = np.zeros(len(frame.node_ids), dtype=int)
        for m in HARDWARE_METRICS:
            if m in self._metric_idx:
                mask = all_counts[self._metric_idx[m]] >= need
                support_masks[m] = mask
                support_count += mask

        raw_flag = stalled | step_deviant | (support_count >= cfg.min_support)

        # --- hysteresis latch, vectorized over node-indexed state arrays
        if self._state_ids is None or \
                not np.array_equal(self._state_ids, frame.node_ids):
            self._realign_state(frame.node_ids)
        latched, clean = self._latched, self._clean
        clean[:] = np.where(raw_flag, 0,
                            np.where(latched, clean + 1, clean))
        latched[:] = raw_flag | (latched & (clean < cfg.clear_windows))

        return FleetAssessment(
            node_ids=frame.node_ids, slowdown=slowdown, stalled=stalled,
            step_deviant=step_deviant, support_masks=support_masks,
            flagged=latched.copy())

    # ------------------------------------------------------- latch queries

    def latched_many(self, ids: np.ndarray) -> np.ndarray:
        """Vectorized ``is_latched`` over an id array: O(latched + len)
        numpy set membership instead of one fleet scan (or one Python
        set probe) per query."""
        ids = np.asarray(ids)
        out = np.zeros(len(ids), bool)
        if self._state_ids is not None and self._latched.any():
            out |= np.isin(ids, self._state_ids[self._latched])
        off_lat = [n for n, st in self._off.items() if st[0]]
        if off_lat:
            out |= np.isin(ids, np.asarray(off_lat, dtype=ids.dtype))
        return out

    def is_latched(self, node_id: int) -> bool:
        """Public latch query: is this node currently flagged (with
        hysteresis)? The health manager's deferred-swap confirmation and
        any external trace/UI consumer must use this instead of reaching
        into detector internals."""
        if self._state_ids is not None:
            hit = np.flatnonzero(self._state_ids == node_id)
            if hit.size:
                return bool(self._latched[hit[0]])
        st = self._off.get(int(node_id))
        return bool(st[0]) if st is not None else False

    def latched_nodes(self) -> List[int]:
        """All currently latched node ids (sorted, for stable iteration)."""
        ids = set()
        if self._state_ids is not None:
            ids.update(int(n) for n in self._state_ids[self._latched])
        ids.update(n for n, st in self._off.items() if st[0])
        return sorted(ids)

    def memory_nbytes(self) -> int:
        """Resident detector footprint: ring buffers, score caches,
        scratch and latch arrays (the scale benchmark's memory report)."""
        total = self.history.nbytes
        for a in (self._rows_mat, self._dev3, self._rel, self._contrib,
                  self._latched, self._clean, self._state_ids):
            if a is not None:
                total += a.nbytes
        return total

    def reset_node(self, node_id: int) -> None:
        """Forget latch state (node replaced/repaired)."""
        self._off.pop(int(node_id), None)
        if self._state_ids is not None:
            hit = np.flatnonzero(self._state_ids == node_id)
            if hit.size:
                self._latched[hit[0]] = False
                self._clean[hit[0]] = 0
