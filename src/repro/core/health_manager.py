"""Closed-loop node health management (Fig. 1).

Glues the pieces together: online monitoring emits HealthEvents; the manager
applies the tiered policy's action against the cluster (swap now / swap at
checkpoint / watch), quarantines suspects, drives the event-driven offline
qualification (sweep -> triage -> sweep ...) and returns qualified nodes to
the healthy pool. All substrate access goes through ``ClusterControl`` so
the loop is identical over the simulator and a real fleet control plane.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Protocol

from repro.core.monitor import HealthEvent, OnlineMonitor
from repro.core.policy import Action
from repro.core.sweep import SweepBackend, SweepConfig, qualification_sweep
from repro.core.triage import (ErrorSignals, TriageConfig, TriageOutcome,
                               TriageWorkflow)


class NodeState(enum.Enum):
    ACTIVE = "active"              # serving the training job
    PENDING = "pending"            # in job, flagged pending-verification
    QUARANTINED = "quarantined"    # out of job, awaiting qualification
    HEALTHY_SPARE = "healthy_spare"
    TERMINATED = "terminated"


class ClusterControl(Protocol):
    """Fleet actions the manager can take."""

    def swap_node(self, old: int, new: int) -> None:
        """Replace ``old`` with ``new`` in the job (at a restart boundary)."""
        ...

    def restart_job(self, reason: str) -> None:
        """Restart from the last checkpoint (costs recovery time)."""
        ...

    def provision_node(self) -> int:
        """Bring a brand-new node into the spare pool; returns its id."""
        ...

    def error_signals(self, node_id: int) -> ErrorSignals: ...

    def remediate(self, node_id: int, stage: str) -> None: ...

    def now(self) -> float: ...


@dataclasses.dataclass
class ManagerStats:
    immediate_restarts: int = 0
    deferred_swaps: int = 0
    sweeps_run: int = 0
    sweeps_failed: int = 0
    triages_run: int = 0
    nodes_terminated: int = 0
    nodes_requalified: int = 0
    human_seconds: float = 0.0
    downtime_seconds: float = 0.0


class HealthManager:
    def __init__(self, control: ClusterControl, sweep_backend: SweepBackend,
                 monitor: OnlineMonitor,
                 sweep_cfg: Optional[SweepConfig] = None,
                 triage_cfg: Optional[TriageConfig] = None,
                 enhanced_sweep: bool = True,
                 max_qualification_rounds: int = 3,
                 pending_patience_s: float = 1800.0):
        self.control = control
        self.backend = sweep_backend
        self.monitor = monitor
        self.sweep_cfg = sweep_cfg or SweepConfig()
        self.triage = TriageWorkflow(triage_cfg)
        self.enhanced_sweep = enhanced_sweep
        self.max_rounds = max_qualification_rounds
        self.pending_patience_s = pending_patience_s
        self.state: Dict[int, NodeState] = {}
        self.spares: List[int] = []
        self.deferred: List[int] = []     # swap at next checkpoint
        self.pending_since: Dict[int, float] = {}
        self.stats = ManagerStats()

    # --------------------------------------------------------- pools

    def register(self, node_id: int, state: NodeState) -> None:
        self.state[node_id] = state
        if state == NodeState.HEALTHY_SPARE:
            self.spares.append(node_id)

    def _take_spare(self) -> int:
        while not self.spares:
            nid = self.control.provision_node()
            self.register(nid, NodeState.HEALTHY_SPARE)
        nid = self.spares.pop(0)
        self.state[nid] = NodeState.ACTIVE
        return nid

    # --------------------------------------------------- event handling

    def handle(self, ev: HealthEvent) -> None:
        nid = ev.decision.node_id
        st = self.state.get(nid)
        if st not in (NodeState.ACTIVE, NodeState.PENDING):
            return                       # already out of the job
        act = ev.decision.action
        if act == Action.PENDING_VERIFICATION:
            self.state[nid] = NodeState.PENDING
            self.pending_since.setdefault(nid, self.control.now())
        elif act == Action.DEFER_TO_CHECKPOINT:
            if nid not in self.deferred:
                self.deferred.append(nid)
                self.stats.deferred_swaps += 1
        elif act == Action.IMMEDIATE_RESTART:
            self.deferred = [d for d in self.deferred if d != nid]
            self._swap_out(nid)
            self.control.restart_job(ev.decision.reason)
            self.stats.immediate_restarts += 1

    def on_checkpoint(self) -> int:
        """Apply deferred mitigations at a checkpoint boundary. Nodes that
        stayed flagged at the pending tier past the patience window are
        pulled for offline verification too (§4.2: a flagged node leaves
        the healthy pool and is scheduled for verification)."""
        now = self.control.now()
        for nid, since in list(self.pending_since.items()):
            still_pending = self.state.get(nid) == NodeState.PENDING
            if not still_pending or nid not in self.monitor.pending:
                self.pending_since.pop(nid, None)
                if still_pending:
                    self.state[nid] = NodeState.ACTIVE   # cleared itself
                continue
            if now - since >= self.pending_patience_s and \
                    nid not in self.deferred:
                self.deferred.append(nid)
                self.stats.deferred_swaps += 1
        n = 0
        for nid in self.deferred:
            if self.state.get(nid) not in (NodeState.ACTIVE,
                                           NodeState.PENDING):
                continue
            # §4.2: deferral exists to CONFIRM the diagnosis — only nodes
            # still latched by the detector are swapped; transients that
            # cleared themselves stay in the job
            if not self.monitor.detector._latched.get(nid, False):
                continue
            self._swap_out(nid)
            self.pending_since.pop(nid, None)
            n += 1
        self.deferred.clear()
        if n:
            self.control.restart_job(f"{n} deferred replacement(s)")
        return n

    def _swap_out(self, nid: int) -> None:
        new = self._take_spare()
        self.control.swap_node(nid, new)
        self.state[nid] = NodeState.QUARANTINED
        self.monitor.node_replaced(nid)

    # ------------------------------------------------- qualification

    def qualify(self, node_id: int) -> NodeState:
        """Event-driven offline qualification of a quarantined node:
        sweep; on failure triage; loop until requalified or terminated.

        The 2-node stage needs a known-good buddy: a failure is re-tried
        against a second buddy before it counts (disambiguates a
        contaminated buddy from a genuinely bad node)."""
        nb = max(self.sweep_cfg.group_size - 1, 1)
        for _ in range(self.max_rounds):
            rep = None
            for attempt in range(2):
                buddies = self.spares[attempt * nb:(attempt + 1) * nb] or \
                    self.spares[:nb]
                rep = qualification_sweep(self.backend, node_id, buddies,
                                          self.sweep_cfg,
                                          enhanced=self.enhanced_sweep)
                self.stats.sweeps_run += 1
                self.stats.downtime_seconds += rep.duration_s
                if rep.passed or not buddies:
                    break
            if rep.passed:
                self.state[node_id] = NodeState.HEALTHY_SPARE
                self.spares.append(node_id)
                self.stats.nodes_requalified += 1
                return NodeState.HEALTHY_SPARE
            self.stats.sweeps_failed += 1
            res = self.triage.run(
                node_id, self.control.error_signals(node_id),
                self.control.now(), self.control.remediate,
                lambda nid: single_pass(self.backend, nid, self.sweep_cfg))
            self.stats.triages_run += 1
            self.stats.human_seconds += res.human_s
            self.stats.downtime_seconds += res.elapsed_s
            if res.outcome == TriageOutcome.TERMINATED:
                self.state[node_id] = NodeState.TERMINATED
                self.stats.nodes_terminated += 1
                return NodeState.TERMINATED
            # else: returned to sweep — loop re-sweeps
        self.state[node_id] = NodeState.TERMINATED
        self.stats.nodes_terminated += 1
        return NodeState.TERMINATED

    def qualify_all_quarantined(self) -> None:
        for nid, st in list(self.state.items()):
            if st == NodeState.QUARANTINED:
                self.qualify(nid)


def single_pass(backend: SweepBackend, node_id: int,
                cfg: SweepConfig) -> bool:
    """Cheap post-remediation health check (short single-node sweep)."""
    from repro.core.sweep import single_node_sweep
    short = dataclasses.replace(cfg, burn_seconds=min(cfg.burn_seconds, 60.0))
    return single_node_sweep(backend, node_id, short, enhanced=False).passed
