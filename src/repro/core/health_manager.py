"""Closed-loop node health management (Fig. 1).

Glues the pieces together: online monitoring emits HealthEvents; the manager
applies the tiered policy's action against the cluster (swap now / swap at
checkpoint / watch), quarantines suspects, drives the event-driven offline
qualification (sweep -> triage -> sweep ...) and returns qualified nodes to
the healthy pool. All substrate access goes through ``ClusterControl`` so
the loop is identical over the simulator and a real fleet control plane.

The manager is the **single source of truth for node pools**: callers take
replacement capacity through ``take_spare`` and hand recovered nodes back
through ``return_spare`` — nothing above this layer keeps its own spare
list. Offline qualification is split into ``begin_qualification`` (runs the
sweep→triage loop and returns a ticket with the outcome and its simulated
duration) and ``complete_qualification`` (applies the outcome to the
pools), so a scheduler can overlap qualification with the running job
instead of blocking on it; ``qualify`` composes the two for the
synchronous path.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Dict, List, Optional, Protocol, Tuple

from repro.core.monitor import HealthEvent, OnlineMonitor
from repro.core.policy import Action
from repro.core.sweep import (SweepBackend, SweepConfig, SweepReport,
                              multi_node_sweep, single_node_sweep)
from repro.core.triage import (ErrorSignals, TriageConfig, TriageOutcome,
                               TriageResult, TriageWorkflow)


class NodeState(enum.Enum):
    ACTIVE = "active"              # serving the training job
    PENDING = "pending"            # in job, flagged pending-verification
    QUARANTINED = "quarantined"    # out of job, awaiting qualification
    HEALTHY_SPARE = "healthy_spare"
    TERMINATED = "terminated"


class ClusterControl(Protocol):
    """Fleet actions the manager can take."""

    def swap_node(self, old: int, new: int) -> None:
        """Replace ``old`` with ``new`` in the job (at a restart boundary)."""
        ...

    def restart_job(self, reason: str) -> None:
        """Restart from the last checkpoint (costs recovery time)."""
        ...

    def provision_node(self) -> int:
        """Bring a brand-new node into the spare pool; returns its id."""
        ...

    def error_signals(self, node_id: int) -> ErrorSignals: ...

    def remediate(self, node_id: int, stage: str) -> None: ...

    def now(self) -> float: ...


@dataclasses.dataclass
class ManagerStats:
    immediate_restarts: int = 0
    deferred_swaps: int = 0
    sweeps_run: int = 0
    sweeps_failed: int = 0
    triages_run: int = 0
    nodes_terminated: int = 0     # pulled by Guard (triage / 3-strikes)
    nodes_lost: int = 0           # died fail-stop (hardware left with them)
    nodes_requalified: int = 0
    nodes_provisioned: int = 0
    human_seconds: float = 0.0
    downtime_seconds: float = 0.0


@dataclasses.dataclass
class QualificationTicket:
    """Outcome of one offline qualification, not yet applied to the pools.

    ``duration_s`` is the node-down time the sweep→triage loop consumed —
    a scheduler uses it to decide *when* (in job time) the outcome lands.
    ``records`` interleaves the sweep reports and triage results in the
    order they ran, for event emission and audit. ``buddy_exhausted``
    marks a qualification that could not disambiguate the node from a
    (possibly contaminated) buddy for lack of a DISJOINT second buddy —
    the outcome is then ``QUARANTINED`` (parked until buddy capacity
    exists), never a silent pass."""
    node_id: int
    outcome: NodeState
    duration_s: float
    sweeps: int
    records: List[Tuple[str, object]]
    applied: bool = False
    buddy_exhausted: bool = False


# Manager-level notification callback: (topic, payload). Kept as a plain
# callable so ``repro.core`` stays free of any event-bus dependency; the
# ``repro.guard`` session translates these into typed GuardEvents.
Notify = Callable[[str, Dict[str, object]], None]


class SparePool(Protocol):
    """Fleet-level replacement-capacity provider (lease/grant protocol).

    When attached (``HealthManager.attach_pool``) the manager stops
    keeping a private ``spares`` list: replacement capacity is leased
    from a shared pool that multiplexes many concurrent jobs (see
    ``repro.fleet.FleetController``), and requalified nodes are granted
    back to it. ``kind`` is the urgency class of the lease — plain
    strings here so ``repro.core`` stays dependency-free: ``"swap"``
    (straggler eviction), ``"crash"`` (fail-stop replacement),
    ``"hang"`` (hang-culprit eviction, the most urgent)."""

    def take(self, kind: str = "swap") -> int:
        """Lease one healthy node (may provision; always returns)."""
        ...

    def give(self, node_id: int) -> None:
        """Return a healthy node to the shared pool (lease closed)."""
        ...

    def count(self) -> int:
        """Healthy nodes available for lease right now."""
        ...

    def buddies(self, n: int, skip: int = 0) -> List[int]:
        """Known-good sweep-buddy candidates co-located with this job
        (free pool nodes this job's sweep bench can physically pair
        with), skipping the first ``skip``."""
        ...


class HealthManager:
    def __init__(self, control: ClusterControl, sweep_backend: SweepBackend,
                 monitor: OnlineMonitor,
                 sweep_cfg: Optional[SweepConfig] = None,
                 triage_cfg: Optional[TriageConfig] = None,
                 enhanced_sweep: bool = True,
                 max_qualification_rounds: int = 3,
                 pending_patience_s: float = 1800.0,
                 on_provision: Optional[Callable[[int], None]] = None,
                 notify: Optional[Notify] = None):
        self.control = control
        self.backend = sweep_backend
        self.monitor = monitor
        self.sweep_cfg = sweep_cfg or SweepConfig()
        self.triage = TriageWorkflow(triage_cfg)
        self.enhanced_sweep = enhanced_sweep
        self.max_rounds = max_qualification_rounds
        self.pending_patience_s = pending_patience_s
        self.on_provision = on_provision
        # multi-subscriber notification list: the session hook AND a
        # fleet controller can both observe pool transitions without
        # clobbering each other (``add_listener``); the constructor arg
        # registers the first subscriber
        self._listeners: List[Notify] = []
        if notify is not None:
            self._listeners.append(notify)
        # optional fleet-level spare provider (lease/grant): when set,
        # the private ``spares`` list stays empty and every take/return
        # goes through the shared pool
        self.pool: Optional[SparePool] = None
        self.state: Dict[int, NodeState] = {}
        self.spares: List[int] = []
        self.deferred: List[int] = []     # swap at next checkpoint
        self.pending_since: Dict[int, float] = {}
        self.stats = ManagerStats()
        # optional attribution hooks (wired by GuardSession when a
        # repro.diagnose.Diagnoser runs):
        #   hold_check(nid) -> True  = the latest diagnosis says the node
        #   is a victim/transient — keep it in the job, do not evict
        self.hold_check: Optional[Callable[[int], bool]] = None
        #   signals_for(nid) -> rich ErrorSignals from attribution (or
        #   None); merged over the substrate's error counters for triage
        self.signals_for: \
            Optional[Callable[[int], Optional[ErrorSignals]]] = None

    def _notify(self, topic: str, **payload) -> None:
        for fn in self._listeners:
            fn(topic, dict(payload))     # each listener gets its own copy

    def add_listener(self, fn: Notify) -> None:
        """Subscribe one more (topic, payload) observer of pool
        transitions; listeners are invoked in attach order."""
        self._listeners.append(fn)

    # --------------------------------------------------------- pools

    def attach_pool(self, pool: SparePool) -> None:
        """Switch replacement capacity to a fleet-level shared pool
        (lease/grant). Caller (the fleet controller) is responsible for
        adopting any privately-held spares first — see
        ``release_private_spares``."""
        self.pool = pool

    def release_private_spares(self) -> List[int]:
        """Hand every privately-held healthy spare to the caller (the
        fleet controller adopts them into the global pool); they leave
        this manager's census entirely."""
        out = list(self.spares)
        self.spares.clear()
        for nid in out:
            self.state.pop(nid, None)
        return out

    def register(self, node_id: int, state: NodeState) -> None:
        self.state[node_id] = state
        if state == NodeState.HEALTHY_SPARE and node_id not in self.spares:
            self.spares.append(node_id)

    @property
    def spare_count(self) -> int:
        """Healthy spares available right now (public pool query)."""
        if self.pool is not None:
            return self.pool.count()
        return len(self.spares)

    def provision_spare(self) -> int:
        """Bring one brand-new node through admission into the spare pool."""
        nid = self.deliver_node()
        if self.pool is not None:
            self.pool.give(nid)          # lands in the shared pool
        else:
            self.register(nid, NodeState.HEALTHY_SPARE)
        return nid

    def deliver_node(self) -> int:
        """Provision one node through the control plane + admission and
        hand it straight to the caller (no pool membership) — the fleet
        controller's materialization path for lease grants."""
        nid = self.control.provision_node()
        self.stats.nodes_provisioned += 1
        if self.on_provision is not None:
            self.on_provision(nid)       # tier-dependent admission check
        self._notify("provision", node_id=nid)
        return nid

    def take_spare(self, kind: str = "swap") -> int:
        """Remove one healthy spare from the pool and mark it ACTIVE.

        Provisions fresh capacity through the control plane if the pool is
        dry. The returned node is in exactly one place afterwards: the job.
        ``kind`` is the lease urgency class when a fleet-level pool is
        attached (``"swap"`` / ``"crash"`` / ``"hang"``)."""
        if self.pool is not None:
            nid = self.pool.take(kind)
        else:
            while not self.spares:
                self.provision_spare()
            nid = self.spares.pop(0)
        self.state[nid] = NodeState.ACTIVE
        return nid

    def return_spare(self, node_id: int) -> None:
        """Hand a healthy node back to the spare pool."""
        if self.pool is not None:
            # the node leaves this job's census: the shared pool owns it
            self.state.pop(node_id, None)
            self.pool.give(node_id)
            return
        self.state[node_id] = NodeState.HEALTHY_SPARE
        if node_id not in self.spares:
            self.spares.append(node_id)

    def spare_pool_ids(self) -> List[int]:
        """Healthy-spare ids visible to this job (buddy candidates):
        the private list, or the co-located slice of the shared pool."""
        if self.pool is not None:
            return self.pool.buddies(len(self.state) + 8)
        return list(self.spares)

    def quarantined(self) -> List[int]:
        """Node ids currently awaiting offline qualification."""
        return sorted(n for n, s in self.state.items()
                      if s == NodeState.QUARANTINED)

    def retire(self, node_id: int, reason: str = "",
               crashed: bool = False) -> None:
        """Terminate a node (leaves the fleet; replacement hw arrives via
        provisioning). ``crashed`` keeps fail-stop deaths out of the
        Guard-driven ``nodes_terminated`` count."""
        self.state[node_id] = NodeState.TERMINATED
        self.spares = [s for s in self.spares if s != node_id]
        if crashed:
            self.stats.nodes_lost += 1
        else:
            self.stats.nodes_terminated += 1
        self._notify("terminate", node_id=node_id, reason=reason)

    # --------------------------------------------------- event handling

    def handle(self, ev: HealthEvent) -> None:
        nid = ev.decision.node_id
        st = self.state.get(nid)
        if st not in (NodeState.ACTIVE, NodeState.PENDING):
            return                       # already out of the job
        act = ev.decision.action
        if act == Action.PENDING_VERIFICATION:
            self.state[nid] = NodeState.PENDING
            self.pending_since.setdefault(nid, self.control.now())
        elif act == Action.DEFER_TO_CHECKPOINT:
            if nid not in self.deferred:
                self.deferred.append(nid)
                self.stats.deferred_swaps += 1
        elif act == Action.IMMEDIATE_RESTART:
            self.deferred = [d for d in self.deferred if d != nid]
            self._swap_out(nid, reason=ev.decision.reason)
            self.control.restart_job(ev.decision.reason)
            self.stats.immediate_restarts += 1

    def on_checkpoint(self) -> int:
        """Apply deferred mitigations at a checkpoint boundary. Nodes that
        stayed flagged at the pending tier past the patience window are
        pulled for offline verification too (§4.2: a flagged node leaves
        the healthy pool and is scheduled for verification)."""
        now = self.control.now()
        for nid, since in list(self.pending_since.items()):
            still_pending = self.state.get(nid) == NodeState.PENDING
            if not still_pending or nid not in self.monitor.pending:
                self.pending_since.pop(nid, None)
                if still_pending:
                    self.state[nid] = NodeState.ACTIVE   # cleared itself
                continue
            if now - since >= self.pending_patience_s and \
                    nid not in self.deferred:
                # attribution hold: a cascade victim stays latched as
                # long as its culprit is in the job — patience must not
                # convert "watched" into an eviction
                if self.hold_check is not None and self.hold_check(nid):
                    continue
                self.deferred.append(nid)
                self.stats.deferred_swaps += 1
        n = 0
        for nid in self.deferred:
            if self.state.get(nid) not in (NodeState.ACTIVE,
                                           NodeState.PENDING):
                continue
            # §4.2: deferral exists to CONFIRM the diagnosis — only nodes
            # still latched by the detector are swapped; transients that
            # cleared themselves stay in the job
            if not self.monitor.detector.is_latched(nid):
                continue
            # attribution may have re-classified the node as a victim /
            # transient since the deferral was queued: hold it
            if self.hold_check is not None and self.hold_check(nid):
                continue
            self._swap_out(nid, reason="deferred replacement", deferred=True)
            self.pending_since.pop(nid, None)
            n += 1
        self.deferred.clear()
        if n:
            self.control.restart_job(f"{n} deferred replacement(s)")
        return n

    def _swap_out(self, nid: int, reason: str = "",
                  deferred: bool = False) -> int:
        new = self.take_spare()
        self.control.swap_node(nid, new)
        self.state[nid] = NodeState.QUARANTINED
        self.monitor.node_replaced(nid)
        self._notify("swap", old=nid, new=new, reason=reason,
                     deferred=deferred)
        return new

    # ------------------------------------------------- qualification

    def _error_signals(self, node_id: int) -> ErrorSignals:
        """Triage evidence: the substrate's error counters, enriched by
        the latest blame-attribution diagnosis when a Diagnoser runs
        (the diagnosis picks the lane; counters fill in what it missed).

        A stale cascade-victim verdict loses to actionable counters: the
        diagnosis was made while the node sat behind a degraded peer,
        but the substrate now reports real errors — honoring the old
        verdict would short-circuit triage (no strike, no stages) and
        leave a remediable fault untreated."""
        sig = self.control.error_signals(node_id)
        if self.signals_for is not None:
            diag = self.signals_for(node_id)
            if diag is not None and not (
                    diag.root_cause == "cascade_victim" and sig.actionable):
                sig = diag.merged(sig)
        return sig

    def begin_qualification(self, node_id: int) -> QualificationTicket:
        """Run the event-driven offline qualification of a quarantined
        node — sweep; on failure triage; loop until requalified or
        terminated — and return the outcome WITHOUT applying it to the
        pools. The node stays QUARANTINED until
        ``complete_qualification`` lands the ticket, which lets a
        scheduler overlap the sweep's ``duration_s`` with the job.

        The 2-node stage needs a known-good buddy: a failure is re-tried
        against a DISJOINT second buddy before it counts (disambiguates
        a contaminated buddy from a genuinely bad node). When there is
        no buddy at all, or no disjoint retry buddy after a group
        failure, the node is parked with ``buddy_exhausted`` set and a
        QUARANTINED outcome — it is neither passed unverified nor
        condemned on one ambiguous measurement."""
        nb = max(self.sweep_cfg.group_size - 1, 1)
        duration = 0.0
        sweeps = 0
        records: List[Tuple[str, object]] = []

        def run(rep: SweepReport) -> SweepReport:
            nonlocal duration, sweeps
            self.stats.sweeps_run += 1
            sweeps += 1
            self.stats.downtime_seconds += rep.duration_s
            duration += rep.duration_s
            records.append(("sweep", rep))
            return rep

        def ticket(outcome: NodeState,
                   exhausted: bool = False) -> QualificationTicket:
            return QualificationTicket(node_id, outcome, duration, sweeps,
                                       records, buddy_exhausted=exhausted)

        for _ in range(self.max_rounds):
            rep = run(single_node_sweep(self.backend, node_id,
                                        self.sweep_cfg,
                                        enhanced=self.enhanced_sweep))
            passed = rep.passed
            if passed and self.enhanced_sweep:
                avail = self.spare_pool_ids()
                buddies = avail[:nb]
                if not buddies:
                    # no known-good buddy: the multi-node stage cannot
                    # run — park the node instead of passing it blind
                    return ticket(NodeState.QUARANTINED, exhausted=True)
                multi = run(multi_node_sweep(self.backend, node_id,
                                             buddies, self.sweep_cfg))
                if not multi.passed:
                    retry = [s for s in avail[nb:]
                             if s not in buddies][:nb]
                    if not retry:
                        # the only buddy may itself be contaminated —
                        # one ambiguous failure condemns nobody
                        return ticket(NodeState.QUARANTINED,
                                      exhausted=True)
                    multi = run(multi_node_sweep(self.backend, node_id,
                                                 retry, self.sweep_cfg))
                passed = multi.passed
            if passed:
                return ticket(NodeState.HEALTHY_SPARE)
            self.stats.sweeps_failed += 1
            res: TriageResult = self.triage.run(
                node_id, self._error_signals(node_id),
                self.control.now(), self.control.remediate,
                lambda nid: single_pass(self.backend, nid, self.sweep_cfg))
            self.stats.triages_run += 1
            self.stats.human_seconds += res.human_s
            self.stats.downtime_seconds += res.elapsed_s
            duration += res.elapsed_s
            records.append(("triage", res))
            if res.outcome == TriageOutcome.TERMINATED:
                return ticket(NodeState.TERMINATED)
            # else: returned to sweep — loop re-sweeps
        return ticket(NodeState.TERMINATED)

    def complete_qualification(self, ticket: QualificationTicket
                               ) -> NodeState:
        """Apply a qualification outcome to the pools (idempotent)."""
        if ticket.applied:
            return ticket.outcome
        ticket.applied = True
        if ticket.outcome == NodeState.HEALTHY_SPARE:
            self.return_spare(ticket.node_id)
            self.stats.nodes_requalified += 1
        elif ticket.outcome == NodeState.QUARANTINED:
            # unresolved (buddy exhaustion): the node stays parked and a
            # later submission retries once buddy capacity exists
            self.state[ticket.node_id] = NodeState.QUARANTINED
        else:
            self.state[ticket.node_id] = NodeState.TERMINATED
            self.stats.nodes_terminated += 1
        return ticket.outcome

    def qualify(self, node_id: int) -> NodeState:
        """Synchronous qualification: begin + complete in one call."""
        return self.complete_qualification(self.begin_qualification(node_id))

    def qualify_all_quarantined(self) -> None:
        for nid in self.quarantined():
            self.qualify(nid)


def single_pass(backend: SweepBackend, node_id: int,
                cfg: SweepConfig) -> bool:
    """Cheap post-remediation health check (short single-node sweep)."""
    from repro.core.sweep import single_node_sweep
    short = dataclasses.replace(cfg, burn_seconds=min(cfg.burn_seconds, 60.0))
    return single_node_sweep(backend, node_id, short, enhanced=False).passed
