"""Tiered grey-node triage workflow (§6, Fig. 8).

Remediation is staged from cheap/reversible to invasive, with a health
re-check gate after every stage:

  cascade-victim attribution   ->  RETURN TO SWEEP, no strike, no stages
                                   (the node was stalled behind a degraded
                                   peer — it is not the problem)
  no actionable error signals  ->  EARLY TERMINATION (don't burn remediation
                                   effort on an undiagnosable node)
  GPU errors                   ->  device reset -> reboot -> re-image
  network errors               ->  NIC reset    -> reboot -> re-image
  host/data errors             ->  reboot -> re-image

A node that passes the post-stage health check returns to the sweep pipeline
(NOT directly to production — §5.4's conservative rule). A node that
exhausts its stages is terminated and replaced. Independently, the
3-strikes rule (§6): a node entering triage >= ``strike_limit`` times within
``strike_window`` seconds is terminally bad — terminate without triage.
"""
from __future__ import annotations

import dataclasses
import enum
from collections import defaultdict
from typing import Callable, Dict, List, Optional


class TriageOutcome(enum.Enum):
    RETURNED_TO_SWEEP = "returned_to_sweep"
    TERMINATED = "terminated"


@dataclasses.dataclass(frozen=True)
class ErrorSignals:
    """Actionable error evidence gathered by online monitoring and (when
    a ``repro.diagnose.Diagnoser`` runs) by blame attribution.

    The booleans pick the remediation lane; ``root_cause`` carries the
    attribution taxonomy value (``repro.diagnose.RootCause``) so triage
    can recognize verdicts — notably ``cascade_victim``, which must
    neither consume a 3-strikes strike nor burn remediation stages."""
    gpu_errors: bool = False       # XID-equivalent device errors, throttle
    nic_errors: bool = False       # link flaps, retx storms, adapter down
    host_errors: bool = False      # host/data-pipeline evidence (CPU cfg)
    root_cause: str = ""           # repro.diagnose taxonomy value, if known
    detail: str = ""               # human-readable evidence summary

    @property
    def actionable(self) -> bool:
        return self.gpu_errors or self.nic_errors or self.host_errors

    def merged(self, other: "ErrorSignals") -> "ErrorSignals":
        """Union of two evidence sources (diagnosis + substrate counters);
        this object's attribution fields win when both are set."""
        return ErrorSignals(
            gpu_errors=self.gpu_errors or other.gpu_errors,
            nic_errors=self.nic_errors or other.nic_errors,
            host_errors=self.host_errors or other.host_errors,
            root_cause=self.root_cause or other.root_cause,
            detail=self.detail or other.detail)


@dataclasses.dataclass(frozen=True)
class Stage:
    name: str
    duration_s: float              # node-down time
    human_s: float                 # operator attention consumed


@dataclasses.dataclass(frozen=True)
class TriageConfig:
    strike_limit: int = 3
    strike_window_s: float = 7 * 86_400.0       # one week
    gpu_stages: tuple = (
        Stage("gpu_reset", 600.0, 120.0),
        Stage("reboot", 1_200.0, 120.0),
        Stage("reimage", 7_200.0, 600.0),
    )
    nic_stages: tuple = (
        Stage("nic_reset", 600.0, 120.0),
        Stage("reboot", 1_200.0, 120.0),
        Stage("reimage", 7_200.0, 600.0),
    )
    host_stages: tuple = (
        Stage("reboot", 1_200.0, 120.0),
        Stage("reimage", 7_200.0, 600.0),
    )
    terminate_human_s: float = 300.0


@dataclasses.dataclass
class TriageResult:
    node_id: int
    outcome: TriageOutcome
    stages_run: List[str]
    elapsed_s: float
    human_s: float
    reason: str


class TriageWorkflow:
    """Drives remediation through substrate callbacks so the same FSM runs
    against simulation and against real fleet tooling.

      remediate(node_id, stage_name) -> None   apply the action
      verify(node_id) -> bool                  post-stage health check
    """

    def __init__(self, cfg: Optional[TriageConfig] = None):
        self.cfg = cfg or TriageConfig()
        self._strikes: Dict[int, List[float]] = defaultdict(list)
        self.results: List[TriageResult] = []

    def strike_count(self, node_id: int, now: float) -> int:
        w = [t for t in self._strikes[node_id]
             if now - t <= self.cfg.strike_window_s]
        self._strikes[node_id] = w
        return len(w)

    def run(self, node_id: int, signals: ErrorSignals, now: float,
            remediate: Callable[[int, str], None],
            verify: Callable[[int], bool]) -> TriageResult:
        cfg = self.cfg

        # attribution says the node is a victim — stalled behind a
        # degraded peer (cascade_victim) or blocked on the barrier of a
        # hung collective (hang_victim) — not degraded itself. Return it
        # to the sweep pipeline WITHOUT a strike (a strike here would
        # ratchet a healthy node toward 3-strikes termination) and
        # without burning remediation stages on it.
        if signals.root_cause in ("cascade_victim", "hang_victim"):
            res = TriageResult(node_id, TriageOutcome.RETURNED_TO_SWEEP,
                               [], 0.0, 0.0,
                               "cascade victim: no strike, no remediation"
                               if signals.root_cause == "cascade_victim"
                               else "hang victim: no strike, "
                                    "no remediation")
            self.results.append(res)
            return res

        self._strikes[node_id].append(now)

        # 3-strikes: terminally bad, skip the workflow
        if self.strike_count(node_id, now) >= cfg.strike_limit:
            res = TriageResult(node_id, TriageOutcome.TERMINATED, [],
                               0.0, cfg.terminate_human_s,
                               f"{cfg.strike_limit} strikes in window")
            self.results.append(res)
            return res

        # no actionable errors: early termination
        if not signals.actionable:
            res = TriageResult(node_id, TriageOutcome.TERMINATED, [],
                               0.0, cfg.terminate_human_s,
                               "no actionable error signals")
            self.results.append(res)
            return res

        if signals.gpu_errors:
            stages = cfg.gpu_stages
        elif signals.nic_errors:
            stages = cfg.nic_stages
        else:
            stages = cfg.host_stages
        elapsed = human = 0.0
        run: List[str] = []
        for st in stages:
            remediate(node_id, st.name)
            run.append(st.name)
            elapsed += st.duration_s
            human += st.human_s
            if verify(node_id):
                res = TriageResult(node_id, TriageOutcome.RETURNED_TO_SWEEP,
                                   run, elapsed, human,
                                   f"healthy after {st.name}")
                self.results.append(res)
                return res
        res = TriageResult(node_id, TriageOutcome.TERMINATED, run,
                           elapsed, human + cfg.terminate_human_s,
                           "remediation exhausted")
        self.results.append(res)
        return res
