"""Offline node-sweep verification (§5).

Event-driven qualification: a node flagged by online monitoring (or returning
from repair) must pass sweeps before re-entering the healthy pool.

  Single-node sweep (§5.2): sustained per-device compute throughput (matmul
  burn — on TPU hardware this is the ``repro.kernels.sweep_burn`` Pallas
  kernel) + pairwise intra-node interconnect bandwidth/symmetry.

  Multi-node sweep (§5.3): collective-communication mini-workload on small
  groups. 2-node sweeps against a known-good buddy are the default — the
  paper finds most communication degradations already visible at 2 nodes;
  4/8-node configurations are supported but offer diminishing returns.

  Fleet campaign: ``fleet_qualification`` sweeps every node of a campaign
  in one vectorized pass — batched compute/bandwidth/collective probes
  (the optional ``batch_*`` backend methods, with a scalar-compat
  fallback), round-robin buddy pairing from a known-good reference pool
  (suspects are never each other's buddies), and per-node verdicts that
  are bit-identical to running the scalar sweeps node by node.

Verdicts are conservative (§5.4): a node re-enters service only if EVERY
probe is within tolerance both of the fleet reference and of its own peers
(intra-node symmetry); otherwise it stays quarantined for triage.

Cost model: the per-device burns run SEQUENTIALLY on the node, so a
single-node sweep occupies the sweep bench for ``burn_seconds * devices``
(+ a fixed setup cost per bandwidth pair) — an 8-device enhanced sweep is
a multi-hour bench occupation, which is exactly why qualification is
scheduled off the job's critical path.

The sweep talks to hardware through ``SweepBackend`` — the simulated fleet
and the local-JAX demo backend both implement it.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Protocol, Sequence, Tuple

import numpy as np

# fixed per-pair setup/teardown cost of a bandwidth probe, seconds
PAIR_PROBE_S = 30.0


@dataclasses.dataclass(frozen=True)
class SweepReference:
    """Fleet-expected healthy values (from qualification of known-good
    hardware; refreshed whenever the platform generation changes)."""
    device_tflops: float          # sustained matmul TFLOP/s per device
    intra_bw_gbps: float          # pairwise interconnect GB/s
    pair_step_time: float         # 2-node sweep-workload step time, s


@dataclasses.dataclass(frozen=True)
class SweepConfig:
    # single-node
    compute_tolerance: float = 0.07      # device within 7% of reference
    symmetry_tolerance: float = 0.05     # device within 5% of node median
    bw_tolerance: float = 0.10           # pair bw within 10% of reference
    burn_seconds: float = 120.0          # per-device sustained burn
    # multi-node
    group_size: int = 2                  # default 2-node sweeps
    sweep_steps: int = 50                # mini-workload steps per group
    inflation_tolerance: float = 0.08    # step time within 8% of reference
    # enhanced sweep = longer burns + multi-node stage (§7.2 Table 4 tier 4)
    enhanced_burn_seconds: float = 3600.0


class SweepBackend(Protocol):
    """What the sweep needs from the substrate.

    The ``batch_*`` methods are OPTIONAL: a backend that can amortize
    probes fleet-wide (the simulator, a real campaign runner fanning out
    over hosts) implements them and ``fleet_qualification`` uses them;
    otherwise the campaign falls back to the scalar probes node by node
    with identical results.
    """

    def device_count(self, node_id: int) -> int: ...

    def compute_probe(self, node_id: int, device: int,
                      seconds: float) -> float:
        """Sustained matmul throughput, TFLOP/s."""
        ...

    def intra_bw_probe(self, node_id: int, dev_a: int, dev_b: int) -> float:
        """Pairwise intra-node interconnect bandwidth, GB/s."""
        ...

    def multi_node_probe(self, node_ids: Sequence[int],
                         steps: int) -> np.ndarray:
        """Step times (s) of a collective mini-workload over the group."""
        ...

    def reference(self) -> SweepReference: ...

    # --- optional batched protocol (fleet campaigns) ---
    # def batch_compute_probe(node_ids, seconds) -> (N, D) array
    # def batch_intra_bw_probe(node_ids, pairs) -> (N, len(pairs)) array
    # def batch_multi_node_probe(groups, steps) -> (G, steps) array


@dataclasses.dataclass
class SweepReport:
    node_id: int
    passed: bool
    failures: List[str]
    duration_s: float
    measurements: Dict[str, object]


def intra_pairs(nd: int) -> List[Tuple[int, int]]:
    """Deduped canonical (lo, hi) bandwidth-probe pairs covering every
    device: the ring plus a few cross pairs. Single-device nodes have no
    intra-node interconnect to probe (the naive ring would emit a
    degenerate (0, 0) self-pair), and for small ``nd`` the ring and
    cross sets overlap — duplicates are dropped in first-seen order."""
    if nd <= 1:
        return []
    raw = [(a, (a + 1) % nd) for a in range(nd)]
    raw += [(a, (a + nd // 2) % nd) for a in range(nd // 2)]
    seen = set()
    pairs: List[Tuple[int, int]] = []
    for a, b in raw:
        key = (a, b) if a <= b else (b, a)
        if key not in seen:
            seen.add(key)
            pairs.append(key)
    return pairs


# ------------------------------------------------------- verdict builders
# Shared by the scalar sweeps and the batched campaign: both paths MUST
# produce identical failure strings for identical measurements (the
# batched-vs-scalar golden contract).

def _single_node_failures(tflops: np.ndarray,
                          pairs: Sequence[Tuple[int, int]],
                          bw: Sequence[float], ref: SweepReference,
                          cfg: SweepConfig) -> List[str]:
    failures: List[str] = []
    node_med = np.median(tflops)
    for d in range(len(tflops)):
        if tflops[d] < ref.device_tflops * (1 - cfg.compute_tolerance):
            failures.append(
                f"compute dev{d}: {tflops[d]:.1f} TF/s < "
                f"{(1 - cfg.compute_tolerance) * ref.device_tflops:.1f}")
        if tflops[d] < node_med * (1 - cfg.symmetry_tolerance):
            failures.append(
                f"asymmetry dev{d}: {tflops[d]:.1f} TF/s vs node median "
                f"{node_med:.1f}")
    for (a, b), g in zip(pairs, bw):
        if g < ref.intra_bw_gbps * (1 - cfg.bw_tolerance):
            failures.append(
                f"intra-bw {a}<->{b}: {g:.0f} GB/s < "
                f"{(1 - cfg.bw_tolerance) * ref.intra_bw_gbps:.0f}")
    return failures


def _multi_failure(group: Sequence[int], med: float, ref: SweepReference,
                   cfg: SweepConfig) -> str:
    return (f"group step time {med:.3f}s > "
            f"{(1 + cfg.inflation_tolerance) * ref.pair_step_time:.3f}s "
            f"(group={list(group)})")


def _single_duration(burn: float, nd: int, n_pairs: int) -> float:
    # per-device burns are sequential on the node: the bench is occupied
    # for burn * nd, NOT burn (the pre-fix `burn * nd / max(nd, 1)`
    # collapsed to `burn` for every device count, releasing 8-device
    # qualifications ~8x too early)
    return burn * nd + PAIR_PROBE_S * n_pairs


# ---------------------------------------------------------- scalar sweeps

def single_node_sweep(backend: SweepBackend, node_id: int,
                      cfg: Optional[SweepConfig] = None,
                      enhanced: bool = False,
                      reference: Optional[SweepReference] = None
                      ) -> SweepReport:
    cfg = cfg or SweepConfig()
    ref = reference if reference is not None else backend.reference()
    nd = backend.device_count(node_id)
    burn = cfg.enhanced_burn_seconds if enhanced else cfg.burn_seconds

    tflops = np.array([backend.compute_probe(node_id, d, burn)
                       for d in range(nd)])
    pairs = intra_pairs(nd)
    bw = [backend.intra_bw_probe(node_id, a, b) for a, b in pairs]
    failures = _single_node_failures(tflops, pairs, bw, ref, cfg)
    duration = _single_duration(burn, nd, len(pairs))
    return SweepReport(node_id, not failures, failures, duration,
                       {"tflops": tflops, "bw": dict(zip(pairs, bw))})


def multi_node_sweep(backend: SweepBackend, node_id: int,
                     buddies: Sequence[int],
                     cfg: Optional[SweepConfig] = None,
                     reference: Optional[SweepReference] = None
                     ) -> SweepReport:
    """Sweep ``node_id`` in a group with known-good ``buddies``."""
    cfg = cfg or SweepConfig()
    ref = reference if reference is not None else backend.reference()
    group = [node_id, *buddies][: max(cfg.group_size, 2)]
    times = backend.multi_node_probe(group, cfg.sweep_steps)
    med = float(np.median(times))
    failures = []
    if med > ref.pair_step_time * (1 + cfg.inflation_tolerance):
        failures.append(_multi_failure(group, med, ref, cfg))
    duration = med * cfg.sweep_steps
    return SweepReport(node_id, not failures, failures, duration,
                       {"group": group, "step_times": times})


def qualification_sweep(backend: SweepBackend, node_id: int,
                        buddies: Sequence[int],
                        cfg: Optional[SweepConfig] = None,
                        enhanced: bool = True,
                        reference: Optional[SweepReference] = None
                        ) -> SweepReport:
    """Full offline qualification: single-node stage, then (enhanced only)
    the 2-node collective stage. Conservative: all stages must pass."""
    cfg = cfg or SweepConfig()
    rep = single_node_sweep(backend, node_id, cfg, enhanced=enhanced,
                            reference=reference)
    if not enhanced:
        return rep
    if rep.passed and buddies:
        multi = multi_node_sweep(backend, node_id, buddies, cfg,
                                 reference=reference)
        rep = SweepReport(
            node_id, rep.passed and multi.passed,
            rep.failures + multi.failures,
            rep.duration_s + multi.duration_s,
            {**rep.measurements, **multi.measurements})
    return rep


# ------------------------------------------------------ fleet campaigns

@dataclasses.dataclass(frozen=True)
class SweepCampaign:
    """One offline fleet-qualification campaign (pre-job or periodic).

    ``reference_pool`` holds tracked known-good nodes used as multi-node
    buddies (round-robin), so campaign suspects are never each other's
    buddies; when empty, the campaign bootstraps the pool from its own
    single-stage passers. ``reference=None`` auto-calibrates the
    :class:`SweepReference` from fleet medians — the §5 practice of
    qualifying a new platform generation against itself."""
    node_ids: Tuple[int, ...]
    reference_pool: Tuple[int, ...] = ()
    enhanced: bool = True
    reference: Optional[SweepReference] = None


@dataclasses.dataclass
class CampaignResult:
    reports: List[SweepReport]            # one per campaign node, in order
    reference: SweepReference             # the reference verdicts used
    calibrated: bool                      # True when derived from medians
    buddies: Dict[int, Tuple[int, ...]]   # first-attempt buddy sets
    retry_buddies: Dict[int, Tuple[int, ...]]   # disjoint retry sets
    sweeps: int                           # total sweep executions
    node_seconds: float                   # summed bench occupancy
    wall_s: float                         # real wall of the campaign pass

    @property
    def passed(self) -> List[int]:
        return [r.node_id for r in self.reports if r.passed]

    @property
    def failed(self) -> List[int]:
        return [r.node_id for r in self.reports if not r.passed]


def _batch_compute(backend: SweepBackend, nodes: Sequence[int], nd: int,
                   seconds: float) -> np.ndarray:
    fn = getattr(backend, "batch_compute_probe", None)
    if fn is not None:
        out = np.asarray(fn(nodes, seconds), dtype=float)
    else:
        out = np.array([[backend.compute_probe(n, d, seconds)
                         for d in range(nd)] for n in nodes], dtype=float)
    assert out.shape == (len(nodes), nd), out.shape
    return out


def _batch_bw(backend: SweepBackend, nodes: Sequence[int],
              pairs: Sequence[Tuple[int, int]]) -> np.ndarray:
    if not pairs:
        return np.zeros((len(nodes), 0))
    fn = getattr(backend, "batch_intra_bw_probe", None)
    if fn is not None:
        out = np.asarray(fn(nodes, tuple(pairs)), dtype=float)
    else:
        out = np.array([[backend.intra_bw_probe(n, a, b) for a, b in pairs]
                        for n in nodes], dtype=float)
    assert out.shape == (len(nodes), len(pairs)), out.shape
    return out


def _batch_multi(backend: SweepBackend, groups: Sequence[Sequence[int]],
                 steps: int) -> np.ndarray:
    if not groups:
        return np.zeros((0, steps))
    fn = getattr(backend, "batch_multi_node_probe", None)
    if fn is not None:
        out = np.asarray(fn(tuple(tuple(g) for g in groups), steps),
                         dtype=float)
    else:
        out = np.array([backend.multi_node_probe(list(g), steps)
                        for g in groups], dtype=float)
    assert out.shape == (len(groups), steps), out.shape
    return out


def _round_robin_buddies(candidates: Sequence[int], pool: Sequence[int],
                         nb: int,
                         avoid: Optional[Dict[int, set]] = None
                         ) -> Dict[int, Tuple[int, ...]]:
    """Round-robin buddy assignment from a known-good pool. A candidate
    never buddies itself, never repeats a buddy within its set, and
    (via ``avoid``) never re-tests against a buddy it already failed
    with — the retry must be DISJOINT to disambiguate a contaminated
    buddy from a genuinely bad node."""
    out: Dict[int, Tuple[int, ...]] = {}
    k = 0
    for c in candidates:
        banned = {c} | (avoid.get(c, set()) if avoid else set())
        bs: List[int] = []
        for _ in range(len(pool) + nb):
            if len(bs) == nb or not pool:
                break
            b = pool[k % len(pool)]
            k += 1
            if b not in banned and b not in bs:
                bs.append(b)
        out[c] = tuple(bs)
    return out


def fleet_qualification(backend: SweepBackend, campaign: SweepCampaign,
                        cfg: Optional[SweepConfig] = None
                        ) -> CampaignResult:
    """Qualify every campaign node in one vectorized pass.

    Stage 1 batches all compute burns and bandwidth probes; stage 2
    (enhanced campaigns) batches the collective mini-workloads of the
    single-stage passers against round-robin buddies from the reference
    pool, retrying each failing group once against a disjoint buddy set.
    Per-node verdicts, failure strings and measurements are bit-identical
    to running the scalar sweeps node by node with the same reference
    and buddy assignment."""
    t0 = time.perf_counter()
    cfg = cfg or SweepConfig()
    nodes = [int(n) for n in campaign.node_ids]
    ref0 = campaign.reference
    calibrated = ref0 is None
    if not nodes:
        return CampaignResult([], ref0 or backend.reference(), calibrated,
                              {}, {}, 0, 0.0, time.perf_counter() - t0)
    nd = int(backend.device_count(nodes[0]))
    hetero = [n for n in nodes if int(backend.device_count(n)) != nd]
    if hetero:
        # the batched pass is one uniform (N, D) composition — a mixed
        # fleet must be split into per-device-count campaigns
        raise ValueError(
            f"fleet_qualification needs a uniform device count: "
            f"node {nodes[0]} has {nd}, nodes {hetero[:4]} differ")
    burn = cfg.enhanced_burn_seconds if campaign.enhanced \
        else cfg.burn_seconds
    pairs = intra_pairs(nd)

    # ---- stage 1: batched single-node probes
    tflops = _batch_compute(backend, nodes, nd, burn)        # (N, D)
    bw = _batch_bw(backend, nodes, pairs)                    # (N, P)
    ref_tf = float(np.median(tflops)) if calibrated \
        else ref0.device_tflops
    ref_bw = float(np.median(bw)) if calibrated and bw.size \
        else (backend.reference().intra_bw_gbps if calibrated
              else ref0.intra_bw_gbps)
    node_med = np.median(tflops, axis=1)                     # (N,)
    comp_bad = tflops < ref_tf * (1 - cfg.compute_tolerance)
    asym_bad = tflops < node_med[:, None] * (1 - cfg.symmetry_tolerance)
    bw_bad = bw < ref_bw * (1 - cfg.bw_tolerance)
    single_bad = comp_bad.any(axis=1) | asym_bad.any(axis=1) | \
        bw_bad.any(axis=1)
    single_dur = _single_duration(burn, nd, len(pairs))
    sweeps = len(nodes)

    # ---- stage 2: batched multi-node collective stage
    buddies: Dict[int, Tuple[int, ...]] = {}
    retry_buddies: Dict[int, Tuple[int, ...]] = {}
    med1: Dict[int, float] = {}
    med2: Dict[int, float] = {}
    times1: Dict[int, np.ndarray] = {}
    times2: Dict[int, np.ndarray] = {}
    multi_ok: Dict[int, bool] = {}
    ref_pair = backend.reference().pair_step_time if calibrated \
        else ref0.pair_step_time
    if campaign.enhanced:
        nb = max(cfg.group_size - 1, 1)
        cands = [n for n, bad in zip(nodes, single_bad) if not bad]
        pool = [int(p) for p in campaign.reference_pool] or cands
        buddies = _round_robin_buddies(cands, pool, nb)
        runnable = [c for c in cands if buddies[c]]
        groups = [[c, *buddies[c]][: max(cfg.group_size, 2)]
                  for c in runnable]
        t_all = _batch_multi(backend, groups, cfg.sweep_steps)
        sweeps += len(groups)
        meds = np.median(t_all, axis=1) if len(groups) else np.zeros(0)
        if calibrated and len(groups):
            # fleet-median calibration of the pair reference: median of
            # the per-group medians (robust to the faulty minority)
            ref_pair = float(np.median(meds))
        for c, m, row in zip(runnable, meds, t_all):
            med1[c] = float(m)
            times1[c] = row
            multi_ok[c] = float(m) <= ref_pair * \
                (1 + cfg.inflation_tolerance)
        # retry the failing groups against DISJOINT buddies: a failure
        # shared with a contaminated buddy must not condemn the node
        retry_cands = [c for c in runnable if not multi_ok[c]]
        if retry_cands:
            avoid = {c: set(buddies[c]) for c in retry_cands}
            retry_buddies = _round_robin_buddies(retry_cands, pool, nb,
                                                 avoid=avoid)
            retry_run = [c for c in retry_cands if retry_buddies[c]]
            rgroups = [[c, *retry_buddies[c]][: max(cfg.group_size, 2)]
                       for c in retry_run]
            rt = _batch_multi(backend, rgroups, cfg.sweep_steps)
            sweeps += len(rgroups)
            rmeds = np.median(rt, axis=1) if len(rgroups) else np.zeros(0)
            for c, m, row in zip(retry_run, rmeds, rt):
                med2[c] = float(m)
                times2[c] = row
                multi_ok[c] = float(m) <= ref_pair * \
                    (1 + cfg.inflation_tolerance)

    reference = SweepReference(ref_tf, ref_bw, ref_pair)

    # ---- per-node reports (failure strings materialized O(failing))
    reports: List[SweepReport] = []
    node_seconds = 0.0
    for i, n in enumerate(nodes):
        failures: List[str] = []
        duration = single_dur
        meas: Dict[str, object] = {"tflops": tflops[i],
                                   "bw": dict(zip(pairs, bw[i]))}
        if single_bad[i]:
            failures = _single_node_failures(tflops[i], pairs, bw[i],
                                             reference, cfg)
        elif campaign.enhanced:
            bs = buddies.get(n, ())
            if not bs:
                failures.append(
                    "buddy_exhausted: no known-good buddy for the "
                    "multi-node stage")
            else:
                group = [n, *bs][: max(cfg.group_size, 2)]
                duration += med1[n] * cfg.sweep_steps
                meas["group"] = group
                meas["step_times"] = times1[n]
                if not multi_ok[n] or n in med2:
                    rbs = retry_buddies.get(n, ())
                    if not multi_ok[n] and not rbs:
                        failures.append(_multi_failure(group, med1[n],
                                                       reference, cfg))
                        failures.append(
                            "buddy_exhausted: no disjoint retry buddy")
                    elif n in med2:
                        rgroup = [n, *rbs][: max(cfg.group_size, 2)]
                        duration += med2[n] * cfg.sweep_steps
                        meas["first_group"] = group
                        meas["first_step_times"] = times1[n]
                        meas["group"] = rgroup
                        meas["step_times"] = times2[n]
                        meas["retried"] = True
                        if not multi_ok[n]:
                            failures.append(_multi_failure(
                                rgroup, med2[n], reference, cfg))
        node_seconds += duration
        reports.append(SweepReport(n, not failures, failures, duration,
                                   meas))
    return CampaignResult(reports, reference, calibrated, buddies,
                          retry_buddies, sweeps, node_seconds,
                          time.perf_counter() - t0)
