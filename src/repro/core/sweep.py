"""Offline node-sweep verification (§5).

Event-driven qualification: a node flagged by online monitoring (or returning
from repair) must pass sweeps before re-entering the healthy pool.

  Single-node sweep (§5.2): sustained per-device compute throughput (matmul
  burn — on TPU hardware this is the ``repro.kernels.sweep_burn`` Pallas
  kernel) + pairwise intra-node interconnect bandwidth/symmetry.

  Multi-node sweep (§5.3): collective-communication mini-workload on small
  groups. 2-node sweeps against a known-good buddy are the default — the
  paper finds most communication degradations already visible at 2 nodes;
  4/8-node configurations are supported but offer diminishing returns.

Verdicts are conservative (§5.4): a node re-enters service only if EVERY
probe is within tolerance both of the fleet reference and of its own peers
(intra-node symmetry); otherwise it stays quarantined for triage.

The sweep talks to hardware through ``SweepBackend`` — the simulated fleet
and the local-JAX demo backend both implement it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Protocol, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class SweepReference:
    """Fleet-expected healthy values (from qualification of known-good
    hardware; refreshed whenever the platform generation changes)."""
    device_tflops: float          # sustained matmul TFLOP/s per device
    intra_bw_gbps: float          # pairwise interconnect GB/s
    pair_step_time: float         # 2-node sweep-workload step time, s


@dataclasses.dataclass(frozen=True)
class SweepConfig:
    # single-node
    compute_tolerance: float = 0.07      # device within 7% of reference
    symmetry_tolerance: float = 0.05     # device within 5% of node median
    bw_tolerance: float = 0.10           # pair bw within 10% of reference
    burn_seconds: float = 120.0          # per-device sustained burn
    # multi-node
    group_size: int = 2                  # default 2-node sweeps
    sweep_steps: int = 50                # mini-workload steps per group
    inflation_tolerance: float = 0.08    # step time within 8% of reference
    # enhanced sweep = longer burns + multi-node stage (§7.2 Table 4 tier 4)
    enhanced_burn_seconds: float = 3600.0


class SweepBackend(Protocol):
    """What the sweep needs from the substrate."""

    def device_count(self, node_id: int) -> int: ...

    def compute_probe(self, node_id: int, device: int,
                      seconds: float) -> float:
        """Sustained matmul throughput, TFLOP/s."""
        ...

    def intra_bw_probe(self, node_id: int, dev_a: int, dev_b: int) -> float:
        """Pairwise intra-node interconnect bandwidth, GB/s."""
        ...

    def multi_node_probe(self, node_ids: Sequence[int],
                         steps: int) -> np.ndarray:
        """Step times (s) of a collective mini-workload over the group."""
        ...

    def reference(self) -> SweepReference: ...


@dataclasses.dataclass
class SweepReport:
    node_id: int
    passed: bool
    failures: List[str]
    duration_s: float
    measurements: Dict[str, object]


def single_node_sweep(backend: SweepBackend, node_id: int,
                      cfg: Optional[SweepConfig] = None,
                      enhanced: bool = False) -> SweepReport:
    cfg = cfg or SweepConfig()
    ref = backend.reference()
    nd = backend.device_count(node_id)
    burn = cfg.enhanced_burn_seconds if enhanced else cfg.burn_seconds
    failures: List[str] = []

    tflops = np.array([backend.compute_probe(node_id, d, burn)
                       for d in range(nd)])
    node_med = np.median(tflops)
    for d in range(nd):
        if tflops[d] < ref.device_tflops * (1 - cfg.compute_tolerance):
            failures.append(
                f"compute dev{d}: {tflops[d]:.1f} TF/s < "
                f"{(1 - cfg.compute_tolerance) * ref.device_tflops:.1f}")
        if tflops[d] < node_med * (1 - cfg.symmetry_tolerance):
            failures.append(
                f"asymmetry dev{d}: {tflops[d]:.1f} TF/s vs node median "
                f"{node_med:.1f}")

    # pairwise interconnect: ring + a few cross pairs covers every device
    pairs = [(a, (a + 1) % nd) for a in range(nd)]
    pairs += [(a, (a + nd // 2) % nd) for a in range(nd // 2)]
    bw = {}
    for a, b in pairs:
        g = backend.intra_bw_probe(node_id, a, b)
        bw[(a, b)] = g
        if g < ref.intra_bw_gbps * (1 - cfg.bw_tolerance):
            failures.append(
                f"intra-bw {a}<->{b}: {g:.0f} GB/s < "
                f"{(1 - cfg.bw_tolerance) * ref.intra_bw_gbps:.0f}")

    duration = burn * nd / max(nd, 1) + 30.0 * len(pairs)
    return SweepReport(node_id, not failures, failures, duration,
                       {"tflops": tflops, "bw": bw})


def multi_node_sweep(backend: SweepBackend, node_id: int,
                     buddies: Sequence[int],
                     cfg: Optional[SweepConfig] = None) -> SweepReport:
    """Sweep ``node_id`` in a group with known-good ``buddies``."""
    cfg = cfg or SweepConfig()
    ref = backend.reference()
    group = [node_id, *buddies][: max(cfg.group_size, 2)]
    times = backend.multi_node_probe(group, cfg.sweep_steps)
    med = float(np.median(times))
    failures = []
    if med > ref.pair_step_time * (1 + cfg.inflation_tolerance):
        failures.append(
            f"group step time {med:.3f}s > "
            f"{(1 + cfg.inflation_tolerance) * ref.pair_step_time:.3f}s "
            f"(group={group})")
    duration = med * cfg.sweep_steps
    return SweepReport(node_id, not failures, failures, duration,
                       {"group": group, "step_times": times})


def qualification_sweep(backend: SweepBackend, node_id: int,
                        buddies: Sequence[int],
                        cfg: Optional[SweepConfig] = None,
                        enhanced: bool = True) -> SweepReport:
    """Full offline qualification: single-node stage, then (enhanced only)
    the 2-node collective stage. Conservative: all stages must pass."""
    cfg = cfg or SweepConfig()
    rep = single_node_sweep(backend, node_id, cfg, enhanced=enhanced)
    if not enhanced:
        return rep
    if rep.passed and buddies:
        multi = multi_node_sweep(backend, node_id, buddies, cfg)
        rep = SweepReport(
            node_id, rep.passed and multi.passed,
            rep.failures + multi.failures,
            rep.duration_s + multi.duration_s,
            {**rep.measurements, **multi.measurements})
    return rep
