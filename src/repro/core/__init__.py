"""Guard — the paper's contribution: scalable straggler detection and node
health management for large-scale training.

  telemetry       metric schema, ring buffers, Collector protocol (§4.1)
  detector        peer-relative multi-signal temporal detection (§4.2)
  policy          tiered response policy (§4.2)
  monitor         online monitoring loop -> HealthEvents (§4)
  sweep           offline single-/multi-node qualification sweeps (§5)
  triage          remediation FSM + 3-strikes rule (§6, Fig. 8)
  health_manager  closed loop: pools, swaps, event-driven sweeps (Fig. 1)
"""
from repro.core.detector import (DetectorConfig, FleetAssessment,
                                 NodeAssessment, StragglerDetector,
                                 robust_z)
from repro.core.health_manager import (ClusterControl, HealthManager,
                                       ManagerStats, NodeState,
                                       QualificationTicket)
from repro.core.monitor import HealthEvent, OnlineMonitor
from repro.core.policy import Action, Decision, PolicyConfig, TieredPolicy
from repro.core.sweep import (CampaignResult, SweepBackend, SweepCampaign,
                              SweepConfig, SweepReference, SweepReport,
                              fleet_qualification, intra_pairs,
                              multi_node_sweep, qualification_sweep,
                              single_node_sweep)
from repro.core.telemetry import (HARDWARE_METRICS, METRIC_DIRECTION, METRICS,
                                  Collector, Frame, RingHistory,
                                  reduce_device_metrics)
from repro.core.triage import (ErrorSignals, Stage, TriageConfig,
                               TriageOutcome, TriageResult, TriageWorkflow)

__all__ = [
    "Action", "CampaignResult", "ClusterControl", "Collector", "Decision",
    "DetectorConfig",
    "ErrorSignals", "FleetAssessment", "Frame", "HARDWARE_METRICS",
    "HealthEvent",
    "HealthManager", "METRICS", "METRIC_DIRECTION", "ManagerStats",
    "NodeAssessment", "NodeState", "OnlineMonitor", "PolicyConfig",
    "QualificationTicket",
    "RingHistory", "Stage", "StragglerDetector", "SweepBackend",
    "SweepCampaign",
    "SweepConfig", "SweepReference", "SweepReport", "TieredPolicy",
    "TriageConfig", "TriageOutcome", "TriageResult", "TriageWorkflow",
    "fleet_qualification", "intra_pairs", "multi_node_sweep",
    "qualification_sweep", "reduce_device_metrics",
    "robust_z", "single_node_sweep",
]
