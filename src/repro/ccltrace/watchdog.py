"""Barrier-timeout hang watchdog with culprit/victim attribution.

A hung collective is the failure the z-score path cannot see: step times
stop arriving entirely, so there is no sample to score. Today's
framework-level answer is the CCL abort — kill the job after a long
fixed silence and restart blind, with the wedged rank still in it. The
watchdog replaces that with CCL-D's slow-vs-hang taxonomy:

  deadline rule    a group is HUNG when its in-flight collective has
                   been pending longer than ``clamp(mult * trailing,
                   floor, cap)`` where ``trailing`` is the group's worst
                   span duration over the trace's kept windows (the
                   ``default_deadline_s`` fallback covers a cold trace).

  classification   per involved rank, from observable span state only:

                   | entered | link evidence | role                    |
                   |---------|---------------|--------------------------|
                   | no      | (any)         | culprit — never entered  |
                   | yes     | yes           | culprit — entered+stalled|
                   | yes     | no            | victim — blocked barrier |

                   If SOME ranks never arrived, they are the culprits
                   and every rank that did arrive is a victim. If ALL
                   ranks arrived and the collective still never
                   completed, blame needs independent link evidence
                   (down port, degraded quality, error-counter creep);
                   with none, the verdict carries victims only —
                   detection without attribution beats a false eviction.

The same deadline rule backs ``GuardStepHook``'s per-step liveness path
(``adaptive_deadline`` over the hook's rolling healthy baseline), so the
single-host and fleet-side detectors stay consistent.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.ccltrace.spans import CollectiveSpanTrace, PendingCollective


def adaptive_deadline(trailing_s: float, mult: float,
                      floor_s: float, cap_s: float) -> float:
    """``clamp(mult * trailing, floor, cap)`` — the shared deadline rule
    of the collective watchdog (trailing = group's worst recent span)
    and the step hook's liveness path (trailing = healthy step wall)."""
    return float(min(max(mult * trailing_s, floor_s), cap_s))


@dataclasses.dataclass(frozen=True)
class WatchdogConfig:
    """Deadline-rule knobs.

    ``deadline_mult`` trades detection latency against false alarms on a
    legitimately slow collective: 8x the worst recent span is far above
    any fail-slow inflation the detector would tolerate, yet orders of
    magnitude below the framework CCL abort. ``min_history`` windows of
    span history are required before the adaptive rule engages;
    a cold trace falls back to ``default_deadline_s``."""

    deadline_mult: float = 8.0
    deadline_floor_s: float = 30.0
    deadline_cap_s: float = 600.0
    default_deadline_s: float = 120.0
    min_history: int = 2


class HangRole(str, enum.Enum):
    """CCL-D classification of a rank involved in a hung collective."""

    CULPRIT_NEVER_ENTERED = "never_entered"
    CULPRIT_STALLED = "entered_stalled"
    VICTIM = "victim"


CULPRIT_ROLES = (HangRole.CULPRIT_NEVER_ENTERED, HangRole.CULPRIT_STALLED)


@dataclasses.dataclass(frozen=True)
class HangVerdict:
    """One hung group's attribution: who to evict, who to leave alone."""

    t: float                             # verdict time
    step: int                            # step the job hung on
    op: str
    group: int
    waited_s: float                      # pending time at verdict
    deadline_s: float                    # the deadline that tripped
    culprits: Tuple[int, ...]            # node ids to pull from the job
    victims: Tuple[int, ...]             # node ids blocked on the barrier
    roles: Dict[int, HangRole]           # every involved rank

    @property
    def attributed(self) -> bool:
        return bool(self.culprits)


class HangWatchdog:
    """Polls a ``PendingCollective`` snapshot against per-group adaptive
    deadlines and classifies the overdue groups' ranks.

    One verdict per (hang onset, group): re-polling the same stuck
    collective returns nothing new, so callers can poll at window
    cadence without deduplicating downstream."""

    def __init__(self, spans: Optional[CollectiveSpanTrace] = None,
                 cfg: Optional[WatchdogConfig] = None):
        self.spans = spans
        self.cfg = cfg or WatchdogConfig()
        self.verdicts: List[HangVerdict] = []
        self._fired: Set[Tuple[float, int]] = set()

    # ----------------------------------------------------------- deadline

    def group_deadline_s(self, trailing_span_s: Optional[float]) -> float:
        """Deadline for one group given its trailing worst span (None ->
        cold-trace fallback)."""
        cfg = self.cfg
        if trailing_span_s is None:
            return cfg.default_deadline_s
        return adaptive_deadline(trailing_span_s, cfg.deadline_mult,
                                 cfg.deadline_floor_s, cfg.deadline_cap_s)

    def _trailing(self, pend: PendingCollective) -> Optional[np.ndarray]:
        tr = self.spans
        if (tr is None or len(tr) < self.cfg.min_history
                or tr.node_count != len(pend.node_ids)):
            return None
        return tr.trailing_duration()

    # -------------------------------------------------------------- check

    def check(self, pend: Optional[PendingCollective],
              now: float) -> List[HangVerdict]:
        """Classify every overdue, not-yet-fired group of ``pend``."""
        if pend is None:
            return []
        waited = now - pend.t_start
        if waited <= 0:
            return []
        trail = self._trailing(pend)
        out: List[HangVerdict] = []
        for g in np.unique(pend.group_of):
            rows = pend.group_of == g
            if bool(pend.completed[rows].all()):
                continue                 # this group's op finished
            dl = self.group_deadline_s(
                None if trail is None else float(trail[rows].max()))
            if waited < dl:
                continue
            key = (round(pend.t_start, 6), int(g))
            if key in self._fired:
                continue
            self._fired.add(key)
            out.append(self._classify(pend, rows, int(g), now, waited, dl))
        self.verdicts.extend(out)
        return out

    def _classify(self, pend: PendingCollective, rows: np.ndarray,
                  group: int, now: float, waited: float,
                  deadline: float) -> HangVerdict:
        ids = pend.node_ids[rows]
        entered = pend.entered[rows]
        suspect = pend.nic_suspect[rows]
        roles: Dict[int, HangRole] = {}
        if not bool(entered.all()):
            # some ranks never arrived: they are the culprits, everyone
            # who did arrive is blocked on the barrier behind them
            for nid, ent in zip(ids, entered):
                roles[int(nid)] = (HangRole.VICTIM if ent
                                   else HangRole.CULPRIT_NEVER_ENTERED)
        else:
            # all arrived and the op still never completed: accuse only
            # ranks with independent link evidence
            for nid, sus in zip(ids, suspect):
                roles[int(nid)] = (HangRole.CULPRIT_STALLED if sus
                                   else HangRole.VICTIM)
        culprits = tuple(n for n, r in roles.items() if r in CULPRIT_ROLES)
        victims = tuple(n for n, r in roles.items() if r is HangRole.VICTIM)
        return HangVerdict(t=float(now), step=int(pend.step), op=pend.op,
                           group=group, waited_s=float(waited),
                           deadline_s=float(deadline), culprits=culprits,
                           victims=victims, roles=roles)


__all__ = ["CULPRIT_ROLES", "HangRole", "HangVerdict", "HangWatchdog",
           "WatchdogConfig", "adaptive_deadline"]
