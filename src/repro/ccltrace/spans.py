"""Per-collective span records (the hang-diagnosis substrate).

The timing trace (``repro.diagnose.trace``) answers "where did the
window go"; it cannot answer "who is INSIDE the stuck collective right
now". ARGUS-style hang diagnosis needs collective-granular spans: for
each blocking collective, when did every rank *enter* (finish the work
that precedes the barrier) and when did the group *exit* (the collective
completed). ``CollectiveSpanTrace`` keeps a fixed-depth history of those
spans as preallocated circular ``(depth, N)`` float arrays — the same
discipline as ``RingHistory``/``TimingTrace``: one ``push`` per
evaluation window costs one row-write per channel, never a re-stack.

Producers:

  - ``SimCluster`` feeds the trace from the step-time model itself
    (``SimCluster.attach_spans``): enter = window-mean own pre-barrier
    work (compute + host), exit = window-mean group wall.
  - ``GuardStepHook`` feeds the watchdog's shared deadline rule from
    measured step walls (``repro.guard.hook.GuardStepHook.step_deadline``).
  - A real deployment feeds it from CCL tracing hooks (the per-collective
    enqueue/kernel-complete timeline ARGUS records).

Consumers: ``repro.ccltrace.watchdog`` reads trailing span *durations*
(exit - enter = collective time + barrier stall) to scale each group's
hang deadline, and ``PendingCollective`` snapshots the one currently
stuck collective for culprit/victim classification.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

# span channels: enter = rank finished its pre-barrier work and posted
# the collective; exit = the group's collective completed (group wall)
SPAN_CHANNELS = ("enter", "exit")


@dataclasses.dataclass
class SpanWindow:
    """One evaluation window's collective span, per rank.

    ``enter``/``exit`` are window-mean seconds from step start, aligned
    with ``node_ids``; ``group_of`` maps each row to its blocking-
    collective group id. ``exit - enter`` is the rank's collective span:
    its exposed communication plus any barrier stall behind slower
    group peers."""

    t: float
    step: int
    op: str
    node_ids: np.ndarray                 # (N,) int64
    group_of: np.ndarray                 # (N,) int64 barrier-group ids
    enter: np.ndarray                    # (N,) entered the collective at
    exit: np.ndarray                     # (N,) group collective completed

    def __post_init__(self):
        n = len(self.node_ids)
        assert self.group_of.shape == (n,), ("group_of", n)
        for ch in SPAN_CHANNELS:
            assert getattr(self, ch).shape == (n,), (ch, n)

    @property
    def duration(self) -> np.ndarray:
        """(N,) span seconds inside the collective (comm + stall)."""
        return self.exit - self.enter


class CollectiveSpanTrace:
    """Fixed-depth circular history of ``SpanWindow`` rows.

    Preallocated ``(depth, N)`` buffers per channel. Fleet membership
    changes follow the ``TimingTrace`` discipline: a resize reallocates
    (history no longer aligns), a same-size node replacement backfills
    only the changed columns so a freshly swapped-in spare never
    inherits its predecessor's span history."""

    def __init__(self, depth: int = 8):
        assert depth >= 1
        self.depth = depth
        self._bufs: Dict[str, np.ndarray] = {}     # channel -> (depth, N)
        self._ids: Optional[np.ndarray] = None
        self._group_of: Optional[np.ndarray] = None
        self._used = 0
        self._head = 0
        self._last: Optional[SpanWindow] = None
        self.generation = 0          # bumped on every (re)allocation

    # ------------------------------------------------------------- intake

    def _alloc(self, sw: SpanWindow) -> None:
        n = len(sw.node_ids)
        self._bufs = {ch: np.empty((self.depth, n)) for ch in SPAN_CHANNELS}
        self._ids = sw.node_ids.copy()
        self._used = 0
        self._head = 0
        self.generation += 1

    def push(self, sw: SpanWindow) -> None:
        ids = self._ids
        if ids is None or len(sw.node_ids) != len(ids):
            self._alloc(sw)
        elif not np.array_equal(sw.node_ids, ids):
            changed = sw.node_ids != ids
            for ch, buf in self._bufs.items():
                buf[:, changed] = getattr(sw, ch)[changed]
            self._ids = ids.copy()
            self._ids[changed] = sw.node_ids[changed]
        row = self._head
        for ch, buf in self._bufs.items():
            buf[row] = getattr(sw, ch)
        self._group_of = sw.group_of
        self._head = (row + 1) % self.depth
        self._used = min(self._used + 1, self.depth)
        self._last = sw

    # ------------------------------------------------------------ queries

    def __len__(self) -> int:
        return self._used

    @property
    def full(self) -> bool:
        return self._used == self.depth

    @property
    def node_ids(self) -> Optional[np.ndarray]:
        return self._ids

    @property
    def node_count(self) -> int:
        return 0 if self._ids is None else len(self._ids)

    @property
    def group_of(self) -> Optional[np.ndarray]:
        """(N,) barrier-group id per row, from the latest push."""
        return self._group_of

    def last(self) -> SpanWindow:
        if self._last is None:
            raise IndexError("empty span trace")
        return self._last

    def rows(self, channel: str) -> np.ndarray:
        """(used, N) raw buffer rows in ARBITRARY window order — zero-copy
        view for order-invariant reductions. Callers must not mutate."""
        return self._bufs[channel][:self._used]

    def duration_rows(self) -> np.ndarray:
        """(used, N) span seconds (exit - enter) per kept window."""
        return self.rows("exit") - self.rows("enter")

    def trailing_duration(self) -> np.ndarray:
        """(N,) per-rank worst span over the kept windows — the basis of
        the watchdog's adaptive deadline (order-invariant max)."""
        return self.duration_rows().max(axis=0)

    def clear(self) -> None:
        self._used = 0
        self._head = 0
        self._last = None


@dataclasses.dataclass
class PendingCollective:
    """Observable snapshot of ONE stuck in-flight collective.

    This is what a CCL tracing layer can actually see at hang time —
    which ranks posted the collective and when, which groups already
    completed theirs, and which ranks show independent link evidence
    (down/degraded port, error-counter creep). It deliberately carries
    no ground-truth fault state; the watchdog classifies from these
    fields alone.

    ``enter_t`` is absolute seconds for ranks that entered and ``inf``
    for ranks that never arrived. A group whose members all completed
    (``completed``) is not hung on THIS op — its ranks block at the next
    global sync point and are out of scope for the verdict."""

    t_start: float                       # hang onset (step start)
    step: int
    op: str
    node_ids: np.ndarray                 # (N,) int64
    group_of: np.ndarray                 # (N,) int64
    entered: np.ndarray                  # (N,) bool — posted the collective
    enter_t: np.ndarray                  # (N,) float, inf if never entered
    completed: np.ndarray                # (N,) bool — group's op finished
    nic_suspect: np.ndarray              # (N,) bool — link evidence

    def __post_init__(self):
        n = len(self.node_ids)
        for ch in ("group_of", "entered", "enter_t", "completed",
                   "nic_suspect"):
            assert getattr(self, ch).shape == (n,), (ch, n)


__all__ = ["SPAN_CHANNELS", "CollectiveSpanTrace", "PendingCollective",
           "SpanWindow"]
