"""``repro.ccltrace`` — collective-granular tracing and hang detection.

  spans      per-collective span ring buffers (enter/exit per rank, in
             the circular (depth, N) TimingTrace idiom) + the observable
             ``PendingCollective`` snapshot of a stuck collective
  watchdog   barrier-timeout hang detector: adaptive per-group deadline
             from trailing span durations, CCL-D culprit/victim
             classification (never-entered / entered-and-stalled vs
             arrived-and-blocked)

This package is substrate-free: it imports neither the simulator nor
the guard loop, so both (and a real CCL tracing layer) can feed it.
"""
from repro.ccltrace.spans import (SPAN_CHANNELS, CollectiveSpanTrace,
                                  PendingCollective, SpanWindow)
from repro.ccltrace.watchdog import (CULPRIT_ROLES, HangRole, HangVerdict,
                                     HangWatchdog, WatchdogConfig,
                                     adaptive_deadline)

__all__ = [
    "CULPRIT_ROLES", "CollectiveSpanTrace", "HangRole", "HangVerdict",
    "HangWatchdog", "PendingCollective", "SPAN_CHANNELS", "SpanWindow",
    "WatchdogConfig", "adaptive_deadline",
]
