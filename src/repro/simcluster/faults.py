"""Fault catalogue and injection model.

Each fault kind reproduces a §3 degradation pattern:

  THERMAL       cooling deficiency -> device temp target rises -> Table-2
                downclocking (compute straggler)
  POWER         power-delivery deficit: 10-15% low draw, full utilization,
                reduced sustained FLOPS (§3.3)
  MEM_ECC       marginal memory: stalls, reduced effective bandwidth
  NIC_DOWN      adapter dead; traffic reroutes via link 0 (§3.2, Table 1)
  NIC_DEGRADED  lossy/downtrained link: reduced bandwidth + error counters
  HOST_CPU      bad CPU allocation/frequency settings (Fig. 2)
  CONGESTION    transient fabric congestion: short comm spikes, NOT a node
                fault (the detector must not quarantine for these)
  FAIL_STOP     hard crash — the fail-fast class traditional checks catch
  COLLECTIVE_HANG  a rank wedges around a blocking collective (CCL-D's
                hang class): device -1 = stuck BEFORE the collective
                (never enters), device >= 0 = deadlocked INSIDE it with
                error-counter creep on the stuck channel. The job's
                barrier never completes — steps stop, no crash
  NIC_BROWNOUT  intermittent link brownout: heavy downtraining + error
                bursts; severe episodes (severity >= BROWNOUT_HANG_SEV)
                wedge the in-flight collective outright

Grey (fail-slow) faults carry an ESCALATION clock: unmitigated, a degrading
component eventually hard-fails. This is what gives proactive removal its
MTTF benefit (§7.2): pulling a grey node early prevents the later crash.

The injector is event-driven: Poisson arrivals are pre-sampled as per-kind
exponential next-arrival clocks, and every future state change (arrival,
transient expiry, escalation, scheduled scenario injection) lives on one
time-ordered heap. ``tick`` pops only the events that actually fire inside
the interval, and active faults are indexed per node and counted per kind
in fleet-width arrays — so per-window cost scales with fired events, not
with the monotonically growing fault history, and ``next_change_t`` gives
the sim engine an exact horizon for batching whole windows of steps.
"""
from __future__ import annotations

import dataclasses
import enum
import heapq
import itertools
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.simcluster.node import Fleet


class FaultKind(enum.Enum):
    THERMAL = "thermal"
    POWER = "power"
    MEM_ECC = "mem_ecc"
    NIC_DOWN = "nic_down"
    NIC_DEGRADED = "nic_degraded"
    HOST_CPU = "host_cpu"
    CONGESTION = "congestion"
    FAIL_STOP = "fail_stop"
    COLLECTIVE_HANG = "collective_hang"
    NIC_BROWNOUT = "nic_brownout"


GREY_KINDS = (FaultKind.THERMAL, FaultKind.POWER, FaultKind.MEM_ECC,
              FaultKind.NIC_DOWN, FaultKind.NIC_DEGRADED, FaultKind.HOST_CPU)

# hang-capable kinds and the Fleet.hang_phase values they induce
# (repro.ccltrace taxonomy: a never-entering rank outranks a stalled one)
HANG_KINDS = (FaultKind.COLLECTIVE_HANG, FaultKind.NIC_BROWNOUT)
HANG_NONE, HANG_STALLED, HANG_NEVER_ENTER = 0, 1, 2
# brownout severity at or above which the in-flight collective wedges
# (below it the link is merely slow — the z-score path's territory)
BROWNOUT_HANG_SEV = 0.55

# which remediation stages can clear which fault kinds (triage FSM model)
REMEDIATION_FIX: Dict[str, tuple] = {
    "gpu_reset": (FaultKind.THERMAL,),            # driver reset re-seats clocks
    "nic_reset": (FaultKind.NIC_DEGRADED, FaultKind.NIC_BROWNOUT),
    "reboot": (FaultKind.THERMAL, FaultKind.NIC_DEGRADED, FaultKind.HOST_CPU,
               FaultKind.MEM_ECC, FaultKind.COLLECTIVE_HANG,
               FaultKind.NIC_BROWNOUT),
    "reimage": (FaultKind.THERMAL, FaultKind.NIC_DEGRADED, FaultKind.HOST_CPU,
                FaultKind.MEM_ECC, FaultKind.NIC_DOWN,
                FaultKind.COLLECTIVE_HANG, FaultKind.NIC_BROWNOUT),
}
# probability each stage actually clears an eligible fault
REMEDIATION_P = {"gpu_reset": 0.5, "nic_reset": 0.5, "reboot": 0.6,
                 "reimage": 0.8}


@dataclasses.dataclass
class Fault:
    fid: int
    kind: FaultKind
    node: int
    device: int                      # device/link index (-1: node-level)
    severity: float                  # kind-specific magnitude in [0, 1]
    t_start: float
    t_end: Optional[float]           # None = persistent until remediated
    escalate_at: Optional[float]     # grey -> fail-stop time (None = never)
    active: bool = True
    t_cleared: Optional[float] = None  # when the fault actually reverted
    # (injector clock at revert; None while active — benchmark ground
    # truth for was-this-node-faulty-at-time-t queries)

    def active_at(self, t: float) -> bool:
        if t < self.t_start:
            return False
        return self.t_cleared is None or t < self.t_cleared


@dataclasses.dataclass(frozen=True)
class FaultRates:
    """Poisson arrival rates, events per node-hour (fitted so an unmanaged
    multi-week run degrades the way §3/§7 describes: total grey arrival
    ~3.3e-3/node-h, background hard-failure ~4.7e-4/node-h)."""
    thermal: float = 1.0e-3
    power: float = 0.6e-3
    mem_ecc: float = 0.4e-3
    nic_down: float = 0.4e-3
    nic_degraded: float = 0.6e-3
    host_cpu: float = 0.3e-3
    congestion: float = 3.0e-2       # transient, short-lived
    fail_stop: float = 4.7e-4        # background hard-failure rate
    # hang-class arrivals default OFF: they freeze the job's collective,
    # so runs opt in via scenarios or explicit rates (and rate-0 kinds
    # draw no rng, keeping pre-existing runs bit-identical)
    collective_hang: float = 0.0
    nic_brownout: float = 0.0
    # mean time for an unmitigated grey fault to escalate to fail-stop
    escalation_mean_s: float = 90 * 3600.0
    # fraction of freshly provisioned nodes that are grey on arrival
    # (they passed burn-in — §5.1)
    admission_grey_p: float = 0.08

    def rate_of(self, kind: FaultKind) -> float:
        return {
            FaultKind.THERMAL: self.thermal,
            FaultKind.POWER: self.power,
            FaultKind.MEM_ECC: self.mem_ecc,
            FaultKind.NIC_DOWN: self.nic_down,
            FaultKind.NIC_DEGRADED: self.nic_degraded,
            FaultKind.HOST_CPU: self.host_cpu,
            FaultKind.CONGESTION: self.congestion,
            FaultKind.FAIL_STOP: self.fail_stop,
            FaultKind.COLLECTIVE_HANG: self.collective_hang,
            FaultKind.NIC_BROWNOUT: self.nic_brownout,
        }[kind]


# heap event ops
_EXPIRE = "expire"
_ESCALATE = "escalate"
_INJECT = "inject"           # pre-scheduled (scenario-layer) injection


class FaultInjector:
    def __init__(self, fleet: Fleet, rates: Optional[FaultRates] = None,
                 seed: int = 1):
        self.fleet = fleet
        self.rates = rates or FaultRates()
        self.rng = np.random.RandomState(seed)
        self.faults: List[Fault] = []            # full history (audit only)
        self._next_id = itertools.count()
        self._seq = itertools.count()            # heap tie-break
        # future state changes: (t, seq, op, payload)
        self._heap: List[Tuple[float, int, str, object]] = []
        # per-kind Poisson next-arrival clocks (exponential inter-arrivals,
        # rescaled when the active-set size changes — memorylessness makes
        # that exact); (time, seq) so merge order with the heap is total
        self._arrival: Dict[FaultKind, Tuple[float, int]] = {}
        self._n_active = -1                      # -1: clocks not seeded yet
        # active-fault indexes: per node for revert ops, per kind for O(1)
        # error-signal queries
        self._by_node: Dict[int, List[Fault]] = {}
        self._kind_count: Dict[FaultKind, np.ndarray] = {
            k: np.zeros(fleet.n, dtype=np.int64) for k in FaultKind}
        # transient congestion multiplies a node's comm time; maintained
        # incrementally (multiply on arrival, divide on expiry, snapped
        # back to exactly 1.0 when a node's active-congestion count hits
        # zero) so one event costs O(1), not an O(N) rebuild
        self.congestion_factor = np.ones(fleet.n)
        self._cong_count = np.zeros(fleet.n, dtype=np.int64)
        # injector clock: the latest sim time this injector has seen;
        # stamps Fault.t_cleared for audit/ground-truth queries
        self.t_last = 0.0

    # --------------------------------------------------------- creation

    def inject(self, kind: FaultKind, node: int, now: float = 0.0,
               severity: Optional[float] = None,
               device: Optional[int] = None,
               duration_s: Optional[float] = None) -> Fault:
        """Deterministic manual fault injection (benchmarks/tests/scenarios).

        ``duration_s`` bounds the fault in time (auto-revert; used by the
        scenario layer for e.g. maintenance windows); time-bounded faults
        do not escalate."""
        return self._mk(kind, node, now, severity, device,
                        duration_s=duration_s)

    def schedule(self, kind: FaultKind, node: int, at: float,
                 severity: Optional[float] = None,
                 device: Optional[int] = None,
                 duration_s: Optional[float] = None) -> None:
        """Pre-schedule an injection at absolute sim time ``at`` (the
        scenario layer's primitive for correlated future events)."""
        spec = (kind, int(node), severity, device, duration_s)
        heapq.heappush(self._heap, (at, next(self._seq), _INJECT, spec))

    def _mk(self, kind: FaultKind, node: int, now: float,
            severity: Optional[float] = None,
            device: Optional[int] = None,
            duration_s: Optional[float] = None) -> Fault:
        r = self.rates
        dev = int(self.rng.randint(self.fleet.d)) if device is None \
            else int(device)
        sev = severity if severity is not None else float(
            np.clip(self.rng.beta(2, 3), 0.05, 0.95))
        t_end = None
        esc = None
        if duration_s is not None:
            t_end = now + float(duration_s)
        elif kind == FaultKind.CONGESTION:
            t_end = now + float(self.rng.uniform(30, 180))
        elif kind in GREY_KINDS:
            esc = now + float(self.rng.exponential(r.escalation_mean_s))
        self.t_last = max(self.t_last, now)
        f = Fault(next(self._next_id), kind, node, dev, sev, now, t_end, esc)
        self.faults.append(f)
        self._register(f)
        self._apply(f)
        if t_end is not None:
            heapq.heappush(self._heap, (t_end, next(self._seq), _EXPIRE, f))
        elif esc is not None:
            heapq.heappush(self._heap, (esc, next(self._seq), _ESCALATE, f))
        return f

    @staticmethod
    def _cong_mult(severity: float) -> float:
        return 1.0 + 0.5 + 1.5 * severity

    def _register(self, f: Fault) -> None:
        self._by_node.setdefault(f.node, []).append(f)
        self._kind_count[f.kind][f.node] += 1
        if f.kind == FaultKind.CONGESTION:
            self._cong_count[f.node] += 1
            self.congestion_factor[f.node] *= self._cong_mult(f.severity)
        elif f.kind in HANG_KINDS:
            self._refresh_hang(f.node)

    def _unregister(self, f: Fault) -> None:
        lst = self._by_node.get(f.node)
        if lst is not None and f in lst:
            lst.remove(f)
        self._kind_count[f.kind][f.node] -= 1
        if f.kind == FaultKind.CONGESTION:
            self._cong_count[f.node] -= 1
            if self._cong_count[f.node] == 0:
                self.congestion_factor[f.node] = 1.0   # exact recovery
            else:
                self.congestion_factor[f.node] /= self._cong_mult(f.severity)
        elif f.kind in HANG_KINDS:
            self._refresh_hang(f.node)

    def _refresh_hang(self, node: int) -> None:
        """Recompute one node's hang phase from its remaining active
        hang-class faults (never-enter outranks stalled)."""
        phase = HANG_NONE
        for f in self.active_faults(node):
            if f.kind == FaultKind.COLLECTIVE_HANG:
                phase = max(phase, HANG_NEVER_ENTER if f.device < 0
                            else HANG_STALLED)
            elif (f.kind == FaultKind.NIC_BROWNOUT
                  and f.severity >= BROWNOUT_HANG_SEV):
                phase = max(phase, HANG_STALLED)
        self.fleet.hang_phase[node] = phase
        self.fleet.state_version += 1

    def _apply(self, f: Fault) -> None:
        fl = self.fleet
        k, n, d, s = f.kind, f.node, f.device, f.severity
        if k == FaultKind.THERMAL:
            # severity -> target temperature 65..90 °C
            fl.temp_target[n, d] = 65.0 + 25.0 * s
            fl.mark_thermal_dirty()
        elif k == FaultKind.POWER:
            fl.power_factor[n, d] = 1.0 - (0.08 + 0.12 * s)   # 8-20% deficit
            fl.refresh_node_perf(n)
        elif k == FaultKind.MEM_ECC:
            fl.mem_factor[n, d] = 1.0 - (0.05 + 0.15 * s)
            fl.refresh_node_perf(n)
        elif k == FaultKind.NIC_DOWN:
            fl.nic_up[n, d] = False
            fl.nic_err_count[n, d] += 1000
            fl.invalidate_link_state(node=n)
        elif k == FaultKind.NIC_DEGRADED:
            fl.nic_quality[n, d] = 1.0 - (0.2 + 0.5 * s)
            fl.invalidate_link_state(node=n)
        elif k == FaultKind.HOST_CPU:
            fl.host_factor[n] = 1.0 - (0.2 + 0.4 * s)
        elif k == FaultKind.CONGESTION:
            pass                     # factor maintained by _register
        elif k == FaultKind.FAIL_STOP:
            fl.alive[n] = False
        elif k == FaultKind.COLLECTIVE_HANG:
            # hang_phase maintained by _register/_refresh_hang; a rank
            # deadlocked INSIDE the collective (device >= 0) leaves
            # observable error-counter creep on the stuck channel — the
            # evidence the watchdog's entered-and-stalled verdict needs
            if d >= 0:
                fl.nic_err_count[n, d] += 400
                fl.invalidate_link_state(node=n)
        elif k == FaultKind.NIC_BROWNOUT:
            fl.nic_quality[n, d] = 1.0 - (0.45 + 0.45 * s)
            fl.nic_err_count[n, d] += 200 + 600 * s
            fl.invalidate_link_state(node=n)

    def _revert(self, f: Fault, at: Optional[float] = None) -> None:
        if not f.active:
            return
        f.t_cleared = self.t_last if at is None else at
        fl = self.fleet
        k, n, d = f.kind, f.node, f.device
        if k == FaultKind.THERMAL:
            fl.temp_target[n, d] = fl.hw.load_temp_c
            fl.mark_thermal_dirty()
        elif k == FaultKind.POWER:
            fl.power_factor[n, d] = 1.0
            fl.refresh_node_perf(n)
        elif k == FaultKind.MEM_ECC:
            fl.mem_factor[n, d] = 1.0
            fl.refresh_node_perf(n)
        elif k == FaultKind.NIC_DOWN:
            fl.nic_up[n, d] = True
            fl.invalidate_link_state(node=n)
        elif k == FaultKind.NIC_DEGRADED:
            fl.nic_quality[n, d] = 1.0
            fl.invalidate_link_state(node=n)
        elif k == FaultKind.HOST_CPU:
            fl.host_factor[n] = 1.0
        elif k == FaultKind.CONGESTION:
            pass                     # factor maintained by _unregister
        elif k == FaultKind.COLLECTIVE_HANG:
            pass                     # hang_phase maintained by _unregister
        elif k == FaultKind.NIC_BROWNOUT:
            fl.nic_quality[n, d] = 1.0
            fl.invalidate_link_state(node=n)
        f.active = False
        self._unregister(f)

    # ----------------------------------------------------- arrival clocks

    def _sample_arrival(self, kind: FaultKind, now: float) -> None:
        rate_s = self.rates.rate_of(kind) * self._n_active / 3600.0
        if rate_s <= 0.0:
            self._arrival[kind] = (math.inf, next(self._seq))
        else:
            self._arrival[kind] = (
                now + float(self.rng.exponential(1.0 / rate_s)),
                next(self._seq))

    def _set_active_count(self, n: int, now: float) -> None:
        """(Re)scale the per-kind arrival clocks to the active-set size.

        An exponential clock conditioned on not having fired is still
        exponential, so remaining time scales by old_n/new_n exactly."""
        if n == self._n_active:
            return
        old = self._n_active
        self._n_active = n
        for kind in FaultKind:
            t, seq = self._arrival.get(kind, (math.inf, -1))
            if old <= 0 or not math.isfinite(t) or n <= 0:
                if n <= 0:
                    self._arrival[kind] = (math.inf, next(self._seq))
                else:
                    self._sample_arrival(kind, now)
            else:
                self._arrival[kind] = (now + (t - now) * old / n, seq)

    def prime(self, now: float, active_nodes: np.ndarray) -> None:
        """Seed/rescale the arrival clocks without firing anything: the
        window engine must know the true event horizon BEFORE the first
        tick of a batch (matching the clock state a per-step loop would
        have after its first tick)."""
        self.t_last = max(self.t_last, now)
        if self._n_active < 0:
            self._n_active = 0
        self._set_active_count(len(active_nodes), now)

    def next_change_t(self) -> Optional[float]:
        """Earliest future time anything about the fleet state changes:
        the sim engine batches whole windows of steps up to this horizon."""
        # drop stale heap entries (faults already reverted by other paths)
        h = self._heap
        while h and h[0][2] in (_EXPIRE, _ESCALATE) and not h[0][3].active:
            heapq.heappop(h)
        t = h[0][0] if h else math.inf
        for at, _ in self._arrival.values():
            t = min(t, at)
        return None if not math.isfinite(t) else t

    # ------------------------------------------------------------ tick

    def tick(self, now: float, dt_s: float, active_nodes: np.ndarray) -> None:
        """Fire every pre-sampled event in (now, now+dt]: Poisson
        arrivals, transient expiries, grey escalations and scheduled
        scenario injections, in global time order. Cost is O(events
        fired), independent of how many faults have ever existed."""
        t_end = now + dt_s
        self.t_last = max(self.t_last, t_end)
        if self._n_active < 0:
            self._n_active = 0
        self._set_active_count(len(active_nodes), now)
        while True:
            # next arrival across kinds vs. next heap event, merged by
            # (time, seq) so processing order is deterministic
            akind = None
            at, aseq = math.inf, -1
            for kind, (t, seq) in self._arrival.items():
                if (t, seq) < (at, aseq) or akind is None:
                    at, aseq, akind = t, seq, kind
            ht, hseq = (self._heap[0][0], self._heap[0][1]) if self._heap \
                else (math.inf, -1)
            if min(at, ht) > t_end:
                break
            if (at, aseq) <= (ht, hseq):
                # Poisson arrival lands on a random active node
                if len(active_nodes):
                    node = int(self.rng.choice(active_nodes))
                    self._mk(akind, node, at)
                self._sample_arrival(akind, at)
            else:
                _, _, op, payload = heapq.heappop(self._heap)
                if op == _INJECT:
                    kind, node, sev, dev, dur = payload
                    self._mk(kind, node, ht, sev, dev, duration_s=dur)
                elif op == _EXPIRE:
                    self._revert(payload, at=ht)
                elif op == _ESCALATE and payload.active:
                    self._revert(payload, at=ht)
                    self._mk(FaultKind.FAIL_STOP, payload.node, ht,
                             severity=1.0)

    # ----------------------------------------------------- queries/ops

    def active_faults(self, node: Optional[int] = None) -> List[Fault]:
        if node is not None:
            return [f for f in self._by_node.get(node, ()) if f.active]
        return [f for lst in self._by_node.values() for f in lst if f.active]

    def node_error_signals(self, node: int):
        """Actionable evidence for triage routing (O(1) via kind counts)."""
        from repro.core.triage import ErrorSignals
        kc = self._kind_count
        gpu = bool(kc[FaultKind.THERMAL][node] + kc[FaultKind.MEM_ECC][node])
        nic = bool(kc[FaultKind.NIC_DOWN][node] +
                   kc[FaultKind.NIC_DEGRADED][node] +
                   kc[FaultKind.NIC_BROWNOUT][node])
        host = bool(kc[FaultKind.HOST_CPU][node] +
                    kc[FaultKind.COLLECTIVE_HANG][node])
        return ErrorSignals(gpu_errors=gpu, nic_errors=nic,
                            host_errors=host)

    def remediate(self, node: int, stage: str) -> None:
        """Apply a triage stage: eligible faults clear with stage-specific
        probability (models the paper's escalating-invasiveness ladder)."""
        eligible = REMEDIATION_FIX.get(stage, ())
        p = REMEDIATION_P.get(stage, 0.5)
        for f in self.active_faults(node):
            if f.kind in eligible and self.rng.rand() < p:
                self._revert(f)

    def clear_node(self, node: int) -> None:
        """Node replaced: all its faults go with the hardware."""
        for f in self.active_faults(node):
            self._revert(f)

    def seed_admission_grey(self, node: int, now: float) -> Optional[Fault]:
        """Fresh hardware that passed burn-in may still be grey (§5.1)."""
        if self.rng.rand() < self.rates.admission_grey_p:
            kind = self.rng.choice(
                [FaultKind.THERMAL, FaultKind.POWER, FaultKind.MEM_ECC,
                 FaultKind.NIC_DEGRADED])
            return self._mk(kind, node, now)
        return None
