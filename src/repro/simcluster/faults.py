"""Fault catalogue and injection model.

Each fault kind reproduces a §3 degradation pattern:

  THERMAL       cooling deficiency -> device temp target rises -> Table-2
                downclocking (compute straggler)
  POWER         power-delivery deficit: 10-15% low draw, full utilization,
                reduced sustained FLOPS (§3.3)
  MEM_ECC       marginal memory: stalls, reduced effective bandwidth
  NIC_DOWN      adapter dead; traffic reroutes via link 0 (§3.2, Table 1)
  NIC_DEGRADED  lossy/downtrained link: reduced bandwidth + error counters
  HOST_CPU      bad CPU allocation/frequency settings (Fig. 2)
  CONGESTION    transient fabric congestion: short comm spikes, NOT a node
                fault (the detector must not quarantine for these)
  FAIL_STOP     hard crash — the fail-fast class traditional checks catch

Grey (fail-slow) faults carry an ESCALATION clock: unmitigated, a degrading
component eventually hard-fails. This is what gives proactive removal its
MTTF benefit (§7.2): pulling a grey node early prevents the later crash.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Dict, List, Optional

import numpy as np

from repro.simcluster.node import Fleet


class FaultKind(enum.Enum):
    THERMAL = "thermal"
    POWER = "power"
    MEM_ECC = "mem_ecc"
    NIC_DOWN = "nic_down"
    NIC_DEGRADED = "nic_degraded"
    HOST_CPU = "host_cpu"
    CONGESTION = "congestion"
    FAIL_STOP = "fail_stop"


GREY_KINDS = (FaultKind.THERMAL, FaultKind.POWER, FaultKind.MEM_ECC,
              FaultKind.NIC_DOWN, FaultKind.NIC_DEGRADED, FaultKind.HOST_CPU)

# which remediation stages can clear which fault kinds (triage FSM model)
REMEDIATION_FIX: Dict[str, tuple] = {
    "gpu_reset": (FaultKind.THERMAL,),            # driver reset re-seats clocks
    "nic_reset": (FaultKind.NIC_DEGRADED,),
    "reboot": (FaultKind.THERMAL, FaultKind.NIC_DEGRADED, FaultKind.HOST_CPU,
               FaultKind.MEM_ECC),
    "reimage": (FaultKind.THERMAL, FaultKind.NIC_DEGRADED, FaultKind.HOST_CPU,
                FaultKind.MEM_ECC, FaultKind.NIC_DOWN),
}
# probability each stage actually clears an eligible fault
REMEDIATION_P = {"gpu_reset": 0.5, "nic_reset": 0.5, "reboot": 0.6,
                 "reimage": 0.8}


@dataclasses.dataclass
class Fault:
    fid: int
    kind: FaultKind
    node: int
    device: int                      # device/link index (-1: node-level)
    severity: float                  # kind-specific magnitude in [0, 1]
    t_start: float
    t_end: Optional[float]           # None = persistent until remediated
    escalate_at: Optional[float]     # grey -> fail-stop time (None = never)
    active: bool = True


@dataclasses.dataclass(frozen=True)
class FaultRates:
    """Poisson arrival rates, events per node-hour (fitted so an unmanaged
    multi-week run degrades the way §3/§7 describes: total grey arrival
    ~3.3e-3/node-h, background hard-failure ~4.7e-4/node-h)."""
    thermal: float = 1.0e-3
    power: float = 0.6e-3
    mem_ecc: float = 0.4e-3
    nic_down: float = 0.4e-3
    nic_degraded: float = 0.6e-3
    host_cpu: float = 0.3e-3
    congestion: float = 3.0e-2       # transient, short-lived
    fail_stop: float = 4.7e-4        # background hard-failure rate
    # mean time for an unmitigated grey fault to escalate to fail-stop
    escalation_mean_s: float = 90 * 3600.0
    # fraction of freshly provisioned nodes that are grey on arrival
    # (they passed burn-in — §5.1)
    admission_grey_p: float = 0.08

    def rate_of(self, kind: FaultKind) -> float:
        return {
            FaultKind.THERMAL: self.thermal,
            FaultKind.POWER: self.power,
            FaultKind.MEM_ECC: self.mem_ecc,
            FaultKind.NIC_DOWN: self.nic_down,
            FaultKind.NIC_DEGRADED: self.nic_degraded,
            FaultKind.HOST_CPU: self.host_cpu,
            FaultKind.CONGESTION: self.congestion,
            FaultKind.FAIL_STOP: self.fail_stop,
        }[kind]


class FaultInjector:
    def __init__(self, fleet: Fleet, rates: Optional[FaultRates] = None,
                 seed: int = 1):
        self.fleet = fleet
        self.rates = rates or FaultRates()
        self.rng = np.random.RandomState(seed)
        self.faults: List[Fault] = []
        self._next_id = itertools.count()
        # transient congestion multiplies a node's comm time
        self.congestion_factor = np.ones(fleet.n)

    # --------------------------------------------------------- creation

    def inject(self, kind: FaultKind, node: int, now: float = 0.0,
               severity: Optional[float] = None,
               device: Optional[int] = None) -> Fault:
        """Deterministic manual fault injection (benchmarks/tests)."""
        return self._mk(kind, node, now, severity, device)

    def _mk(self, kind: FaultKind, node: int, now: float,
            severity: Optional[float] = None,
            device: Optional[int] = None) -> Fault:
        r = self.rates
        dev = int(self.rng.randint(self.fleet.d)) if device is None \
            else int(device)
        sev = severity if severity is not None else float(
            np.clip(self.rng.beta(2, 3), 0.05, 0.95))
        t_end = None
        esc = None
        if kind == FaultKind.CONGESTION:
            t_end = now + float(self.rng.uniform(30, 180))
        elif kind in GREY_KINDS:
            esc = now + float(self.rng.exponential(r.escalation_mean_s))
        f = Fault(next(self._next_id), kind, node, dev, sev, now, t_end, esc)
        self.faults.append(f)
        self._apply(f)
        return f

    def _apply(self, f: Fault) -> None:
        fl = self.fleet
        k, n, d, s = f.kind, f.node, f.device, f.severity
        if k == FaultKind.THERMAL:
            # severity -> target temperature 65..90 °C
            fl.temp_target[n, d] = 65.0 + 25.0 * s
        elif k == FaultKind.POWER:
            fl.power_factor[n, d] = 1.0 - (0.08 + 0.12 * s)   # 8-20% deficit
        elif k == FaultKind.MEM_ECC:
            fl.mem_factor[n, d] = 1.0 - (0.05 + 0.15 * s)
        elif k == FaultKind.NIC_DOWN:
            fl.nic_up[n, d] = False
            fl.nic_err_count[n, d] += 1000
        elif k == FaultKind.NIC_DEGRADED:
            fl.nic_quality[n, d] = 1.0 - (0.2 + 0.5 * s)
        elif k == FaultKind.HOST_CPU:
            fl.host_factor[n] = 1.0 - (0.2 + 0.4 * s)
        elif k == FaultKind.CONGESTION:
            self.congestion_factor[n] *= (1.0 + 0.5 + 1.5 * s)
        elif k == FaultKind.FAIL_STOP:
            fl.alive[n] = False

    def _revert(self, f: Fault) -> None:
        fl = self.fleet
        k, n, d = f.kind, f.node, f.device
        if k == FaultKind.THERMAL:
            fl.temp_target[n, d] = fl.hw.load_temp_c
        elif k == FaultKind.POWER:
            fl.power_factor[n, d] = 1.0
        elif k == FaultKind.MEM_ECC:
            fl.mem_factor[n, d] = 1.0
        elif k == FaultKind.NIC_DOWN:
            fl.nic_up[n, d] = True
        elif k == FaultKind.NIC_DEGRADED:
            fl.nic_quality[n, d] = 1.0
        elif k == FaultKind.HOST_CPU:
            fl.host_factor[n] = 1.0
        elif k == FaultKind.CONGESTION:
            pass  # factor rebuilt every tick
        f.active = False

    # ------------------------------------------------------------ tick

    def tick(self, now: float, dt_s: float, active_nodes: np.ndarray) -> None:
        """Sample arrivals over [now, now+dt) and expire/escalate faults
        (expiry/escalation evaluated at the interval END)."""
        hours = dt_s / 3600.0
        t_end = now + dt_s
        for kind in FaultKind:
            lam = self.rates.rate_of(kind) * hours * len(active_nodes)
            for _ in range(self.rng.poisson(lam)):
                node = int(self.rng.choice(active_nodes))
                self._mk(kind, node, now)

        self.congestion_factor[:] = 1.0
        for f in self.faults:
            if not f.active:
                continue
            if f.t_end is not None and t_end >= f.t_end:
                self._revert(f)
            elif f.kind == FaultKind.CONGESTION:
                self._apply(f)           # rebuild transient factor
            elif f.escalate_at is not None and t_end >= f.escalate_at:
                self._revert(f)
                self._mk(FaultKind.FAIL_STOP, f.node, t_end, severity=1.0)

    # ----------------------------------------------------- queries/ops

    def active_faults(self, node: Optional[int] = None) -> List[Fault]:
        return [f for f in self.faults if f.active and
                (node is None or f.node == node)]

    def node_error_signals(self, node: int):
        """Actionable evidence for triage routing."""
        from repro.core.triage import ErrorSignals
        gpu = nic = False
        for f in self.active_faults(node):
            if f.kind in (FaultKind.THERMAL, FaultKind.MEM_ECC):
                gpu = True
            if f.kind in (FaultKind.NIC_DOWN, FaultKind.NIC_DEGRADED):
                nic = True
        return ErrorSignals(gpu_errors=gpu, nic_errors=nic)

    def remediate(self, node: int, stage: str) -> None:
        """Apply a triage stage: eligible faults clear with stage-specific
        probability (models the paper's escalating-invasiveness ladder)."""
        eligible = REMEDIATION_FIX.get(stage, ())
        p = REMEDIATION_P.get(stage, 0.5)
        for f in self.active_faults(node):
            if f.kind in eligible and self.rng.rand() < p:
                self._revert(f)

    def clear_node(self, node: int) -> None:
        """Node replaced: all its faults go with the hardware."""
        for f in self.active_faults(node):
            self._revert(f)

    def seed_admission_grey(self, node: int, now: float) -> Optional[Fault]:
        """Fresh hardware that passed burn-in may still be grey (§5.1)."""
        if self.rng.rand() < self.rates.admission_grey_p:
            kind = self.rng.choice(
                [FaultKind.THERMAL, FaultKind.POWER, FaultKind.MEM_ECC,
                 FaultKind.NIC_DEGRADED])
            return self._mk(kind, node, now)
        return None
