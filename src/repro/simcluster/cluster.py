# guardlint: hot  (fleet-sized arrays live here: float32, no per-node loops)
"""Synchronous-job step-time composition + Guard substrate adapters.

``SimCluster`` owns the fleet, the fault injector and the active node set,
and composes per-step node barrier times the way a hybrid-parallel job does:

  node_time = compute/compute_factor + comm_exposed/(comm_factor/congestion)
              + host/host_factor + noise
  step_time = max over active nodes            (synchronous collectives)

It implements all three Guard substrate protocols — telemetry ``Collector``,
``SweepBackend`` and ``ClusterControl`` — so the identical detection stack
runs over the simulator and (with different adapters) over real hardware.

The workload profile can be seeded from the *real* compiled model's roofline
terms via ``WorkloadProfile.from_roofline`` so the simulation's
compute/comm/host split matches the architecture being trained.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

import numpy as np

from repro.ccltrace.spans import (CollectiveSpanTrace, PendingCollective,
                                  SpanWindow)
from repro.core.sweep import SweepReference
from repro.core.telemetry import Frame, reduce_device_metrics
from repro.diagnose.trace import TimingTrace, WindowTiming
from repro.diagnose.whatif import Topology
from repro.simcluster.faults import (HANG_NEVER_ENTER, FaultInjector,
                                     FaultRates)
from repro.simcluster.node import Fleet, HWConfig, freq_at_temp


@dataclasses.dataclass(frozen=True)
class WorkloadProfile:
    """Healthy per-step time decomposition of one training step."""
    name: str = "guard_pretrain"
    compute_s: float = 8.0          # device-gated compute
    comm_exposed_s: float = 0.6     # non-overlapped inter-node collectives
    host_s: float = 1.4             # data loading / checkpoint / coordination
    bytes_per_link_gb: float = 4.0  # per-step per-link transmit (Fig. 4)
    step_noise: float = 0.01        # lognormal sigma on node barrier times
    mfu_at_healthy: float = 0.20    # job MFU when every node is healthy
    step_tflops: float = 4500.0     # model FLOPs per step (goodput scale)

    @property
    def healthy_step_s(self) -> float:
        return self.compute_s + self.comm_exposed_s + self.host_s

    @classmethod
    def from_roofline(cls, name: str, compute_term_s: float,
                      memory_term_s: float, collective_term_s: float,
                      host_s: float = 1.0, overlap: float = 0.7,
                      mfu: float = 0.2) -> "WorkloadProfile":
        """Seed the sim split from a compiled step's roofline terms:
        the device-gated part is max(compute, memory); a fraction
        ``overlap`` of collective time hides under compute."""
        return cls(
            name=name,
            compute_s=max(compute_term_s, memory_term_s),
            comm_exposed_s=collective_term_s * (1.0 - overlap),
            host_s=host_s,
            mfu_at_healthy=mfu,
        )


# 2-node offline-sweep mini workload (§5.3): collective-heavy by design so
# link problems dominate the measurement.
SWEEP_PROFILE = WorkloadProfile(
    name="node_sweep", compute_s=0.6, comm_exposed_s=0.5, host_s=0.1,
    step_noise=0.01)


class SimSweepBackend:
    """``SweepBackend`` over a simulated :class:`Fleet` — scalar probes
    plus the batched fleet-campaign protocol (``batch_compute_probe`` /
    ``batch_intra_bw_probe`` / ``batch_multi_node_probe``), all reading
    the same keyed probe noise and the same cached node perf factors, so
    a batched campaign over N nodes is a handful of ``(N, D)`` array
    expressions and its measurements are bit-identical to N scalar
    sweeps."""

    def __init__(self, fleet: Fleet):
        self.fleet = fleet

    def device_count(self, node_id: int) -> int:
        return self.fleet.d

    # --- compute -----------------------------------------------------

    def _effective_temp(self, temp, target, seconds: float):
        # longer burns average away sensor noise and surface slow thermal
        # ramps: let the node reach its thermal target first
        frac = min(seconds / self.fleet.hw.temp_tau_s, 5.0)
        return temp + (1 - math.exp(-frac)) * (target - temp)

    def compute_probe(self, node_id: int, device: int,
                      seconds: float) -> float:
        fl = self.fleet
        # a wedged node's burn kernel never completes: the probe times
        # out and reports zero sustained throughput (so qualification
        # fails until triage actually clears the hang)
        if fl.hang_phase[node_id]:
            return 0.0
        t_eff = self._effective_temp(fl.temp_c[node_id, device],
                                     fl.temp_target[node_id, device],
                                     seconds)
        saved = fl.temp_c[node_id, device]
        fl.temp_c[node_id, device] = t_eff
        try:
            return fl.probe_device_tflops(node_id, device)
        finally:
            fl.temp_c[node_id, device] = saved

    def batch_compute_probe(self, node_ids: Sequence[int],
                            seconds: float) -> np.ndarray:
        """(len(node_ids), D) sustained throughputs, one array pass."""
        fl = self.fleet
        idx = np.asarray(list(node_ids))
        temp = fl.temp_c[idx]
        t_eff = self._effective_temp(temp, fl.temp_target[idx], seconds)
        f = freq_at_temp(t_eff) / fl.hw.base_freq_ghz * \
            fl.power_factor[idx] * fl.mem_factor[idx]
        out = fl.hw.base_tflops * f * fl.probe_noise_compute()[idx]
        # same wedged-node timeout as the scalar probe (exact zeros keep
        # the batched-vs-scalar bit-identity contract)
        out[fl.hang_phase[idx] != 0] = 0.0
        return out

    # --- intra-node bandwidth ----------------------------------------

    def intra_bw_probe(self, node_id: int, dev_a: int, dev_b: int) -> float:
        return self.fleet.probe_intra_bw(node_id, dev_a, dev_b)

    def batch_intra_bw_probe(self, node_ids: Sequence[int],
                             pairs: Sequence[tuple]) -> np.ndarray:
        """(len(node_ids), len(pairs)) pairwise bandwidths."""
        fl = self.fleet
        idx = np.asarray(list(node_ids))
        pa = np.asarray([p[0] for p in pairs])
        pb = np.asarray([p[1] for p in pairs])
        mem = fl.mem_factor[idx]
        q = np.minimum(mem[:, pa], mem[:, pb])
        lo = np.minimum(pa, pb)
        hi = np.maximum(pa, pb)
        noise = fl.probe_noise_bw()[idx[:, None], lo[None, :], hi[None, :]]
        return fl.hw.intra_bw_gbps * q * noise

    # --- multi-node collective stage ---------------------------------

    def _group_base(self, groups: np.ndarray) -> np.ndarray:
        """(G,) noise-free group step times over the perf caches."""
        fl = self.fleet
        w = SWEEP_PROFILE
        comp = w.compute_s / fl.node_compute_factor()[groups]
        comm = w.comm_exposed_s / np.maximum(
            fl.node_comm_factor()[groups], 1e-9)
        host = w.host_s / fl.host_factor[groups]
        return (comp + comm + host).max(axis=-1)

    def multi_node_probe(self, node_ids: Sequence[int],
                         steps: int) -> np.ndarray:
        """2/4/8-node collective mini-workload (§5.3)."""
        idx = np.asarray(list(node_ids))
        base = self._group_base(idx)
        noise = np.exp(self.fleet.pair_noise(int(idx[0]), steps,
                                             SWEEP_PROFILE.step_noise))
        return base * noise

    def batch_multi_node_probe(self, groups: Sequence[Sequence[int]],
                               steps: int) -> np.ndarray:
        """(len(groups), steps) step times; group g's noise is keyed on
        its candidate (first member), exactly as the scalar probe."""
        g = np.asarray([list(gr) for gr in groups])
        base = self._group_base(g)
        sigma = SWEEP_PROFILE.step_noise
        noise = np.stack([self.fleet.pair_noise(int(gr[0]), steps, sigma)
                          for gr in g])
        return base[:, None] * np.exp(noise)

    def reference(self) -> SweepReference:
        return SweepReference(
            device_tflops=self.fleet.hw.base_tflops,
            intra_bw_gbps=self.fleet.hw.intra_bw_gbps,
            pair_step_time=SWEEP_PROFILE.healthy_step_s,
        )


class SimCluster:
    """N-node synchronous training job over a simulated fleet."""

    def __init__(self, n_active: int, n_spare: int = 16,
                 reserve: Optional[int] = None,
                 workload: Optional[WorkloadProfile] = None,
                 hw: Optional[HWConfig] = None,
                 rates: Optional[FaultRates] = None,
                 window_steps: int = 6,
                 topology: Optional[Topology] = None,
                 seed: int = 0):
        reserve = reserve if reserve is not None else max(n_active // 2, 32)
        total = n_active + n_spare + reserve
        self.fleet = Fleet(total, hw, seed=seed)
        self.injector = FaultInjector(self.fleet, rates, seed=seed + 1)
        self.sweep_backend = SimSweepBackend(self.fleet)
        self.workload = workload or WorkloadProfile()
        self.window_steps = window_steps
        # barrier-noise source; must support exact state save/restore and
        # batch==sequential gaussian streams (run_window's rewind replay)
        self.rng = np.random.Generator(np.random.SFC64(seed + 2))

        self.active = list(range(n_active))
        # initial spare population only: once these ids are registered
        # with a GuardSession/HealthManager, the manager owns pool
        # membership (take_spare/return_spare) and this list is NOT kept
        # in sync (swap_node drops a node it promotes, nothing re-adds)
        self.spares = list(range(n_active, n_active + n_spare))
        self._unprovisioned = list(range(n_active + n_spare, total))

        self.t = 0.0
        self.step = 0
        self.restarts: List[dict] = []
        self._active_arr: Optional[np.ndarray] = None
        # per-window buffers: (k, N) barrier-time blocks + one (N,) alive
        # row per committed block/step
        self._win_node_times: List[np.ndarray] = []
        self._win_alive: List[np.ndarray] = []
        # per-node cumulative NIC error baseline for window deltas;
        # re-snapshotted per node at swap-in so a spare's idle-time errors
        # are never misattributed to its first in-job window
        self._prev_err = np.zeros_like(self.fleet.nic_err_count)
        self._err_seen = self.fleet.err_version
        self._err_dirty = False
        # --- diagnosis substrate (all optional; the hot path pays only
        # when wired). ``topology`` is the blocking-collective structure:
        # when set, telemetry step_time becomes the MEASURED wall (each
        # node reports its barrier-group max — what real per-host
        # instrumentation sees, stall contamination included). A
        # ``TimingTrace`` attached via ``attach_timing`` additionally
        # receives the true per-window compute/comm/host/stall split.
        self.topology = topology
        if topology is not None:
            assert topology.n == n_active, (topology.n, n_active)
        self.timing: Optional[TimingTrace] = None
        self._parts_sum: Optional[np.ndarray] = None   # (3, N) seconds
        self._wall_sum: Optional[np.ndarray] = None    # (N,) seconds
        # collective span capture (repro.ccltrace substrate): enter =
        # own pre-barrier work (compute + host), exit = group wall
        self.spans: Optional[CollectiveSpanTrace] = None
        self._span_op = "all_reduce"
        self._enter_sum: Optional[np.ndarray] = None   # (N,) seconds

    # ------------------------------------------------------------ stepping

    def _active_idx(self) -> np.ndarray:
        """Cached ndarray view of the active list (invalidated on swap
        and on any length change, e.g. tests removing nodes in place)."""
        arr = self._active_arr
        if arr is None or len(arr) != len(self.active):
            arr = self._active_arr = np.asarray(self.active)
        return arr

    def _barrier_parts(self, idx: np.ndarray):
        """Noise-free (compute, comm, host) decomposition of the barrier
        time, each (n_active,). The single source of the step-time model
        for the per-step path, the window-batched path AND the diagnosis
        trace (their bit-identical contract depends on sharing it)."""
        w = self.workload
        comp = w.compute_s / self.fleet.node_compute_factor()[idx]
        commf = self.fleet.node_comm_factor()[idx] / \
            self.injector.congestion_factor[idx]
        comm = w.comm_exposed_s / np.maximum(commf, 1e-9)
        host = w.host_s / self.fleet.host_factor[idx]
        return comp, comm, host

    def _barrier_base(self, idx: np.ndarray) -> np.ndarray:
        """(n_active,) noise-free barrier-time composition."""
        comp, comm, host = self._barrier_parts(idx)
        return comp + comm + host

    def node_barrier_times(self) -> np.ndarray:
        """(n_active,) seconds for each node to finish the current step."""
        idx = self._active_idx()
        noise = np.exp(self.rng.standard_normal(
            len(idx), dtype=np.float32) * self.workload.step_noise)
        return self._barrier_base(idx) * noise

    # ------------------------------------------------- diagnosis capture

    def attach_timing(self, trace: TimingTrace) -> None:
        """Feed per-window timing decompositions into ``trace`` (the
        ``repro.diagnose`` substrate). One push per ``collect()``."""
        self.timing = trace

    def attach_spans(self, trace: CollectiveSpanTrace,
                     op: str = "all_reduce") -> None:
        """Feed per-window collective spans into ``trace`` (the
        ``repro.ccltrace`` substrate): enter = window-mean pre-barrier
        work (compute + host), exit = window-mean group wall, group ids
        from the attached topology (one global group without one). One
        push per ``collect()``."""
        self.spans = trace
        self._span_op = op

    def _accum_decomp(self, times: np.ndarray, dts: np.ndarray,
                      parts) -> None:
        """Accumulate one committed block's decomposition: ``times`` is
        the (k, N) own barrier times, ``dts`` the (k,) job step times,
        ``parts`` the PRE-TICK (compute, comm, host) split the times
        were composed from (the tick that closes the block may fire
        fault events — the post-event state must not relabel this
        block's seconds). O(N) per block regardless of k — the
        multiplicative step noise scales every component alike, so
        component sums derive from the own-time sums and the noise-free
        split."""
        n = times.shape[1]
        if self._parts_sum is None or self._parts_sum.shape[1] != n:
            # f64 accumulators by design: the sim composes device physics
            # in f64, Frame metrics are f64 at the collector boundary,
            # and the telemetry ring downcasts to f32 on ingest
            # guardlint: disable=GL002 reason=f64 device-physics accumulator
            self._parts_sum = np.zeros((3, n), np.float64)
            # guardlint: disable=GL002 reason=f64 device-physics accumulator
            self._wall_sum = np.zeros(n, np.float64)
            # guardlint: disable=GL002 reason=f64 device-physics accumulator
            self._enter_sum = np.zeros(n, np.float64)
        if self.timing is not None or self.spans is not None:
            comp, comm, host = parts
            scale = times.sum(axis=0) / np.maximum(comp + comm + host,
                                                   1e-12)
            if self.timing is not None:
                self._parts_sum[0] += comp * scale
                self._parts_sum[1] += comm * scale
                self._parts_sum[2] += host * scale
            if self.spans is not None:
                self._enter_sum += (comp + host) * scale
        if self.topology is not None:
            self._wall_sum += self.topology.group_max(times).sum(axis=0)
        else:
            # single global barrier: every node's wall is the step time
            self._wall_sum += float(dts.sum())

    def _reset_decomp(self) -> None:
        if self._parts_sum is not None:
            self._parts_sum[:] = 0.0
            self._wall_sum[:] = 0.0
            self._enter_sum[:] = 0.0

    def run_step(self) -> dict:
        """Advance the job by one training step; returns the step record."""
        idx = self._active_idx()
        alive = self.fleet.alive[idx]
        track = (self.timing is not None or self.topology is not None
                 or self.spans is not None)
        if track:
            # pre-tick split (the tick below may fire events that change
            # it); compose the barrier times from it directly instead of
            # rebuilding the identical components in node_barrier_times
            parts = self._barrier_parts(idx)
            noise = np.exp(self.rng.standard_normal(
                len(idx), dtype=np.float32) * self.workload.step_noise)
            times = (parts[0] + parts[1] + parts[2]) * noise
        else:
            parts = None
            times = self.node_barrier_times()
        step_time = float(times.max())
        crashed = not alive.all()

        dt = step_time if not crashed else 60.0
        self.injector.tick(self.t, dt, idx)
        self.fleet.advance_thermals(dt)
        self.fleet.account_traffic(self.workload.bytes_per_link_gb)
        self.t += dt
        if not crashed:
            self.step += 1
            self._win_node_times.append(times[None, :])
            self._win_alive.append(alive)
            if track:
                self._accum_decomp(times[None, :],
                                   np.asarray([step_time]), parts)
        return {"t": self.t, "step": self.step, "step_time": step_time,
                "crashed": crashed, "node_times": times}

    def run_window(self, steps: Optional[int] = None) -> dict:
        """Advance the job by up to one evaluation window of steps,
        batching the barrier-time composition between fault events.

        The stretch of steps up to the fault injector's
        ``next_change_t`` horizon is composed as ONE ``(k, N)``
        vectorized draw — the per-step loop, its per-step injector
        ticks, and its per-step thermal/traffic updates all collapse.
        The batch draws replay the rng stream exactly as k successive
        per-step draws would, so with no thermal ramp in flight a fixed
        seed produces trajectories bit-identical to repeated
        ``run_step`` — through instant-effect fault events (power,
        memory, NIC, host, congestion, fail-stop) included. Thermal
        ramps integrate at batch granularity: device temperatures hold
        for the span of one batch (at most one evaluation window — the
        telemetry cadence, well inside the thermal time constant) and
        then advance by the batch's total dt, reaching the same
        equilibrium as per-step integration with transiently coarser
        sampling of the throttle curve.

        Stops early on a fail-stop crash, or on a hung collective (any
        active node with nonzero ``hang_phase``): the barrier never
        completes, so no further step can commit — the record comes back
        with ``hung`` set and the caller drives the ccltrace watchdog
        (or the blind CCL-timeout fallback). Returns the window record:
        ``step_times`` holds the committed steps' job step times."""
        target = self.window_steps if steps is None else int(steps)
        step_times: List[float] = []
        crashed = False
        hung = False
        while len(step_times) < target and not crashed:
            idx = self._active_idx()
            if not self.fleet.alive[idx].all():
                self.run_step()              # crash bookkeeping path
                crashed = True
                break
            if self.fleet.hang_phase[idx].any():
                hung = True
                break
            k = target - len(step_times)
            if k == 1:
                rec = self.run_step()
                if rec["crashed"]:
                    crashed = True
                else:
                    step_times.append(rec["step_time"])
                continue
            # ---- frozen-state fast path: one (k, N) composition
            self.injector.prime(self.t, idx)
            w = self.workload
            track = (self.timing is not None or self.topology is not None
                     or self.spans is not None)
            parts = self._barrier_parts(idx) if track else None
            base = parts[0] + parts[1] + parts[2] if track \
                else self._barrier_base(idx)               # (N,)
            rng_state = self.rng.bit_generator.state
            noise = np.exp(self.rng.standard_normal(
                (k, len(idx)), dtype=np.float32) * w.step_noise)
            times = base[None, :] * noise                  # (k, N)
            dts = times.max(axis=1)
            ends = self.t + np.cumsum(dts)
            horizon = self.injector.next_change_t()
            m = k
            if horizon is not None and ends[-1] > horizon:
                # an event fires inside the window: commit only the steps
                # up to (and including) the one whose tick lands it, and
                # rewind the rng so the stream position matches m
                # per-step draws exactly
                m = min(int(np.searchsorted(ends, horizon, "left")) + 1, k)
                self.rng.bit_generator.state = rng_state
                noise = np.exp(self.rng.standard_normal(
                    (m, len(idx)), dtype=np.float32) * w.step_noise)
                times = base[None, :] * noise
                dts = times.max(axis=1)
            # rows 0..m-2 are event-free: their ticks are no-ops by
            # construction, traffic accounting runs batched, and any
            # thermal ramp integrates over the head's total time in one
            # call (a no-op for settled fleets, keeping the bitwise
            # contract); the last row's tick may land events — same
            # order as the per-step loop
            if m > 1:
                self.fleet.account_traffic(
                    (m - 1) * w.bytes_per_link_gb)
                head = 0.0
                for dt in dts[:-1]:      # sequential: bit-identical t
                    self.t += float(dt)
                    head += float(dt)
                self.fleet.advance_thermals(head)
            last_dt = float(dts[-1])
            self.injector.tick(self.t, last_dt, idx)
            self.fleet.advance_thermals(last_dt)
            self.fleet.account_traffic(w.bytes_per_link_gb)
            self.t += last_dt
            self.step += m
            self._win_node_times.append(times)
            self._win_alive.append(np.ones(len(idx), bool))
            if track:
                self._accum_decomp(times, dts, parts)
            step_times.extend(dts.tolist())
        return {"t": self.t, "step": self.step,
                "step_times": np.asarray(step_times),
                "steps_run": len(step_times), "crashed": crashed,
                "hung": hung}

    def crashed_nodes(self) -> List[int]:
        return [n for n in self.active if not self.fleet.alive[n]]

    def hang_pending(self) -> Optional[PendingCollective]:
        """Observable snapshot of the stuck in-flight collective, for the
        ccltrace watchdog. Built ONLY from what a CCL tracing layer sees:
        which ranks posted the collective (never-entering ranks are wedged
        before it), which groups completed theirs, and per-rank link
        evidence (down/degraded port or error-counter creep since the
        last window). Returns None while nothing is hung."""
        idx = self._active_idx()
        ph = self.fleet.hang_phase[idx]
        if not ph.any():
            return None
        comp, comm, host = self._barrier_parts(idx)
        entered = ph != HANG_NEVER_ENTER
        enter_off = comp + host
        enter_t = np.where(entered, self.t + enter_off, np.inf)
        group_of = (self.topology.stage_of.astype(np.int64)
                    if self.topology is not None
                    else np.zeros(len(idx), np.int64))
        # a group with no wedged member finished its own collective; its
        # ranks block at the next global sync point, outside this op
        hung_groups = np.unique(group_of[ph > 0])
        completed = ~np.isin(group_of, hung_groups)
        fl = self.fleet
        err_delta = (fl.nic_err_count[idx] - self._prev_err[idx]).sum(axis=1)
        nic_suspect = ((~fl.nic_up[idx]).any(axis=1)
                       | (fl.nic_quality[idx] < 0.95).any(axis=1)
                       | (err_delta > 0))
        return PendingCollective(
            t_start=self.t, step=self.step, op=self._span_op,
            node_ids=idx.astype(np.int64), group_of=group_of,
            entered=entered, enter_t=enter_t, completed=completed,
            nic_suspect=nic_suspect)

    def advance_idle(self, seconds: float) -> None:
        """Advance wall time without training (restart/recovery windows)."""
        idx = self._active_idx() if self.active else np.arange(0)
        self.injector.tick(self.t, seconds, idx)
        self.fleet.advance_thermals(seconds)
        self.t += seconds

    # --------------------------------------------------- telemetry Collector

    def collect(self) -> Optional[Frame]:
        """Aggregate the last window of steps into a telemetry Frame."""
        if not self._win_node_times:
            return None
        idx = self._active_idx()
        times = np.vstack(self._win_node_times)       # (W, N)
        valid = np.stack(self._win_alive).all(axis=0) & self.fleet.alive[idx]
        self._win_node_times.clear()
        self._win_alive.clear()
        sensors = self.fleet.read_sensors(idx)
        metrics = reduce_device_metrics(
            sensors["temp"], sensors["util"], sensors["freq"],
            sensors["power"], sensors["nic_err"], sensors["nic_tx"],
            sensors["nic_up"])
        own_mean = times.mean(axis=0)
        node_ids = idx.astype(np.int64)
        w = times.shape[0]
        wall_mean = None
        if self.topology is not None:
            # measured wall: each node reports its blocking-collective
            # group's completion time — barrier-stall contamination, the
            # signal a real per-host collector sees (one degraded node
            # inflates every group peer's step_time)
            wall_mean = self._wall_sum / w
            metrics["step_time"] = wall_mean
        else:
            metrics["step_time"] = own_mean
        if self.timing is not None and self._parts_sum is not None and \
                self._parts_sum.shape[1] == len(idx):
            if wall_mean is None:
                wall_mean = self._wall_sum / w
            self.timing.push(WindowTiming(
                t=self.t, step=self.step, node_ids=node_ids,
                compute=self._parts_sum[0] / w,
                comm=self._parts_sum[1] / w,
                host=self._parts_sum[2] / w,
                stall=np.maximum(wall_mean - own_mean, 0.0)))
        if self.spans is not None and self._enter_sum is not None and \
                self._enter_sum.shape[0] == len(idx):
            if wall_mean is None:
                wall_mean = self._wall_sum / w
            group_of = (self.topology.stage_of.astype(np.int64)
                        if self.topology is not None
                        else np.zeros(len(idx), np.int64))
            self.spans.push(SpanWindow(
                t=self.t, step=self.step, op=self._span_op,
                node_ids=node_ids, group_of=group_of,
                enter=self._enter_sum / w, exit=wall_mean))
        self._reset_decomp()
        # error counters are cumulative — report the window delta. Clean
        # windows (no NIC events since the last collect, no swaps moving
        # baselines) skip the full-fleet delta scan outright.
        if self.fleet.err_version == self._err_seen and not self._err_dirty:
            # guardlint: disable=GL002 reason=Frame metrics are f64 at the
            # collector boundary; the telemetry ring downcasts on ingest
            metrics["nic_errors"] = np.zeros(len(idx), np.float64)
        else:
            delta = self.fleet.nic_err_count - self._prev_err
            np.copyto(self._prev_err, self.fleet.nic_err_count)
            metrics["nic_errors"] = delta[idx].sum(axis=1)
            self._err_seen = self.fleet.err_version
            self._err_dirty = False
        return Frame(t=self.t, step=self.step, node_ids=node_ids,
                     metrics=metrics, valid=valid)

    # ------------------------------------------------------- SweepBackend
    # Probe logic lives in SimSweepBackend (scalar + batched protocol);
    # the cluster keeps the protocol surface by delegation so passing
    # ``sweep_backend=cluster`` stays valid — and batched campaigns get
    # the array path automatically.

    def device_count(self, node_id: int) -> int:
        return self.sweep_backend.device_count(node_id)

    def compute_probe(self, node_id: int, device: int,
                      seconds: float) -> float:
        return self.sweep_backend.compute_probe(node_id, device, seconds)

    def batch_compute_probe(self, node_ids: Sequence[int],
                            seconds: float) -> np.ndarray:
        return self.sweep_backend.batch_compute_probe(node_ids, seconds)

    def intra_bw_probe(self, node_id: int, dev_a: int, dev_b: int) -> float:
        return self.sweep_backend.intra_bw_probe(node_id, dev_a, dev_b)

    def batch_intra_bw_probe(self, node_ids: Sequence[int],
                             pairs: Sequence[tuple]) -> np.ndarray:
        return self.sweep_backend.batch_intra_bw_probe(node_ids, pairs)

    def multi_node_probe(self, node_ids: Sequence[int],
                         steps: int) -> np.ndarray:
        return self.sweep_backend.multi_node_probe(node_ids, steps)

    def batch_multi_node_probe(self, groups: Sequence[Sequence[int]],
                               steps: int) -> np.ndarray:
        return self.sweep_backend.batch_multi_node_probe(groups, steps)

    def reference(self) -> SweepReference:
        return self.sweep_backend.reference()

    # ------------------------------------------------------ ClusterControl

    def swap_node(self, old: int, new: int) -> None:
        i = self.active.index(old)
        self.active[i] = new
        self._active_arr = None
        if new in self.spares:
            self.spares.remove(new)
        # baseline the spare's cumulative NIC error counters at swap-in:
        # errors it accrued while idle must not surface as one giant
        # first-window delta (instant spurious peer-relative flag)
        self._prev_err[new] = self.fleet.nic_err_count[new]
        self._err_dirty = True

    def restart_job(self, reason: str) -> None:
        self.restarts.append({"t": self.t, "step": self.step,
                              "reason": reason})
        self._win_node_times.clear()
        self._win_alive.clear()
        self._reset_decomp()

    def provision_node(self) -> int:
        if not self._unprovisioned:
            raise RuntimeError("simulated provisioning pool exhausted")
        nid = self._unprovisioned.pop(0)
        self.injector.seed_admission_grey(nid, self.t)
        return nid

    def error_signals(self, node_id: int):
        return self.injector.node_error_signals(node_id)

    def remediate(self, node_id: int, stage: str) -> None:
        self.injector.remediate(node_id, stage)

    def now(self) -> float:
        return self.t
