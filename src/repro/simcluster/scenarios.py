"""Declarative fault-scenario layer: correlated injections over the fleet.

Independent Poisson arrivals (``FaultRates``) model background wear, but
real incident logs are dominated by CORRELATED events — a rack loses
cooling and eight nodes throttle together, a leaf switch dies and every
NIC behind it downtrains, fabric congestion storms sweep a job, planned
maintenance degrades a block of hosts for a bounded window. A
``Scenario`` is a frozen declarative spec of one such event; ``arm``
compiles it against a concrete ``SimCluster`` into scheduled injections
on the fault injector's event heap (or immediate injections for t<=0
events such as the pre-existing grey population a long-unmanaged cluster
has accumulated).

Usage::

    from repro.simcluster.scenarios import scenario, RackThermal
    cfg = RunConfig(scenarios=(RackThermal(at_h=8.0, rack=3),
                               scenario("congestion_storm", at_h=20.0)))

New scenarios subclass ``Scenario``, implement ``arm``, and register
with ``@register_scenario`` so config files / CLIs can name them.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Type

import numpy as np

from repro.simcluster.faults import (BROWNOUT_HANG_SEV, Fault, FaultKind,
                                     GREY_KINDS)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """Base declarative scenario spec. Subclasses add their knobs as
    dataclass fields; ``arm`` resolves the spec against a cluster and
    schedules/injects the underlying faults. ``arm`` returns the faults
    it injected immediately (t<=0 events); scheduled future events live
    on the injector heap and fire during the run."""

    name = "scenario"            # registry key (subclass class attribute)

    def arm(self, cluster, rng: np.random.RandomState) -> List[Fault]:
        raise NotImplementedError

    # ------------------------------------------------------------ helpers

    def _group(self, cluster, rng: np.random.RandomState, size: int,
               start: Optional[int]) -> List[int]:
        """A contiguous block of ``size`` active nodes (rack / switch
        neighbourhood). ``start`` pins the block's first active slot;
        None picks one at random."""
        active = list(cluster.active)
        size = min(size, len(active))
        lo = int(start) if start is not None else \
            int(rng.randint(max(len(active) - size + 1, 1)))
        lo = min(lo, len(active) - size)
        return active[lo:lo + size]

    def _emit(self, cluster, kind: FaultKind, node: int, at_s: float,
              severity: float, device: Optional[int] = None,
              duration_s: Optional[float] = None) -> Optional[Fault]:
        """Inject now (at_s <= 0) or schedule on the event heap."""
        if at_s <= 0.0:
            return cluster.injector.inject(kind, node, now=0.0,
                                           severity=severity, device=device,
                                           duration_s=duration_s)
        cluster.injector.schedule(kind, node, at_s, severity=severity,
                                  device=device, duration_s=duration_s)
        return None


_REGISTRY: Dict[str, Type[Scenario]] = {}


def register_scenario(cls: Type[Scenario]) -> Type[Scenario]:
    assert cls.name not in _REGISTRY, f"duplicate scenario {cls.name!r}"
    _REGISTRY[cls.name] = cls
    return cls


def scenario(name: str, **kw) -> Scenario:
    """Build a registered scenario by name with keyword overrides."""
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"registered: {sorted(_REGISTRY)}") from None
    return cls(**kw)


def builtin_scenarios() -> Dict[str, Type[Scenario]]:
    return dict(_REGISTRY)


def arm_all(scenarios: Sequence, cluster,
            rng: np.random.RandomState) -> List[Fault]:
    """Arm a mixed sequence of Scenario instances and registry names."""
    injected: List[Fault] = []
    for sc in scenarios:
        if isinstance(sc, str):
            sc = scenario(sc)
        injected.extend(sc.arm(cluster, rng))
    return injected


# --------------------------------------------------------------- built-ins


@register_scenario
@dataclasses.dataclass(frozen=True)
class RackThermal(Scenario):
    """Rack-level cooling/power-delivery incident: every node in one rack
    ramps hot (or power-starved) within ``stagger_s`` of the onset —
    the correlated compute-straggler signature of a CRAC/ CDU failure."""

    name = "rack_thermal"
    at_h: float = 4.0            # onset, hours into the run (<=0: at start)
    rack_size: int = 8
    rack_start: Optional[int] = None   # first active slot; None = random
    severity: float = 0.7
    stagger_s: float = 120.0     # per-node onset jitter
    power_fraction: float = 0.25  # fraction seeing POWER instead of THERMAL

    def arm(self, cluster, rng) -> List[Fault]:
        out = []
        for nid in self._group(cluster, rng, self.rack_size,
                               self.rack_start):
            kind = FaultKind.POWER if rng.rand() < self.power_fraction \
                else FaultKind.THERMAL
            at = self.at_h * 3600.0 + float(rng.uniform(0, self.stagger_s))
            f = self._emit(cluster, kind, nid, at, self.severity,
                           device=int(rng.randint(cluster.fleet.d)))
            if f is not None:
                out.append(f)
        return out


@register_scenario
@dataclasses.dataclass(frozen=True)
class SwitchFailure(Scenario):
    """Leaf-switch failure: every node behind the switch loses one link
    outright and the rest downtrain — many NICs degrade in the same
    window (§3.2's reroute pattern, fleet-wide)."""

    name = "switch_failure"
    at_h: float = 4.0
    group_size: int = 16
    group_start: Optional[int] = None
    down_fraction: float = 0.25  # nodes whose link goes fully DOWN
    severity: float = 0.8        # downtrain severity for the rest

    def arm(self, cluster, rng) -> List[Fault]:
        out = []
        at = self.at_h * 3600.0
        for nid in self._group(cluster, rng, self.group_size,
                               self.group_start):
            dev = int(rng.randint(cluster.fleet.d))
            if rng.rand() < self.down_fraction:
                f = self._emit(cluster, FaultKind.NIC_DOWN, nid, at,
                               1.0, device=dev)
            else:
                f = self._emit(cluster, FaultKind.NIC_DEGRADED, nid, at,
                               self.severity, device=dev)
            if f is not None:
                out.append(f)
        return out


@register_scenario
@dataclasses.dataclass(frozen=True)
class CongestionStorm(Scenario):
    """Fabric congestion storm: a burst train of short transient
    congestion events across a large random slice of the fleet. The
    detector must ride it out without quarantining anyone."""

    name = "congestion_storm"
    at_h: float = 2.0
    duration_h: float = 1.0
    hit_fraction: float = 0.3    # fleet fraction hit over the storm
    bursts_per_node: float = 2.0
    severity: float = 0.6

    def arm(self, cluster, rng) -> List[Fault]:
        out = []
        active = list(cluster.active)
        n_hit = max(int(len(active) * self.hit_fraction), 1)
        hit = rng.choice(active, size=n_hit, replace=False)
        start = self.at_h * 3600.0
        for nid in hit:
            for _ in range(max(int(round(self.bursts_per_node)), 1)):
                at = start + float(rng.uniform(0, self.duration_h * 3600.0))
                f = self._emit(cluster, FaultKind.CONGESTION, int(nid), at,
                               self.severity)
                if f is not None:
                    out.append(f)
        return out


@register_scenario
@dataclasses.dataclass(frozen=True)
class MaintenanceWindow(Scenario):
    """Planned maintenance: a block of hosts runs degraded (patching,
    firmware flashes, daemon churn -> HOST_CPU pressure) for a bounded
    window, then reverts on its own — no escalation clock, because it is
    not a hardware fault."""

    name = "maintenance_window"
    at_h: float = 6.0
    duration_h: float = 2.0
    group_size: int = 16
    group_start: Optional[int] = None
    severity: float = 0.4

    def arm(self, cluster, rng) -> List[Fault]:
        out = []
        at = self.at_h * 3600.0
        for nid in self._group(cluster, rng, self.group_size,
                               self.group_start):
            f = self._emit(cluster, FaultKind.HOST_CPU, nid, at,
                           self.severity,
                           duration_s=self.duration_h * 3600.0)
            if f is not None:
                out.append(f)
        return out


@register_scenario
@dataclasses.dataclass(frozen=True)
class InitialGreyPopulation(Scenario):
    """The grey population a long-unmanaged cluster has accumulated by
    t=0 — the state of the world Guard inherits (was the inline
    ``initial_grey_p`` seeding block in ``simulate_run``)."""

    name = "initial_grey"
    p: float = 0.10              # per-active-node grey probability

    def arm(self, cluster, rng) -> List[Fault]:
        out = []
        for nid in cluster.active:
            if rng.rand() < self.p:
                kind = GREY_KINDS[rng.randint(len(GREY_KINDS))]
                out.append(cluster.injector.inject(kind, nid, now=0.0))
        return out


@register_scenario
@dataclasses.dataclass(frozen=True)
class DeadlockedCollective(Scenario):
    """A rank wedges around a blocking collective and the job's barrier
    never completes (CCL-D's hang class): ``count`` sequential incidents
    on distinct nodes, each either stuck BEFORE the collective (never
    enters; device -1) or deadlocked INSIDE it (device >= 0, with
    error-counter creep on the stuck channel). Ground truth for the
    watchdog's culprit attribution."""

    name = "deadlocked_collective"
    at_h: float = 1.0            # first incident onset
    count: int = 2               # sequential incidents
    interval_h: float = 0.75     # spacing between incidents
    never_enter_fraction: float = 0.5

    def arm(self, cluster, rng) -> List[Fault]:
        out = []
        active = list(cluster.active)
        n = min(self.count, len(active))
        targets = rng.choice(active, size=n, replace=False)
        for i, nid in enumerate(targets):
            at = (self.at_h + i * self.interval_h) * 3600.0
            never = rng.rand() < self.never_enter_fraction
            dev = -1 if never else int(rng.randint(cluster.fleet.d))
            f = self._emit(cluster, FaultKind.COLLECTIVE_HANG, int(nid), at,
                           1.0, device=dev)
            if f is not None:
                out.append(f)
        return out


@register_scenario
@dataclasses.dataclass(frozen=True)
class PartialNicBrownout(Scenario):
    """Link brownout across a switch neighbourhood: every node in the
    block downtrains hard with error bursts, and the severe subset
    (always at least the first node) brown out far enough to wedge the
    in-flight collective — the all-entered hang whose attribution needs
    link evidence rather than a missing rank."""

    name = "partial_nic_brownout"
    at_h: float = 1.0
    group_size: int = 8
    group_start: Optional[int] = None
    severe_fraction: float = 0.35  # wedging (vs merely slow) fraction
    stagger_s: float = 60.0        # per-node onset jitter

    def arm(self, cluster, rng) -> List[Fault]:
        out = []
        for i, nid in enumerate(self._group(cluster, rng, self.group_size,
                                            self.group_start)):
            severe = i == 0 or rng.rand() < self.severe_fraction
            sev = float(rng.uniform(BROWNOUT_HANG_SEV, 0.95)) if severe \
                else float(rng.uniform(0.1, BROWNOUT_HANG_SEV - 0.1))
            at = self.at_h * 3600.0 + float(rng.uniform(0, self.stagger_s))
            f = self._emit(cluster, FaultKind.NIC_BROWNOUT, nid, at, sev,
                           device=int(rng.randint(cluster.fleet.d)))
            if f is not None:
                out.append(f)
        return out


@register_scenario
@dataclasses.dataclass(frozen=True)
class StragglerTimeoutCascade(Scenario):
    """A compute straggler degrades and then times out: a THERMAL fault
    lands first, and ``lag_h`` later the same node wedges before the
    collective entirely (data/compute watchdog timeout). ``count``
    incidents on distinct nodes — the hang-after-slow pattern that makes
    the slow-vs-hang split matter (the z-path alone sees only the slow
    prologue, never the deadlock)."""

    name = "straggler_timeout_cascade"
    at_h: float = 1.0
    count: int = 2
    interval_h: float = 0.75
    lag_h: float = 0.05          # slow prologue before the wedge
    severity: float = 0.85       # thermal prologue severity

    def arm(self, cluster, rng) -> List[Fault]:
        out = []
        active = list(cluster.active)
        n = min(self.count, len(active))
        targets = rng.choice(active, size=n, replace=False)
        for i, nid in enumerate(targets):
            at = (self.at_h + i * self.interval_h) * 3600.0
            f = self._emit(cluster, FaultKind.THERMAL, int(nid), at,
                           self.severity,
                           device=int(rng.randint(cluster.fleet.d)))
            if f is not None:
                out.append(f)
            f = self._emit(cluster, FaultKind.COLLECTIVE_HANG, int(nid),
                           at + self.lag_h * 3600.0, 1.0, device=-1)
            if f is not None:
                out.append(f)
        return out
