"""Vectorized fleet hardware model.

State is struct-of-arrays over (num_nodes, devices_per_node): device
temperature, power factor, memory factor, per-link NIC state, plus per-node
host-CPU factor. The dynamics are fitted to the paper's published numbers:

  - Table 2 thermal-throttle curve: temp -> core clock (piecewise linear),
  - §3.3 power-deficit observation: 10-15% low power -> reduced sustained
    throughput at normal utilization/frequency,
  - §3.2 / Fig. 3-4 NIC-down reroute: a dead link's traffic rides the
    fallback link (link 0), doubling its traffic and doubling the node's
    exposed communication time,
  - Fig. 2 host-CPU setting effect: up to 15% step-time impact.

Hardware constants are the TPU-v5e adaptation targets used throughout the
repo (197 bf16 TFLOP/s per chip, ~50 GB/s per ICI link); "node" = 8 chips,
matching the paper's 8-accelerator node granularity for health accounting.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

# Table 2 (paper): temperature -> core frequency. Extended flat below and
# linearly degrading above the published range.
THROTTLE_CURVE_C = np.array([0.0, 50.0, 60.0, 69.0, 77.0, 95.0])
THROTTLE_CURVE_GHZ = np.array([1.93, 1.93, 1.93, 1.78, 1.38, 0.90])


def freq_at_temp(temp_c: np.ndarray) -> np.ndarray:
    """Piecewise-linear Table-2 throttle curve."""
    return np.interp(temp_c, THROTTLE_CURVE_C, THROTTLE_CURVE_GHZ)


@dataclasses.dataclass(frozen=True)
class HWConfig:
    devices_per_node: int = 8
    base_tflops: float = 197.0        # bf16 peak per chip (v5e target)
    base_freq_ghz: float = 1.93
    idle_temp_c: float = 50.0
    load_temp_c: float = 58.0         # healthy steady-state under load
    base_power_w: float = 350.0
    link_gbps: float = 50.0           # per inter-node link (ICI-class)
    intra_bw_gbps: float = 400.0      # intra-node pairwise interconnect
    temp_tau_s: float = 180.0         # first-order thermal lag
    sensor_temp_sigma: float = 0.8    # °C
    sensor_rate_sigma: float = 0.01   # relative, throughput probes


class Fleet:
    """Vectorized hardware state for N nodes x D devices."""

    def __init__(self, num_nodes: int, hw: Optional[HWConfig] = None,
                 seed: int = 0):
        self.hw = hw or HWConfig()
        self.n = num_nodes
        self.d = self.hw.devices_per_node
        self.rng = np.random.RandomState(seed)
        n, d = self.n, self.d
        # --- mutable hardware state
        self.temp_c = np.full((n, d), self.hw.load_temp_c)
        self.temp_target = np.full((n, d), self.hw.load_temp_c)
        self.power_factor = np.ones((n, d))     # <1: power-delivery deficit
        self.mem_factor = np.ones((n, d))       # <1: ECC/bandwidth stalls
        self.nic_up = np.ones((n, d), bool)     # one link per device
        self.nic_quality = np.ones((n, d))      # <1: degraded link
        self.host_factor = np.ones((n,))        # <1: bad CPU settings
        self.alive = np.ones((n,), bool)
        # cumulative per-link transmit counters (Fig. 4 accounting)
        self.nic_tx_bytes = np.zeros((n, d))
        self.nic_err_count = np.zeros((n, d))

    # ------------------------------------------------------------ dynamics

    def advance_thermals(self, dt_s: float) -> None:
        """First-order lag of device temperature toward its target."""
        alpha = 1.0 - np.exp(-dt_s / self.hw.temp_tau_s)
        self.temp_c += alpha * (self.temp_target - self.temp_c)

    # ------------------------------------------------------- performance

    def device_freq(self) -> np.ndarray:
        return freq_at_temp(self.temp_c)

    def device_compute_factor(self) -> np.ndarray:
        """(N, D) sustained-throughput fraction of healthy peak."""
        f = self.device_freq() / self.hw.base_freq_ghz
        return f * self.power_factor * self.mem_factor

    def node_compute_factor(self) -> np.ndarray:
        """(N,) — intra-node collectives gate on the slowest device."""
        return self.device_compute_factor().min(axis=1)

    def node_comm_factor(self) -> np.ndarray:
        """(N,) effective inter-node communication speed fraction.

        Per-device links carry equal traffic shares in parallel; a DOWN
        link's traffic is rerouted through link 0 (§3.2), so link 0 carries
        (1 + n_down) shares. Node comm time scales with the busiest link's
        share divided by its quality."""
        shares = self._link_shares()
        flow_time = shares / np.maximum(self.nic_quality, 1e-9)
        worst = flow_time.max(axis=1)                   # healthy == 1.0
        # all links down -> node effectively stalled on comm
        worst = np.where(self.nic_up.any(axis=1), worst, 1e3)
        return 1.0 / np.maximum(worst, 1e-9)

    def _link_shares(self) -> np.ndarray:
        """(N, D) traffic shares per link: every down link's share rides the
        first UP link (the §3.2 fallback path)."""
        up = self.nic_up
        n_down = (~up).sum(axis=1)
        shares = np.where(up, 1.0, 0.0)
        has_up = up.any(axis=1)
        fallback = np.argmax(up, axis=1)                # first up link
        rows = np.arange(self.n)[has_up]
        shares[rows, fallback[has_up]] += n_down[has_up]
        return shares

    def account_traffic(self, bytes_per_link: float) -> None:
        """Add one step's transmit volume to the per-link counters."""
        self.nic_tx_bytes += self._link_shares() * bytes_per_link

    # --------------------------------------------------------- telemetry

    def read_sensors(self) -> dict:
        """Noisy per-device sensor readout (what DCGM-equivalent reports)."""
        hw = self.hw
        temp = self.temp_c + self.rng.normal(
            0, hw.sensor_temp_sigma, self.temp_c.shape)
        freq = freq_at_temp(temp)
        # utilization stays high even for power-limited nodes (§3.3) —
        # that's exactly why util alone is insufficient
        util = np.clip(self.rng.normal(0.97, 0.01, self.temp_c.shape), 0, 1)
        util = util * np.where(self.mem_factor < 0.99, 0.97, 1.0)
        power = hw.base_power_w * self.power_factor * \
            np.clip(freq / hw.base_freq_ghz, 0.5, 1.0) * \
            self.rng.normal(1.0, 0.01, self.temp_c.shape)
        tx_rate = hw.link_gbps * self.nic_quality * self.nic_up * \
            self.rng.normal(1.0, 0.01, self.temp_c.shape)
        return {
            "temp": temp,
            "freq": freq,
            "util": util,
            "power": power,
            "nic_err": self.nic_err_count.copy(),
            "nic_tx": tx_rate,
            "nic_up": self.nic_up.astype(float),
        }

    # ------------------------------------------------------- probes

    def probe_device_tflops(self, node: int, device: int) -> float:
        """Sustained matmul burn measurement (sweep compute probe)."""
        f = self.device_compute_factor()[node, device]
        noise = self.rng.normal(1.0, self.hw.sensor_rate_sigma)
        return float(self.hw.base_tflops * f * noise)

    def probe_intra_bw(self, node: int, a: int, b: int) -> float:
        """Pairwise intra-node bandwidth; a marginal memory/link device
        drags the pair."""
        q = min(self.mem_factor[node, a], self.mem_factor[node, b])
        noise = self.rng.normal(1.0, self.hw.sensor_rate_sigma)
        return float(self.hw.intra_bw_gbps * q * noise)
