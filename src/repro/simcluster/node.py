"""Vectorized fleet hardware model.

State is struct-of-arrays over (num_nodes, devices_per_node): device
temperature, power factor, memory factor, per-link NIC state, plus per-node
host-CPU factor. The dynamics are fitted to the paper's published numbers:

  - Table 2 thermal-throttle curve: temp -> core clock (piecewise linear),
  - §3.3 power-deficit observation: 10-15% low power -> reduced sustained
    throughput at normal utilization/frequency,
  - §3.2 / Fig. 3-4 NIC-down reroute: a dead link's traffic rides the
    fallback link (link 0), doubling its traffic and doubling the node's
    exposed communication time,
  - Fig. 2 host-CPU setting effect: up to 15% step-time impact.

Hardware constants are the TPU-v5e adaptation targets used throughout the
repo (197 bf16 TFLOP/s per chip, ~50 GB/s per ICI link); "node" = 8 chips,
matching the paper's 8-accelerator node granularity for health accounting.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

# Table 2 (paper): temperature -> core frequency. Extended flat below and
# linearly degrading above the published range.
THROTTLE_CURVE_C = np.array([0.0, 50.0, 60.0, 69.0, 77.0, 95.0])
THROTTLE_CURVE_GHZ = np.array([1.93, 1.93, 1.93, 1.78, 1.38, 0.90])


def freq_at_temp(temp_c: np.ndarray) -> np.ndarray:
    """Piecewise-linear Table-2 throttle curve."""
    return np.interp(temp_c, THROTTLE_CURVE_C, THROTTLE_CURVE_GHZ)


@dataclasses.dataclass(frozen=True)
class HWConfig:
    devices_per_node: int = 8
    base_tflops: float = 197.0        # bf16 peak per chip (v5e target)
    base_freq_ghz: float = 1.93
    idle_temp_c: float = 50.0
    load_temp_c: float = 58.0         # healthy steady-state under load
    base_power_w: float = 350.0
    link_gbps: float = 50.0           # per inter-node link (ICI-class)
    intra_bw_gbps: float = 400.0      # intra-node pairwise interconnect
    temp_tau_s: float = 180.0         # first-order thermal lag
    sensor_temp_sigma: float = 0.8    # °C
    sensor_rate_sigma: float = 0.01   # relative, throughput probes


class Fleet:
    """Vectorized hardware state for N nodes x D devices."""

    def __init__(self, num_nodes: int, hw: Optional[HWConfig] = None,
                 seed: int = 0):
        self.hw = hw or HWConfig()
        self.n = num_nodes
        self.d = self.hw.devices_per_node
        # sensor/probe noise source. SFC64 + ziggurat normal sampling is
        # several times faster than RandomState's polar method, and the
        # fleet burns millions of gaussians per simulated day of telemetry
        self.rng = np.random.Generator(np.random.SFC64(seed))
        n, d = self.n, self.d
        # --- mutable hardware state
        self.temp_c = np.full((n, d), self.hw.load_temp_c)
        self.temp_target = np.full((n, d), self.hw.load_temp_c)
        self.power_factor = np.ones((n, d))     # <1: power-delivery deficit
        self.mem_factor = np.ones((n, d))       # <1: ECC/bandwidth stalls
        self.nic_up = np.ones((n, d), bool)     # one link per device
        self.nic_quality = np.ones((n, d))      # <1: degraded link
        self.host_factor = np.ones((n,))        # <1: bad CPU settings
        self.alive = np.ones((n,), bool)
        # collective-hang phase (repro.ccltrace taxonomy): 0 = none,
        # 1 = entered the collective and stalled inside it, 2 = wedged
        # before the collective (never enters). Any nonzero phase on an
        # active node deadlocks the job's blocking collective — steps
        # stop completing until the node is pulled or the fault clears
        self.hang_phase = np.zeros((n,), np.int8)
        # cumulative per-link transmit counters (Fig. 4 accounting);
        # materialized lazily from pending share-units (see nic_tx_bytes)
        self._nic_tx = np.zeros((n, d))
        self._pending_tx_units = 0.0
        # share-units already settled per node row: a row-targeted link
        # event flushes ONLY its own row on the old shares, so the next
        # full flush must not re-apply those units to it
        self._row_flushed = np.zeros((n,))
        self.nic_err_count = np.zeros((n, d))
        # thermal-equilibrium tracking: True while every device sits exactly
        # on its target, letting the window-granular sim engine treat the
        # fleet's compute factors as frozen between fault events
        self._settled = True
        self._ramp_rows: Optional[np.ndarray] = None   # nodes off-target
        # performance caches: node compute/comm factors and link shares
        # change only on fault events and thermal ramps, never per step —
        # the injector invalidates on NIC/power/memory transitions and
        # advance_thermals refreshes exactly the ramping rows
        self._ncf: Optional[np.ndarray] = None         # (N,) compute factor
        self._comm: Optional[np.ndarray] = None        # (N,) comm factor
        self._shares: Optional[np.ndarray] = None      # (N, D) link shares
        # monotone fleet-state version: bumped on any change observable
        # through the sensor surface, so per-subset sensor gathers can be
        # reused across quiet evaluation windows
        self.state_version = 0
        # hardware (power/memory/NIC) slice of the version: excludes the
        # thermal-ramp bumps so non-thermal sensor bases survive ramps
        self.hw_version = 0
        self._sensor_cache: Optional[list] = None
        # bumped whenever NIC error counters may have moved (collectors
        # skip the full-fleet delta scan across clean windows)
        self.err_version = 0
        # probe noise is KEYED, not streamed: each (node, device) / pair /
        # group measurement has a fixed noise value derived from the fleet
        # seed, so the scalar sweep path and the batched fleet-campaign
        # path read bit-identical measurements regardless of probe order
        # (the batched-vs-scalar golden contract). Lazily materialized.
        self._seed = seed
        self._probe_noise_compute: Optional[np.ndarray] = None  # (N, D)
        self._probe_noise_bw: Optional[np.ndarray] = None       # (N, D, D)

    # ------------------------------------------------------------ dynamics

    # temperature gap below which a device snaps onto its target: the lag
    # is asymptotic, snapping makes equilibrium reachable in finite steps.
    # 0.01 °C maps to <0.1% clock error on the steepest Table-2 segment —
    # far below sensor noise and the detector's slowdown floor.
    TEMP_SNAP_C = 1e-2

    @property
    def thermally_settled(self) -> bool:
        return self._settled

    _EMPTY_ROWS = np.arange(0)

    def ramping_rows(self) -> np.ndarray:
        """Node rows with any device still off its thermal target."""
        if self._settled:
            return self._EMPTY_ROWS
        if self._ramp_rows is None:
            self._ramp_rows = np.flatnonzero(
                (self.temp_c != self.temp_target).any(axis=1))
            if not len(self._ramp_rows):
                self._settled = True
        return self._ramp_rows

    def mark_thermal_dirty(self) -> None:
        """A temperature target moved (fault applied/reverted)."""
        self._settled = False
        self._ramp_rows = None           # recompute the ramping set lazily
        self.state_version += 1

    def advance_thermals(self, dt_s: float) -> None:
        """First-order lag of device temperature toward its target.

        Only the ramping rows (nodes with any device off-target) are
        integrated; the settled majority of a large fleet costs nothing."""
        rows = self.ramping_rows()
        if not len(rows):
            return
        alpha = 1.0 - np.exp(-dt_s / self.hw.temp_tau_s)
        tc = self.temp_c[rows]
        tt = self.temp_target[rows]
        tc += alpha * (tt - tc)
        near = np.abs(tt - tc) < self.TEMP_SNAP_C
        tc[near] = tt[near]
        self.temp_c[rows] = tc
        self.state_version += 1
        self._refresh_node_perf(rows)
        still = ~(tc == tt).all(axis=1)
        self._ramp_rows = rows[still]
        self._settled = not len(self._ramp_rows)

    # ------------------------------------------------------- performance

    def device_freq(self) -> np.ndarray:
        return freq_at_temp(self.temp_c)

    def device_compute_factor(self) -> np.ndarray:
        """(N, D) sustained-throughput fraction of healthy peak."""
        f = self.device_freq() / self.hw.base_freq_ghz
        return f * self.power_factor * self.mem_factor

    def node_compute_factor(self) -> np.ndarray:
        """(N,) — intra-node collectives gate on the slowest device.

        Cached: refreshed per-row by thermal ramps and invalidated by
        power/memory fault transitions."""
        if self._ncf is None:
            self._ncf = self.device_compute_factor().min(axis=1)
        return self._ncf

    def _refresh_node_perf(self, rows: np.ndarray) -> None:
        if self._ncf is None or not len(rows):
            return
        f = freq_at_temp(self.temp_c[rows]) / self.hw.base_freq_ghz
        self._ncf[rows] = (f * self.power_factor[rows] *
                           self.mem_factor[rows]).min(axis=1)

    def refresh_node_perf(self, node: int) -> None:
        """Device power/memory state changed on one node (fault event)."""
        self.state_version += 1
        self.hw_version += 1
        self._refresh_node_perf(np.asarray([node]))

    def invalidate_link_state(self, node: Optional[int] = None) -> None:
        """NIC up/quality state changed (fault event).

        Callers mutate link state FIRST, then invalidate: the cached
        shares still describe the pre-event topology, so traffic is
        settled on them before the caches move.

        ``node`` names the single node whose links changed: its counters
        are settled and its share/comm cache rows recomputed in O(D) —
        reroute fallback never crosses nodes, so the rest of the fleet's
        caches stay valid. ``None`` (or cold caches) drops everything."""
        self.state_version += 1
        self.hw_version += 1
        self.err_version += 1
        if node is None or self._shares is None or self._comm is None:
            self._flush_traffic()        # settle counters on OLD shares
            self._comm = None
            self._shares = None
            return
        self._flush_row(node)
        self._refresh_link_row(node)

    def _flush_row(self, node: int) -> None:
        """Settle one row's traffic counters on its current cached shares."""
        owed = self._pending_tx_units - self._row_flushed[node]
        if owed:
            self._nic_tx[node] += self._shares[node] * owed
            self._row_flushed[node] = self._pending_tx_units

    def _refresh_link_row(self, node: int) -> None:
        """Recompute one node's share and comm-factor cache rows in O(D)
        (same arithmetic as the vectorized builds, bit-identical)."""
        up = self.nic_up[node]
        shares = np.where(up, 1.0, 0.0)
        has_up = up.any()
        if has_up:
            shares[np.argmax(up)] += (~up).sum()
        self._shares[node] = shares
        flow_time = shares / np.maximum(self.nic_quality[node], 1e-9)
        worst = flow_time.max() if has_up else 1e3
        self._comm[node] = 1.0 / max(worst, 1e-9)

    def node_comm_factor(self) -> np.ndarray:
        """(N,) effective inter-node communication speed fraction.

        Per-device links carry equal traffic shares in parallel; a DOWN
        link's traffic is rerouted through link 0 (§3.2), so link 0 carries
        (1 + n_down) shares. Node comm time scales with the busiest link's
        share divided by its quality. Cached between NIC fault events."""
        if self._comm is None:
            shares = self._link_shares()
            flow_time = shares / np.maximum(self.nic_quality, 1e-9)
            worst = flow_time.max(axis=1)               # healthy == 1.0
            # all links down -> node effectively stalled on comm
            worst = np.where(self.nic_up.any(axis=1), worst, 1e3)
            self._comm = 1.0 / np.maximum(worst, 1e-9)
        return self._comm

    def _link_shares(self) -> np.ndarray:
        """(N, D) traffic shares per link: every down link's share rides the
        first UP link (the §3.2 fallback path). Cached between NIC events."""
        if self._shares is None:
            up = self.nic_up
            n_down = (~up).sum(axis=1)
            shares = np.where(up, 1.0, 0.0)
            has_up = up.any(axis=1)
            fallback = np.argmax(up, axis=1)            # first up link
            rows = np.arange(self.n)[has_up]
            shares[rows, fallback[has_up]] += n_down[has_up]
            self._shares = shares
        return self._shares

    def account_traffic(self, bytes_per_link: float) -> None:
        """Add one step's transmit volume to the per-link counters.

        O(1): while the link topology is unchanged the per-link shares
        are constant, so volume accumulates as scalar share-units and is
        materialized only when the shares change or the counters are
        read."""
        self._pending_tx_units += bytes_per_link

    def _flush_traffic(self) -> None:
        if self._pending_tx_units:
            owed = self._pending_tx_units - self._row_flushed
            self._nic_tx += self._link_shares() * owed[:, None]
            self._pending_tx_units = 0.0
            self._row_flushed[:] = 0.0

    @property
    def nic_tx_bytes(self) -> np.ndarray:
        self._flush_traffic()
        return self._nic_tx

    @nic_tx_bytes.setter
    def nic_tx_bytes(self, value) -> None:
        # tests reset counters wholesale (fleet.nic_tx_bytes[:] = 0 goes
        # through the getter; full reassignment lands here)
        self._nic_tx = np.asarray(value, dtype=float)
        self._pending_tx_units = 0.0
        self._row_flushed[:] = 0.0

    def memory_nbytes(self) -> int:
        """Resident bytes of the fleet's hardware-state and cache arrays
        (scale-benchmark memory report)."""
        arrs = [self.temp_c, self.temp_target, self.power_factor,
                self.mem_factor, self.nic_up, self.nic_quality,
                self.host_factor, self.alive, self.hang_phase,
                self._nic_tx, self.nic_err_count, self._row_flushed]
        arrs += [a for a in (self._ncf, self._comm, self._shares,
                             self._probe_noise_compute,
                             self._probe_noise_bw) if a is not None]
        return int(sum(a.nbytes for a in arrs))

    # --------------------------------------------------------- telemetry

    def read_sensors(self, nodes: Optional[np.ndarray] = None) -> dict:
        """Noisy per-device sensor readout (what DCGM-equivalent reports).

        ``nodes`` restricts the readout (and the rng draws) to a node
        subset — the telemetry collector only pays for the active job,
        not the reserve pool."""
        hw = self.hw
        ent = self._sensor_entry(nodes)
        shape = ent["temp_c"].shape
        # the whole noisy pipeline runs in float32 and consumes the noise
        # buffer in place: sensor noise is 1%-scale on O(100) bases, so
        # single precision sits far below every modeled sensor sigma
        # (per-node reductions upcast later)
        g = self.rng.standard_normal((4,) + shape, dtype=np.float32)
        temp, g1, g2, g3 = g[0], g[1], g[2], g[3]
        temp *= hw.sensor_temp_sigma
        temp += ent["temp_c"]
        freq = freq_at_temp(temp).astype(np.float32, copy=False)
        # utilization stays high even for power-limited nodes (§3.3) —
        # that's exactly why util alone is insufficient
        g1 *= 0.01
        g1 += 0.97
        np.minimum(g1, 1.0, out=g1)
        np.maximum(g1, 0.0, out=g1)
        g1 *= ent["util_mask"]
        power = freq * np.float32(1.0 / hw.base_freq_ghz)
        np.minimum(power, 1.0, out=power)
        np.maximum(power, 0.5, out=power)
        power *= ent["power_base"]
        g2 *= 0.01
        g2 += 1.0
        power *= g2
        g3 *= 0.01
        g3 += 1.0
        g3 *= ent["tx_base"]
        return {
            "temp": temp,
            "freq": freq,
            "util": g1,
            "power": power,
            "nic_err": ent["nic_err"],
            "nic_tx": g3,
            "nic_up": ent["nic_up_f"],
        }

    def _sensor_entry(self, nodes: Optional[np.ndarray]) -> dict:
        """Noise-free sensor bases for a node subset, cached against the
        fleet-state version: quiet windows re-use the gathers and derived
        products (cast once to float32) and only pay for fresh noise.
        The temperature gather is keyed separately on the full state
        version (it moves on every thermal-ramp integration); the
        hardware bases only move on fault transitions."""
        hw = self.hw
        f32 = np.float32
        c = self._sensor_cache
        if c is None or c[0] is not nodes or c[1] != self.hw_version:
            sl = slice(None) if nodes is None else nodes
            ent = {
                "util_mask": np.where(self.mem_factor[sl] < 0.99,
                                      0.97, 1.0).astype(f32),
                "power_base": (hw.base_power_w *
                               self.power_factor[sl]).astype(f32),
                "tx_base": (hw.link_gbps * self.nic_quality[sl] *
                            self.nic_up[sl]).astype(f32),
                "nic_err": self.nic_err_count[sl].copy(),
                "nic_up_f": self.nic_up[sl].astype(float),
            }
            c = self._sensor_cache = [nodes, self.hw_version, ent, -1]
        ent = c[2]
        if c[3] != self.state_version:
            sl = slice(None) if nodes is None else nodes
            ent["temp_c"] = self.temp_c[sl].astype(f32)
            c[3] = self.state_version
        return ent

    # ------------------------------------------------------- probes

    def probe_noise_compute(self) -> np.ndarray:
        """(N, D) fixed relative measurement noise of the compute probes."""
        if self._probe_noise_compute is None:
            gen = np.random.Generator(np.random.SFC64([self._seed, 1]))
            self._probe_noise_compute = gen.normal(
                1.0, self.hw.sensor_rate_sigma, (self.n, self.d))
        return self._probe_noise_compute

    def probe_noise_bw(self) -> np.ndarray:
        """(N, D, D) fixed relative noise of the pairwise bw probes;
        read at the canonical (lo, hi) device ordering."""
        if self._probe_noise_bw is None:
            gen = np.random.Generator(np.random.SFC64([self._seed, 2]))
            self._probe_noise_bw = gen.normal(
                1.0, self.hw.sensor_rate_sigma, (self.n, self.d, self.d))
        return self._probe_noise_bw

    def pair_noise(self, node: int, steps: int, sigma: float) -> np.ndarray:
        """(steps,) log-noise of a multi-node sweep mini-workload, keyed
        on the candidate node (the group's first member)."""
        gen = np.random.Generator(
            np.random.SFC64([self._seed, 3, int(node), int(steps)]))
        return gen.normal(0.0, sigma, steps)

    def probe_device_tflops(self, node: int, device: int) -> float:
        """Sustained matmul burn measurement (sweep compute probe)."""
        f = float(freq_at_temp(self.temp_c[node, device])) / \
            self.hw.base_freq_ghz * self.power_factor[node, device] * \
            self.mem_factor[node, device]
        noise = self.probe_noise_compute()[node, device]
        return float(self.hw.base_tflops * f * noise)

    def probe_intra_bw(self, node: int, a: int, b: int) -> float:
        """Pairwise intra-node bandwidth; a marginal memory/link device
        drags the pair. Symmetric: (a, b) and (b, a) measure the same
        link and read the same noise cell."""
        lo, hi = (a, b) if a <= b else (b, a)
        q = min(self.mem_factor[node, a], self.mem_factor[node, b])
        noise = self.probe_noise_bw()[node, lo, hi]
        return float(self.hw.intra_bw_gbps * q * noise)
