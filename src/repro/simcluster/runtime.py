"""Multi-week training-run simulator — the §7 evaluation substrate.

``simulate_run`` drives a synchronous job over the simulated fleet under one
of the four ablation tiers of Table 4 (see ``repro.guard.Tier``):

  BURNIN            NCCL/burn-in only: fail-stop crashes are handled
                    (replace + restart); grey nodes persist until a human
                    notices the slowdown and hand-debugs, or the fault
                    escalates into a crash.
  NODE_SWEEP        + offline single-node sweep: spares/repairs are swept
                    before (re-)entering service, and human investigations
                    can use sweep tooling (faster, more accurate).
  ONLINE            + Guard online monitoring: peer-relative detection with
                    the tiered policy drives automated quarantine/swap.
  ENHANCED          + enhanced sweep: multi-node (2-node) collective stage
                    and long sustained burns in qualification/admission —
                    comm-level greys stop bouncing back into the job.

The whole closed loop runs through the public ``repro.guard`` API: one
``GuardSession`` owns detection, the node pools, and the non-blocking
sweep scheduler (offline qualification overlaps the job in simulated
time); every incident lands on the session's event bus and comes back in
``RunResult.events`` as typed records.

Outputs: MTTF (mean active time between job-interrupting hardware
failures), MFU (model-FLOPs utilization: completed-step FLOPs over elapsed
wall time), mean human hours per incident, plus full step-time and event
traces for the figure-level benchmarks.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.ccltrace import (CollectiveSpanTrace, HangWatchdog,
                            WatchdogConfig)
from repro.core.sweep import SweepConfig, multi_node_sweep, single_node_sweep
from repro.diagnose import Diagnoser, RootCauseConfig, TimingTrace, Topology
from repro.guard import (CheckpointTier, GuardSession, JobRestart,
                         RecoveryEvent, RecoveryModel, Tier,
                         goodput_tflop_h, mttr_decomposition,
                         replica_partner, young_daly_interval)
from repro.simcluster.cluster import SimCluster, WorkloadProfile
from repro.simcluster.faults import FaultRates
from repro.simcluster.scenarios import InitialGreyPopulation, Scenario, \
    arm_all


@dataclasses.dataclass(frozen=True)
class RunConfig:
    tier: Tier = Tier.ENHANCED
    n_nodes: int = 128
    n_spare: int = 12
    duration_h: float = 72.0
    window_steps: int = 6                # evaluation window (≈60 s of steps)
    checkpoint_interval_steps: int = 90  # 15 min at the healthy step
    crash_detect_s: float = 120.0
    restart_overhead_s: float = 600.0
    provision_delay_s: float = 1800.0
    # crash recovery: with no tooling a hard failure needs hours of manual
    # diagnosis before the job is back; Guard tiers automate it away
    crash_recovery_s: Dict[int, float] = dataclasses.field(
        default_factory=lambda: {1: 7_200.0, 2: 2_700.0, 3: 600.0,
                                 4: 600.0})
    crash_human_h: Dict[int, float] = dataclasses.field(
        default_factory=lambda: {1: 3.0, 2: 1.5, 3: 0.25, 4: 0.25})
    # manual grey hunting pauses/perturbs the job in the untooled tiers
    hunt_downtime_s: Dict[int, float] = dataclasses.field(
        default_factory=lambda: {1: 5_400.0, 2: 2_700.0})
    # grey population a long-unmanaged cluster has accumulated at t=0
    # (armed through the scenario layer as InitialGreyPopulation)
    initial_grey_p: float = 0.10
    # declarative correlated-fault scenarios (Scenario instances or
    # registry names — see repro.simcluster.scenarios)
    scenarios: Tuple = ()
    # blocking-collective structure: nodes per DP gradient-barrier group
    # (0 = legacy idealized telemetry, each node reports its OWN barrier
    # time; > 0 = realistic measured walls — each node reports its
    # group's completion time, barrier-stall contamination included)
    dp_group_size: int = 0
    # run the repro.diagnose attribution stage (trace -> what-if ->
    # root cause) between detector and policy: cascade victims are
    # watched instead of evicted, triage gets root-caused ErrorSignals,
    # and DiagnosisEvents land in RunResult.events
    diagnose: bool = False
    trace_depth: int = 8
    rootcause_cfg: Optional[RootCauseConfig] = None
    # collective-granular hang watchdog (repro.ccltrace): feed per-window
    # spans into a CollectiveSpanTrace and poll the barrier-timeout
    # watchdog when a window wedges — culprits are evicted, victims
    # watched, and the job restarts instead of blocking until the blind
    # framework-level CCL abort (``ccl_timeout_s``) fires
    hang_watchdog: bool = False
    hang_cfg: Optional[WatchdogConfig] = None
    ccl_timeout_s: float = 600.0
    # manual grey-hunting model (tiers 1-2 have no online detection)
    manual_trigger_ratio: float = 1.12   # hour-mean step/healthy to notice
    manual_delay_h: Dict[int, float] = dataclasses.field(
        default_factory=lambda: {1: 6.0, 2: 3.0})
    manual_hours: Dict[int, float] = dataclasses.field(
        default_factory=lambda: {1: 5.0, 2: 1.8})
    manual_success_p: Dict[int, float] = dataclasses.field(
        default_factory=lambda: {1: 0.75, 2: 0.92})
    # automated-tier residual human attention per incident (approve swap,
    # ticket hygiene): online needs more eyes than enhanced
    auto_human_h: Dict[int, float] = dataclasses.field(
        default_factory=lambda: {3: 0.9, 4: 0.35})
    # tiered-checkpoint recovery model: per-tier restore seconds, fast-
    # snapshot cost, and the cadence clamp for the Young-Daly auto-tuner.
    # Which checkpoint tiers exist follows the ablation tier (goodput.py):
    # BURNIN/NODE_SWEEP cold-only, ONLINE + local shard, ENHANCED + peer
    # replica with hot-spare promotion.
    recovery: RecoveryModel = dataclasses.field(
        default_factory=RecoveryModel)
    workload: WorkloadProfile = dataclasses.field(
        default_factory=WorkloadProfile)
    rates: FaultRates = dataclasses.field(default_factory=FaultRates)
    seed: int = 0


@dataclasses.dataclass
class RunResult:
    tier: Tier
    elapsed_h: float
    active_h: float
    steps: int
    crashes: int
    mttf_h: float
    mfu: float
    mean_step_s: float
    p95_step_s: float
    human_hours: float
    incidents: int
    human_h_per_incident: float
    guard_restarts: int
    deferred_swaps: int
    nodes_terminated: int
    step_times: np.ndarray
    events: List[dict]
    # injector fault history (ground truth for attribution scoring):
    # one dict per fault with node/kind/severity/t_start/t_cleared
    fault_log: List[dict] = dataclasses.field(default_factory=list)
    # good (unique-progress) FLOPs per wall hour: replayed steps excluded
    goodput_tflop_h: float = 0.0
    # recovery accounting: MTTR decomposition over the run's
    # RecoveryEvents + fast-snapshot cadence + unique progress
    recovery: Dict = dataclasses.field(default_factory=dict)
    # end-of-run node-pool census (NodeState value -> count) — the
    # conservation check the property tests assert on: every node the
    # run ever touched is in exactly one pool when it ends
    pools: Dict[str, int] = dataclasses.field(default_factory=dict)


def _admission_check(cluster: SimCluster, nid: int, tier: Tier,
                     sweep_cfg: SweepConfig,
                     buddies: List[int]) -> bool:
    """Qualify a freshly provisioned node before it becomes a spare."""
    if tier == Tier.BURNIN:
        return True                      # burn-in passes grey nodes (§5.1)
    enhanced = tier == Tier.ENHANCED
    rep = single_node_sweep(cluster, nid, sweep_cfg, enhanced=enhanced)
    if rep.passed and enhanced and buddies:
        rep = multi_node_sweep(cluster, nid, buddies[:1], sweep_cfg)
    if not rep.passed:
        cluster.injector.clear_node(nid)  # sim shorthand for RMA/replace
    return True


def simulate_run(cfg: RunConfig) -> RunResult:
    rng = np.random.RandomState(cfg.seed + 7)
    topology = Topology.grouped(cfg.n_nodes, cfg.dp_group_size) \
        if cfg.dp_group_size else None
    cluster = SimCluster(cfg.n_nodes, cfg.n_spare,
                         workload=cfg.workload, rates=cfg.rates,
                         window_steps=cfg.window_steps,
                         topology=topology, seed=cfg.seed)
    sweep_cfg = SweepConfig()
    tier = Tier(cfg.tier)

    diagnoser = None
    if cfg.diagnose:
        trace = TimingTrace(depth=cfg.trace_depth)
        cluster.attach_timing(trace)
        diagnoser = Diagnoser(trace,
                              topology or Topology.single(cfg.n_nodes),
                              cfg=cfg.rootcause_cfg)

    watchdog = None
    if cfg.hang_watchdog:
        spans = CollectiveSpanTrace(depth=cfg.trace_depth)
        cluster.attach_spans(spans)
        watchdog = HangWatchdog(spans, cfg=cfg.hang_cfg)

    session = GuardSession.from_tier(
        tier, control=cluster, sweep_backend=cluster, sweep_cfg=sweep_cfg,
        diagnoser=diagnoser,
        on_provision=lambda nid: _admission_check(
            cluster, nid, tier, sweep_cfg, session.spare_ids()))
    session.register_active(cluster.active)
    session.register_spares(cluster.spares)
    # correlated scenarios + the pre-existing grey population (the state
    # of the world Guard inherits), all through the declarative layer
    scenarios: List[Scenario] = list(cfg.scenarios)
    if cfg.initial_grey_p > 0:
        scenarios.append(InitialGreyPopulation(p=cfg.initial_grey_p))
    arm_all(scenarios, cluster, rng)
    cluster.fleet.advance_thermals(3600.0)

    duration_s = cfg.duration_h * 3600.0
    healthy_step = cfg.workload.healthy_step_s
    ckpt_every = cfg.checkpoint_interval_steps
    rec = cfg.recovery
    fast_tiers = rec.fast_tier_enabled(int(tier))
    last_ckpt_step = 0             # durable (COLD) restore point
    last_fast_step = 0             # fast-tier restore point (peer/local)
    snap_interval = young_daly_interval(
        session.mttf.estimate(cluster.t), rec.snapshot_cost_s,
        rec.min_interval_s, rec.max_interval_s)
    next_snap_t = cluster.t + snap_interval
    step_chunks: List[np.ndarray] = []
    total_steps = 0
    crashes = 0
    human_hours = 0.0
    incidents = 0
    downtime_s = 0.0
    slow_since: Optional[float] = None
    hour_steps = 0
    hour_sum = 0.0
    win_accum = 0                  # steps gathered toward the next window

    def recover(reason: str, *, rewind: bool, node_alive: bool = True,
                replica_lost: bool = False, detect_s: float = 0.0,
                drain_s: float = 0.0) -> None:
        """One restart, charged at the recovery model's rate: restore from
        the fastest checkpoint tier this ablation tier has built, then the
        generic warmup (re-shard / re-JIT / rejoin). Non-COLD restores
        rewind only to the last *fast* snapshot — the whole point of the
        fast tiers is a shorter replay. Publishes the JobRestart plus the
        MTTR-decomposed RecoveryEvent. ``detect_s``/``drain_s`` were
        already charged by the caller (they precede the restore); they
        ride along for the decomposition only."""
        nonlocal last_fast_step, downtime_s
        ck = rec.pick(int(tier), node_alive, replica_lost)
        restore_s = rec.restore_s(ck)
        warmup_s = cfg.restart_overhead_s
        cluster.advance_idle(restore_s + warmup_s)
        downtime_s += restore_s + warmup_s
        lost = 0
        if rewind:
            target = last_fast_step if ck is not CheckpointTier.COLD \
                else last_ckpt_step
            target = min(target, cluster.step)
            lost = cluster.step - target
            cluster.step = target
        # a fast snapshot taken past the current position is unusable now
        last_fast_step = min(last_fast_step, cluster.step)
        cluster.restart_job(reason)
        session.publish(JobRestart(t=cluster.t, step=cluster.step,
                                   reason=reason, lost_steps=lost,
                                   rewind=rewind))
        session.publish(RecoveryEvent(
            t=cluster.t, step=cluster.step, reason=reason,
            ckpt_tier=ck.value,
            hot_spare=ck is CheckpointTier.PEER,
            detect_s=detect_s, drain_s=drain_s,
            restore_s=restore_s, warmup_s=warmup_s, replay_steps=lost))

    while cluster.t < duration_s:
        # ---------------- one evaluation window (or the slice of one
        # that reaches the next checkpoint boundary), batched
        to_ckpt = ckpt_every - (cluster.step % ckpt_every)
        win = cluster.run_window(min(cfg.window_steps - win_accum, to_ckpt))

        # ---------------- crash path (fail-stop)
        if win["crashed"]:
            if win["steps_run"]:
                step_chunks.append(win["step_times"])
                total_steps += win["steps_run"]
            crashes += 1
            incidents += 1
            drain = cfg.crash_recovery_s[int(tier)]
            cluster.advance_idle(cfg.crash_detect_s + drain)
            downtime_s += cfg.crash_detect_s + drain
            human_hours += cfg.crash_human_h[int(tier)]
            # batch handling: every node found dead during this recovery
            # window is swapped in the same restart
            replica_lost = False
            while cluster.crashed_nodes():
                dead = cluster.crashed_nodes()
                # peer-replica coverage check BEFORE the swaps rewrite the
                # active list: if both members of a DP mirror pair died,
                # some shard has no surviving in-memory replica and the
                # restore degrades to the cold tier
                idx = {n: i for i, n in enumerate(cluster.active)}
                n_act = len(cluster.active)
                dead_idx = {idx[d] for d in dead}
                replica_lost |= any(
                    replica_partner(i, n_act) in dead_idx
                    for i in dead_idx)
                missing = max(0, len(dead) - session.spares_free)
                if missing:
                    # pool ran dry mid-incident: the job waits for delivery
                    cluster.advance_idle(missing * cfg.provision_delay_s)
                    drain += missing * cfg.provision_delay_s
                    downtime_s += missing * cfg.provision_delay_s
                session.handle_crash(
                    dead, lost_steps=cluster.step - last_ckpt_step,
                    step=cluster.step)
                for bad in dead:
                    cluster.injector.clear_node(bad)  # hw leaves with node
            # the dead nodes' local shards died with them (node_alive
            # False); the ENHANCED tier still hot-spare-promotes from the
            # surviving peer replicas unless a whole mirror pair is gone
            recover("fail-stop crash", rewind=True, node_alive=False,
                    replica_lost=replica_lost,
                    detect_s=cfg.crash_detect_s, drain_s=drain)
            win_accum = 0
            hour_steps, hour_sum = 0, 0.0
            continue

        # ---------------- hang path (wedged collective, no step samples)
        if win["hung"]:
            if win["steps_run"]:
                step_chunks.append(win["step_times"])
                total_steps += win["steps_run"]
            incidents += 1
            t_onset = cluster.t
            pend = cluster.hang_pending()
            window_s = cfg.window_steps * healthy_step
            verdicts: List = []
            if watchdog is not None:
                # poll at window cadence: silence accrues against the
                # per-group adaptive deadlines, bounded by the blind
                # framework-level CCL abort
                while not verdicts and \
                        cluster.t - t_onset < cfg.ccl_timeout_s:
                    cluster.advance_idle(window_s)
                    downtime_s += window_s
                    verdicts = watchdog.check(pend, cluster.t)
            else:
                # no ccltrace layer: nothing fires until the framework
                # CCL abort kills the job blind
                cluster.advance_idle(cfg.ccl_timeout_s)
                downtime_s += cfg.ccl_timeout_s
            detect_s = cluster.t - t_onset
            if verdicts:
                n_culprits = sum(len(v.culprits) for v in verdicts)
                missing = max(0, n_culprits - session.spares_free)
                if missing:
                    # pool ran dry mid-incident: wait for delivery
                    cluster.advance_idle(missing * cfg.provision_delay_s)
                    downtime_s += missing * cfg.provision_delay_s
                attributed = False
                for v in verdicts:
                    attributed |= v.attributed
                    session.handle_hang(
                        v, step=cluster.step,
                        latency_windows=detect_s / window_s)
                human_hours += cfg.auto_human_h[int(tier)]
                # culprits left with their hardware faults attached; the
                # quarantine -> sweep -> triage path owns them now (the
                # hang-gated probes keep a still-wedged node from
                # requalifying). Victims were merely blocked: they stay.
                recover("collective hang (culprit evicted)" if attributed
                        else "collective hang (no culprit attributed)",
                        rewind=True, node_alive=True, detect_s=detect_s)
            else:
                # the watchdog never attributed within the CCL abort:
                # blind framework restart, crash-grade human cost
                crashes += 1
                human_hours += cfg.crash_human_h[int(tier)]
                session.mttf.observe_failure(cluster.t)
                recover("collective hang (CCL timeout)", rewind=True,
                        node_alive=True, detect_s=detect_s)
            win_accum = 0
            hour_steps, hour_sum = 0, 0.0
            continue

        step_chunks.append(win["step_times"])
        total_steps += win["steps_run"]
        win_accum += win["steps_run"]
        hour_steps += win["steps_run"]
        hour_sum += float(win["step_times"].sum())
        # offline qualification overlaps the job: let the sweep bench
        # catch up to job time after every window
        session.advance(cluster.t, step=cluster.step)

        # ---------------- fast-tier snapshot (peer replica + local shard)
        if fast_tiers and cluster.t >= next_snap_t:
            last_fast_step = cluster.step
            cluster.advance_idle(rec.snapshot_cost_s)
            downtime_s += rec.snapshot_cost_s
            # cadence follows the live MTTF estimate (Young-Daly): a
            # crashing fleet snapshots more often, a quiet one backs off
            snap_interval = young_daly_interval(
                session.mttf.estimate(cluster.t), rec.snapshot_cost_s,
                rec.min_interval_s, rec.max_interval_s)
            next_snap_t = cluster.t + snap_interval

        # ---------------- online monitoring (tiers 3-4)
        if session.online_monitoring and win_accum >= cfg.window_steps:
            win_accum = 0
            frame = cluster.collect()
            if frame is not None:
                outcome = session.observe(frame)
                restarted = False
                for reason in outcome.restarts:
                    incidents += 1
                    human_hours += cfg.auto_human_h[int(tier)]
                    # eviction: the grey node is alive, so even the
                    # local-shard tier can serve; ENHANCED promotes the
                    # spare from the peer replica (hot-spare resume)
                    recover(reason, rewind=True, node_alive=True)
                    restarted = True
                if restarted:
                    hour_steps, hour_sum = 0, 0.0
        elif win_accum >= cfg.window_steps:
            win_accum = 0

        # ---------------- checkpoint boundary
        if cluster.step > 0 and cluster.step % ckpt_every == 0:
            last_ckpt_step = cluster.step
            if fast_tiers:
                # the durable snapshot is (at least) as fresh as any
                # fast-tier one: both restore points now coincide
                last_fast_step = cluster.step
            ck = session.on_checkpoint(now=cluster.t, step=cluster.step)
            if ck.applied_swaps:
                incidents += ck.applied_swaps
                human_hours += ck.applied_swaps * cfg.auto_human_h[int(tier)]
                # planned restart at the boundary: state is fresh, no
                # rewind; the swapped-out nodes are alive (evictions)
                recover("deferred swaps", rewind=False, node_alive=True)
                win_accum = 0
            human_hours += session.drain_human_hours()
            # background warm-pool maintenance overlaps the job
            session.top_up_spares(cfg.n_spare)

        # ---------------- manual grey hunting (tiers 1-2)
        if not session.online_monitoring and \
                hour_steps * healthy_step >= 3600.0:
            hour_mean = hour_sum / hour_steps
            hour_steps, hour_sum = 0, 0.0
            if hour_mean > cfg.manual_trigger_ratio * healthy_step:
                if slow_since is None:
                    slow_since = cluster.t
                delay = cfg.manual_delay_h[int(tier)] * 3600.0
                if cluster.t - slow_since >= delay:
                    slow_since = None
                    incidents += 1
                    human_hours += cfg.manual_hours[int(tier)]
                    hunt_dt = cfg.hunt_downtime_s[int(tier)]
                    cluster.advance_idle(hunt_dt)
                    downtime_s += hunt_dt
                    times = cluster.node_barrier_times()
                    worst = cluster.active[int(np.argmax(times))]
                    if rng.rand() < cfg.manual_success_p[int(tier)]:
                        if not session.spares_free:
                            # pool dry: the job waits for delivery
                            cluster.advance_idle(cfg.provision_delay_s)
                            downtime_s += cfg.provision_delay_s
                        # a hand-debugged node leaves the fleet for RMA;
                        # it is NOT requalified back into the pool — with
                        # no online monitoring a bounced-back grey would
                        # go unwatched until it escalates
                        session.replace_node(
                            worst, "manual grey-node replacement",
                            quarantine=False, step=cluster.step)
                        if session.sweep_tooling:
                            # tier 2: the human confirms the diagnosis
                            # with the sweep tooling before the RMA
                            rep = single_node_sweep(cluster, worst,
                                                    sweep_cfg)
                            if not rep.passed:
                                cluster.injector.clear_node(worst)
                        else:
                            cluster.injector.clear_node(worst)
                        recover("manual grey-node replacement",
                                rewind=False, node_alive=True)
                        win_accum = 0
            else:
                slow_since = None

    # land any still-running offline qualifications for final accounting
    # (drain stamps the end-of-run events with the FINAL global step, not
    # whatever step the last mid-run advance happened to see)
    session.scheduler.drain(cluster.t, step=cluster.step)
    human_hours += session.drain_human_hours()

    # ----------------------------------------------------------- metrics
    st = np.concatenate(step_chunks) if step_chunks else np.asarray([])
    elapsed_h = cluster.t / 3600.0
    active_h = max(elapsed_h - downtime_s / 3600.0, 1e-9)
    steps = len(st)
    mttf_h = active_h / max(crashes, 1)
    # MFU: completed useful FLOPs over total elapsed time
    mfu = cfg.workload.mfu_at_healthy * (steps * healthy_step) / cluster.t
    stats = session.stats
    events = session.trace.as_dicts()
    # goodput counts only unique forward progress: every step re-executed
    # after a rewind is excluded (MFU above counts it — that's throughput)
    good_steps = int(cluster.step)
    recovery_summary = mttr_decomposition(
        e for e in events if e.get("kind") == "recovery")
    recovery_summary["good_steps"] = good_steps
    recovery_summary["wasted_steps"] = max(steps - good_steps, 0)
    recovery_summary["snap_interval_s"] = float(snap_interval) \
        if fast_tiers else 0.0
    pools: Dict[str, int] = {}
    for state in session.manager.state.values():
        pools[state.value] = pools.get(state.value, 0) + 1
    return RunResult(
        tier=tier, elapsed_h=elapsed_h, active_h=active_h, steps=steps,
        crashes=crashes, mttf_h=mttf_h, mfu=float(mfu),
        mean_step_s=float(st.mean()) if steps else float("nan"),
        p95_step_s=float(np.percentile(st, 95)) if steps else float("nan"),
        human_hours=human_hours, incidents=max(incidents, 1),
        human_h_per_incident=human_hours / max(incidents, 1),
        guard_restarts=stats.immediate_restarts,
        deferred_swaps=stats.deferred_swaps,
        nodes_terminated=stats.nodes_terminated,
        step_times=st, events=events,
        fault_log=[{"node": f.node, "kind": f.kind.value,
                    "device": f.device, "severity": f.severity,
                    "t_start": f.t_start, "t_cleared": f.t_cleared}
                   for f in cluster.injector.faults],
        goodput_tflop_h=goodput_tflop_h(
            good_steps, cfg.workload.step_tflops, elapsed_h),
        recovery=recovery_summary, pools=pools)


# --------------------------------------------------------------------------
# Fleet mode: N concurrent jobs through one FleetController
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FleetJobSpec:
    """One tenant of the fleet control plane."""
    name: str
    tier: Tier = Tier.ONLINE
    n_nodes: int = 128
    n_spare: int = 4          # private spares adopted into the pool at t=0
    priority: Optional[int] = None    # defaults to the tier value
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class FleetRunConfig:
    """The multi-tenant sim: every job shares the controller's global
    spare pool, sweep bench, healthscan and event log."""
    jobs: Tuple[FleetJobSpec, ...] = ()
    duration_h: float = 24.0
    window_steps: int = 6
    checkpoint_interval_steps: int = 90
    crash_detect_s: float = 120.0
    crash_recovery_s: float = 600.0
    restart_overhead_s: float = 600.0
    provision_delay_s: float = 1800.0
    ccl_timeout_s: float = 600.0
    initial_grey_p: float = 0.05
    auto_human_h: float = 0.5
    # fleet control plane
    bench_slots: int = 4
    healthscan_period_s: Optional[float] = 6 * 3600.0
    healthscan_batch: int = 16
    starvation_age_s: float = 3600.0
    floor_frac: float = 0.5
    spare_target: int = 16            # global free-pool floor
    home_min: int = 2                 # per-job sweep-buddy floor
    log_capacity: int = 65536
    workload: WorkloadProfile = dataclasses.field(
        default_factory=WorkloadProfile)
    rates: FaultRates = dataclasses.field(default_factory=FaultRates)
    seed: int = 0


@dataclasses.dataclass
class FleetRunResult:
    jobs: List[dict]                  # per-job summaries
    elapsed_h: float                  # sim-time horizon reached
    starvation_events: int
    max_wait_s: float
    census: Dict[str, object]         # FleetController.census()
    census_ok: bool
    pool: Dict[str, int]              # grants / transfers / provisions
    healthscan: Dict[str, int]
    events_logged: int
    overhead_s: float                 # control-plane self-time
    wall_s: float                     # total sim wall time
    overhead_frac: float              # overhead_s / wall_s (<5% gated)


@dataclasses.dataclass
class _FleetJobState:
    spec: FleetJobSpec
    cluster: SimCluster
    session: GuardSession
    last_ckpt_step: int = 0
    win_accum: int = 0
    crashes: int = 0
    restarts: int = 0
    total_steps: int = 0
    human_hours: float = 0.0


def _fleet_restart(job: "_FleetJobState", cfg: FleetRunConfig,
                   reason: str, rewind: bool) -> None:
    """Cold-restore restart for the fleet driver (the tiered-checkpoint
    ladder lives in ``simulate_run``; fleet mode keeps recovery lean)."""
    cluster = job.cluster
    cluster.advance_idle(cfg.restart_overhead_s)
    lost = 0
    if rewind:
        target = min(job.last_ckpt_step, cluster.step)
        lost = cluster.step - target
        cluster.step = target
    cluster.restart_job(reason)
    job.restarts += 1
    job.session.publish(JobRestart(t=cluster.t, step=cluster.step,
                                   reason=reason, lost_steps=lost,
                                   rewind=rewind))


def _fleet_window(job: "_FleetJobState", cfg: FleetRunConfig,
                  controller) -> None:
    """Advance one job by (up to) one evaluation window — the fleet
    driver's unit of interleaving."""
    cluster, session = job.cluster, job.session
    ckpt_every = cfg.checkpoint_interval_steps
    to_ckpt = ckpt_every - (cluster.step % ckpt_every)
    win = cluster.run_window(min(cfg.window_steps - job.win_accum, to_ckpt))
    job.total_steps += win["steps_run"]

    if win["crashed"]:
        job.crashes += 1
        cluster.advance_idle(cfg.crash_detect_s + cfg.crash_recovery_s)
        job.human_hours += cfg.auto_human_h
        while cluster.crashed_nodes():
            dead = cluster.crashed_nodes()
            missing = max(0, len(dead) - session.spares_free)
            if missing:
                # global pool dry mid-incident: the job waits for the
                # controller to materialize capacity
                cluster.advance_idle(missing * cfg.provision_delay_s)
            session.handle_crash(dead,
                                 lost_steps=cluster.step -
                                 job.last_ckpt_step,
                                 step=cluster.step)
            for bad in dead:
                cluster.injector.clear_node(bad)
        _fleet_restart(job, cfg, "fail-stop crash", rewind=True)
        job.win_accum = 0
        return

    if win["hung"]:
        # lean hang handling: wait out the framework CCL abort, evict
        # nothing (no ccltrace layer in fleet mode), restart blind
        cluster.advance_idle(cfg.ccl_timeout_s)
        job.crashes += 1
        job.human_hours += cfg.auto_human_h
        session.mttf.observe_failure(cluster.t)
        _fleet_restart(job, cfg, "collective hang (CCL timeout)",
                       rewind=True)
        job.win_accum = 0
        return

    job.win_accum += win["steps_run"]
    session.advance(cluster.t, step=cluster.step)

    if session.online_monitoring and job.win_accum >= cfg.window_steps:
        job.win_accum = 0
        frame = cluster.collect()
        if frame is not None:
            outcome = session.observe(frame)
            for reason in outcome.restarts:
                job.human_hours += cfg.auto_human_h
                _fleet_restart(job, cfg, reason, rewind=True)
    elif job.win_accum >= cfg.window_steps:
        job.win_accum = 0

    if cluster.step > 0 and cluster.step % ckpt_every == 0:
        job.last_ckpt_step = cluster.step
        ck = session.on_checkpoint(now=cluster.t, step=cluster.step)
        if ck.applied_swaps:
            job.human_hours += ck.applied_swaps * cfg.auto_human_h
            _fleet_restart(job, cfg, "deferred swaps", rewind=False)
            job.win_accum = 0
        job.human_hours += session.drain_human_hours()
        # warm-pool maintenance is a CONTROLLER duty in fleet mode: the
        # global floor + per-job buddy floor replace per-job n_spare
        controller.top_up(cfg.spare_target, home_min=cfg.home_min)


def simulate_fleet(cfg: FleetRunConfig) -> FleetRunResult:
    """Drive N concurrent jobs through one ``FleetController``.

    Jobs advance in global event order (the job with the smallest sim
    clock steps next), so cross-job contention on the pool and bench is
    resolved in the same order a real shared control plane would see
    the requests. Control-plane overhead is the controller's self-timed
    entry points as a fraction of total sim wall time."""
    from repro.fleet import FleetController

    assert cfg.jobs, "FleetRunConfig needs at least one job"
    wall0 = time.perf_counter()
    controller = FleetController(
        bench_slots=cfg.bench_slots,
        starvation_age_s=cfg.starvation_age_s,
        floor_frac=cfg.floor_frac,
        log_capacity=cfg.log_capacity,
        healthscan_period_s=cfg.healthscan_period_s,
        healthscan_batch=cfg.healthscan_batch)
    rng = np.random.RandomState(cfg.seed + 17)
    jobs: List[_FleetJobState] = []
    for spec in cfg.jobs:
        cluster = SimCluster(spec.n_nodes, spec.n_spare,
                             workload=cfg.workload, rates=cfg.rates,
                             window_steps=cfg.window_steps,
                             seed=cfg.seed + spec.seed)
        # no inline admission in fleet mode: spares live in the shared
        # pool and the healthscan orchestrator is the line of defense
        # against admission greys (the cluster-service model)
        session = GuardSession.from_tier(Tier(spec.tier), control=cluster,
                                         sweep_backend=cluster,
                                         sweep_cfg=SweepConfig())
        session.register_active(cluster.active)
        session.register_spares(cluster.spares)
        controller.register_job(spec.name, session,
                                priority=spec.priority)
        if cfg.initial_grey_p > 0:
            arm_all([InitialGreyPopulation(p=cfg.initial_grey_p)],
                    cluster, rng)
        cluster.fleet.advance_thermals(3600.0)
        jobs.append(_FleetJobState(spec, cluster, session))
    controller.top_up(cfg.spare_target, home_min=cfg.home_min)

    duration_s = cfg.duration_h * 3600.0
    while True:
        pending = [j for j in jobs if j.cluster.t < duration_s]
        if not pending:
            break
        job = min(pending, key=lambda j: j.cluster.t)
        _fleet_window(job, cfg, controller)
        controller.tick(job.cluster.t)

    for job in jobs:
        job.session.scheduler.drain(job.cluster.t, step=job.cluster.step)
        job.human_hours += job.session.drain_human_hours()

    census = controller.census()
    wall_s = time.perf_counter() - wall0
    fj = controller.jobs
    return FleetRunResult(
        jobs=[{
            "name": j.spec.name,
            "tier": int(j.spec.tier),
            "priority": fj[j.spec.name].priority,
            "n_nodes": j.spec.n_nodes,
            "steps": j.total_steps,
            "good_steps": int(j.cluster.step),
            "crashes": j.crashes,
            "restarts": j.restarts,
            "leases": fj[j.spec.name].leases,
            "transfers": fj[j.spec.name].transfer_grants,
            "provision_grants": fj[j.spec.name].provision_grants,
            "human_hours": j.human_hours,
            "elapsed_h": j.cluster.t / 3600.0,
        } for j in jobs],
        elapsed_h=max(j.cluster.t for j in jobs) / 3600.0,
        starvation_events=controller.starvation_events(),
        max_wait_s=controller.pool.stats.max_wait_s,
        census=census,
        census_ok=bool(census["conserved"]),
        pool={"grants": controller.pool.stats.grants,
              "transfers": controller.pool.stats.transfers,
              "provisions": controller.pool.stats.provisions},
        healthscan={
            "campaigns": controller.healthscan.campaigns,
            "scanned": controller.healthscan.scanned,
            "failed": len(controller.healthscan.failed),
        } if controller.healthscan is not None else {},
        events_logged=controller.log.head,
        overhead_s=controller.overhead_s,
        wall_s=wall_s,
        overhead_frac=controller.overhead_s / max(wall_s, 1e-9))
