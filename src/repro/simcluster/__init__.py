"""Simulated fleet: hardware dynamics, event-driven fault injection, the
window-granular synchronous step engine, the declarative correlated-fault
scenario layer, and the multi-week run simulator. Everything above this
layer (Guard's detection/triage/sweep logic) is substrate-independent."""
from repro.simcluster.cluster import SWEEP_PROFILE, SimCluster, \
    SimSweepBackend, WorkloadProfile
from repro.simcluster.faults import (BROWNOUT_HANG_SEV, FaultInjector,
                                     FaultKind, FaultRates, GREY_KINDS,
                                     HANG_KINDS)
from repro.simcluster.node import (Fleet, HWConfig, THROTTLE_CURVE_C,
                                   THROTTLE_CURVE_GHZ, freq_at_temp)
from repro.simcluster.runtime import (FleetJobSpec, FleetRunConfig,
                                      FleetRunResult, RunConfig, RunResult,
                                      Tier, simulate_fleet, simulate_run)
from repro.simcluster.scenarios import (CongestionStorm,
                                        DeadlockedCollective,
                                        InitialGreyPopulation,
                                        MaintenanceWindow,
                                        PartialNicBrownout, RackThermal,
                                        Scenario, StragglerTimeoutCascade,
                                        SwitchFailure, arm_all,
                                        builtin_scenarios, register_scenario,
                                        scenario)

__all__ = [
    "BROWNOUT_HANG_SEV",
    "CongestionStorm", "DeadlockedCollective", "FaultInjector", "FaultKind",
    "FaultRates", "Fleet", "FleetJobSpec", "FleetRunConfig",
    "FleetRunResult",
    "GREY_KINDS", "HANG_KINDS", "HWConfig", "InitialGreyPopulation",
    "MaintenanceWindow",
    "PartialNicBrownout",
    "RackThermal", "RunConfig", "RunResult", "SWEEP_PROFILE", "Scenario",
    "SimCluster", "SimSweepBackend", "StragglerTimeoutCascade",
    "SwitchFailure", "THROTTLE_CURVE_C",
    "THROTTLE_CURVE_GHZ",
    "Tier", "WorkloadProfile", "arm_all", "builtin_scenarios",
    "freq_at_temp", "register_scenario", "scenario", "simulate_fleet",
    "simulate_run",
]
