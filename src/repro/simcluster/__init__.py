"""Simulated fleet: hardware dynamics, fault injection, synchronous step
composition and the multi-week run simulator. Everything above this layer
(Guard's detection/triage/sweep logic) is substrate-independent."""
from repro.simcluster.cluster import SWEEP_PROFILE, SimCluster, \
    WorkloadProfile
from repro.simcluster.faults import (FaultInjector, FaultKind, FaultRates,
                                     GREY_KINDS)
from repro.simcluster.node import (Fleet, HWConfig, THROTTLE_CURVE_C,
                                   THROTTLE_CURVE_GHZ, freq_at_temp)
from repro.simcluster.runtime import RunConfig, RunResult, Tier, simulate_run

__all__ = [
    "FaultInjector", "FaultKind", "FaultRates", "Fleet", "GREY_KINDS",
    "HWConfig", "RunConfig", "RunResult", "SWEEP_PROFILE", "SimCluster",
    "THROTTLE_CURVE_C", "THROTTLE_CURVE_GHZ", "Tier", "WorkloadProfile",
    "freq_at_temp", "simulate_run",
]
