"""Training loop with Guard hooks, sharded step construction, fault-tolerant
restart, and optional gradient accumulation.

``make_train_step`` builds the functional (params, opt, batch) -> (params,
opt, metrics) step used identically by the real trainer, the benchmarks and
the multi-pod dry-run. When a mesh context is active, in/out shardings are
derived from the parameter trees' logical axes (see repro.dist.api), so the
same code path covers single-CPU smoke tests and the 512-chip production
mesh.

Guard integration: the trainer reports its per-step wall time (each host's
time-to-barrier in a real deployment) to a ``StepHook``; when the hook
requests a restart — Guard's IMMEDIATE tier — the trainer restores the last
checkpoint, notifies the hook via ``on_restart`` (if present) so partial
telemetry windows are dropped, and continues: exactly the closed-loop
behaviour in Fig. 1. ``repro.guard.GuardStepHook`` is the production
implementation — it turns these wall times into telemetry Frames and runs
them through the real monitor → policy → manager pipeline.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Protocol

import jax
import jax.numpy as jnp

from repro.dist import api as dist
from repro.models.model import Model
from repro.train.checkpoint import CheckpointManager
from repro.train.data import SyntheticLM
from repro.train.optimizer import AdamWConfig, apply_adamw, init_opt_state


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    ckpt_interval: int = 50
    log_interval: int = 10
    microbatch: int = 0          # >0: grad-accumulation chunk (batch dim)
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)


def make_train_step(model: Model, opt_cfg: AdamWConfig,
                    microbatch: int = 0) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def loss_fn(params, batch):
        loss, metrics = model.train_loss(params, batch)
        return loss, metrics

    def grads_of(params, batch):
        if not microbatch:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return grads, metrics
        # gradient accumulation over batch-dim chunks via scan
        B = batch["tokens"].shape[0]
        assert B % microbatch == 0, (B, microbatch)
        n = B // microbatch

        def split(x):
            return x.reshape((n, microbatch) + x.shape[1:]) \
                if x.ndim and x.shape[0] == B else \
                jnp.broadcast_to(x, (n,) + x.shape)

        chunks = {k: split(v) for k, v in batch.items()}

        def body(acc, chunk):
            (loss, metrics), g = jax.value_and_grad(
                loss_fn, has_aux=True)(params, chunk)
            acc = jax.tree.map(jnp.add, acc, g)
            return acc, metrics

        zero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        acc, metrics = jax.lax.scan(body, zero, chunks)
        grads = jax.tree.map(lambda g: g / n, acc)
        metrics = jax.tree.map(lambda m: m.mean(), metrics)
        return grads, metrics

    def train_step(params, opt_state, batch):
        grads, metrics = grads_of(params, batch)
        params, opt_state, opt_metrics = apply_adamw(
            params, grads, opt_state, opt_cfg)
        metrics = {**metrics, **opt_metrics}
        return params, opt_state, metrics

    return train_step


def make_serve_step(model: Model) -> Callable:
    """(params, tokens, cache) -> (logits, cache) — the decode-shape step."""
    def serve_step(params, tokens, cache):
        return model.decode_step(params, tokens, cache)
    return serve_step


class StepHook(Protocol):
    """Guard-side per-step callback. Return True to request a restart."""

    def __call__(self, step: int, wall_s: float,
                 metrics: Dict[str, float]) -> bool: ...


class Trainer:
    def __init__(self, model: Model, data: SyntheticLM, cfg: TrainConfig,
                 ckpt: Optional[CheckpointManager] = None,
                 hook: Optional[StepHook] = None,
                 seed: int = 0):
        self.model = model
        self.data = data
        self.cfg = cfg
        self.ckpt = ckpt
        self.hook = hook
        self.seed = seed
        self.history: list = []
        self.last_recovery: Optional[Dict[str, Any]] = None

        self.params, self.axes = model.init_params(jax.random.key(seed))
        self.opt_state = init_opt_state(self.params)
        self._step_fn = self._build_step()

    def _build_step(self):
        step = make_train_step(self.model, self.cfg.opt, self.cfg.microbatch)
        ctx = dist.current()
        if ctx is None:
            return jax.jit(step)
        p_sh = dist.param_sharding(self.axes, self.params, ctx)
        o_sh = {"mu": p_sh, "nu": p_sh,
                "count": ctx.sharding((), ())}
        b_sh = None  # batch sharding constrained inside the model
        return jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                       out_shardings=(p_sh, o_sh, None),
                       donate_argnums=(0, 1))

    # ------------------------------------------------------------- loop

    def restore(self) -> int:
        """Restore from the fastest checkpoint tier available. With a
        ``TieredCheckpointManager`` this is the hot-spare path: the peer
        replica or local shard serves before durable storage; the tier
        used is recorded in ``self.last_recovery``."""
        if self.ckpt is None:
            return 0
        restore_any = getattr(self.ckpt, "restore_any", None)
        if restore_any is not None:
            out = restore_any(self.params, self.opt_state)
            if out is None:
                return 0
            self.params, self.opt_state, step, tier = out
            self.last_recovery = {"step": step, "ckpt_tier": tier.value,
                                  "hot_spare": tier.value == "peer"}
            return step
        out = self.ckpt.restore(self.params, self.opt_state)
        if out is None:
            return 0
        self.params, self.opt_state, step = out
        self.last_recovery = {"step": step, "ckpt_tier": "cold",
                              "hot_spare": False}
        return step

    def run(self, on_metrics: Optional[Callable[[int, dict], None]] = None
            ) -> Dict[str, Any]:
        step = self.restore()
        while step < self.cfg.steps:
            batch = self.data.batch_at(step)
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self._step_fn(
                self.params, self.opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            wall = time.perf_counter() - t0
            step += 1
            m = {k: float(v) for k, v in metrics.items()}
            self.history.append({"step": step, "wall_s": wall, **m})
            if on_metrics:
                on_metrics(step, m)

            if self.ckpt:
                # fast-tier snapshots (tiered manager only): peer replica
                # + local shard on the MTTF-tuned cadence
                on_step = getattr(self.ckpt, "on_step", None)
                if on_step:
                    on_step(step, self.params, self.opt_state)

            if self.ckpt and step % self.cfg.ckpt_interval == 0:
                self.ckpt.save(step, self.params, self.opt_state)
                # checkpoint boundary: Guard lands deferred mitigations
                # here (the hook may request a restart on the next step)
                on_ckpt = getattr(self.hook, "on_checkpoint", None)
                if on_ckpt:
                    on_ckpt(step)

            if self.hook and self.hook(step, wall, m):
                # Guard requested an immediate restart: rewind to the last
                # checkpoint (replacement happens at the cluster layer)
                fail_step = step
                t_restore = time.perf_counter()
                step = self.restore()
                restore_wall = time.perf_counter() - t_restore
                on_restart = getattr(self.hook, "on_restart", None)
                if on_restart:
                    on_restart(step)
                on_recovery = getattr(self.hook, "on_recovery", None)
                if on_recovery:
                    info = dict(self.last_recovery or
                                {"ckpt_tier": "cold", "hot_spare": False})
                    info["restore_s"] = restore_wall
                    info["replay_steps"] = max(fail_step - step, 0)
                    on_recovery(step, info)
        if self.ckpt:
            self.ckpt.wait()
        return {"final_step": step, "history": self.history}
