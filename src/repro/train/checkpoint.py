"""Checkpoint managers: atomic, asynchronous, retention-managed — and tiered.

``CheckpointManager`` (the durable/COLD tier) saves the flattened
(params, opt_state, step) tree as an ``.npz`` plus a JSON manifest.
Writes go to a unique temp path and are renamed atomically so a crash
mid-save can never corrupt the restore point — the fault-tolerance
contract the Guard runtime relies on when it restarts jobs. Saves can run
on a background thread (overlapping the next training steps) mirroring
production async-checkpoint behaviour; ``wait()`` joins before exit and
surfaces any writer failure instead of swallowing it, and ``restore``
skips torn/incomplete directories — an in-flight snapshot racing a
crash either lands fully or is discarded.

``TieredCheckpointManager`` adds the two fast tiers of the recovery
architecture (see ``repro.guard.goodput``):

  PEER    the full flattened state mirrored in a DP peer's host memory
          (``replica_partner`` over the ``repro.dist`` "batch" axis; in
          this single-process reproduction the replica is held in RAM).
          A hot spare promoted into the job restores from here.
  LOCAL   a node-local fast shard (``local/`` subdir, synchronous atomic
          writes) that survives evictions but dies with the node.

Fast snapshots share the durable tier's flattening and rebuild code, so
a restore from any tier is bit-identical to a cold restore of the same
step. Cadence is Young–Daly-optimal for the live MTTF estimate fed in
through ``update_mttf`` (GuardSession tracks it).

Restore is topology-independent: leaves are stored by tree path, so a job
restarted on a different mesh (elastic scaling) re-shards the restored
arrays through its own ``in_shardings`` when they enter the jitted step.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.guard.goodput import (CheckpointTier, RecoveryModel,
                                 replica_partner, young_daly_interval)


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _rebuild(data: Dict[str, np.ndarray], prefix: str, like):
    """Unflatten ``data[prefix + <tree path>]`` into the structure of
    ``like`` (templates may be ShapeDtypeStructs or arrays on any mesh)."""
    leaves_p = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for pth, leaf in leaves_p[0]:
        key = prefix + "/".join(
            str(getattr(p, "key", getattr(p, "idx", p)))
            for p in pth)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        out.append(arr)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self._error_ctx: Optional[Tuple[int, int]] = None   # (step, seq)
        self._seq = 0           # unique tmp suffix: re-saves never collide
        os.makedirs(directory, exist_ok=True)
        self._clean_debris()

    def _clean_debris(self) -> None:
        """Remove leftovers of writes that died mid-flight (tmp dirs and
        displaced old versions) so they can never shadow a valid
        checkpoint or block a future rename."""
        for name in os.listdir(self.dir):
            if name.startswith(".tmp-") or name.startswith(".old-"):
                shutil.rmtree(os.path.join(self.dir, name),
                              ignore_errors=True)

    # ------------------------------------------------------------- save

    def save(self, step: int, params, opt_state,
             extra: Optional[Dict[str, Any]] = None) -> None:
        flat = {f"p/{k}": v for k, v in _flatten(params).items()}
        flat.update({f"o/{k}": v for k, v in _flatten(opt_state).items()})
        manifest = {"step": int(step), "time": time.time(),
                    "extra": extra or {}}
        self.wait()
        self._seq += 1
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write_safe, args=(step, self._seq, flat,
                                               manifest), daemon=True)
            self._thread.start()
        else:
            self._write(step, self._seq, flat, manifest)

    def _write_safe(self, step: int, seq: int, flat, manifest) -> None:
        try:
            self._write(step, seq, flat, manifest)
        except BaseException as e:      # surfaced by the next wait()
            self._error = e
            self._error_ctx = (int(step), int(seq))

    def _write(self, step: int, seq: int, flat, manifest) -> None:
        tmp = os.path.join(self.dir, f".tmp-{step}-{seq}")
        final = os.path.join(self.dir, f"ckpt-{step:08d}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        # atomic publish. rename() can't replace a non-empty directory, so
        # a re-save of the same step (rewind after restore) first swings
        # the stale version aside — readers only ever see a complete dir.
        if os.path.isdir(final):
            old = os.path.join(self.dir, f".old-{step}-{seq}")
            os.rename(final, old)
            os.rename(tmp, final)
            shutil.rmtree(old, ignore_errors=True)
        else:
            os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            path = os.path.join(self.dir, f"ckpt-{s:08d}")
            for root, dirs, files in os.walk(path, topdown=False):
                for fn in files:
                    os.unlink(os.path.join(root, fn))
                os.rmdir(root)

    def wait(self, raise_errors: bool = True) -> None:
        """Join the in-flight async save. A writer failure is re-raised
        here (the save call site) unless ``raise_errors=False`` — restore
        paths pass False and fall back to the last *complete* checkpoint
        instead of dying on a snapshot that raced the crash."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            ctx, self._error_ctx = self._error_ctx, None
            if raise_errors:
                where = f" (step {ctx[0]}, seq {ctx[1]})" if ctx else ""
                raise RuntimeError(
                    f"async checkpoint write failed{where}") from err

    # ---------------------------------------------------------- restore

    def _is_complete(self, step: int) -> bool:
        path = os.path.join(self.dir, f"ckpt-{step:08d}")
        return (os.path.isfile(os.path.join(path, "arrays.npz"))
                and os.path.isfile(os.path.join(path, "manifest.json")))

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("ckpt-"):
                out.append(int(name.split("-")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        """Newest checkpoint that is fully on disk (torn dirs skipped)."""
        for s in reversed(self.all_steps()):
            if self._is_complete(s):
                return s
        return None

    def restore(self, params_like, opt_like,
                step: Optional[int] = None
                ) -> Optional[Tuple[Any, Any, int]]:
        """Restore into the structure of (params_like, opt_like) — the
        templates may be ShapeDtypeStructs or arrays on any mesh."""
        self.wait(raise_errors=False)
        step = step if step is not None else self.latest_step()
        if step is None or not self._is_complete(step):
            return None
        path = os.path.join(self.dir, f"ckpt-{step:08d}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            data = {k: z[k] for k in z.files}
        return _rebuild(data, "p/", params_like), \
            _rebuild(data, "o/", opt_like), step


class TieredCheckpointManager(CheckpointManager):
    """Durable tier + node-local fast shards + in-memory DP peer replica.

    ``on_step`` is the fast-tier driver: call it every step with the live
    state; it snapshots when the MTTF-tuned cadence says one is due.
    ``restore_any`` is the recovery entry point: it serves from the
    fastest tier that has a complete snapshot (PEER → LOCAL → COLD) and
    reports which one, so callers can charge the right MTTR.
    """

    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = True, *,
                 node_id: int = 0,
                 dp_size: Optional[int] = None,
                 recovery: Optional[RecoveryModel] = None,
                 fast_interval_s: Optional[float] = None,
                 keep_local: int = 2):
        super().__init__(directory, keep=keep, async_save=async_save)
        self.recovery = recovery or RecoveryModel()
        self.node_id = int(node_id)
        if dp_size is None:
            # DP width from the active mesh context, when there is one
            from repro.dist import api as dist
            ctx = dist.current()
            dp_size = ctx.axis_size("batch") if ctx is not None else 1
        self.dp_size = max(int(dp_size), 1)
        self.peer_rank = replica_partner(self.node_id % self.dp_size,
                                         self.dp_size)
        self.keep_local = keep_local
        self.local_dir = os.path.join(directory, "local")
        os.makedirs(self.local_dir, exist_ok=True)
        self._fixed_interval = fast_interval_s
        self._interval_s = (fast_interval_s
                            if fast_interval_s is not None
                            else self.recovery.max_interval_s)
        self._last_snap_t: Optional[float] = None
        self._peer: Optional[Dict[str, Any]] = None   # in-memory replica
        self.snapshots_taken = 0

    # -------------------------------------------------------- cadence

    @property
    def fast_interval_s(self) -> float:
        """Current fast-snapshot interval (seconds of wall time)."""
        return self._interval_s

    def update_mttf(self, mttf_s: float) -> float:
        """Re-tune the fast-tier cadence to the live MTTF estimate
        (Young-Daly optimum, clamped). No-op when the interval was pinned
        explicitly at construction. Returns the interval now in force."""
        if self._fixed_interval is None:
            self._interval_s = young_daly_interval(
                mttf_s, self.recovery.snapshot_cost_s,
                self.recovery.min_interval_s, self.recovery.max_interval_s)
        return self._interval_s

    # ------------------------------------------------------ fast tiers

    def on_step(self, step: int, params, opt_state,
                now: Optional[float] = None) -> bool:
        """Per-step driver: take a fast snapshot when one is due.
        Returns True when a snapshot was taken this call."""
        t = time.monotonic() if now is None else float(now)
        if self._last_snap_t is not None and \
                t - self._last_snap_t < self._interval_s:
            return False
        self.save_fast(step, params, opt_state)
        self._last_snap_t = t
        return True

    def save_fast(self, step: int, params, opt_state) -> None:
        """Snapshot into both fast tiers: the in-memory peer replica and
        the node-local shard. Same flat layout as the durable tier, so a
        restore from any tier is bit-identical."""
        flat = {f"p/{k}": v for k, v in _flatten(params).items()}
        flat.update({f"o/{k}": v for k, v in _flatten(opt_state).items()})
        # PEER: replica handed to the DP partner; copy so later donated/
        # mutated buffers can't reach back into the snapshot
        self._peer = {"step": int(step),
                      "holder": self.peer_rank,
                      "flat": {k: np.array(v, copy=True)
                               for k, v in flat.items()}}
        # LOCAL: synchronous atomic write of the node-local shard
        tmp = os.path.join(self.local_dir, f".tmp-fast-{step}")
        final = os.path.join(self.local_dir, f"fast-{step:08d}.npz")
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, final)
        self.snapshots_taken += 1
        self._gc_local()

    def _gc_local(self) -> None:
        for s in self.local_steps()[:-self.keep_local]:
            os.unlink(os.path.join(self.local_dir, f"fast-{s:08d}.npz"))

    def local_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.local_dir):
            if name.startswith("fast-") and name.endswith(".npz"):
                out.append(int(name[5:-4]))
        return sorted(out)

    def peer_step(self) -> Optional[int]:
        return self._peer["step"] if self._peer is not None else None

    def drop_peer(self) -> None:
        """The replica holder left the job (its memory is gone) — e.g. a
        fail-stop that took out the partner. PEER tier degrades away."""
        self._peer = None

    def drop_local(self) -> None:
        """The node died; its local shards died with it."""
        for s in self.local_steps():
            os.unlink(os.path.join(self.local_dir, f"fast-{s:08d}.npz"))

    # ---------------------------------------------------------- restore

    def restore_any(self, params_like, opt_like,
                    step: Optional[int] = None
                    ) -> Optional[Tuple[Any, Any, int, CheckpointTier]]:
        """Restore from the fastest available tier; returns the tier the
        state came from alongside (params, opt_state, step). Pass
        ``step`` to demand an exact snapshot step (tiers that can't serve
        it are skipped)."""
        if self._peer is not None and \
                (step is None or self._peer["step"] == step):
            data = self._peer["flat"]
            return (_rebuild(data, "p/", params_like),
                    _rebuild(data, "o/", opt_like),
                    self._peer["step"], CheckpointTier.PEER)
        local = self.local_steps()
        pick = None
        if local:
            pick = local[-1] if step is None else \
                (step if step in local else None)
        if pick is not None:
            path = os.path.join(self.local_dir, f"fast-{pick:08d}.npz")
            with np.load(path) as z:
                data = {k: z[k] for k in z.files}
            return (_rebuild(data, "p/", params_like),
                    _rebuild(data, "o/", opt_like),
                    pick, CheckpointTier.LOCAL)
        out = self.restore(params_like, opt_like, step=step)
        if out is None:
            return None
        return out[0], out[1], out[2], CheckpointTier.COLD
