"""Checkpoint manager: atomic, asynchronous, retention-managed.

Saves the flattened (params, opt_state, step) tree as an ``.npz`` plus a
JSON manifest. Writes go to a temp path and are renamed atomically so a
crash mid-save can never corrupt the restore point — the fault-tolerance
contract the Guard runtime relies on when it restarts jobs. Saves can run
on a background thread (overlapping the next training steps) mirroring
production async-checkpoint behaviour; ``wait()`` joins before exit.

Restore is topology-independent: leaves are stored by tree path, so a job
restarted on a different mesh (elastic scaling) re-shards the restored
arrays through its own ``in_shardings`` when they enter the jitted step.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save

    def save(self, step: int, params, opt_state,
             extra: Optional[Dict[str, Any]] = None) -> None:
        flat = {f"p/{k}": v for k, v in _flatten(params).items()}
        flat.update({f"o/{k}": v for k, v in _flatten(opt_state).items()})
        manifest = {"step": int(step), "time": time.time(),
                    "extra": extra or {}}
        self.wait()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, manifest), daemon=True)
            self._thread.start()
        else:
            self._write(step, flat, manifest)

    def _write(self, step: int, flat, manifest) -> None:
        tmp = os.path.join(self.dir, f".tmp-{step}")
        final = os.path.join(self.dir, f"ckpt-{step:08d}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        os.rename(tmp, final)                      # atomic publish
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            path = os.path.join(self.dir, f"ckpt-{s:08d}")
            for root, dirs, files in os.walk(path, topdown=False):
                for fn in files:
                    os.unlink(os.path.join(root, fn))
                os.rmdir(root)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ---------------------------------------------------------- restore

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("ckpt-"):
                out.append(int(name.split("-")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, params_like, opt_like,
                step: Optional[int] = None
                ) -> Optional[Tuple[Any, Any, int]]:
        """Restore into the structure of (params_like, opt_like) — the
        templates may be ShapeDtypeStructs or arrays on any mesh."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            return None
        path = os.path.join(self.dir, f"ckpt-{step:08d}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            data = {k: z[k] for k in z.files}

        def rebuild(prefix, like):
            leaves_p = jax.tree_util.tree_flatten_with_path(like)
            out = []
            for pth, leaf in leaves_p[0]:
                key = prefix + "/".join(
                    str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in pth)
                arr = data[key]
                assert arr.shape == tuple(leaf.shape), (key, arr.shape,
                                                        leaf.shape)
                out.append(arr)
            return jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(like), out)

        return rebuild("p/", params_like), rebuild("o/", opt_like), step
