"""Deterministic synthetic LM data pipeline.

``batch_at(step)`` is a pure function of (seed, step, shard), so any worker
can reconstruct any batch — exact-resume after checkpoint restore and
elastic re-sharding (a restarted job with a different host count replays
the same global batch) come for free. Token streams are Zipf-distributed
with short-range Markov structure so the loss actually decreases and MoE
routers see realistic skew, which matters for exercising the EP dispatch
path that §3.2 identifies as the straggler amplifier."""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2           # unigram skew
    repeat_p: float = 0.3         # P(copy a recent token) -> learnable bigrams


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # fixed Zipf unigram table (top 4096 ranks folded into the vocab)
        ranks = np.arange(1, min(cfg.vocab_size, 4096) + 1)
        p = ranks ** (-cfg.zipf_a)
        self._uni_p = p / p.sum()
        self._uni_ids = (np.arange(len(ranks)) * 2654435761 %
                         cfg.vocab_size).astype(np.int64)

    def batch_at(self, step: int, shard: int = 0,
                 num_shards: int = 1) -> Dict[str, np.ndarray]:
        """The (deterministic) global batch for ``step``, sliced for
        ``shard`` of ``num_shards`` along the batch dim."""
        cfg = self.cfg
        assert cfg.global_batch % num_shards == 0
        b = cfg.global_batch // num_shards
        rng = np.random.RandomState(
            (cfg.seed * 1_000_003 + step) % (2 ** 31 - 1))
        # draw the full global batch then slice — all shards agree
        draws = rng.choice(len(self._uni_p), size=(cfg.global_batch,
                                                   cfg.seq_len + 1),
                           p=self._uni_p)
        toks = self._uni_ids[draws]
        rep = rng.rand(cfg.global_batch, cfg.seq_len + 1) < cfg.repeat_p
        for off in (1, 2):
            m = rep & (rng.rand(*rep.shape) < 0.5)
            m[:, :off] = False
            toks = np.where(m, np.roll(toks, off, axis=1), toks)
        toks = toks[shard * b:(shard + 1) * b]
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}
