"""AdamW with global-norm clipping and warmup+cosine schedule, implemented
natively in JAX. Optimizer moments are plain pytrees mirroring the param
tree, so they inherit the parameters' logical sharding axes (ZeRO-3-style:
wherever a parameter is FSDP-sharded, its moments are too)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    min_lr_frac: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def lr_at(cfg: AdamWConfig, step):
    """Linear warmup then cosine decay to ``min_lr_frac * peak``."""
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.peak_lr * step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.peak_lr * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) *
                         0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> Dict[str, Any]:
    zeros = lambda t: jax.tree.map(jnp.zeros_like, t)
    return {"mu": zeros(params), "nu": zeros(params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_adamw(params, grads, state, cfg: AdamWConfig
                ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """One AdamW update. Returns (params, state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        step = (mu / b1c) / (jnp.sqrt(nu / b2c) + cfg.eps)
        if p.ndim >= 2:                       # decay matrices, not norms/bias
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p - lr * step.astype(p.dtype)).astype(p.dtype), mu, nu

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    # unzip the 3-tuples
    new_p = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"mu": new_mu, "nu": new_nu, "count": count}, \
        {"grad_norm": gnorm, "lr": lr}
