from repro.train.checkpoint import CheckpointManager, TieredCheckpointManager
from repro.train.data import DataConfig, SyntheticLM
from repro.train.optimizer import (AdamWConfig, apply_adamw, global_norm,
                                   init_opt_state, lr_at)
from repro.train.trainer import (TrainConfig, Trainer, make_serve_step,
                                 make_train_step)

__all__ = [
    "AdamWConfig", "CheckpointManager", "DataConfig", "SyntheticLM",
    "TieredCheckpointManager",
    "TrainConfig", "Trainer", "apply_adamw", "global_norm", "init_opt_state",
    "lr_at", "make_serve_step", "make_train_step",
]
