"""guardlint: AST-based linter for this repo's hard-won invariants.

Run as ``python -m repro.analysis.guardlint src/`` (stdlib-only; no
numeric stack needed). See ``rules.py`` for the GL001–GL008 rule set and
``pragmas.py`` for the ``# guardlint:`` scoping/suppression grammar.
"""
from repro.analysis.guardlint.engine import (META_RULE, RULES, LintResult,
                                             Project, Violation, lint_paths,
                                             rule, run)
from repro.analysis.guardlint import rules as _rules  # noqa: F401  register

__all__ = ["META_RULE", "RULES", "LintResult", "Project", "Violation",
           "lint_paths", "rule", "run"]
