"""Guardlint engine: file loading, rule registry, suppression, reports.

The engine is deliberately small: a ``LintFile`` per parsed source file
(AST + pragmas + repo-relative path), a ``Project`` holding the lint
targets plus the cross-file context some rules need (README text, the
``benchmarks/`` tree and its gate manifest, the ``tests/`` sources, the
``src/repro/kernels/`` layout), and a flat registry of rule functions
``fn(project) -> Iterable[Violation]``. Suppression happens centrally
after collection so every rule stays pure, and the pragma layer —
including the mandatory-reason policy — is enforced in exactly one
place.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
from typing import Callable, Dict, Iterable, List, Optional, Tuple

from repro.analysis.guardlint.pragmas import FilePragmas, parse_pragmas

META_RULE = "GL000"         # pragma/parse problems; never suppressible


@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str
    path: str               # repo-relative, posix separators
    line: int
    message: str

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


@dataclasses.dataclass(frozen=True)
class RuleInfo:
    rule_id: str
    title: str
    doc: str
    fn: Callable[["Project"], Iterable[Violation]]


RULES: Dict[str, RuleInfo] = {}


def rule(rule_id: str, title: str):
    """Register a rule function under ``rule_id``."""
    def deco(fn):
        assert rule_id not in RULES, f"duplicate rule {rule_id}"
        RULES[rule_id] = RuleInfo(rule_id, title, (fn.__doc__ or "").strip(),
                                  fn)
        return fn
    return deco


class LintFile:
    """One parsed lint target."""

    def __init__(self, path: str, rel: str, source: str,
                 tree: Optional[ast.AST], pragmas: FilePragmas,
                 parse_error: Optional[str] = None):
        self.path = path
        self.rel = rel
        self.source = source
        self.tree = tree
        self.pragmas = pragmas
        self.parse_error = parse_error

    @property
    def hot(self) -> bool:
        return self.pragmas.hot

    def in_package(self, *names: str) -> bool:
        """True when the file lives under any ``.../<name>/`` directory."""
        parts = self.rel.split("/")
        return any(n in parts[:-1] for n in names)


def _rel(path: str, root: str) -> str:
    try:
        r = os.path.relpath(os.path.abspath(path), root)
    except ValueError:              # different drive (windows)
        r = os.path.abspath(path)
    return r.replace(os.sep, "/")


def load_file(path: str, root: str) -> LintFile:
    with open(path, encoding="utf-8") as fh:
        source = fh.read()
    pragmas = parse_pragmas(source, set(RULES))
    try:
        tree = ast.parse(source, filename=path)
        err = None
    except SyntaxError as e:
        tree, err = None, f"syntax error: {e.msg} (line {e.lineno})"
    return LintFile(path, _rel(path, root), source, tree, pragmas, err)


def find_root(start: str) -> str:
    """Walk up from ``start`` to the nearest directory holding a
    ``pyproject.toml`` or ``.git`` (the repo root); fall back to
    ``start`` itself."""
    cur = os.path.abspath(start)
    if os.path.isfile(cur):
        cur = os.path.dirname(cur)
    probe = cur
    while True:
        if os.path.exists(os.path.join(probe, "pyproject.toml")) or \
                os.path.exists(os.path.join(probe, ".git")):
            return probe
        parent = os.path.dirname(probe)
        if parent == probe:
            return cur
        probe = parent


def _iter_py(path: str) -> List[str]:
    if os.path.isfile(path):
        return [path]
    out = []
    for dirpath, dirnames, filenames in os.walk(path):
        dirnames[:] = sorted(d for d in dirnames if d != "__pycache__")
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                out.append(os.path.join(dirpath, fn))
    return out


class Project:
    """Lint targets + the cross-file context project rules need."""

    def __init__(self, paths: List[str], root: Optional[str] = None):
        # rules must be registered before sources are loaded, so pragma
        # parsing can validate rule ids (lazy: rules.py imports us back)
        from repro.analysis.guardlint import rules as _rules  # noqa: F401
        self.root = os.path.abspath(root) if root else find_root(paths[0])
        self.files: List[LintFile] = []
        seen = set()
        for p in paths:
            for fp in _iter_py(p):
                ap = os.path.abspath(fp)
                if ap not in seen:
                    seen.add(ap)
                    self.files.append(load_file(ap, self.root))
        self._readme: Optional[str] = None
        self._tests: Optional[Dict[str, str]] = None
        self._bench: Optional[Dict[str, LintFile]] = None
        self._manifest: Optional[Dict[str, Dict[str, float]]] = None
        self._manifest_error: Optional[str] = None

    # ----------------------------------------------- cross-file context

    @property
    def readme(self) -> Optional[str]:
        if self._readme is None:
            p = os.path.join(self.root, "README.md")
            self._readme = open(p, encoding="utf-8").read() \
                if os.path.isfile(p) else ""
        return self._readme

    @property
    def tests(self) -> Dict[str, str]:
        """tests/*.py sources keyed by repo-relative path."""
        if self._tests is None:
            self._tests = {}
            tdir = os.path.join(self.root, "tests")
            if os.path.isdir(tdir):
                for fp in _iter_py(tdir):
                    self._tests[_rel(fp, self.root)] = \
                        open(fp, encoding="utf-8").read()
        return self._tests

    @property
    def bench_files(self) -> Dict[str, LintFile]:
        """benchmarks/bench_*.py parsed, keyed by basename."""
        if self._bench is None:
            self._bench = {}
            bdir = os.path.join(self.root, "benchmarks")
            if os.path.isdir(bdir):
                for fn in sorted(os.listdir(bdir)):
                    if fn.startswith("bench_") and fn.endswith(".py"):
                        self._bench[fn] = load_file(
                            os.path.join(bdir, fn), self.root)
        return self._bench

    @property
    def gate_manifest(self) -> Optional[Dict[str, Dict[str, float]]]:
        """Parsed ``benchmarks/gates.json`` (None when absent)."""
        if self._manifest is None and self._manifest_error is None:
            p = os.path.join(self.root, "benchmarks", "gates.json")
            if not os.path.isfile(p):
                self._manifest_error = "missing"
                return None
            try:
                self._manifest = json.load(open(p, encoding="utf-8"))
            except ValueError as e:
                self._manifest_error = f"unreadable gates.json: {e}"
        return self._manifest

    @property
    def manifest_error(self) -> Optional[str]:
        self.gate_manifest            # noqa: B018 — populate lazily
        return self._manifest_error

    def kernels_dir(self) -> Optional[str]:
        p = os.path.join(self.root, "src", "repro", "kernels")
        return p if os.path.isdir(p) else None


@dataclasses.dataclass
class LintResult:
    violations: List[Violation]
    suppressed: List[Tuple[Violation, str]]     # (violation, reason)
    files_scanned: int

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_dict(self) -> Dict[str, object]:
        counts: Dict[str, int] = {}
        for v in self.violations:
            counts[v.rule] = counts.get(v.rule, 0) + 1
        return {
            "ok": self.ok,
            "files_scanned": self.files_scanned,
            "counts": counts,
            "violations": [v.to_dict() for v in self.violations],
            "suppressed": [{**v.to_dict(), "reason": r}
                           for v, r in self.suppressed],
        }


def run(project: Project,
        only: Optional[List[str]] = None) -> LintResult:
    """Run every registered rule (or the ``only`` subset) and apply
    pragma suppression. GL000 (meta) violations are never suppressed."""
    # rules must be importable exactly once, wherever run() is called from
    from repro.analysis.guardlint import rules as _rules  # noqa: F401
    raw: List[Violation] = []
    for f in project.files:
        if f.parse_error:
            raw.append(Violation(META_RULE, f.rel, 1, f.parse_error))
        for err in f.pragmas.errors:
            raw.append(Violation(META_RULE, f.rel, err.line, err.message))
    for info in RULES.values():
        if info.rule_id == META_RULE:
            continue
        if only and info.rule_id not in only:
            continue
        raw.extend(info.fn(project))

    by_rel = {f.rel: f for f in project.files}
    kept: List[Violation] = []
    suppressed: List[Tuple[Violation, str]] = []
    for v in sorted(raw, key=lambda v: (v.path, v.line, v.rule)):
        f = by_rel.get(v.path)
        reason = None
        if f is not None and v.rule != META_RULE:
            reason = f.pragmas.suppresses(v.rule, v.line)
        if reason is None:
            kept.append(v)
        else:
            suppressed.append((v, reason))
    return LintResult(kept, suppressed, len(project.files))


def lint_paths(paths: List[str], root: Optional[str] = None,
               only: Optional[List[str]] = None) -> LintResult:
    return run(Project(paths, root=root), only=only)
