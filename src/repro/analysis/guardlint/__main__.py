"""CLI: ``python -m repro.analysis.guardlint [paths...]``.

Exit code 0 when the tree is clean, 1 on any violation (including GL000
meta-violations for reason-less or malformed suppressions), 2 on usage
errors — so CI can gate on it directly.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis.guardlint import RULES, lint_paths


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.guardlint",
        description="AST-based invariant linter for this repo "
                    "(GL001-GL008; see README 'Enforced invariants').")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint (default: src)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the full JSON report to PATH "
                         "('-' for stdout)")
    ap.add_argument("--only", metavar="RULES", default=None,
                    help="comma-separated rule ids to run (e.g. "
                         "GL002,GL006)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid in sorted(RULES):
            info = RULES[rid]
            print(f"{rid}  {info.title}")
            first = info.doc.split("\n\n")[0].replace("\n", " ")
            if first:
                print(f"       {first}")
        return 0

    only = None
    if args.only:
        only = [r.strip() for r in args.only.split(",") if r.strip()]
        unknown = [r for r in only if r not in RULES]
        if unknown:
            print(f"unknown rule id(s): {', '.join(unknown)}",
                  file=sys.stderr)
            return 2

    result = lint_paths(args.paths or ["src"], only=only)

    # With --json -, stdout IS the report: keep it valid JSON and route
    # the human-readable lines to stderr so `guardlint --json - | jq`
    # works.
    human = sys.stderr if args.json == "-" else sys.stdout
    if args.json:
        payload = json.dumps(result.to_dict(), indent=2, sort_keys=True)
        if args.json == "-":
            print(payload)
        else:
            with open(args.json, "w", encoding="utf-8") as fh:
                fh.write(payload + "\n")

    for v in result.violations:
        print(v.render(), file=human)
    n_sup = len(result.suppressed)
    if result.ok:
        print(f"guardlint: clean — {result.files_scanned} files, "
              f"{len(RULES)} rules, {n_sup} documented suppression(s)",
              file=human)
        return 0
    counts = {}
    for v in result.violations:
        counts[v.rule] = counts.get(v.rule, 0) + 1
    summary = ", ".join(f"{k}:{v}" for k, v in sorted(counts.items()))
    print(f"guardlint: {len(result.violations)} violation(s) "
          f"[{summary}] in {result.files_scanned} files", file=sys.stderr)
    return 1


if __name__ == "__main__":
    sys.exit(main())
