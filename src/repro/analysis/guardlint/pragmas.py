"""Guardlint pragma comments: scoping and suppression with mandatory reasons.

Three pragma forms, all inside ordinary ``#`` comments:

  ``# guardlint: hot``
      Tags the MODULE as a detection/sim hot path. Hot modules opt in to
      the dtype-discipline (GL002) and allocation-discipline (GL003)
      rules; cold modules are exempt because a float64 scratch array or
      a per-node Python loop only costs something where the fleet-sized
      arrays live.

  ``# guardlint: disable=GL002[,GL003] reason=<why this is safe>``
      Suppresses the listed rules. Trailing on a code line it applies to
      that line's violations; on a comment-only line it applies to the
      next code line (for statements whose pragma would not fit). The
      ``reason=`` clause is MANDATORY — a suppression without a written
      justification is itself a violation (GL000), so every exemption in
      the tree documents why the invariant does not apply.

  ``# guardlint: disable-file=GL003 reason=<why>``
      Same, scoped to the whole file.

Comments are found with ``tokenize`` (never by string search), so pragma
look-alikes inside string literals are ignored.
"""
from __future__ import annotations

import dataclasses
import io
import re
import tokenize
from typing import Dict, List, Optional, Set, Tuple

PRAGMA_RE = re.compile(r"#\s*guardlint:\s*(?P<body>.*)$")
DISABLE_RE = re.compile(
    r"^disable(?P<scope>-file)?\s*=\s*(?P<rules>[A-Za-z0-9,\s]+?)"
    r"(?:\s+reason\s*=\s*(?P<reason>.*))?$")
RULE_ID_RE = re.compile(r"^GL\d{3}$")


@dataclasses.dataclass(frozen=True)
class PragmaError:
    """A malformed pragma — surfaced as a GL000 violation (never
    suppressible: the suppression policy cannot opt out of itself)."""
    line: int
    message: str


@dataclasses.dataclass
class FilePragmas:
    """Parsed pragma state for one source file."""
    hot: bool = False
    # rule id -> file-wide suppression reason
    file_disables: Dict[str, str] = dataclasses.field(default_factory=dict)
    # line -> {rule id -> reason}
    line_disables: Dict[int, Dict[str, str]] = \
        dataclasses.field(default_factory=dict)
    errors: List[PragmaError] = dataclasses.field(default_factory=list)

    def suppresses(self, rule: str, line: int) -> Optional[str]:
        """Reason string if ``rule`` is suppressed at ``line``, else None."""
        if rule in self.file_disables:
            return self.file_disables[rule]
        by_line = self.line_disables.get(line)
        if by_line and rule in by_line:
            return by_line[rule]
        return None


def _comment_tokens(source: str) -> Tuple[List[tokenize.TokenInfo], Set[int]]:
    """All COMMENT tokens plus the set of lines that carry real code."""
    comments: List[tokenize.TokenInfo] = []
    code_lines: Set[int] = set()
    skip = {tokenize.COMMENT, tokenize.NL, tokenize.NEWLINE,
            tokenize.INDENT, tokenize.DEDENT, tokenize.ENCODING,
            tokenize.ENDMARKER}
    try:
        for tok in tokenize.generate_tokens(io.StringIO(source).readline):
            if tok.type == tokenize.COMMENT:
                comments.append(tok)
            elif tok.type not in skip:
                for ln in range(tok.start[0], tok.end[0] + 1):
                    code_lines.add(ln)
    # guardlint: disable=GL006 reason=partial comment list on a broken
    # file is the intended result; ast.parse reports the syntax error as
    # GL000 with line info, so nothing is hidden from the user
    except (tokenize.TokenError, IndentationError):
        pass
    return comments, code_lines


def parse_pragmas(source: str, known_rules: Set[str]) -> FilePragmas:
    out = FilePragmas()
    comments, code_lines = _comment_tokens(source)
    n_lines = source.count("\n") + 1
    for tok in comments:
        m = PRAGMA_RE.search(tok.string)
        if m is None:
            continue
        line = tok.start[0]
        body = m.group("body").strip()
        if body == "hot" or body.startswith("hot "):
            # trailing prose after "hot" is annotation, e.g.
            # "# guardlint: hot  (detector window lives here)"
            out.hot = True
            continue
        dm = DISABLE_RE.match(body)
        if dm is None:
            out.errors.append(PragmaError(
                line, f"malformed guardlint pragma: {body!r} "
                      f"(expected 'hot' or 'disable[-file]=GLxxx "
                      f"reason=...')"))
            continue
        rules = [r.strip() for r in dm.group("rules").split(",") if r.strip()]
        reason = (dm.group("reason") or "").strip()
        bad = [r for r in rules if not RULE_ID_RE.match(r)
               or (known_rules and r not in known_rules)]
        if bad:
            out.errors.append(PragmaError(
                line, f"unknown rule id(s) in pragma: {', '.join(bad)}"))
            continue
        if not reason:
            out.errors.append(PragmaError(
                line, f"suppression of {','.join(rules)} carries no "
                      f"reason= — every exemption must say why it is safe"))
            continue
        if dm.group("scope"):                       # disable-file
            for r in rules:
                out.file_disables[r] = reason
        else:
            target = line
            if line not in code_lines:
                # comment-only pragma line: applies to the next code line
                target = next((ln for ln in range(line + 1, n_lines + 1)
                               if ln in code_lines), line)
            slot = out.line_disables.setdefault(target, {})
            for r in rules:
                slot[r] = reason
    return out
