"""The guardlint rule set: this repo's hard-won invariants, as AST checks.

Each rule encodes a discipline that was once enforced only by review
(and, in several cases, violated and hand-fixed in a prior PR — see the
README "Enforced invariants" table for the incident behind each):

  GL001  determinism in replay paths (rng-rewind, bit-identical goldens)
  GL002  float32 dtype discipline in hot modules (the PR 8 leak class)
  GL003  no per-node Python loops over fleet-sized iterables in hot code
  GL004  event-taxonomy completeness (kind + registry + README + JSONL)
  GL005  census assertion in every pool-mutating control-plane method
  GL006  no swallowed exceptions (the PR 6 stale-restore class)
  GL007  benchmark CI gates tracked in a checked manifest
  GL008  every kernel backend ships a ref.py and a golden test using it
"""
from __future__ import annotations

import ast
import os
import re
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.guardlint.engine import (LintFile, Project, Violation,
                                             rule)

# --------------------------------------------------------------- helpers


def build_aliases(tree: ast.AST) -> Dict[str, str]:
    """local name -> canonical dotted module/object it refers to."""
    aliases: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    aliases[a.asname] = a.name
                else:
                    aliases[a.name.split(".")[0]] = a.name.split(".")[0]
        elif isinstance(node, ast.ImportFrom) and node.level == 0 \
                and node.module:
            for a in node.names:
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def canonical(node: ast.AST, aliases: Dict[str, str]) -> Optional[str]:
    """Dotted name of an attribute chain with its root resolved through
    the file's imports: ``np.random.rand`` -> ``numpy.random.rand``."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(aliases.get(node.id, node.id))
    return ".".join(reversed(parts))


def _has_kw(call: ast.Call, name: str) -> bool:
    return any(kw.arg == name for kw in call.keywords)


def _terminal(node: ast.AST) -> Optional[str]:
    """Last component of a Name/Attribute chain (``self.nodes`` -> nodes)."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


# ------------------------------------------------------- GL001 determinism

# generator constructors that are fine WHEN GIVEN an explicit seed/bitgen
_SEEDABLE = {"RandomState", "default_rng", "SFC64", "PCG64", "MT19937",
             "Philox", "Generator", "Random"}
_REPLAY_PACKAGES = ("simcluster", "core", "diagnose", "ccltrace")


@rule("GL001", "determinism in replay paths")
def gl001(project: Project) -> Iterable[Violation]:
    """The sim composes windows with rng-rewind replay and the detector
    goldens pin bit-identical scalar-vs-batched verdicts (PRs 3, 5, 8).
    Both break the moment any replay-path module reads wall-clock time
    or draws from a global RNG stream: ``time.time()``, module-level
    ``np.random.*`` / ``random.*`` calls, and UNSEEDED generator
    constructions are banned in ``simcluster``/``core``/``diagnose``/
    ``ccltrace``. Explicitly seeded generators (``np.random.RandomState
    (seed)``, ``default_rng(seed)``, keyed ``SFC64`` streams) pass, as
    does ``time.perf_counter`` self-timing (it measures cost, it never
    enters sim state)."""
    for f in project.files:
        if f.tree is None or not f.in_package(*_REPLAY_PACKAGES):
            continue
        aliases = build_aliases(f.tree)
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.Call):
                continue
            dn = canonical(node.func, aliases)
            if dn is None:
                continue
            if dn == "time.time":
                yield Violation(
                    "GL001", f.rel, node.lineno,
                    "wall-clock time.time() in a replay path — sim/"
                    "detector state must be a function of seeds and "
                    "inputs only (use the sim clock, or perf_counter "
                    "for pure self-timing)")
            elif dn.startswith("numpy.random.") or dn == "random.seed":
                last = dn.rsplit(".", 1)[1]
                if last in _SEEDABLE:
                    if not node.args and not node.keywords:
                        yield Violation(
                            "GL001", f.rel, node.lineno,
                            f"unseeded {dn}() — replay paths must seed "
                            f"every generator explicitly")
                else:
                    yield Violation(
                        "GL001", f.rel, node.lineno,
                        f"module-level RNG stream {dn}() — shared global "
                        f"state breaks rng-rewind replay; draw from an "
                        f"explicitly seeded generator instance")
            elif dn.startswith("random.") and aliases.get("random") == \
                    "random":
                last = dn.rsplit(".", 1)[1]
                if last in _SEEDABLE:
                    if not node.args and not node.keywords:
                        yield Violation(
                            "GL001", f.rel, node.lineno,
                            f"unseeded {dn}() in a replay path")
                else:
                    yield Violation(
                        "GL001", f.rel, node.lineno,
                        f"stdlib global RNG {dn}() in a replay path — "
                        f"use a seeded random.Random or numpy generator")


# --------------------------------------------------- GL002 dtype discipline

_FLOAT_CTORS = {"zeros", "ones", "empty", "full"}
_NP_MODULES = ("numpy", "jax.numpy")


def _is_float64_expr(node: ast.AST, aliases: Dict[str, str]) -> bool:
    dn = canonical(node, aliases)
    if dn in {f"{m}.float64" for m in _NP_MODULES}:
        return True
    if dn == "float":                  # builtin float == f64 for numpy
        return True
    return isinstance(node, ast.Constant) and node.value == "float64"


@rule("GL002", "float32 dtype discipline in hot modules")
def gl002(project: Project) -> Iterable[Violation]:
    """PR 8 hand-fixed float64 upcast leaks that silently doubled the
    resident detector window (a dtype-defaulting ``np.zeros`` here, a
    stray ``astype`` there). In modules tagged ``# guardlint: hot`` the
    fleet-sized arrays are float32 end-to-end by contract (the
    fleet_score kernel is bit-reproducible only in f32), so this rule
    bans float64 mentions (``np.float64``, ``astype(float)``,
    ``dtype="float64"``) and dtype-DEFAULTING float constructors
    (``np.zeros(shape)`` defaults to f64). Deliberate f64 accumulators
    carry a ``disable=GL002`` pragma with the reason written down."""
    for f in project.files:
        if f.tree is None or not f.hot:
            continue
        aliases = build_aliases(f.tree)
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.Call):
                continue
            dn = canonical(node.func, aliases)
            # explicit float64 (or builtin float) anywhere in a call's
            # arguments: astype(np.float64), dtype=float, "float64"...
            for sub in list(node.args) + [kw.value for kw in node.keywords]:
                if _is_float64_expr(sub, aliases):
                    yield Violation(
                        "GL002", f.rel, node.lineno,
                        "float64 dtype in a hot module — the detection/"
                        "sim hot path is float32 end-to-end (PR 8 leak "
                        "class); pass np.float32 or justify with a "
                        "disable=GL002 pragma")
                    break
            if dn is None:
                continue
            mod, _, last = dn.rpartition(".")
            if mod in _NP_MODULES and last in _FLOAT_CTORS:
                dtype_pos = 2 if last == "full" else 1
                if len(node.args) <= dtype_pos and \
                        not _has_kw(node, "dtype"):
                    yield Violation(
                        "GL002", f.rel, node.lineno,
                        f"dtype-defaulting {dn}() allocates float64 — "
                        f"hot-module arrays must state their dtype "
                        f"(np.float32 for fleet data)")


# ------------------------------------------------ GL003 hot-path allocation

_FLEET_ITER_NAMES = {"nodes", "node_ids", "all_nodes", "fleet"}
_FLEET_SIZE_NAMES = {"n", "n_nodes", "num_nodes", "fleet_size"}


def _fleet_sized(it: ast.AST) -> Optional[str]:
    """Describe ``it`` if it looks like a fleet-sized iterable."""
    t = _terminal(it)
    if t in _FLEET_ITER_NAMES:
        return t
    if isinstance(it, ast.Call) and _terminal(it.func) == "range":
        for sub in ast.walk(it):
            st = _terminal(sub)
            if st in _FLEET_SIZE_NAMES:
                return f"range(..{st}..)"
            if isinstance(sub, ast.Call) and _terminal(sub.func) == "len" \
                    and sub.args and _terminal(sub.args[0]) in \
                    (_FLEET_ITER_NAMES | {"node_ids"}):
                return f"range(len({_terminal(sub.args[0])}))"
    return None


@rule("GL003", "no per-node Python loops in hot modules")
def gl003(project: Project) -> Iterable[Violation]:
    """The 8.9x (PR 3) and 100k-node (PR 8) scale-ups came from deleting
    per-node Python loops: one window must cost a fixed number of numpy
    reductions, not O(N) interpreter iterations. In ``# guardlint: hot``
    modules, ``for``/comprehension iteration over fleet-sized iterables
    (``nodes``, ``node_ids``, ``range(self.n)``, ``range(len(nodes))``)
    is banned. O(flagged)/O(changed) loops are fine (and don't match);
    a deliberate O(N) materialization (debug helpers, compat iterators)
    carries a pragma saying so."""
    for f in project.files:
        if f.tree is None or not f.hot:
            continue
        for node in ast.walk(f.tree):
            iters: List[Tuple[ast.AST, int]] = []
            if isinstance(node, (ast.For, ast.AsyncFor)):
                iters.append((node.iter, node.lineno))
            elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                   ast.GeneratorExp)):
                iters.extend((g.iter, node.lineno)
                             for g in node.generators)
            for it, lineno in iters:
                desc = _fleet_sized(it)
                if desc:
                    yield Violation(
                        "GL003", f.rel, lineno,
                        f"per-node Python loop over fleet-sized "
                        f"iterable '{desc}' in a hot module — vectorize "
                        f"(numpy reduction / gather) or justify with a "
                        f"disable=GL003 pragma")


# --------------------------------------------- GL004 event-taxonomy complete

_JSON_ATOMS = {"int", "float", "str", "bool", "bytes", "None", "object"}
_JSON_CONTAINERS = {"Tuple", "tuple", "List", "list", "Dict", "dict",
                    "Optional", "Union", "FrozenSet", "frozenset",
                    "Sequence", "Mapping"}


def _jsonable_annotation(ann: ast.AST) -> bool:
    if isinstance(ann, ast.Constant):
        return ann.value is None or ann.value is Ellipsis or \
            isinstance(ann.value, str)
    t = _terminal(ann)
    if t in _JSON_ATOMS or t in _JSON_CONTAINERS:
        if isinstance(ann, (ast.Name, ast.Attribute)):
            return True
    if isinstance(ann, ast.Subscript) and _terminal(ann.value) in \
            _JSON_CONTAINERS:
        inner = ann.slice
        elts = inner.elts if isinstance(inner, ast.Tuple) else [inner]
        return all(_jsonable_annotation(e) for e in elts)
    if isinstance(ann, ast.BinOp) and isinstance(ann.op, ast.BitOr):
        return _jsonable_annotation(ann.left) and \
            _jsonable_annotation(ann.right)
    return False


def _event_classes(project: Project) \
        -> List[Tuple[ast.ClassDef, LintFile]]:
    """Every class transitively subclassing ``GuardEvent`` (by name)."""
    defs: List[Tuple[ast.ClassDef, LintFile]] = []
    for f in project.files:
        if f.tree is None:
            continue
        for node in ast.walk(f.tree):
            if isinstance(node, ast.ClassDef):
                defs.append((node, f))
    event_names = {"GuardEvent"}
    changed = True
    while changed:
        changed = False
        for cd, _ in defs:
            if cd.name in event_names:
                continue
            if any(_terminal(b) in event_names for b in cd.bases):
                event_names.add(cd.name)
                changed = True
    return [(cd, f) for cd, f in defs
            if cd.name in event_names and cd.name != "GuardEvent"]


def _registry_members(tree: ast.AST) -> Set[str]:
    """Class names listed in any module-level ``*EVENT_TYPES`` tuple."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, ast.AnnAssign):
            targets = [node.target]
        else:
            continue
        if not any(isinstance(t, ast.Name) and t.id.endswith("EVENT_TYPES")
                   for t in targets):
            continue
        if isinstance(node.value, (ast.Tuple, ast.List)):
            for e in node.value.elts:
                t = _terminal(e)
                if t:
                    out.add(t)
    return out


@rule("GL004", "event-taxonomy completeness")
def gl004(project: Project) -> Iterable[Violation]:
    """Every consumer of the control plane — sinks, the fleet log, the
    benchmarks, the README's operator docs — reads the typed GuardEvent
    taxonomy. A subclass that forgets its ``kind``, skips the
    ``EVENT_TYPES`` registry, misses its README taxonomy row, or smuggles
    a non-JSONL-serializable payload field breaks one of them silently.
    All four are cross-checked statically for every ``GuardEvent``
    subclass in the tree."""
    events = _event_classes(project)
    kinds: Dict[str, Tuple[str, str, int]] = {}
    registries: Dict[str, Set[str]] = {}
    for cd, f in events:
        if f.rel not in registries:
            registries[f.rel] = _registry_members(f.tree)
        kind_value: Optional[str] = None
        for stmt in cd.body:
            if isinstance(stmt, ast.AnnAssign) and \
                    isinstance(stmt.target, ast.Name) and \
                    stmt.target.id == "kind":
                if isinstance(stmt.value, ast.Constant) and \
                        isinstance(stmt.value.value, str):
                    kind_value = stmt.value.value
        if kind_value is None:
            yield Violation(
                "GL004", f.rel, cd.lineno,
                f"event class {cd.name} does not declare its own "
                f"``kind: ClassVar[str]`` wire name")
        else:
            prev = kinds.get(kind_value)
            if prev is not None:
                yield Violation(
                    "GL004", f.rel, cd.lineno,
                    f"event kind {kind_value!r} of {cd.name} collides "
                    f"with {prev[0]} ({prev[1]}:{prev[2]})")
            else:
                kinds[kind_value] = (cd.name, f.rel, cd.lineno)
            if f"`{kind_value}`" not in (project.readme or ""):
                yield Violation(
                    "GL004", f.rel, cd.lineno,
                    f"event kind `{kind_value}` ({cd.name}) has no row "
                    f"in the README event-taxonomy table")
        if cd.name not in registries[f.rel]:
            yield Violation(
                "GL004", f.rel, cd.lineno,
                f"event class {cd.name} is not listed in its module's "
                f"*EVENT_TYPES registry tuple")
        for stmt in cd.body:
            if isinstance(stmt, ast.AnnAssign) and \
                    isinstance(stmt.target, ast.Name) and \
                    stmt.target.id != "kind":
                ann = stmt.annotation
                if _terminal(ann) == "ClassVar" or (
                        isinstance(ann, ast.Subscript) and
                        _terminal(ann.value) == "ClassVar"):
                    continue
                if not _jsonable_annotation(ann):
                    yield Violation(
                        "GL004", f.rel, stmt.lineno,
                        f"{cd.name}.{stmt.target.id} is not statically "
                        f"JSONL-serializable — event payloads must be "
                        f"int/float/str/bool or tuples/dicts of those "
                        f"(the JsonlSink writes them verbatim)")


# ----------------------------------------------- GL005 census discipline

_CENSUS_CLASSES = {"GlobalSparePool", "FleetController"}
_POOL_ATTRS = {"_free", "_free_by_home", "_leased", "_queue", "granted_to",
               "jobs", "ghosts", "pool"}
_MUTATORS = {"add", "remove", "pop", "popleft", "append", "appendleft",
             "extend", "insert", "clear", "update", "setdefault",
             "grant", "note_provisioned", "request", "serve",
             "register_job"}


def _self_attr(node: ast.AST) -> Optional[str]:
    """``self.<attr>`` (possibly under subscripts) -> attr name."""
    while isinstance(node, ast.Subscript):
        node = node.value
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and node.value.id == "self":
        return node.attr
    return None


def _mutates_pool(fn: ast.FunctionDef) -> Optional[int]:
    """Line of the first pool-state mutation in ``fn`` (None if pure)."""
    for node in ast.walk(fn):
        targets: List[ast.AST] = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            targets = [node.target]
        elif isinstance(node, ast.Delete):
            targets = node.targets
        for t in targets:
            if _self_attr(t) in _POOL_ATTRS:
                return node.lineno
        if isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr in _MUTATORS and \
                _self_attr(node.func.value) in _POOL_ATTRS:
            return node.lineno
    return None


def _has_census_assert(fn: ast.FunctionDef) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr == "_assert_census":
            return True
        if isinstance(node, ast.Assert) and \
                "census" in ast.dump(node.test).lower():
            return True
    return False


@rule("GL005", "census assertion in pool-mutating methods")
def gl005(project: Project) -> Iterable[Violation]:
    """The fleet bench gates a bit-consistent census: every node is in
    exactly one place (a job, the free pool, or the ghost ledger). That
    conservation law survives refactors only because the mutating
    control-plane entry points assert it on the spot — so every
    ``GlobalSparePool``/``FleetController`` method that touches pool
    state (free list, lease table, queue, ghosts, grant counters) must
    call ``self._assert_census()`` before returning. ``__init__`` is
    exempt (there is nothing to conserve mid-construction)."""
    for f in project.files:
        if f.tree is None:
            continue
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.ClassDef) or \
                    node.name not in _CENSUS_CLASSES:
                continue
            for stmt in node.body:
                if not isinstance(stmt, ast.FunctionDef):
                    continue
                if stmt.name in {"__init__", "_assert_census"}:
                    continue
                mut_line = _mutates_pool(stmt)
                if mut_line is not None and not _has_census_assert(stmt):
                    yield Violation(
                        "GL005", f.rel, stmt.lineno,
                        f"{node.name}.{stmt.name} mutates pool state "
                        f"(line {mut_line}) without invoking the census "
                        f"assertion — call self._assert_census() before "
                        f"returning")


# -------------------------------------------- GL006 swallowed exceptions

def _swallow_only(body: List[ast.stmt]) -> bool:
    for stmt in body:
        if isinstance(stmt, (ast.Pass, ast.Continue)):
            continue
        if isinstance(stmt, ast.Expr) and \
                isinstance(stmt.value, ast.Constant):
            continue                           # docstring / ellipsis
        return False
    return True


@rule("GL006", "no swallowed exceptions")
def gl006(project: Project) -> Iterable[Violation]:
    """PR 6 found the daemon-thread checkpoint writer swallowing its
    failures, so restores silently loaded STALE state — the exact
    failure mode Guard exists to catch, reproduced in our own plumbing.
    Bare ``except:`` is banned outright, and ANY handler whose body only
    passes/continues is a swallowed exception: surface it (store it for
    the caller like ``CheckpointManager._write_safe``), log it with the
    failing payload, or restructure so the exception cannot happen."""
    for f in project.files:
        if f.tree is None:
            continue
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if node.type is None:
                yield Violation(
                    "GL006", f.rel, node.lineno,
                    "bare except: catches SystemExit/KeyboardInterrupt "
                    "and hides the failure — name the exception type "
                    "and handle or surface it")
            elif _swallow_only(node.body):
                yield Violation(
                    "GL006", f.rel, node.lineno,
                    "exception handler swallows the error (body is only "
                    "pass/continue) — surface it, log it with the "
                    "failing payload, or restructure (PR 6 stale-"
                    "restore class)")


# --------------------------------------------- GL007 bench-gate manifest

_GATE_NAME_RE = re.compile(r"^(?=[A-Z])(?=[A-Z0-9_]*GATE)[A-Z0-9_]+$")


def _gate_constants(tree: ast.AST) -> Dict[str, Tuple[float, int]]:
    """Module-level numeric GATE constants: name -> (value, line)."""
    out: Dict[str, Tuple[float, int]] = {}
    for node in tree.body if isinstance(tree, ast.Module) else []:
        if not isinstance(node, ast.Assign) or len(node.targets) != 1:
            continue
        t = node.targets[0]
        if not isinstance(t, ast.Name) or not _GATE_NAME_RE.match(t.id):
            continue
        v: ast.AST = node.value
        sign = 1.0
        if isinstance(v, ast.UnaryOp) and isinstance(v.op, ast.USub):
            sign, v = -1.0, v.operand
        if isinstance(v, ast.Constant) and \
                isinstance(v.value, (int, float)) and \
                not isinstance(v.value, bool):
            out[t.id] = (sign * float(v.value), node.lineno)
    return out


@rule("GL007", "bench gates tracked in the manifest")
def gl007(project: Project) -> Iterable[Violation]:
    """CI regression gates live as module constants in
    ``benchmarks/bench_*.py``. A refactor that renames, deletes or
    quietly relaxes one silently removes a CI guarantee — so every gate
    constant must appear, with its exact value, in the checked manifest
    ``benchmarks/gates.json``. Loosening a gate therefore always shows
    up as a reviewed manifest diff, and a deleted gate leaves a stale
    manifest row that fails the lint until someone owns the removal."""
    benches = project.bench_files
    if not benches:
        return
    manifest = project.gate_manifest
    if manifest is None:
        has_gates = any(_gate_constants(bf.tree) for bf in benches.values()
                        if bf.tree is not None)
        if has_gates:
            yield Violation(
                "GL007", "benchmarks/gates.json", 1,
                f"gate manifest missing/unreadable "
                f"({project.manifest_error}) but bench modules define "
                f"CI gate constants")
        return
    for fname, bf in benches.items():
        if bf.tree is None:
            continue
        gates = _gate_constants(bf.tree)
        listed: Dict[str, float] = dict(manifest.get(fname, {}))
        for name, (value, line) in gates.items():
            if name not in listed:
                yield Violation(
                    "GL007", bf.rel, line,
                    f"gate constant {name} = {value} is not in "
                    f"benchmarks/gates.json — register it so it cannot "
                    f"silently vanish")
            elif float(listed[name]) != value:
                yield Violation(
                    "GL007", bf.rel, line,
                    f"gate constant {name} = {value} drifted from the "
                    f"manifest value {listed[name]} — changing a CI "
                    f"gate requires updating benchmarks/gates.json in "
                    f"the same change")
        for name in listed:
            if name not in gates:
                yield Violation(
                    "GL007", "benchmarks/gates.json", 1,
                    f"manifest lists gate {name} for {fname} but the "
                    f"constant no longer exists — a CI gate vanished")
    for fname in manifest:
        if fname.startswith("__"):          # manifest self-documentation
            continue
        if fname not in benches:
            yield Violation(
                "GL007", "benchmarks/gates.json", 1,
                f"manifest lists {fname} but no such bench module "
                f"exists — a gated benchmark vanished")


# ----------------------------------------------- GL008 kernel ref parity

@rule("GL008", "kernel backends ship a ref.py and a golden test")
def gl008(project: Project) -> Iterable[Violation]:
    """Every ``src/repro/kernels/<name>/`` backend follows the
    ops/ref/impl idiom: ``ref.py`` is the plain-NumPy oracle the fused
    jax/pallas paths are golden-tested against (bit-identical verdicts
    are the fleet_score contract). A kernel without a ref, or whose ref
    no test imports, has no enforced parity — exactly how backend drift
    starts. The rule requires ``ref.py`` next to every ``ops.py`` and at
    least one ``tests/*.py`` that names the kernel package AND one of
    the ref module's public functions."""
    kdir = project.kernels_dir()
    if kdir is None:
        return
    tests = project.tests
    for name in sorted(os.listdir(kdir)):
        sub = os.path.join(kdir, name)
        if not os.path.isdir(sub) or \
                not os.path.isfile(os.path.join(sub, "ops.py")):
            continue
        rel = f"src/repro/kernels/{name}"
        ref_path = os.path.join(sub, "ref.py")
        if not os.path.isfile(ref_path):
            yield Violation(
                "GL008", f"{rel}/ops.py", 1,
                f"kernel backend {name} has no ref.py — every backend "
                f"ships a plain-NumPy oracle for golden testing")
            continue
        try:
            ref_tree = ast.parse(open(ref_path, encoding="utf-8").read())
        except SyntaxError as e:
            yield Violation("GL008", f"{rel}/ref.py", e.lineno or 1,
                            f"ref.py unparseable: {e.msg}")
            continue
        ref_names = [n.name for n in ref_tree.body
                     if isinstance(n, (ast.FunctionDef, ast.ClassDef))
                     and not n.name.startswith("_")]
        pkg = f"repro.kernels.{name}"
        hit = any(pkg in src and any(rn in src for rn in ref_names)
                  for src in tests.values())
        if not hit:
            yield Violation(
                "GL008", f"{rel}/ref.py", 1,
                f"no test under tests/ references {pkg} together with a "
                f"ref.py function ({', '.join(ref_names[:4])}...) — the "
                f"backend has no enforced golden parity")
