"""Static-analysis tooling for the repo's own discipline rules.

``repro.analysis.guardlint`` is the machine-checked form of the
invariants this codebase learned the hard way: determinism conventions
that rng-rewind replay depends on, the float32 end-to-end dtype
contract of the detection hot path, census conservation in the fleet
control plane, and the no-swallowed-exceptions rule for writers and
daemon threads. Everything here is stdlib-only (``ast`` + ``tokenize``)
so the CI lint job can run it without installing the numeric stack.
"""
