# guardlint: hot  (fleet-sized arrays live here: float32, no per-node loops)
"""Root-cause classification and routing for flagged nodes.

The what-if engine says *how much* each node delays the job; the
classifier says *why*, so the closed loop routes each flagged node into
the correct lane instead of treating every latch as an eviction:

  compute_degraded   own compute time in sustained excess (thermal
                     throttle, power deficit, marginal memory) -> GPU
                     remediation lane
  comm_degraded      own exposed-communication time in sustained excess
                     (downed/downtrained NIC) -> NIC remediation lane
  data_stall         host/data-pipeline time in excess (bad CPU
                     settings, input starvation) -> host lane
  cascade_victim     no own excess, but barrier stall: the node is
                     WAITING on a degraded peer in its collective group.
                     Watched, never evicted (evicting it would both lose
                     a healthy node and leave the culprit in the job).
  undiagnosed        flagged with no attributable own excess — e.g. a
                     transient fabric-congestion spike (comm excess that
                     is not sustained across the trace, or shared by a
                     large fleet fraction at once). Watched.
  hang_culprit       the ccltrace watchdog accused this node of wedging
                     a blocking collective (never entered, or entered
                     with independent link evidence) -> evicted; triage
                     starts in the NIC lane with link evidence, the host
                     lane otherwise
  hang_victim        arrived at the collective and blocked on the
                     barrier behind a hang culprit. Watched, never
                     evicted (same logic as cascade_victim: pulling it
                     loses a healthy node and fixes nothing)

The z-score lanes key on the ``TimingTrace`` decomposition + what-if
blame; the hang lanes are recorded by ``Diagnoser.record_hang`` from
``repro.ccltrace`` watchdog verdicts — hangs produce no step samples,
so they can never arrive through ``diagnose``.

Classification keys on the ``TimingTrace`` decomposition + what-if blame
and is sharpened by the detector's sustained hardware-signal masks
(thermal/frequency/power for the GPU lane, NIC error-delta/throughput
for the network lane). Diagnoses are exported as rich ``ErrorSignals``
so offline triage starts in the right remediation lane instead of
early-terminating nodes whose substrate reports no error counters.
"""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.detector import FleetAssessment
from repro.core.policy import Action, Decision
from repro.core.telemetry import Frame
from repro.core.triage import ErrorSignals
from repro.diagnose.trace import TimingTrace
from repro.diagnose.whatif import (Topology, fast_median, row_median,
                                   whatif)


class RootCause(enum.Enum):
    COMPUTE_DEGRADED = "compute_degraded"
    COMM_DEGRADED = "comm_degraded"
    DATA_STALL = "data_stall"
    CASCADE_VICTIM = "cascade_victim"
    UNDIAGNOSED = "undiagnosed"
    HANG_CULPRIT = "hang_culprit"
    HANG_VICTIM = "hang_victim"


# causes that must be WATCHED, not evicted: the node itself is (as far
# as attribution can tell) healthy
HOLD_CAUSES = (RootCause.CASCADE_VICTIM, RootCause.UNDIAGNOSED,
               RootCause.HANG_VICTIM)

# detector support masks backing each lane
_GPU_SUPPORT = ("gpu_temp", "gpu_freq", "gpu_power")
_NIC_SUPPORT = ("nic_errors", "nic_tx_rate", "nic_up")


@dataclasses.dataclass(frozen=True)
class RootCauseConfig:
    blame_floor: float = 0.04     # relative blame to call a culprit
    stall_floor: float = 0.04     # stall share of wall -> cascade victim
    component_floor: float = 0.02 # relative per-row excess that counts
    sustain_frac: float = 0.6     # comm-excess row fraction; below =
                                  # transient (congestion, not the NIC)
    fabric_share: float = 0.30    # fleet share with simultaneous comm
                                  # excess -> fabric-wide, not node-level
    min_windows: int = 2          # trace rows required to diagnose


@dataclasses.dataclass
class Diagnosis:
    """One node's attribution verdict for one evaluation window."""

    node_id: int
    root_cause: RootCause
    blame: float                  # standalone what-if excess, seconds
    blame_rel: float              # blame / healthy reference
    marginal: float               # leave-one-out fleet delta, seconds
    stall_share: float            # barrier wait / wall
    evidence: Tuple[str, ...]
    t: float
    step: int

    @property
    def held(self) -> bool:
        return self.root_cause in HOLD_CAUSES

    def to_error_signals(self) -> ErrorSignals:
        rc = self.root_cause
        # hang culprits route by their evidence: link evidence -> NIC
        # lane (nic_reset first), never-entered/wedged -> host lane
        # (reboot unwedges a stuck process)
        hang_nic = rc is RootCause.HANG_CULPRIT and \
            any("link" in e or "nic" in e for e in self.evidence)
        return ErrorSignals(
            gpu_errors=rc == RootCause.COMPUTE_DEGRADED,
            nic_errors=rc == RootCause.COMM_DEGRADED or hang_nic,
            host_errors=rc == RootCause.DATA_STALL or
            (rc is RootCause.HANG_CULPRIT and not hang_nic),
            root_cause=rc.value,
            detail="; ".join(self.evidence))


class FleetDiagnosis:
    """One window's attribution over the fleet (flagged nodes only get
    materialized ``Diagnosis`` records; arrays cover everyone)."""

    __slots__ = ("node_ids", "blame", "blame_rel", "marginal",
                 "stall_share", "records", "new_records")

    def __init__(self, node_ids: np.ndarray, blame: np.ndarray,
                 blame_rel: np.ndarray, marginal: np.ndarray,
                 stall_share: np.ndarray,
                 records: Dict[int, Diagnosis],
                 new_records: List[Diagnosis]):
        self.node_ids = node_ids
        self.blame = blame
        self.blame_rel = blame_rel
        self.marginal = marginal
        self.stall_share = stall_share
        self.records = records           # node_id -> Diagnosis (flagged)
        self.new_records = new_records   # new/changed verdicts this window

    def cause_of(self, node_id: int) -> Optional[RootCause]:
        rec = self.records.get(int(node_id))
        return rec.root_cause if rec is not None else None

    def reroute(self, decision: Decision) -> Decision:
        """The Diagnoser stage between detector and policy: mitigation
        decisions against held causes (victims / undiagnosed transients)
        are downgraded to watching — the node stays in the job."""
        if decision.action not in (Action.DEFER_TO_CHECKPOINT,
                                   Action.IMMEDIATE_RESTART):
            return decision
        rec = self.records.get(decision.node_id)
        if rec is None or not rec.held:
            return decision
        return Decision(
            decision.node_id, Action.PENDING_VERIFICATION,
            f"watched ({rec.root_cause.value}): {decision.reason}",
            decision.slowdown)


class Diagnoser:
    """Stateful attribution stage: trace + topology in, diagnoses out.

    One instance serves one job. ``diagnose`` runs once per evaluation
    window (only when something is flagged — quiet windows cost nothing)
    and keeps the latest per-node verdicts for the health manager's
    hold-check and for triage signal enrichment."""

    def __init__(self, trace: TimingTrace,
                 topology: Optional[Topology] = None,
                 cfg: Optional[RootCauseConfig] = None):
        self.trace = trace
        self.topology = topology
        self.cfg = cfg or RootCauseConfig()
        self.last: Dict[int, Diagnosis] = {}    # survives eviction (triage)
        self._emitted: Dict[int, RootCause] = {}
        self.last_fleet: Optional[FleetDiagnosis] = None
        self.windows_diagnosed = 0
        self.last_cost_s = 0.0
        # per-row comm-deviation cache aligned with the trace's circular
        # buffer: a row's peer-median comparison never changes once
        # written, so steady state re-medians one new row per window
        # instead of the whole (depth, N) buffer
        self._comm_gen = -1
        self._comm_seen = 0                     # trace.pushes consumed
        self._comm_dev: Optional[np.ndarray] = None   # (depth, N) bool
        # steady-state verdict reuse: when the flagged set and its cause
        # codes repeat, last window's record dict is returned as-is
        self._prev_fi: Optional[np.ndarray] = None
        self._prev_causes: Optional[np.ndarray] = None
        self._prev_ids: Optional[np.ndarray] = None
        self._prev_records: Dict[int, Diagnosis] = {}

    # ------------------------------------------------------------- core

    def diagnose(self, frame: Frame,
                 fleet: FleetAssessment) -> Optional[FleetDiagnosis]:
        flagged_idx = fleet.flagged_indices()
        if not flagged_idx.size:
            self.last_fleet = None
            # nodes that cleared may re-flag later: re-emit then
            self._emitted.clear()
            self._prev_fi = None
            return None
        trace = self.trace
        if len(trace) < self.cfg.min_windows or \
                not np.array_equal(trace.node_ids, frame.node_ids):
            self.last_fleet = None
            return None

        t0 = time.perf_counter()
        cfg = self.cfg
        comp = trace.mean("compute")
        comm = trace.mean("comm")
        host = trace.mean("host")
        stall = trace.mean("stall")
        own = comp + comm + host
        topo = self.topology or Topology.single(len(own))
        rep = whatif(own, topo, ref_own=fast_median(own))
        wall = own + stall
        np.maximum(wall, 1e-9, out=wall)
        stall_share = np.divide(stall, wall, out=wall)

        # dominant component: excess of each channel mean over the
        # fleet's healthy median. The medians are fleet-wide scalars but
        # the comparison only matters for flagged rows, so the
        # elementwise part runs on the O(flagged) gather; the nested
        # where matches argmax's first-max tie-breaking
        fi = flagged_idx
        e0 = comp[fi] - fast_median(comp)
        e1 = comm[fi] - fast_median(comm)
        e2 = host[fi] - fast_median(host)
        dm = np.where(e2 > np.maximum(e0, e1), np.int8(2),
                      np.where(e1 > e0, np.int8(1), np.int8(0)))

        # comm transience: sustained excess must cover >= sustain_frac
        # of the kept windows AND still be present in the LATEST window
        # (a congestion burst that already expired keeps polluting the
        # trace means for depth windows — it must not read as a bad
        # NIC); a fabric-wide simultaneous excess is congestion too.
        # The newest cached row IS the latest window's deviation mask.
        comm_sustain = self._comm_sustain()
        last_dev = self._comm_dev[trace.last_row]
        fabric_wide = (np.count_nonzero(last_dev) >=
                       cfg.fabric_share * last_dev.size)

        # ---- vectorized verdicts over the flagged rows
        br = rep.blame_rel[fi]
        ss = stall_share[fi]
        culprit = br >= cfg.blame_floor
        masks = fleet.support_masks
        gpu_any = np.zeros(len(fi), bool)
        nic_any = np.zeros(len(fi), bool)
        for m in _GPU_SUPPORT:
            if m in masks:
                gpu_any |= masks[m][fi]
        for m in _NIC_SUPPORT:
            if m in masks:
                nic_any |= masks[m][fi]
        C = RootCause
        causes = np.full(len(fi), 0, dtype=np.int8)  # 0 = UNDIAGNOSED
        code = {C.UNDIAGNOSED: 0, C.COMPUTE_DEGRADED: 1,
                C.COMM_DEGRADED: 2, C.DATA_STALL: 3, C.CASCADE_VICTIM: 4}
        by_code = {v: k for k, v in code.items()}
        causes[culprit & (dm == 0)] = code[C.COMPUTE_DEGRADED]
        causes[culprit & (dm == 2)] = code[C.DATA_STALL]
        comm_ok = culprit & (dm == 1) & last_dev[fi] & \
            (comm_sustain[fi] >= cfg.sustain_frac) & (not fabric_wide)
        causes[comm_ok] = code[C.COMM_DEGRADED]
        rest = ~culprit
        causes[rest & (ss >= cfg.stall_floor)] = code[C.CASCADE_VICTIM]
        presym = rest & (ss < cfg.stall_floor)
        causes[presym & gpu_any & ~nic_any] = code[C.COMPUTE_DEGRADED]
        causes[presym & nic_any & ~gpu_any] = code[C.COMM_DEGRADED]

        if (self._prev_fi is not None
                and np.array_equal(fi, self._prev_fi)
                and np.array_equal(causes, self._prev_causes)
                and np.array_equal(frame.node_ids, self._prev_ids)):
            # steady state: same flagged rows, same verdict codes — last
            # window's record dict is the answer, no per-node loop
            records = self._prev_records
            new_records: List[Diagnosis] = []
        else:
            records = {}
            new_records = []
            for k, i in enumerate(fi):
                i = int(i)
                nid = int(frame.node_ids[i])
                cause = by_code[int(causes[k])]
                prev = self.last.get(nid)
                if self._emitted.get(nid) == cause and prev is not None \
                        and prev.root_cause is cause:
                    # verdict unchanged for this node — reuse the record
                    # (evidence strings only materialize on change)
                    records[nid] = prev
                    continue
                rec = self._materialize(
                    nid, cause, rep.blame[i], br[k], rep.marginal[i],
                    ss[k], bool(culprit[k]), int(dm[k]), comm_sustain[i],
                    fabric_wide, bool(last_dev[i]), gpu_any[k],
                    nic_any[k], masks, i, frame)
                records[nid] = rec
                self.last[nid] = rec
                self._emitted[nid] = cause
                new_records.append(rec)
            # forget emission state for nodes no longer flagged
            # (re-emits on a later re-flag); keep ``last`` so triage can
            # still read it
            for nid in list(self._emitted):
                if nid not in records:
                    del self._emitted[nid]
            self._prev_fi = fi.copy()
            self._prev_causes = causes.copy()
            self._prev_ids = frame.node_ids.copy()
            self._prev_records = records

        out = FleetDiagnosis(frame.node_ids, rep.blame, rep.blame_rel,
                             rep.marginal, stall_share, records,
                             new_records)
        self.last_fleet = out
        self.windows_diagnosed += 1
        self.last_cost_s = time.perf_counter() - t0
        return out

    def _comm_sustain(self) -> np.ndarray:
        """(N,) fraction of kept trace windows with per-row comm excess.

        Each circular-buffer row's peer-median comparison is frozen once
        the row is written, so the (depth, N) deviation mask is cached
        and only rows pushed since the last diagnose are re-medianed —
        one row per window in steady state instead of the whole buffer."""
        trace, cfg = self.trace, self.cfg
        raw = trace.rows_raw("comm")                  # (depth, N)
        depth, used = trace.depth, len(trace)
        delta = trace.pushes - self._comm_seen
        rebuild = (self._comm_gen != trace.generation
                   or self._comm_dev is None
                   or self._comm_dev.shape != raw.shape
                   or trace.last_backfill is not None
                   or delta >= used or not trace.full)
        if rebuild:
            self._comm_gen = trace.generation
            sub = raw[:used]
            dev = sub > row_median(sub) * (1.0 + cfg.component_floor)
            if self._comm_dev is None or \
                    self._comm_dev.shape != raw.shape:
                self._comm_dev = np.empty(raw.shape, bool)
            self._comm_dev[:used] = dev
            self._comm_count = dev.sum(0, dtype=np.int16)  # rolling
        elif delta == 1:
            # steady state: one new row replaced one old row (row_median
            # keeps the comparison bit-identical to the rebuild path)
            row = trace.last_row
            new = raw[row] > row_median(raw[row:row + 1])[0] * \
                (1.0 + cfg.component_floor)
            self._comm_count += new
            self._comm_count -= self._comm_dev[row]
            self._comm_dev[row] = new
        else:
            rows = (trace.last_row - np.arange(delta)) % depth
            sub = raw[rows]
            dev = sub > row_median(sub) * (1.0 + cfg.component_floor)
            self._comm_count += dev.sum(0, dtype=np.int16)
            self._comm_count -= self._comm_dev[rows].sum(0,
                                                         dtype=np.int16)
            self._comm_dev[rows] = dev
        self._comm_seen = trace.pushes
        return self._comm_count.astype(np.float32) * \
            np.float32(1.0 / used)

    def _materialize(self, nid: int, cause: RootCause, blame: float,
                     blame_rel: float, marginal: float, stall_share: float,
                     culprit: bool, dominant: int, comm_sustain: float,
                     fabric_wide: bool, last_dev: bool,
                     gpu_any: bool, nic_any: bool, masks, i: int,
                     frame: Frame) -> Diagnosis:
        """Build the full record (evidence strings included) for a new
        or changed verdict — the only non-array work per window."""
        cfg = self.cfg
        evidence: List[str] = []
        gpu_sup = [m for m in _GPU_SUPPORT if m in masks and masks[m][i]]
        nic_sup = [m for m in _NIC_SUPPORT if m in masks and masks[m][i]]
        if culprit:
            evidence.append(f"blame +{blame_rel:.0%} own time "
                            f"({blame:.2f}s)")
            if marginal > 0:
                evidence.append(f"fleet impact {marginal:.2f}s/step")
            if dominant == 1 and cause is RootCause.UNDIAGNOSED:
                if fabric_wide:
                    evidence.append("comm excess fabric-wide (congestion)")
                elif not last_dev:
                    evidence.append("comm excess already gone "
                                    "(expired transient)")
                else:
                    evidence.append(
                        f"comm excess transient "
                        f"({comm_sustain:.0%} of trace windows)")
            elif cause is RootCause.COMM_DEGRADED:
                evidence.extend(f"{m} deviant" for m in nic_sup)
            elif cause is RootCause.COMPUTE_DEGRADED:
                evidence.extend(f"{m} deviant" for m in gpu_sup)
        elif cause is RootCause.CASCADE_VICTIM:
            evidence.append(f"barrier stall {stall_share:.0%} of wall, "
                            f"no own excess")
        elif cause is RootCause.COMPUTE_DEGRADED:
            evidence.extend(f"{m} deviant" for m in gpu_sup)
            evidence.append("no step impact yet")
        elif cause is RootCause.COMM_DEGRADED:
            evidence.extend(f"{m} deviant" for m in nic_sup)
            evidence.append("no step impact yet")
        else:
            evidence.append("no attributable excess")
        return Diagnosis(nid, cause, float(blame), float(blame_rel),
                         float(marginal), float(stall_share),
                         tuple(evidence), frame.t, frame.step)

    # ------------------------------------------------------- hang intake

    def record_hang(self, verdict, t: float, step: int) -> List[Diagnosis]:
        """Fold one ccltrace ``HangVerdict`` into the per-node diagnosis
        state: culprits get ``HANG_CULPRIT`` (evidence-routed to the NIC
        or host triage lane), arrived-and-blocked ranks get
        ``HANG_VICTIM`` — a HOLD cause, so the health manager keeps them
        in the job. Duck-typed on the verdict so ``repro.ccltrace``
        stays import-free of this package."""
        out: List[Diagnosis] = []
        victims = {int(v) for v in verdict.victims}
        base = (f"{verdict.op} group {verdict.group} overdue "
                f"{verdict.waited_s:.0f}s "
                f"(deadline {verdict.deadline_s:.0f}s)")
        for nid, role in verdict.roles.items():
            nid = int(nid)
            value = getattr(role, "value", str(role))
            if nid in victims:
                cause = RootCause.HANG_VICTIM
                detail = "arrived, blocked on the barrier"
            else:
                cause = RootCause.HANG_CULPRIT
                detail = ("never entered the collective"
                          if value == "never_entered"
                          else "entered and stalled (link evidence)")
            rec = Diagnosis(nid, cause, 0.0, 0.0, 0.0,
                            1.0 if cause is RootCause.HANG_VICTIM else 0.0,
                            (base, detail), float(t), int(step))
            self.last[nid] = rec
            self._emitted.pop(nid, None)   # a later z-flag must re-emit
            out.append(rec)
        return out

    # ---------------------------------------------------------- consumers

    def should_hold(self, node_id: int) -> bool:
        """Health-manager gate: True = keep this node in the job (its
        latest diagnosis says it is a victim / transient, not a culprit)."""
        rec = self.last.get(int(node_id))
        return rec is not None and rec.held

    def signals_for(self, node_id: int) -> Optional[ErrorSignals]:
        """Rich triage evidence from the latest diagnosis (None if the
        node was never diagnosed)."""
        rec = self.last.get(int(node_id))
        return rec.to_error_signals() if rec is not None else None

    def node_replaced(self, node_id: int) -> None:
        """A node left the job: a later node reusing the id must re-emit.
        The last diagnosis is kept — offline triage consumes it."""
        self._emitted.pop(int(node_id), None)


__all__ = ["Diagnoser", "Diagnosis", "FleetDiagnosis", "HOLD_CAUSES",
           "RootCause", "RootCauseConfig"]
