# guardlint: hot  (fleet-sized arrays live here: float32, no per-node loops)
"""Per-window, per-node timing decompositions (the diagnosis substrate).

Blame attribution needs more than the detector's step-time metric: it
needs to know *where* each node's window went — device compute, exposed
inter-node communication, host/data work, and barrier stall (time spent
waiting on peers inside a blocking collective). ``TimingTrace`` keeps a
fixed-depth history of those decompositions as preallocated circular
``(depth, N)`` float arrays, the same discipline as the detector's
``RingHistory``: one ``push`` per evaluation window costs one row-write
per channel, never a re-stack.

Producers:

  - ``SimCluster`` feeds the trace from the step-time model itself (the
    simulator knows the true compute/comm/host split and the barrier
    structure), via ``SimCluster.attach_timing``.
  - ``GuardStepHook`` feeds it from measured trainer step times, using
    trainer-supplied component timings when available and a configured
    split otherwise (``repro.guard.hook``).
  - A real deployment feeds it from device/collective timeline
    instrumentation (profiler-style busy/wait accounting).

Consumers: the what-if engine (``repro.diagnose.whatif``) and the
root-cause classifier (``repro.diagnose.rootcause``) read the raw rows —
their reductions are order-invariant, so the circular buffers are never
reordered on the hot path.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

# decomposition channels, in "own work" order; wall = own + stall
CHANNELS = ("compute", "comm", "host", "stall")
OWN_CHANNELS = ("compute", "comm", "host")


@dataclasses.dataclass
class WindowTiming:
    """One evaluation window's timing decomposition, per node.

    Every channel is window-mean seconds aligned with ``node_ids``.
    ``stall`` is barrier wait: the gap between the node finishing its own
    work and its blocking collective completing (group wall - own)."""

    t: float
    step: int
    node_ids: np.ndarray                 # (N,) int64
    compute: np.ndarray                  # (N,) device-gated compute
    comm: np.ndarray                     # (N,) exposed inter-node comm
    host: np.ndarray                     # (N,) host/data-pipeline work
    stall: np.ndarray                    # (N,) barrier wait (>= 0)

    def __post_init__(self):
        n = len(self.node_ids)
        for ch in CHANNELS:
            assert getattr(self, ch).shape == (n,), (ch, n)

    @property
    def own(self) -> np.ndarray:
        """(N,) seconds of the node's own work (compute + comm + host)."""
        return self.compute + self.comm + self.host

    @property
    def wall(self) -> np.ndarray:
        """(N,) measured wall seconds (own work + barrier stall)."""
        return self.own + self.stall


class TimingTrace:
    """Fixed-depth circular history of ``WindowTiming`` rows.

    Preallocated ``(depth, N)`` buffers per channel. Fleet membership
    changes are handled like the detector's ``RingHistory``: a resize
    reallocates (history no longer aligns), while a same-size node
    replacement backfills only the changed columns with the new node's
    current readings so a freshly swapped-in spare never inherits its
    predecessor's timing history."""

    def __init__(self, depth: int = 8):
        assert depth >= 1
        self.depth = depth
        self._bufs: Dict[str, np.ndarray] = {}     # channel -> (depth, N)
        self._ids: Optional[np.ndarray] = None
        self._used = 0
        self._head = 0
        self._last: Optional[WindowTiming] = None
        self._sums: Dict[str, np.ndarray] = {}     # channel -> (N,) f64
        self._sums_stale = False
        self.generation = 0          # bumped on every (re)allocation
        self.pushes = 0              # total rows ever written
        self.last_backfill: Optional[np.ndarray] = None  # cols changed by
        # the most recent push's replacement backfill (None if none)

    # ------------------------------------------------------------- intake

    def _alloc(self, wt: WindowTiming) -> None:
        n = len(wt.node_ids)
        # float32 like the detector's RingHistory: window durations are
        # O(seconds) so f32 keeps ~1e-7 s relative resolution, and the
        # downstream what-if reductions stay f32 end-to-end
        self._bufs = {ch: np.empty((self.depth, n), np.float32)
                      for ch in CHANNELS}
        self._ids = wt.node_ids.copy()
        self._used = 0
        self._head = 0
        self.generation += 1
        # rolling per-channel window sums (f64 accumulators: adding and
        # later subtracting the same stored f32 row keeps the drift at
        # rounding noise), so ``mean`` is O(N) instead of O(depth * N)
        # guardlint: disable=GL002 reason=rolling add/subtract accumulator
        # — f32 sums drift as windows cycle; the stored rows stay f32
        self._sums = {ch: np.zeros(n, np.float64) for ch in CHANNELS}
        self._means = {ch: np.empty(n, np.float32) for ch in CHANNELS}
        self._sums_stale = False

    def push(self, wt: WindowTiming) -> None:
        self.last_backfill = None
        ids = self._ids
        if ids is None or len(wt.node_ids) != len(ids):
            self._alloc(wt)
        elif not np.array_equal(wt.node_ids, ids):
            changed = wt.node_ids != ids
            for ch, buf in self._bufs.items():
                buf[:, changed] = getattr(wt, ch)[changed]
            self._ids = ids.copy()
            self._ids[changed] = wt.node_ids[changed]
            self.last_backfill = changed
            self._sums_stale = True
        row = self._head
        full = self._used == self.depth
        for ch, buf in self._bufs.items():
            if full and not self._sums_stale:
                self._sums[ch] -= buf[row]       # evicted row leaves
            buf[row] = getattr(wt, ch)
            if not self._sums_stale:
                self._sums[ch] += buf[row]       # stored f32 row enters
        self._head = (row + 1) % self.depth
        self._used = min(self._used + 1, self.depth)
        self._last = wt
        self.pushes += 1

    # ------------------------------------------------------------ queries

    def __len__(self) -> int:
        return self._used

    @property
    def full(self) -> bool:
        return self._used == self.depth

    @property
    def node_ids(self) -> Optional[np.ndarray]:
        return self._ids

    def last(self) -> WindowTiming:
        if self._last is None:
            raise IndexError("empty timing trace")
        return self._last

    @property
    def last_row(self) -> int:
        """Buffer row index the most recent push wrote."""
        return (self._head - 1) % self.depth

    def rows(self, channel: str) -> np.ndarray:
        """(used, N) raw buffer rows in ARBITRARY window order — zero-copy
        view for order-invariant reductions. Callers must not mutate."""
        return self._bufs[channel][:self._used]

    def rows_raw(self, channel: str) -> np.ndarray:
        """(depth, N) full backing buffer (rows beyond ``len(self)`` are
        uninitialized). For row-indexed caches; do not mutate."""
        return self._bufs[channel]

    def mean(self, channel: str) -> np.ndarray:
        """(N,) per-node mean of one channel over the kept windows,
        float32, served from the rolling sums.

        Returns a per-channel scratch buffer reused across calls — valid
        until the next ``mean`` of the same channel; copy to retain."""
        if self._sums_stale:
            for ch, buf in self._bufs.items():
                # guardlint: disable=GL002 reason=recomputing the rolling
                # f64 accumulator (see _alloc); output means stay f32
                np.sum(buf[:self._used], axis=0, dtype=np.float64,
                       out=self._sums[ch])
            self._sums_stale = False
        out = self._means[channel]
        np.multiply(self._sums[channel], 1.0 / self._used, out=out,
                    casting="unsafe")
        return out

    def own_rows(self) -> np.ndarray:
        """(used, N) own-work seconds per kept window."""
        return (self.rows("compute") + self.rows("comm") +
                self.rows("host"))

    def own_mean(self) -> np.ndarray:
        return self.own_rows().mean(axis=0)

    def wall_mean(self) -> np.ndarray:
        return self.own_mean() + self.mean("stall")

    @property
    def nbytes(self) -> int:
        """Resident bytes of the circular buffers (memory report)."""
        return sum(b.nbytes for b in self._bufs.values())

    def clear(self) -> None:
        self._used = 0
        self._head = 0
        self._last = None
        self._sums_stale = True


__all__ = ["CHANNELS", "OWN_CHANNELS", "TimingTrace", "WindowTiming"]
