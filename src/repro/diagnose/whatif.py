# guardlint: hot  (fleet-sized arrays live here: float32, no per-node loops)
"""Vectorized what-if counterfactual replay over the collective structure.

The peer-relative detector scores the *measured* per-node step time,
which in a real job includes barrier wait: one degraded node inflates
the wall time of every peer in its blocking collective group, and the
z-score cannot tell the culprit from the cascade victims stalled behind
it. The what-if engine separates them by replaying each window against
the collective dependency structure with counterfactual node timings —
the approach of the what-if straggler-analysis line of work, reduced to
array passes.

``Topology`` captures the dependency structure Guard needs: the
partition of job nodes into blocking-collective groups (the DP gradient
barrier within each pipeline/model-parallel stage). Nodes in a group
complete together at the group's slowest member; the job step completes
at the slowest group. Build one from ``repro.dist`` axis sizes
(``Topology.from_dist``) or directly (``grouped`` / ``pipeline`` /
``single``).

Two counterfactuals, both one vectorized pass per window over ``(N,)``
arrays:

  blame      standalone what-if: fleet step time in a world where ONLY
             node i is degraded (everyone else at the healthy
             reference) minus the all-healthy fleet time — i.e. the
             node's own excess over reference. Robust to multiple
             concurrent culprits (a culprit shadowed by a worse one in
             the same group still carries its own blame); exactly zero
             for barrier-stalled victims.
  marginal   leave-one-out what-if: actual fleet step time minus the
             fleet step time with node i replaced by the healthy
             reference — the step-time seconds mitigation would win
             back *right now*. Ranks severity; shadowed culprits show
             zero until the node ahead of them is fixed.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


class Topology:
    """Partition of the N job nodes into blocking-collective groups.

    ``stage_of`` maps each node ROW (its position in the active array /
    telemetry frame, which is stable across spare swaps) to a group id.
    Group reductions are precompiled into a sort permutation +
    ``reduceat`` boundaries so ``group_max`` over a ``(..., N)`` array is
    one gather, one segmented reduction and one scatter."""

    def __init__(self, stage_of: np.ndarray):
        stage_of = np.asarray(stage_of)
        assert stage_of.ndim == 1 and len(stage_of) >= 1
        self.stage_of = stage_of
        self.n = len(stage_of)
        # contiguous fast path: every builder lays groups out in sorted
        # blocks, so the permutation is the identity and each segmented
        # reduction can skip its (..., N) gather/scatter — the difference
        # between O(N) copies and pure reduceat at 100k nodes
        self.contiguous = bool(np.all(stage_of[1:] >= stage_of[:-1]))
        self.order = (np.arange(self.n) if self.contiguous
                      else np.argsort(stage_of, kind="stable"))
        sorted_stages = stage_of if self.contiguous \
            else stage_of[self.order]
        boundary = np.r_[True, sorted_stages[1:] != sorted_stages[:-1]]
        self.starts = np.flatnonzero(boundary)
        self.n_groups = len(self.starts)
        self.counts = np.diff(np.r_[self.starts, self.n])
        # group ordinal of each SORTED position (for expand/scatter)
        self._pos_group = np.repeat(np.arange(self.n_groups), self.counts)

    # ---------------------------------------------------------- builders

    @classmethod
    def single(cls, n: int) -> "Topology":
        """One global barrier: every node blocks on every other."""
        return cls(np.zeros(n, dtype=np.int64))

    @classmethod
    def grouped(cls, n: int, group_size: int) -> "Topology":
        """Contiguous blocks of ``group_size`` nodes barrier together
        (DP gradient all-reduce groups; the last block may be short)."""
        assert group_size >= 1
        return cls(np.arange(n, dtype=np.int64) // group_size)

    @classmethod
    def pipeline(cls, n: int, n_stages: int) -> "Topology":
        """``n_stages`` contiguous pipeline stages, each one barrier
        group (nodes of a stage hold the same model shard and all-reduce
        gradients together)."""
        assert 1 <= n_stages <= n
        return cls.grouped(n, -(-n // n_stages))

    @classmethod
    def from_dist(cls, ctx, n: int) -> "Topology":
        """Derive the barrier structure from a ``repro.dist``
        DistContext: the model-parallel axis size ("tp" -> mesh "model")
        is the number of model shards, i.e. the number of independent DP
        all-reduce groups; nodes are laid out shard-major."""
        stages = max(int(ctx.axis_size("tp")), 1)
        return cls.pipeline(n, min(stages, n))

    # --------------------------------------------------------- reductions

    def group_reduce_max(self, x: np.ndarray) -> np.ndarray:
        """(..., N) -> (..., G) max within each group."""
        xs = x if self.contiguous else x[..., self.order]
        return np.maximum.reduceat(xs, self.starts, axis=-1)

    def group_max(self, x: np.ndarray) -> np.ndarray:
        """(..., N) -> (..., N): each element replaced by its group max
        (the wall time a blocking collective imposes on every member)."""
        gm = self.group_reduce_max(x)
        expanded = gm[..., self._pos_group]
        if self.contiguous:
            return expanded
        out = np.empty_like(x)
        out[..., self.order] = expanded
        return out


@dataclasses.dataclass
class WhatIfReport:
    """Per-window counterfactual attribution for the fleet."""

    fleet_time: float                # actual fleet step time (s)
    healthy_time: float              # all-healthy counterfactual (s)
    ref_own: float                   # healthy per-node own-time reference
    blame: np.ndarray                # (N,) standalone what-if excess, s
    blame_rel: np.ndarray            # (N,) blame / ref_own
    marginal: np.ndarray             # (N,) leave-one-out fleet delta, s

    def culprit_mask(self, floor_rel: float = 0.04) -> np.ndarray:
        return self.blame_rel > floor_rel


def fast_median(a: np.ndarray) -> float:
    """1-D median via one partition — identical result to ``np.median``
    without its per-call dispatch/nan-check overhead (this sits on the
    per-window attribution path).

    Even length uses ONE kth plus a max over the left half (the (h-1)-th
    order statistic): numpy's multi-kth introselect is ~7x slower than
    single-kth, and the max recovers the same element exactly."""
    n = a.size
    h = n // 2
    p = np.partition(a, h)
    if n % 2:
        return float(p[h])
    return float(p[:h].max() + p[h]) / 2.0


def row_median(mat: np.ndarray) -> np.ndarray:
    """(M, N) -> (M, 1) median along axis 1 via one partition (same
    single-kth + left-half-max trick as ``fast_median``)."""
    n = mat.shape[1]
    h = n // 2
    p = np.partition(mat, h, axis=1)
    if n % 2:
        return p[:, h:h + 1]
    return (p[:, :h].max(axis=1, keepdims=True) + p[:, h:h + 1]) / 2.0


def whatif(own: np.ndarray, topology: Topology,
           ref_own: Optional[float] = None) -> WhatIfReport:
    """Counterfactual attribution for one window of own-work times.

    ``own`` is the (N,) per-node own-time (compute + comm + host,
    EXCLUDING barrier stall) — typically a ``TimingTrace`` window mean.
    ``ref_own`` is the healthy per-node reference; defaults to the fleet
    median (robust while the healthy population is the majority).

    One array pass: blame is elementwise; the leave-one-out marginal
    needs each group's (first) argmax and runner-up, both computed with
    segmented reductions — no per-group Python loop.
    """
    own = np.asarray(own)
    if not np.issubdtype(own.dtype, np.floating):
        own = own.astype(np.float32)   # dtype-preserving: f32 stays f32
    assert own.shape == (topology.n,)
    ref = fast_median(own) if ref_own is None else float(ref_own)
    ref = max(ref, 1e-9)

    # standalone what-if: only node i degraded, rest at reference. The
    # job would finish at max(ref, own_i); all-healthy finishes at ref.
    blame = own - ref
    np.maximum(blame, 0.0, out=blame)

    # leave-one-out what-if: group times with node i at reference. Only
    # a group's (first) argmax can lower its group time; the fleet step
    # then re-completes at the slowest remaining group.
    order, starts = topology.order, topology.starts
    xs = own if topology.contiguous else own[order]
    gmax = np.maximum.reduceat(xs, starts)                     # (G,)
    fleet_time = float(gmax.max())
    # first-argmax position per group: the first is-max flag at or after
    # each group's start (every group has one, so searchsorted lands
    # inside the right segment)
    flags = np.flatnonzero(xs == gmax[topology._pos_group])
    pos = flags[np.searchsorted(flags, starts)]
    arg_nodes = pos if topology.contiguous else order[pos]
    xs2 = xs.copy()
    xs2[pos] = -np.inf
    second = np.maximum.reduceat(xs2, starts)    # -inf for singletons
    # "slowest OTHER group": top-2 of the group maxima (ties resolve to
    # the shared max, which is exactly right)
    if topology.n_groups == 1:
        others = np.full(1, -np.inf, own.dtype)
    else:
        part = np.partition(gmax, topology.n_groups - 2)
        g1, g2 = part[-1], part[-2]     # numpy scalars: keep own's dtype
        others = np.where(gmax == g1, g2, g1)
    new_group = np.maximum(np.maximum(second, ref), others)
    marginal = np.zeros_like(own)
    marginal[arg_nodes] = np.maximum(fleet_time - new_group, 0.0)

    return WhatIfReport(
        fleet_time=fleet_time, healthy_time=ref, ref_own=ref,
        blame=blame, blame_rel=blame / ref, marginal=marginal)


__all__ = ["Topology", "WhatIfReport", "fast_median", "row_median",
           "whatif"]
