"""``repro.diagnose`` — what-if blame attribution and root-cause routing.

The detector (``repro.core.detector``) says WHO deviates; this package
says WHY, so the Guard loop routes each flagged node into the right lane
instead of treating every latch as an eviction:

  trace       per-window, per-node timing decompositions
              (compute / comm / host / stall) in circular (depth, N)
              buffers, fed by the simulator and the trainer hook
  whatif      vectorized counterfactual replay over the collective
              dependency structure (DP barrier groups / pipeline stages
              from ``repro.dist`` axes): per-node blame scores that
              separate culprits from barrier-stalled cascade victims
  rootcause   blame + telemetry deltas -> RootCause taxonomy + rich
              ErrorSignals; ``Diagnoser`` is the session stage between
              detector and policy (victims are watched, not evicted)

Wire-up: build a ``TimingTrace`` + ``Topology``, hand them to a
``Diagnoser``, attach the trace to the substrate
(``SimCluster.attach_timing`` / ``GuardStepHook``) and pass the
diagnoser to ``GuardSession``. ``RunConfig(diagnose=True)`` does all of
it for simulated runs.
"""
from repro.diagnose.rootcause import (HOLD_CAUSES, Diagnoser, Diagnosis,
                                      FleetDiagnosis, RootCause,
                                      RootCauseConfig)
from repro.diagnose.trace import (CHANNELS, OWN_CHANNELS, TimingTrace,
                                  WindowTiming)
from repro.diagnose.whatif import Topology, WhatIfReport, whatif

__all__ = [
    "CHANNELS", "Diagnoser", "Diagnosis", "FleetDiagnosis", "HOLD_CAUSES",
    "OWN_CHANNELS", "RootCause", "RootCauseConfig", "TimingTrace",
    "Topology", "WhatIfReport", "WindowTiming", "whatif",
]
