"""Guard-as-a-service: the fleet control plane over many concurrent
jobs sharing one node inventory.

``FleetController`` multiplexes N ``GuardSession``s: a global
home-tagged spare pool with lease/grant arbitration (urgency ladder +
job priority + fair-share floor + hard starvation bound), one shared
sweep bench all qualification campaigns queue on, a healthscan-style
background re-qualification orchestrator, and a cursor-replayable
streaming event log aggregating every session's bus.
"""
from repro.fleet.controller import FleetController, FleetJob
from repro.fleet.events import (FLEET_EVENT_TYPES, CampaignScheduled,
                                SpareLeased, SpareReclaimed)
from repro.fleet.healthscan import HealthScanOrchestrator
from repro.fleet.pool import (GlobalSparePool, Lease, LeaseKind,
                              LeaseRequest, PoolStats, SpareRecord)
from repro.fleet.stream import (FleetEventLog, FleetRecord,
                                JsonlStreamSink, SSEStreamSink)

__all__ = [
    "CampaignScheduled", "FLEET_EVENT_TYPES", "FleetController",
    "FleetEventLog", "FleetJob", "FleetRecord", "GlobalSparePool",
    "HealthScanOrchestrator", "JsonlStreamSink", "Lease", "LeaseKind",
    "LeaseRequest", "PoolStats", "SSEStreamSink", "SpareLeased",
    "SpareRecord", "SpareReclaimed",
]
