"""Background re-qualification campaigns on idle bench capacity.

Modeled on the ``healthrunner`` orchestration of Google's
cluster-health-scanner: health checking is a *periodic fleet service*,
not something a job does inline. Every ``period_s`` of fleet time the
orchestrator walks the global pool's free spares (grouped by home job,
since sweeps run on the home fleet's bench backend), books a batched
``fleet_qualification`` campaign on a sweep-bench slot **only if one is
idle** — foreground qualification always outranks background scans —
and feeds the verdicts back: passers stay in the pool with a refreshed
timestamp, failures are pulled out, quarantined in their home session
and routed into its event-driven sweep→triage loop.

This is what catches nodes that slipped through admission (the sim
seeds admission greys on provisioning): in fleet mode spares sit in the
shared pool instead of being inline-checked by each job, so the
periodic scan is the line of defense the paper's always-on service
provides.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, List

from repro.core.health_manager import NodeState
from repro.core.sweep import SweepCampaign, fleet_qualification
from repro.fleet.events import CampaignScheduled

if TYPE_CHECKING:
    from repro.fleet.controller import FleetController


class HealthScanOrchestrator:
    """Periodic scanner over the global pool's free spares."""

    def __init__(self, controller: "FleetController",
                 period_s: float = 6 * 3600.0, batch: int = 16):
        self.controller = controller
        self.period_s = float(period_s)
        self.batch = int(batch)
        self._next_due = self.period_s
        # rotate the starting job so one tenant's spares don't hog the
        # idle capacity every cycle
        self._rr = 0
        self.campaigns = 0
        self.scanned = 0
        self.failed: List[int] = []
        # host wall spent inside the batched sweep computation itself:
        # that is BENCH work (it would run on the qualification
        # hardware), not control-plane overhead — the controller
        # subtracts it from its self-time
        self.sweep_wall_s = 0.0

    def tick(self, now: float) -> int:
        """Run due campaigns at fleet time ``now``; returns how many
        were scheduled this call."""
        now = float(now)
        if now < self._next_due:
            return 0
        self._next_due = now + self.period_s
        ctl = self.controller
        jobs = list(ctl.jobs.values())
        if not jobs:
            return 0
        ran = 0
        order = jobs[self._rr % len(jobs):] + jobs[:self._rr % len(jobs)]
        self._rr += 1
        for job in order:
            if not ctl.bench.idle_at(now):
                break               # foreground work owns the bench
            ids = ctl.pool.free_ids(home=job.job_id)[:self.batch]
            if not ids:
                continue
            mgr = job.session.manager
            res = fleet_qualification(
                mgr.backend,
                SweepCampaign(node_ids=tuple(ids), reference_pool=(),
                              enhanced=False),
                mgr.sweep_cfg)
            self.sweep_wall_s += res.wall_s
            mgr.stats.sweeps_run += res.sweeps
            mgr.stats.sweeps_failed += len(res.failed)
            start, finish = ctl.bench.occupy(now, res.node_seconds
                                             / max(ctl.bench.slots, 1))
            for nid in res.failed:
                # out of the pool, into the home session's offline loop
                ctl.pool.remove(nid, home=job.job_id)
                mgr.state[nid] = NodeState.QUARANTINED
                job.session.scheduler.submit(nid, now=finish)
                self.failed.append(nid)
            for rec in (ctl.pool.record(nid, home=job.job_id)
                        for nid in res.passed):
                if rec is not None:
                    rec.since_t = now   # freshly re-certified
            ctl.log.append(job.job_id, CampaignScheduled(
                t=now, step=-1, job=job.job_id, nodes=tuple(ids),
                start_t=start, finish_t=finish))
            self.campaigns += 1
            self.scanned += len(ids)
            ran += 1
        return ran


__all__ = ["HealthScanOrchestrator"]
