"""Fleet-level control-plane events.

These extend the ``repro.guard`` taxonomy with the transitions only a
multi-job control plane can see: lease grants and reclaims against the
global spare pool, and background re-qualification campaigns scheduled
by the healthscan orchestrator. They are ordinary ``GuardEvent``
subclasses so every existing sink (trace, JSONL) renders them, and the
fleet event log tags them — like every per-session event it aggregates —
with the owning job id and a monotonic fleet sequence number.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Tuple, Type

from repro.guard.events import GuardEvent


@dataclasses.dataclass(frozen=True)
class SpareLeased(GuardEvent):
    """The global pool granted a node to a job. ``kind`` is the lease
    urgency class (``swap`` / ``crash`` / ``hang``), ``provisioned``
    whether the grant had to materialize brand-new capacity (pool was
    dry or the free nodes were not transferable), ``transfer`` whether
    the granted capacity was donated by another job's homed spare,
    ``wait_s`` how long the request queued before the grant."""
    kind: ClassVar[str] = "spare_leased"
    node_id: int = -1
    job: str = ""
    lease_kind: str = "swap"
    priority: int = 0
    provisioned: bool = False
    transfer: bool = False
    wait_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class SpareReclaimed(GuardEvent):
    """A healthy node returned to the global pool (lease closed, a
    requalified node landed, or a registering job's private spares were
    adopted)."""
    kind: ClassVar[str] = "spare_reclaimed"
    node_id: int = -1
    job: str = ""
    reason: str = ""


@dataclasses.dataclass(frozen=True)
class CampaignScheduled(GuardEvent):
    """The healthscan orchestrator booked a background re-qualification
    campaign on idle bench capacity: ``nodes`` free-pool spares homed in
    ``job`` get swept while no foreground qualification wants the
    slots."""
    kind: ClassVar[str] = "campaign_scheduled"
    job: str = ""
    nodes: Tuple[int, ...] = ()
    start_t: float = 0.0
    finish_t: float = 0.0


FLEET_EVENT_TYPES: Tuple[Type[GuardEvent], ...] = (
    SpareLeased, SpareReclaimed, CampaignScheduled,
)

__all__ = ["CampaignScheduled", "FLEET_EVENT_TYPES", "SpareLeased",
           "SpareReclaimed"]
