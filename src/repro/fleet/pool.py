"""The fleet's global spare pool: lease/grant arbitration over one
shared node inventory.

One pool replaces N private ``HealthManager.spares`` lists. Every free
node carries a *home* job — the fleet whose physical inventory (racks,
NICs, sim ids) it lives in — because grants to the home job are plain
handoffs while cross-job grants are *transfers* (the controller
materializes equivalent capacity in the destination fleet and retires
the donor node; see ``FleetController``).

Arbitration (the paper's cluster-service allocation policy):

1. **Starvation bound first.** A request that has waited past
   ``starvation_age_s`` outranks everything — the no-starvation
   guarantee is absolute, not best-effort. Crossing the bound is ALSO
   counted as a starvation event (the bench gates on zero, i.e. the
   ladder below must keep every wait under the bound on its own).
2. **Fair-share floor.** A job whose granted share has fallen below
   ``floor_frac`` of the per-job mean outranks kind and priority: a
   fleet of ENHANCED tenants cannot structurally starve an ONLINE one.
3. **Lease kind.** Hang-culprit evictions > fail-stop crashes >
   slow-node swaps: a wedged collective idles the whole job, a crash
   idles the job until replacement, a straggler merely degrades it.
4. **Job priority.** ENHANCED-tier jobs outrank ONLINE within a kind.
5. **FIFO** within all of the above.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Dict, List, Optional, Tuple


class LeaseKind(enum.IntEnum):
    """Urgency ladder for spare leases (higher = more urgent)."""
    SLOW_SWAP = 1     # straggler eviction: the job still makes progress
    CRASH = 2         # fail-stop replacement: the job is down until served
    HANG_EVICT = 3    # hang-culprit eviction: the job is wedged, hot path

    @classmethod
    def from_str(cls, kind: str) -> "LeaseKind":
        return _KIND_FROM_STR.get(kind, cls.SLOW_SWAP)


_KIND_FROM_STR = {"swap": LeaseKind.SLOW_SWAP, "crash": LeaseKind.CRASH,
                  "hang": LeaseKind.HANG_EVICT}


@dataclasses.dataclass
class SpareRecord:
    """One free node in the global pool."""
    node_id: int
    home: str               # job whose physical fleet the node lives in
    since_t: float          # when it became free


@dataclasses.dataclass
class Lease:
    """A closed grant: ``node_id`` left the pool for ``job``.

    ``home`` is the fleet the granted record physically lives in; node
    ids are only unique *within* a home fleet (each job's substrate
    numbers its own inventory), so the pool keys everything by
    ``(home, node_id)``. ``home != job`` marks a transfer — the
    controller materializes fresh capacity in ``job``'s fleet and the
    recorded node becomes a ghost."""
    node_id: int
    job: str
    kind: LeaseKind
    granted_t: float
    home: str = ""
    wait_s: float = 0.0
    transfer: bool = False       # donated by another job's homed spare
    provisioned: bool = False    # materialized brand-new (pool was dry)


@dataclasses.dataclass
class LeaseRequest:
    """A queued ask for replacement capacity (async path)."""
    job: str
    kind: LeaseKind
    priority: int
    enqueue_t: float
    seq: int
    lease: Optional[Lease] = None     # set when served

    @property
    def served(self) -> bool:
        return self.lease is not None


@dataclasses.dataclass
class PoolStats:
    grants: int = 0
    transfers: int = 0
    provisions: int = 0
    starvation_events: int = 0
    max_wait_s: float = 0.0


class GlobalSparePool:
    """Home-tagged free list + the lease arbitration queue.

    ``grant`` is the synchronous path (a session's ``take_spare`` cannot
    block); ``request``/``serve`` is the queued path the controller and
    the property tests drive. Both feed the same free list, the same
    per-job grant accounting, and the same starvation bound.
    """

    def __init__(self, starvation_age_s: float = 3600.0,
                 floor_frac: float = 0.5):
        # node ids are only unique within a home fleet — key by both
        self._free: Dict[Tuple[str, int], SpareRecord] = {}
        self._free_by_home: Dict[str, int] = {}   # O(1) per-home census
        self._leased: Dict[Tuple[str, int], Lease] = {}  # open leases
        self._queue: List[LeaseRequest] = []
        self._seq = 0
        self.jobs: List[str] = []
        self.granted_to: Dict[str, int] = {}   # per-job grant counts
        self.starvation_age_s = float(starvation_age_s)
        self.floor_frac = float(floor_frac)
        self.stats = PoolStats()

    # ------------------------------------------------------------ census

    def _assert_census(self) -> None:
        """Pool conservation, asserted by every mutating entry point
        (guardlint GL005): the O(1) per-home counter matches the free
        list exactly, no node is simultaneously free and leased, and
        every job with grants is registered."""
        by_home: Dict[str, int] = {}
        for (home, _nid) in self._free:
            by_home[home] = by_home.get(home, 0) + 1
        recorded = {h: n for h, n in self._free_by_home.items() if n}
        assert recorded == by_home, \
            f"pool census drift: counter {recorded} != free list {by_home}"
        overlap = self._free.keys() & self._leased.keys()
        assert not overlap, \
            f"nodes both free and leased: {sorted(overlap)}"
        assert set(self.granted_to) == set(self.jobs), \
            "grant accounting for unregistered job"

    def register_job(self, job: str) -> None:
        if job not in self.granted_to:
            self.jobs.append(job)
            self.granted_to[job] = 0
        self._assert_census()

    def free_count(self, home: Optional[str] = None) -> int:
        if home is None:
            return len(self._free)
        return self._free_by_home.get(home, 0)

    def free_ids(self, home: Optional[str] = None) -> List[int]:
        """Free node ids; without ``home`` the ids may collide across
        fleets — use only for counting/inspection then."""
        if home is None:
            return sorted(n for (_, n) in self._free)
        return sorted(n for (h, n) in self._free if h == home)

    def record(self, node_id: int, home: str) -> Optional[SpareRecord]:
        return self._free.get((home, node_id))

    def pending(self, job: Optional[str] = None) -> List[LeaseRequest]:
        if job is None:
            return list(self._queue)
        return [r for r in self._queue if r.job == job]

    # ------------------------------------------------------------- intake

    def add(self, node_id: int, home: str, now: float) -> None:
        """A healthy node enters (or re-enters) the free pool. Closes
        any open lease on it; double-adding is an accounting bug."""
        key = (home, node_id)
        assert key not in self._free, \
            f"node {key} already free (double give)"
        self._leased.pop(key, None)
        self._free[key] = SpareRecord(node_id, home, float(now))
        self._free_by_home[home] = self._free_by_home.get(home, 0) + 1
        self._assert_census()

    def remove(self, node_id: int, home: str) -> Optional[SpareRecord]:
        """Pull a free node out of the pool without granting it (the
        healthscan pulls failures into quarantine this way)."""
        rec = self._free.pop((home, node_id), None)
        if rec is not None:
            self._free_by_home[home] -= 1
        self._assert_census()
        return rec

    # ------------------------------------------------------------- grants

    def grant(self, job: str, kind: LeaseKind, now: float,
              wait_s: float = 0.0) -> Optional[Lease]:
        """Synchronously lease one free node to ``job``: oldest home
        spare first, else the oldest foreign spare (a transfer). Returns
        None when the pool is dry — the caller provisions."""
        pick: Optional[SpareRecord] = None
        if self._free_by_home.get(job, 0):
            for rec in self._free.values():
                if rec.home != job:
                    continue
                if pick is None or rec.since_t < pick.since_t:
                    pick = rec
        transfer = False
        if pick is None:
            for rec in self._free.values():
                if pick is None or rec.since_t < pick.since_t:
                    pick = rec
            transfer = pick is not None
        if pick is None:
            return None
        del self._free[(pick.home, pick.node_id)]
        self._free_by_home[pick.home] -= 1
        lease = Lease(pick.node_id, job, kind, float(now), home=pick.home,
                      wait_s=float(wait_s), transfer=transfer)
        self._note_grant(lease)
        self._assert_census()
        return lease

    def note_provisioned(self, node_id: int, job: str, kind: LeaseKind,
                         now: float, wait_s: float = 0.0) -> Lease:
        """Record a grant that had to materialize brand-new capacity
        (pool dry). The node never touched the free list."""
        lease = Lease(int(node_id), job, kind, float(now), home=job,
                      wait_s=float(wait_s), provisioned=True)
        self.stats.provisions += 1
        self._note_grant(lease)
        return lease

    def _note_grant(self, lease: Lease) -> None:
        key = (lease.home, lease.node_id)
        assert key not in self._leased, f"node {key} double-granted"
        self._leased[key] = lease
        self.register_job(lease.job)
        self.granted_to[lease.job] += 1
        self.stats.grants += 1
        if lease.transfer:
            self.stats.transfers += 1
        self.stats.max_wait_s = max(self.stats.max_wait_s, lease.wait_s)
        if lease.wait_s > self.starvation_age_s:
            self.stats.starvation_events += 1
        self._assert_census()

    # -------------------------------------------------------- async queue

    def request(self, job: str, kind: LeaseKind, priority: int,
                now: float) -> LeaseRequest:
        """Queue an ask; ``serve`` arbitrates."""
        self.register_job(job)
        self._seq += 1
        req = LeaseRequest(job, kind, int(priority), float(now), self._seq)
        self._queue.append(req)
        self._assert_census()
        return req

    def _below_floor(self, job: str) -> bool:
        """Fair-share floor: has ``job`` received less than
        ``floor_frac`` of the per-job mean grant count?"""
        n = len(self.jobs)
        if n <= 1:
            return False
        mean = self.stats.grants / n
        return self.granted_to.get(job, 0) < self.floor_frac * mean

    def _rank(self, req: LeaseRequest, now: float) -> Tuple:
        starving = (now - req.enqueue_t) >= self.starvation_age_s
        return (starving, self._below_floor(req.job), int(req.kind),
                req.priority, -req.seq)

    def serve(self, now: float,
              materialize: Optional[Callable[[str], Optional[int]]] = None
              ) -> List[LeaseRequest]:
        """Arbitrate the queue at time ``now``: grant free nodes to the
        highest-ranked requests; when the pool runs dry, ``materialize``
        (controller-provided provisioning, may return None to decline)
        keeps serving. Returns the requests served this round."""
        now = float(now)
        served: List[LeaseRequest] = []
        while self._queue:
            best = max(self._queue, key=lambda r: self._rank(r, now))
            wait = max(0.0, now - best.enqueue_t)
            lease = self.grant(best.job, best.kind, now, wait_s=wait)
            if lease is None and materialize is not None:
                nid = materialize(best.job)
                if nid is not None:
                    lease = self.note_provisioned(nid, best.job, best.kind,
                                                  now, wait_s=wait)
            if lease is None:
                break                      # dry and not provisionable
            best.lease = lease
            self._queue.remove(best)
            served.append(best)
        self._assert_census()
        return served

    # ------------------------------------------------------------ queries

    def open_leases(self) -> Dict[Tuple[str, int], Lease]:
        return dict(self._leased)

    def census(self) -> Dict[str, int]:
        return {"free": len(self._free), "leased": len(self._leased),
                "queued": len(self._queue)}


__all__ = ["GlobalSparePool", "Lease", "LeaseKind", "LeaseRequest",
           "PoolStats", "SpareRecord"]
