"""Fleet-wide streaming event log: every session's GuardEvent bus,
aggregated, tagged, and replayable by cursor.

The control plane serves *thousands* of sessions, so the log is a
bounded-memory ring of ``FleetRecord`` (job tag + monotonic fleet
sequence id + the original typed event). Consumers hold a *cursor* —
the last sequence id they processed — and call
``subscribe(after=cursor)`` to replay everything newer; if the ring has
already evicted part of that range the reply says how many records were
lost, so a slow consumer knows it must re-snapshot instead of silently
missing transitions (the ARGUS streaming-diagnosis contract).

Push-style delivery uses the same record type: ``attach`` a sink (the
JSONL audit sink, or the SSE-style text sink a dashboard would tail)
and it sees every record at append time.
"""
from __future__ import annotations

import collections
import dataclasses
import json
from typing import Deque, Dict, IO, List, Optional, Tuple

from repro.guard.events import GuardEvent


@dataclasses.dataclass(frozen=True)
class FleetRecord:
    """One log entry: a session event stamped with its fleet position."""
    seq: int                  # monotonic fleet-wide sequence id
    job: str                  # owning session ("" = controller itself)
    event: GuardEvent

    def to_dict(self) -> Dict[str, object]:
        d = self.event.to_dict()
        d["seq"] = self.seq
        d["job"] = self.job
        return d


class FleetEventLog:
    """Bounded ring + cursor replay + push sinks."""

    def __init__(self, capacity: int = 65536):
        assert capacity >= 1
        self._ring: Deque[FleetRecord] = collections.deque(maxlen=capacity)
        self._seq = 0                 # last assigned sequence id
        self._sinks: List[object] = []

    # ------------------------------------------------------------- intake

    def append(self, job: str, event: GuardEvent) -> FleetRecord:
        self._seq += 1
        rec = FleetRecord(self._seq, job, event)
        self._ring.append(rec)
        for sink in self._sinks:
            sink.emit(rec)
        return rec

    def session_sink(self, job: str) -> "SessionTap":
        """A per-session bus sink that funnels that session's events
        into this log under its job tag (``session.add_sink(...)``)."""
        return SessionTap(self, job)

    # ------------------------------------------------------------ cursors

    @property
    def head(self) -> int:
        """Latest assigned sequence id (0 = nothing logged yet)."""
        return self._seq

    @property
    def tail(self) -> int:
        """Oldest sequence id still in the ring (0 when empty)."""
        return self._ring[0].seq if self._ring else 0

    def __len__(self) -> int:
        return len(self._ring)

    def subscribe(self, after: int = 0, limit: Optional[int] = None
                  ) -> Tuple[List[FleetRecord], int]:
        """Replay every record with ``seq > after`` (oldest first).

        Returns ``(records, lost)``: ``lost`` counts records in the
        requested range the ring already evicted — nonzero means the
        consumer's cursor fell behind the retention window and it should
        resynchronize from a snapshot, not pretend continuity."""
        after = int(after)
        lost = 0
        if self._ring and after < self._ring[0].seq - 1:
            lost = self._ring[0].seq - 1 - after
        out = [r for r in self._ring if r.seq > after]
        if limit is not None:
            out = out[:limit]
        return out, lost

    # -------------------------------------------------------------- sinks

    def attach(self, sink) -> None:
        """Attach a push consumer (anything with ``emit(record)``)."""
        self._sinks.append(sink)

    def detach(self, sink) -> None:
        self._sinks.remove(sink)


class SessionTap:
    """Bus-sink adapter: tags one session's events into the fleet log."""

    def __init__(self, log: FleetEventLog, job: str):
        self.log = log
        self.job = job

    def emit(self, ev: GuardEvent) -> None:
        self.log.append(self.job, ev)


class JsonlStreamSink:
    """Durable fleet audit log: one JSON object per record."""

    def __init__(self, path: str):
        self.path = path
        self._fh: Optional[IO[str]] = open(path, "a")

    def emit(self, rec: FleetRecord) -> None:
        if self._fh is None:
            raise ValueError(f"JsonlStreamSink({self.path}) is closed")
        json.dump(rec.to_dict(), self._fh)
        self._fh.write("\n")
        self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "JsonlStreamSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class SSEStreamSink:
    """Server-sent-events framing over any text stream: the shape a
    live dashboard would tail (``id:`` carries the cursor so a
    reconnecting client resumes with ``subscribe(after=last_id)``)."""

    def __init__(self, stream: IO[str]):
        self.stream = stream

    def emit(self, rec: FleetRecord) -> None:
        d = rec.to_dict()
        self.stream.write(f"id: {rec.seq}\n")
        self.stream.write(f"event: {rec.event.kind}\n")
        self.stream.write(f"data: {json.dumps(d)}\n\n")


__all__ = ["FleetEventLog", "FleetRecord", "JsonlStreamSink",
           "SSEStreamSink", "SessionTap"]
