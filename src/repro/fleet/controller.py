"""``FleetController``: one Guard control plane over many concurrent
jobs sharing a node inventory.

The paper deploys Guard as a *cluster service* — one health-management
plane qualifying nodes, allocating spares and running background sweeps
for every production workload on the fleet. This module is that plane
for N ``GuardSession``s at once:

* **Global spare pool** (``repro.fleet.pool``): at ``register_job`` the
  session's private spares are adopted into one shared, home-tagged
  pool and the session's ``HealthManager`` is re-pointed at it through
  the ``SparePool`` lease/grant seam — ``take_spare`` becomes a lease
  arbitrated by urgency (hang > crash > swap), job priority and a
  fair-share floor. Grants to a node's home job hand the node over
  directly; cross-job grants are *transfers*: the controller
  materializes equivalent capacity in the destination fleet
  (``deliver_node``) and retires the donor into the ghost ledger, so
  per-fleet physical inventories stay consistent and the fleet-wide
  census is conserved exactly.

* **Shared sweep bench**: every session's ``SweepScheduler`` is rebound
  to one fleet ``BenchSlots``, so concurrent qualification campaigns
  queue on the same physical slots; the healthscan orchestrator books
  background re-qualification on whatever capacity is left idle.

* **Streaming event log** (``repro.fleet.stream``): each session's bus
  is tapped into one cursor-replayable fleet log; controller-level
  transitions (``SpareLeased`` / ``SpareReclaimed`` /
  ``CampaignScheduled``) land in the same stream.

Every controller entry point self-times into ``overhead_s`` so a fleet
driver can report control-plane overhead as a fraction of sim wall time
(the bench gates it below 5%).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

from repro.core.health_manager import NodeState
from repro.fleet.events import SpareLeased, SpareReclaimed
from repro.fleet.pool import GlobalSparePool, LeaseKind
from repro.fleet.stream import FleetEventLog
from repro.guard.scheduler import BenchSlots
from repro.guard.session import GuardSession


@dataclasses.dataclass
class FleetJob:
    """One registered tenant of the control plane."""
    job_id: str
    session: GuardSession
    priority: int                 # higher outranks (defaults to the tier)
    registered_t: float
    inventory: int = 0            # nodes counted at registration
    provisions_base: int = 0      # manager provision count at registration
    leases: int = 0               # grants this job received
    provision_grants: int = 0     # grants that materialized new capacity
    transfer_grants: int = 0      # grants donated by another job's spare

    @property
    def provisions(self) -> int:
        return (self.session.manager.stats.nodes_provisioned
                - self.provisions_base)


class _JobPool:
    """The ``SparePool`` protocol adapter one ``HealthManager`` sees:
    every take/give routes through the controller under this job's
    identity."""

    def __init__(self, controller: "FleetController", job_id: str):
        self.controller = controller
        self.job_id = job_id

    def take(self, kind: str = "swap") -> int:
        return self.controller.acquire(self.job_id, kind)

    def give(self, node_id: int) -> None:
        self.controller.release(self.job_id, node_id)

    def count(self) -> int:
        return self.controller.pool.free_count()

    def buddies(self, n: int, skip: int = 0) -> List[int]:
        # only home-co-located free nodes can physically pair with this
        # job's sweep bench
        ids = self.controller.pool.free_ids(home=self.job_id)
        return ids[skip:skip + n]


class FleetController:
    """The fleet control plane: pool + bench + healthscan + event log."""

    def __init__(self, bench_slots: int = 4,
                 starvation_age_s: float = 3600.0,
                 floor_frac: float = 0.5,
                 log_capacity: int = 65536,
                 healthscan_period_s: Optional[float] = None,
                 healthscan_batch: int = 16,
                 clock: Optional[Callable[[], float]] = None):
        self.bench = BenchSlots(bench_slots)
        self.pool = GlobalSparePool(starvation_age_s=starvation_age_s,
                                    floor_frac=floor_frac)
        self.log = FleetEventLog(capacity=log_capacity)
        self.jobs: Dict[str, FleetJob] = {}
        # transfer donors, as (home_job, node_id): physically idle
        # hardware retired from the pool when its capacity was
        # re-materialized in another fleet
        self.ghosts: List[tuple] = []
        # external clock (fleet sim time); falls back to the max of the
        # registered sessions' control clocks
        self._clock = clock
        self.overhead_s = 0.0
        from repro.fleet.healthscan import HealthScanOrchestrator
        self.healthscan: Optional[HealthScanOrchestrator] = None
        if healthscan_period_s is not None:
            self.healthscan = HealthScanOrchestrator(
                self, period_s=healthscan_period_s, batch=healthscan_batch)

    # -------------------------------------------------------------- clock

    def now(self) -> float:
        if self._clock is not None:
            return self._clock()
        if not self.jobs:
            return 0.0
        return max(j.session.control.now() for j in self.jobs.values())

    # -------------------------------------------------------------- census

    def _assert_census(self, in_flight: int = 0) -> None:
        """The conservation law the fleet bench gates, asserted by every
        pool-mutating entry point (guardlint GL005): each node ever
        registered or provisioned is in exactly one place — some job's
        census, the free pool, or the ghost ledger. ``in_flight`` is the
        number of nodes legitimately between places at the call site
        (a synchronous grant is handed to the caller, who registers it
        into the job census only after ``acquire`` returns)."""
        live = sum(len(j.session.manager.state) for j in self.jobs.values())
        expected = sum(j.inventory + j.provisions
                       for j in self.jobs.values())
        accounted = live + self.pool.free_count() + len(self.ghosts)
        assert accounted + in_flight == expected, (
            f"fleet census drift: live {live} + free "
            f"{self.pool.free_count()} + ghosts {len(self.ghosts)} + "
            f"in-flight {in_flight} != expected {expected}")

    # -------------------------------------------------------- registration

    def register_job(self, job_id: str, session: GuardSession,
                     priority: Optional[int] = None) -> FleetJob:
        """Adopt one session into the control plane: its private spares
        join the global pool (home-tagged), its manager leases through
        the pool from now on, its scheduler queues on the shared bench,
        and its event bus streams into the fleet log."""
        t0 = time.perf_counter()
        assert job_id not in self.jobs, f"job {job_id!r} already registered"
        now = self.now()
        mgr = session.manager
        job = FleetJob(job_id, session,
                       priority=int(session.tier) if priority is None
                       else int(priority),
                       registered_t=now,
                       inventory=len(mgr.state),
                       provisions_base=mgr.stats.nodes_provisioned)
        self.jobs[job_id] = job
        self.pool.register_job(job_id)
        for nid in mgr.release_private_spares():
            self.pool.add(nid, home=job_id, now=now)
            self.log.append(job_id, SpareReclaimed(
                t=now, step=-1, node_id=nid, job=job_id,
                reason="adopted at registration"))
        mgr.attach_pool(_JobPool(self, job_id))
        session.scheduler.rebind_bench(self.bench)
        session.add_sink(self.log.session_sink(job_id))
        self._assert_census()
        self.overhead_s += time.perf_counter() - t0
        return job

    # ------------------------------------------------------------- leases

    def acquire(self, job_id: str, kind: str = "swap") -> int:
        """Synchronous lease (a session's ``take_spare``): grant a free
        node — home spare first, foreign spare as a transfer — or
        materialize fresh capacity when the pool is dry. Always returns
        a node usable in ``job_id``'s fleet."""
        t0 = time.perf_counter()
        substrate_s = 0.0
        job = self.jobs[job_id]
        now = self.now()
        lk = LeaseKind.from_str(kind)
        lease = self.pool.grant(job_id, lk, now)
        if lease is None:
            # dry pool: bring brand-new capacity through this job's
            # admission path and record it as a provisioned grant
            s0 = time.perf_counter()
            nid = job.session.manager.deliver_node()
            substrate_s = time.perf_counter() - s0
            lease = self.pool.note_provisioned(nid, job_id, lk, now)
            job.provision_grants += 1
        elif lease.transfer:
            # donor lives in another fleet: materialize equivalent
            # capacity here, retire the donor into the ghost ledger
            s0 = time.perf_counter()
            nid = job.session.manager.deliver_node()
            substrate_s = time.perf_counter() - s0
            self.ghosts.append((lease.home, lease.node_id))
            lease = dataclasses.replace(lease, node_id=nid)
            job.transfer_grants += 1
        else:
            nid = lease.node_id
        job.leases += 1
        self.log.append(job_id, SpareLeased(
            t=now, step=-1, node_id=nid, job=job_id, lease_kind=kind,
            priority=job.priority, provisioned=lease.provisioned,
            transfer=lease.transfer, wait_s=lease.wait_s))
        # the granted node is between places until the caller's
        # take_spare registers it into the job census
        self._assert_census(in_flight=1)
        # materializing capacity is substrate (datacenter) work, not
        # control-plane arbitration — keep it out of the overhead gate
        self.overhead_s += max(time.perf_counter() - t0 - substrate_s, 0.0)
        return nid

    def release(self, job_id: str, node_id: int) -> None:
        """A healthy node returns to the global pool (requalified spare
        or closed lease), homed where it physically lives."""
        t0 = time.perf_counter()
        now = self.now()
        self.pool.add(node_id, home=job_id, now=now)
        self.log.append(job_id, SpareReclaimed(
            t=now, step=-1, node_id=node_id, job=job_id,
            reason="returned to pool"))
        self._assert_census()
        self.overhead_s += time.perf_counter() - t0

    def request_spare(self, job_id: str, kind: str = "swap"):
        """Queued (async) lease path: enqueue an ask the next ``tick``
        arbitrates. Used for planned top-ups and by the contention
        tests; urgent replacement goes through ``acquire``."""
        job = self.jobs[job_id]
        req = self.pool.request(job_id, LeaseKind.from_str(kind),
                                job.priority, self.now())
        self._assert_census()
        return req

    # -------------------------------------------------------- maintenance

    def top_up(self, global_target: int, home_min: int = 2) -> int:
        """Warm-pool maintenance across the whole fleet: keep at least
        ``home_min`` free spares homed per job (sweep-buddy capacity)
        and ``global_target`` free fleet-wide. Returns nodes added."""
        t0 = time.perf_counter()
        substrate_s = 0.0
        added = 0
        for job in self.jobs.values():
            while self.pool.free_count(home=job.job_id) < home_min:
                s0 = time.perf_counter()
                job.session.manager.provision_spare()
                substrate_s += time.perf_counter() - s0
                added += 1
        # spread the remainder round-robin so no fleet hoards the pool
        while self.pool.free_count() < global_target:
            job = min(self.jobs.values(),
                      key=lambda j: self.pool.free_count(home=j.job_id))
            s0 = time.perf_counter()
            job.session.manager.provision_spare()
            substrate_s += time.perf_counter() - s0
            added += 1
        # provisioning itself is substrate work; only the placement
        # decisions above count as control plane
        self.overhead_s += max(time.perf_counter() - t0 - substrate_s, 0.0)
        return added

    def tick(self, now: Optional[float] = None) -> int:
        """Periodic control-plane work: arbitrate the queued lease
        requests and let the healthscan orchestrator book background
        re-qualification on idle bench capacity. Returns requests
        served."""
        t0 = time.perf_counter()
        now = self.now() if now is None else float(now)

        def materialize(job_id: str) -> Optional[int]:
            job = self.jobs.get(job_id)
            if job is None:
                return None
            job.provision_grants += 1
            return job.session.manager.deliver_node()

        served = self.pool.serve(now, materialize=materialize)
        for req in served:
            lease = req.lease
            nid = lease.node_id
            job = self.jobs[req.job]
            if lease.transfer:
                nid = job.session.manager.deliver_node()
                self.ghosts.append((lease.home, lease.node_id))
                req.lease = dataclasses.replace(lease, node_id=nid)
                job.transfer_grants += 1
            job.leases += 1
            # queued grants land as healthy spares homed to the
            # requester (planned capacity, not an in-flight swap)
            job.session.manager.register(nid, NodeState.ACTIVE)
            self.log.append(req.job, SpareLeased(
                t=now, step=-1, node_id=nid, job=req.job,
                lease_kind={LeaseKind.SLOW_SWAP: "swap",
                            LeaseKind.CRASH: "crash",
                            LeaseKind.HANG_EVICT: "hang"}[req.kind],
                priority=req.priority, provisioned=lease.provisioned,
                transfer=req.lease.transfer, wait_s=lease.wait_s))
        self._assert_census()
        sweep0 = 0.0
        if self.healthscan is not None:
            sweep0 = self.healthscan.sweep_wall_s
            self.healthscan.tick(now)
        elapsed = time.perf_counter() - t0
        if self.healthscan is not None:
            # the batched sweep compute runs on the bench hardware, not
            # the control plane: only the orchestration counts here
            elapsed -= self.healthscan.sweep_wall_s - sweep0
        self.overhead_s += max(elapsed, 0.0)
        return len(served)

    # -------------------------------------------------------------- census

    def census(self) -> Dict[str, object]:
        """Fleet-wide node accounting. ``conserved`` is the invariant
        the bench gates bit-consistent: every node registered or
        provisioned is in exactly one place — some job's census, the
        free pool, or the ghost ledger."""
        per_job: Dict[str, Dict[str, int]] = {}
        live = 0
        inventory = 0
        provisions = 0
        for job in self.jobs.values():
            counts: Dict[str, int] = {}
            for st in job.session.manager.state.values():
                counts[st.value] = counts.get(st.value, 0) + 1
            per_job[job.job_id] = counts
            live += len(job.session.manager.state)
            inventory += job.inventory
            provisions += job.provisions
        free = self.pool.free_count()
        ghosts = len(self.ghosts)
        return {
            "jobs": per_job,
            "live": live,
            "pool_free": free,
            "ghosts": ghosts,
            "inventory": inventory,
            "provisions": provisions,
            "accounted": live + free + ghosts,
            "expected": inventory + provisions,
            "conserved": (live + free + ghosts) == (inventory + provisions),
        }

    def starvation_events(self) -> int:
        return self.pool.stats.starvation_events


__all__ = ["FleetController", "FleetJob"]
