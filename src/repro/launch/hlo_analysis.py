"""Static analysis of post-SPMD compiled HLO text.

XLA's built-in ``cost_analysis()`` counts each ``while`` body ONCE, so for a
scan-over-layers model it under-reports FLOPs/bytes/collectives by ~the
layer count. This module parses ``compiled.as_text()`` into a computation
call graph, extracts trip counts from while conditions, and accumulates

  - dot FLOPs (2 x output x contraction, wherever the dot lives, including
    inside fusions and remat'd backward bodies),
  - HBM-traffic proxy (operand + result bytes of every materializing
    instruction in control computations — fusions account their own I/O),
  - per-collective transfer bytes (max of operand/result, counting *-start
    of async pairs once),

each weighted by the product of enclosing loop trip counts. The result is
the per-device cost of ONE step of the compiled program — the roofline
inputs for EXPERIMENTS.md §Roofline — plus a per-computation FLOPs
breakdown used by the §Perf iteration loop.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all", "collective-broadcast")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*"
    r"((?:\([^()]*\))|(?:[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?))\s+"
    r"([a-z][\w\-]*)\((.*)$")
_COMP_HEAD_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")

_NO_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "while", "conditional", "after-all", "iota",
               "partition-id", "replica-id"}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 1
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str                      # operand list + attrs (raw tail)

    @property
    def operands(self) -> List[str]:
        head = self.rest.split(")", 1)[0]
        return _OPERAND_RE.findall(head)

    @property
    def out_bytes(self) -> int:
        return _shape_bytes(self.type_str)


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: Dict[str, Instr]

    def int_constants(self) -> List[int]:
        out = []
        for i in self.instrs.values():
            if i.opcode == "constant":
                m = _CONST_RE.search("constant(" + i.rest)
                if m:
                    out.append(int(m.group(1)))
        return out


def parse_module(text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry = ""
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HEAD_RE.match(line.strip())
            if m and line.rstrip().endswith("{") and "->" in line:
                cur = Computation(m.group(2), bool(m.group(1)), {})
                if m.group(1):
                    entry = m.group(2)
        else:
            if line.startswith("}") or line.strip() == "}":
                comps[cur.name] = cur
                cur = None
                continue
            m = _INSTR_RE.match(line)
            if m:
                cur.instrs[m.group(1)] = Instr(m.group(1), m.group(2),
                                               m.group(3), m.group(4))
    if cur is not None:
        comps[cur.name] = cur
    return comps, entry


# ----------------------------------------------------------------- costs


def _dot_flops(comp: Computation, instr: Instr) -> float:
    out_elems = _shape_elems(instr.type_str)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.rest)
    cdims = [int(x) for x in m.group(1).split(",") if x] if m else []
    ops = instr.operands
    contract = 1
    if ops:
        lhs = comp.instrs.get(ops[0])
        if lhs is not None:
            sm = _SHAPE_RE.search(lhs.type_str)
            if sm:
                dims = [int(x) for x in sm.group(2).split(",") if x]
                for c in cdims:
                    if c < len(dims):
                        contract *= dims[c]
    return 2.0 * out_elems * contract


def _conv_flops(comp: Computation, instr: Instr) -> float:
    # output elems x 2 x (kernel spatial x in-channels): approximate from
    # rhs shape product / out-channel dim — rare in this repo (stub fronts)
    ops = instr.operands
    out_elems = _shape_elems(instr.type_str)
    if len(ops) >= 2:
        rhs = comp.instrs.get(ops[1])
        if rhs is not None:
            sm = _SHAPE_RE.search(rhs.type_str)
            if sm:
                dims = [int(x) for x in sm.group(2).split(",") if x]
                k = 1
                for d in dims[:-1]:
                    k *= d
                return 2.0 * out_elems * k
    return 2.0 * out_elems


def _local_costs(comp: Computation) -> Dict[str, float]:
    flops = 0.0
    for i in comp.instrs.values():
        if i.opcode == "dot":
            flops += _dot_flops(comp, i)
        elif i.opcode == "convolution":
            flops += _conv_flops(comp, i)
    return {"flops": flops}


_PARAM_IDX_RE = re.compile(r"parameter\((\d+)\)|^(\d+)\)")
_SLICING = ("dynamic-slice", "slice", "gather")


_TRANSPARENT = ("convert", "bitcast", "copy", "negate", "transpose")


def _fusion_operand_bytes(comps: Dict[str, "Computation"],
                          instr: Instr) -> Tuple[float, float]:
    """(read_bytes, write_bytes) for a fusion, looking inside the called
    computation:

      - a parameter consumed (through transparent convert/bitcast chains)
        only by slicing ops costs the slice outputs, not the whole buffer;
      - a whole-result dynamic-update-slice makes the fusion in-place: it
        writes the update slice, and the aliased full-size input is free.

    Both rules compare ELEMENT counts (dtype round-trips through f32 that
    XLA materializes on CPU are free on the real target)."""
    m = _CALLS_RE.search(instr.rest)
    called = comps.get(m.group(1)) if m else None
    if called is None:
        return (0.0, instr.out_bytes)
    params: Dict[int, Instr] = {}
    for i in called.instrs.values():
        if i.opcode == "parameter":
            pm = re.match(r"(\d+)\)", i.rest)
            if pm:
                params[int(pm.group(1))] = i

    def effective_uses(name: str) -> List[Instr]:
        """Transitive uses, looking through transparent unary ops."""
        out, frontier = [], [name]
        seen = set()
        while frontier:
            n = frontier.pop()
            for j in called.instrs.values():
                if n in j.operands and j.name not in seen:
                    seen.add(j.name)
                    if j.opcode in _TRANSPARENT:
                        frontier.append(j.name)
                    else:
                        out.append(j)
        return out

    result_elems = _shape_elems(instr.type_str)
    # detect the in-place whole-result DUS and its update operand
    dus_update_bytes = None
    dus_buffer_param: Optional[str] = None
    for j in called.instrs.values():
        if j.opcode == "dynamic-update-slice" and \
                _shape_elems(j.type_str) == result_elems:
            ops = j.operands
            upd = called.instrs.get(ops[1]) if len(ops) > 1 else None
            if upd is not None:
                dus_update_bytes = upd.out_bytes
                # walk operand 0 back through transparent ops to a parameter
                src = ops[0]
                while src in called.instrs and \
                        called.instrs[src].opcode in _TRANSPARENT and \
                        called.instrs[src].operands:
                    src = called.instrs[src].operands[0]
                if src in called.instrs and \
                        called.instrs[src].opcode == "parameter":
                    dus_buffer_param = src
            break

    reads = 0.0
    for idx in range(len(instr.operands)):
        p = params.get(idx)
        if p is None:
            continue
        if dus_buffer_param is not None and p.name == dus_buffer_param:
            reads += dus_update_bytes or 0.0     # aliased in-place read
            continue
        uses = effective_uses(p.name)
        if uses and all(j.opcode in _SLICING for j in uses):
            reads += sum(j.out_bytes for j in uses)
        else:
            reads += p.out_bytes
    writes = dus_update_bytes if dus_update_bytes is not None \
        else instr.out_bytes
    return reads, writes


def _local_traffic(comp: Computation,
                   comps: Optional[Dict[str, "Computation"]] = None,
                   fused: bool = False) -> float:
    """HBM-traffic model per executed instance of this computation.

    Op-aware: dynamic-update-slice is in-place (costs the update slice,
    read+write); slicing/gather ops cost the bytes actually moved (output),
    not the whole source buffer; `copy` of loop carries is alias-elided on
    TPU and skipped; fusions are introspected (_fusion_operand_bytes).

    ``fused=False`` (upper bound): every op also re-reads its operands —
    the CPU-HLO unfused reality. ``fused=True`` (TPU estimate): assume
    producer->consumer fusion, so each intermediate hits HBM once (output
    write + one read by its consumer ≈ 2x outputs; operand re-reads are
    counted only for dots, whose inputs genuinely stream from HBM)."""
    total = 0.0
    comps = comps or {}
    for i in comp.instrs.values():
        op = i.opcode
        if op in _NO_TRAFFIC or op == "copy" or op.endswith("-done"):
            continue
        if op == "fusion":
            r, w = _fusion_operand_bytes(comps, i)
            total += (w * 2.0) if fused else (r + w)
            continue
        if op == "dynamic-update-slice":
            ops = i.operands
            upd = comp.instrs.get(ops[1]) if len(ops) > 1 else None
            total += 2 * (upd.out_bytes if upd else i.out_bytes)
            continue
        if op in ("dynamic-slice", "slice", "gather", "broadcast",
                  "reduce", "reduce-window"):
            total += 2 * i.out_bytes     # read moved bytes + write result
            if op in ("reduce", "reduce-window") and not fused:
                # unfused reductions read their full operand
                src = comp.instrs.get(i.operands[0]) if i.operands else None
                total += (src.out_bytes if src else 0) - i.out_bytes
            continue
        total += i.out_bytes
        if fused and op not in ("dot", "convolution", "concatenate"):
            continue
        for name in i.operands:
            src = comp.instrs.get(name)
            if src is not None and src.opcode != "constant":
                total += src.out_bytes
    return total


def _local_dot_traffic(comp: Computation) -> float:
    """Operand+result bytes of dot ops only — the fused-ideal lower bound
    on HBM traffic (a perfectly fused TPU program streams matmul operands
    and fuses everything else)."""
    total = 0.0
    for i in comp.instrs.values():
        if i.opcode not in ("dot", "convolution"):
            continue
        total += i.out_bytes
        for op in i.operands:
            src = comp.instrs.get(op)
            if src is not None:
                total += src.out_bytes
    return total


def _local_collectives(comp: Computation) -> Dict[str, float]:
    out: Dict[str, float] = defaultdict(float)
    for i in comp.instrs.values():
        base = i.opcode[:-6] if i.opcode.endswith("-start") else i.opcode
        if base not in COLLECTIVES or i.opcode.endswith("-done"):
            continue
        in_bytes = 0
        for op in i.operands:
            src = comp.instrs.get(op)
            if src is not None:
                in_bytes += src.out_bytes
        out[base] += max(i.out_bytes, in_bytes)
    return dict(out)


# ------------------------------------------------------------ call graph


def _edges(comp: Computation) -> List[Tuple[str, float, str]]:
    """(child, multiplicity factor, kind) for every call-like edge."""
    out = []
    for i in comp.instrs.values():
        if i.opcode == "while":
            m = _WHILE_RE.search(i.rest)
            if m:
                out.append((m.group(1), 1.0, "embedded"))   # cond (cheap)
                out.append((m.group(2), -1.0, "while"))     # body: trip TBD
        elif i.opcode == "conditional":
            m = _BRANCH_RE.search(i.rest)
            if m:
                for b in _OPERAND_RE.findall(m.group(1)):
                    out.append((b, 1.0, "control"))
        else:
            m = _CALLS_RE.search(i.rest)
            if m:
                kind = "control" if i.opcode == "call" else "embedded"
                out.append((m.group(1), 1.0, kind))
    return out


def _trip_count(comps: Dict[str, Computation], cond_name: str) -> float:
    cond = comps.get(cond_name)
    if cond is None:
        return 1.0
    cands = cond.int_constants()
    # the loop bound also hides in fusion-called compare computations
    for child, _, _ in _edges(cond):
        sub = comps.get(child)
        if sub:
            cands += sub.int_constants()
    return float(max(cands)) if cands else 1.0


@dataclasses.dataclass
class ModuleCost:
    flops: float                        # per device, per step
    traffic_bytes: float                # per device, TPU-fused estimate
    traffic_bytes_upper: float          # unfused upper bound
    dot_traffic_bytes: float            # dot-streaming lower bound
    collective_bytes: float             # per device
    collective_breakdown: Dict[str, float]
    flops_by_comp: Dict[str, float]     # top contributors
    coll_by_comp: Dict[str, float]
    trip_counts: Dict[str, float]


def analyze(text: str) -> ModuleCost:
    comps, entry = parse_module(text)
    mult: Dict[str, float] = defaultdict(float)
    kind_of: Dict[str, str] = {entry: "control"}
    mult[entry] = 1.0

    # topological propagation (call graph is a DAG in HLO)
    order: List[str] = []
    seen = set()

    def topo(name: str):
        if name in seen or name not in comps:
            return
        seen.add(name)
        for child, _, _ in _edges(comps[name]):
            topo(child)
        order.append(name)

    topo(entry)
    for name in reversed(order):
        c = comps[name]
        for child, f, kind in _edges(c):
            if kind == "while":
                f = _trip_count(comps, _while_cond_of(c, child))
            mult[child] += mult[name] * f
            if kind in ("while", "control"):
                kind_of[child] = "control"
            else:
                kind_of.setdefault(child, "embedded")

    flops_total = 0.0
    traffic_total = 0.0
    traffic_upper = 0.0
    dot_traffic_total = 0.0
    coll_total: Dict[str, float] = defaultdict(float)
    flops_by: Dict[str, float] = {}
    coll_by: Dict[str, float] = {}
    trips: Dict[str, float] = {}
    for name, c in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        f = _local_costs(c)["flops"] * m
        if f:
            flops_by[name] = f
        flops_total += f
        dot_traffic_total += _local_dot_traffic(c) * m
        if kind_of.get(name) == "control":
            traffic_total += _local_traffic(c, comps, fused=True) * m
            traffic_upper += _local_traffic(c, comps, fused=False) * m
            for k, v in _local_collectives(c).items():
                coll_total[k] += v * m
                coll_by[name] = coll_by.get(name, 0.0) + v * m
        if m > 1:
            trips[name] = m
    return ModuleCost(
        flops=flops_total, traffic_bytes=traffic_total,
        traffic_bytes_upper=traffic_upper,
        dot_traffic_bytes=dot_traffic_total,
        collective_bytes=float(sum(coll_total.values())),
        collective_breakdown=dict(coll_total),
        flops_by_comp=dict(sorted(flops_by.items(),
                                  key=lambda kv: -kv[1])[:20]),
        coll_by_comp=dict(sorted(coll_by.items(),
                                 key=lambda kv: -kv[1])[:20]),
        trip_counts=trips)


def _while_cond_of(comp: Computation, body_name: str) -> str:
    for i in comp.instrs.values():
        if i.opcode == "while":
            m = _WHILE_RE.search(i.rest)
            if m and m.group(2) == body_name:
                return m.group(1)
    return ""
