"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Batched decode serving with continuous batching over a synthetic request
stream: requests arrive with a prompt length and a decode budget; slots are
backfilled as sequences finish. On this container it serves a REDUCED
config; the same driver with ``--full`` + the production mesh is the
decode-shape deployment the dry-run lowers.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models.model import Model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def synthetic_requests(n: int, vocab: int, seed: int = 0) -> List[Request]:
    rng = np.random.RandomState(seed)
    return [Request(i, rng.randint(0, vocab, rng.randint(4, 17)),
                    int(rng.randint(8, 33))) for i in range(n)]


class BatchedServer:
    """Fixed-slot continuous batching decode server."""

    def __init__(self, model: Model, params, slots: int, cache_len: int):
        self.model = model
        self.params = params
        self.slots = slots
        self.cache_len = cache_len
        self.cache = model.init_cache(slots, cache_len)
        # per-slot decode position (cache['pos'] is global in the simple
        # cache; per-slot positions drive sampling masks)
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.slot_len = np.zeros(slots, np.int32)
        self._step = jax.jit(model.decode_step)

    def _feed(self, queue: List[Request]) -> None:
        for s in range(self.slots):
            if self.slot_req[s] is None and queue:
                req = queue.pop(0)
                self.slot_req[s] = req
                # prefill-by-decode: feed prompt tokens one step at a time
                req._cursor = 0
                self.slot_len[s] = 0

    def run(self, queue: List[Request], greedy: bool = True) -> dict:
        done: List[Request] = []
        steps = 0
        t0 = time.perf_counter()
        self._feed(queue)
        while any(r is not None for r in self.slot_req) or queue:
            toks = np.zeros(self.slots, np.int32)
            for s, req in enumerate(self.slot_req):
                if req is None:
                    continue
                if req._cursor < len(req.prompt):
                    toks[s] = req.prompt[req._cursor]
                elif req.out:
                    toks[s] = req.out[-1]
            logits, self.cache = self._step(self.params,
                                            jnp.asarray(toks), self.cache)
            steps += 1
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            for s, req in enumerate(self.slot_req):
                if req is None:
                    continue
                if req._cursor < len(req.prompt) - 1:
                    req._cursor += 1          # still consuming the prompt
                    continue
                req._cursor += 1
                req.out.append(int(nxt[s]))
                if len(req.out) >= req.max_new:
                    req.done = True
                    done.append(req)
                    self.slot_req[s] = None
            self._feed(queue)
            if int(self.cache["pos"]) >= self.cache_len - 1:
                break                          # cache exhausted (demo bound)
        dt = time.perf_counter() - t0
        toks_out = sum(len(r.out) for r in done)
        return {"requests_done": len(done), "decode_steps": steps,
                "tokens_out": toks_out, "wall_s": dt,
                "tok_per_s": toks_out / dt if dt else 0.0}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced(cfg)
    model = Model(cfg)
    params, _ = model.init_params(jax.random.key(args.seed))
    server = BatchedServer(model, params, args.slots, args.cache_len)
    queue = synthetic_requests(args.requests, cfg.vocab_size, args.seed)
    print(f"[serve] {cfg.name}: {args.requests} requests, "
          f"{args.slots} slots, cache {args.cache_len}")
    out = server.run(queue)
    print(f"[serve] {out['requests_done']} done in {out['decode_steps']} "
          f"steps, {out['tokens_out']} tokens, "
          f"{out['tok_per_s']:.1f} tok/s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
