"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state, so smoke tests keep their single CPU device and
only the dry-run (which sets XLA_FLAGS before any jax import) sees the 512
placeholder devices.

Mesh layout: 16x16 within a pod ("data" x "model": FSDP/DP over data, TP/EP
over model), and a leading "pod" axis (pure DP — cross-pod traffic is only
the gradient all-reduce, riding DCN) for the 2-pod, 512-chip configuration.
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # jax.sharding.AxisType landed after 0.4.x; Auto is the default there,
    # so omitting axis_types keeps identical semantics on both sides.
    try:
        types = (jax.sharding.AxisType.Auto,) * len(axes)
        return jax.make_mesh(shape, axes, axis_types=types)
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_cpu_mesh():
    """("data", "model") mesh over every local device — same axis names as
    production, so the identical sharded code paths run in smoke tests.

    "model" stays 1-wide (TP on CPU buys nothing and the manual shard_map
    paths change MoE capacity math); all devices go to "data" so FSDP
    sharding and the JIT all-gather are real whenever the host exposes
    more than one device (CI pins XLA_FLAGS=--xla_force_host_platform_
    device_count=8 for exactly this).
    """
    return _make_mesh((len(jax.devices()), 1), ("data", "model"))
