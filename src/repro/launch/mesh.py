"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state, so smoke tests keep their single CPU device and
only the dry-run (which sets XLA_FLAGS before any jax import) sees the 512
placeholder devices.

Mesh layout: 16x16 within a pod ("data" x "model": FSDP/DP over data, TP/EP
over model), and a leading "pod" axis (pure DP — cross-pod traffic is only
the gradient all-reduce, riding DCN) for the 2-pod, 512-chip configuration.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_cpu_mesh():
    """1x1 mesh over the local device — same axis names, so the identical
    sharded code paths run in smoke tests."""
    return jax.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
