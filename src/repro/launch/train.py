"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it trains a REDUCED config end-to-end (the ~100M-class
example driver); on a real TPU slice the same entrypoint with ``--full``
and a production mesh trains the assigned config. Guard is wired in as the
per-step hook: step times stream into the online monitor, and an
IMMEDIATE-tier event restarts from the last checkpoint — the closed loop of
Fig. 1 at single-host scale.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from repro.configs import get_config, reduced
from repro.core import DetectorConfig, OnlineMonitor, PolicyConfig
from repro.core.telemetry import Frame
from repro.models.model import Model
from repro.train import (AdamWConfig, CheckpointManager, DataConfig,
                         SyntheticLM, TrainConfig, Trainer)


class GuardStepHook:
    """Adapts trainer step timing to Guard telemetry frames.

    Single-host stand-in: each step contributes one 'node' sample; on a real
    deployment every host reports its own barrier time into the fleet frame.
    """

    def __init__(self, window: int = 6):
        self.monitor = OnlineMonitor(
            DetectorConfig(window=6, persistence=4),
            PolicyConfig())
        self.window = window
        self._buf = []
        self.restarts = 0

    def __call__(self, step: int, wall_s: float, metrics) -> bool:
        self._buf.append(wall_s)
        if len(self._buf) < self.window:
            return False
        frame = Frame(
            t=float(step), step=step,
            node_ids=np.arange(1, dtype=np.int64),
            metrics={"step_time": np.asarray([np.mean(self._buf)])},
            valid=np.ones(1, bool))
        self._buf.clear()
        # peer-relative detection needs peers; at single-host scale this
        # exercises the plumbing (stall detection still works)
        events = self.monitor.observe(frame)
        for ev in events:
            if ev.decision.action.value == "immediate_restart":
                self.restarts += 1
                return True
        return False


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--full", action="store_true",
                    help="use the full published config (TPU-scale)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-interval", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced(cfg)
    print(f"[train] {cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
          f"params={cfg.param_count()/1e6:.1f}M")

    model = Model(cfg)
    data = SyntheticLM(DataConfig(cfg.vocab_size, args.seq_len, args.batch,
                                  seed=args.seed))
    hook = GuardStepHook()
    trainer = Trainer(
        model, data,
        TrainConfig(steps=args.steps, ckpt_interval=args.ckpt_interval,
                    microbatch=args.microbatch,
                    opt=AdamWConfig(peak_lr=args.lr,
                                    warmup_steps=max(args.steps // 20, 1),
                                    total_steps=args.steps)),
        ckpt=CheckpointManager(args.ckpt_dir),
        hook=hook, seed=args.seed)

    def log(step, m):
        if step % 10 == 0 or step == 1:
            print(f"  step {step:5d}  loss {m['loss']:.4f}  "
                  f"gnorm {m['grad_norm']:.3f}  lr {m['lr']:.2e}")

    out = trainer.run(on_metrics=log)
    losses = [h["loss"] for h in out["history"]]
    walls = [h["wall_s"] for h in out["history"]]
    print(f"[train] done: {out['final_step']} steps, "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}, "
          f"median step {np.median(walls)*1e3:.0f} ms, "
          f"guard restarts {hook.restarts}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
