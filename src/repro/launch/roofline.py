"""Roofline-term extraction from a compiled dry-run artifact.

Three terms, in seconds, per training/serving step on the target hardware
(TPU v5e class):

  compute    = HLO_FLOPs   / (chips x 197e12 FLOP/s)
  memory     = HLO_bytes   / (chips x 819e9  B/s HBM)
  collective = coll_bytes  / (chips x 50e9   B/s ICI link)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``. Collective
bytes are NOT in cost_analysis: we parse the post-SPMD per-device HLO
(``compiled.as_text()``) and sum operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute /
ragged-all-to-all op. MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE)
gives the useful-work ratio that flags remat/redundancy waste.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

# `%x = bf16[8,128,4096]{2,1,0} all-gather(...)`  (also matches fusion-free
# start/done pairs; we count only the *-start or the plain op, not *-done)
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|([a-z0-9]+)\[([0-9,]*)\][^ ]*)\s+"
    r"(" + "|".join(_COLLECTIVES) + r")(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _nbytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind summed operand (output) bytes in the per-device
    module. Tuple-shaped results (e.g. all-reduce-start) sum their parts."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue
        kind = m.group(3)
        lhs = line.split("=", 1)[1]
        lhs = lhs[: lhs.index(kind)]
        total = sum(_nbytes(dt, dims)
                    for dt, dims in _SHAPE_RE.findall(lhs))
        out[kind] += total
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float             # global, all chips
    hlo_bytes: float             # global HBM traffic (upper-bound proxy)
    coll_bytes_per_chip: float
    coll_breakdown: Dict[str, int]
    model_flops: float           # 6·N(active)·D tokens-based
    peak_mem_bytes: Optional[float] = None
    hlo_bytes_lower: float = 0.0  # global dot-only traffic (fused ideal)

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_chip / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of roofline this step achieves: the ideal step time is
        bounded below by BOTH the useful-FLOPs compute time and the
        irreducible (dot-streaming) HBM time — decode steps are legitimately
        bandwidth-bound, so the memory floor is part of the roofline."""
        ideal = max(self.model_flops / (self.chips * PEAK_FLOPS),
                    self.hlo_bytes_lower / (self.chips * HBM_BW))
        return ideal / self.step_bound_s if self.step_bound_s else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips, "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "coll_breakdown": self.coll_breakdown,
            "model_flops": self.model_flops,
            "peak_mem_bytes": self.peak_mem_bytes,
            "hlo_bytes_lower": self.hlo_bytes_lower,
            "memory_lower_s": self.hlo_bytes_lower / (self.chips * HBM_BW),
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_for(cfg, shape) -> float:
    """Useful FLOPs per step: 6·N·D for training (fwd+bwd), 2·N·D for
    inference (fwd only), N = active params (MoE), D = tokens this step.
    Decode steps process global_batch tokens."""
    n = cfg.active_param_count()
    factor = 6.0 if shape.kind == "train" else 2.0
    return factor * n * shape.tokens
