import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
#   Only the dry-run sees 512 placeholder devices; tests/benches see 1.

"""Multi-pod dry-run: prove the distribution config is coherent without
hardware.

For every (architecture x input shape) cell, on the single-pod 16x16 mesh
and the 2-pod 2x16x16 mesh:

  - build the full-size model functionally (ShapeDtypeStructs only — no
    allocation),
  - jit the train/prefill/serve step with explicit in/out shardings derived
    from the parameter trees' logical axes,
  - ``.lower().compile()`` — sharding mismatches, OOM-at-compile or
    unsupported collectives fail HERE,
  - print ``compiled.memory_analysis()`` (fits) and ``cost_analysis()``
    (FLOPs/bytes) and extract the §Roofline terms.

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all          # every cell, subprocess each
"""
import argparse
import json
import subprocess
import sys
import time
from typing import Optional

import jax

from repro.configs import applicable_shapes, get_config, get_shape, \
    list_configs
from repro.dist import api as dist
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import Roofline, model_flops_for
from repro.models.model import Model, input_specs
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")


def _sds(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _batch_dims(cfg, batch_struct):
    dims = {}
    for k, v in batch_struct.items():
        if k in ("tokens", "labels"):
            dims[k] = ("act_batch", None)
        elif k == "positions":
            dims[k] = (None, "act_batch", None)
        elif k in ("patch_embeds", "enc_frames"):
            dims[k] = ("act_batch", None, None)
        else:
            dims[k] = (None,) * v.ndim
    return dims


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               remat: str = "full", microbatch: int = 0,
               rules_override: Optional[dict] = None,
               fsdp_gather: bool = True):
    """Returns (lowered, meta) for one (arch x shape x mesh) cell."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = Model(cfg, remat=remat, fsdp_gather=fsdp_gather)
    rules = dict(dist.DEFAULT_RULES)
    rules.update(rules_override or {})

    with mesh, dist.use_mesh(mesh, rules) as ctx:
        param_shapes = model.param_shapes()
        axes = model.param_axes()
        p_sh = dist.param_sharding(axes, param_shapes, ctx)
        p_sds = _sds(param_shapes)
        specs = input_specs(cfg, shape)

        if shape.kind == "train":
            batch = specs["batch"]
            b_sh = dist.param_sharding(_batch_dims(cfg, batch), batch, ctx)
            o_sds = {"mu": p_sds, "nu": p_sds,
                     "count": jax.ShapeDtypeStruct((), jax.numpy.int32)}
            o_sh = {"mu": p_sh, "nu": p_sh, "count": ctx.sharding((), ())}
            step = make_train_step(model, AdamWConfig(),
                                   microbatch=microbatch)
            jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                             out_shardings=(p_sh, o_sh, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(p_sds, o_sds, batch)
        elif shape.kind == "prefill":
            batch = specs["batch"]
            b_sh = dist.param_sharding(_batch_dims(cfg, batch), batch, ctx)
            cache_struct = jax.eval_shape(
                lambda p, b: model.prefill(p, b)[1], p_sds, batch)
            cache_dims = dict(model.cache_dims())
            c_sh = dist.param_sharding(cache_dims, cache_struct, ctx)
            l_sh = ctx.sharding(("act_batch", "act_vocab"),
                                (shape.global_batch, model.vocab_padded))
            jitted = jax.jit(model.prefill, in_shardings=(p_sh, b_sh),
                             out_shardings=(l_sh, c_sh))
            lowered = jitted.lower(p_sds, batch)
        else:  # decode
            tokens = specs["tokens"]
            cache = specs["cache"]
            t_sh = ctx.sharding(("act_batch",), tokens.shape)
            c_sh = dist.param_sharding(model.cache_dims(), cache, ctx)
            l_sh = ctx.sharding(("act_batch", "act_vocab"),
                                (shape.global_batch, model.vocab_padded))
            jitted = jax.jit(model.decode_step,
                             in_shardings=(p_sh, t_sh, c_sh),
                             out_shardings=(l_sh, c_sh),
                             donate_argnums=(2,))
            lowered = jitted.lower(p_sds, tokens, cache)

    meta = {"arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "chips": 512 if multi_pod else 256,
            "kind": shape.kind}
    return lowered, meta, cfg, shape


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             remat: str = "full", microbatch: int = 0,
             verbose: bool = True) -> dict:
    t0 = time.time()
    lowered, meta, cfg, shape = build_cell(arch, shape_name, multi_pod,
                                           remat, microbatch)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    cost = cost if isinstance(cost, dict) else cost[0]
    chips = meta["chips"]
    # XLA's cost_analysis counts while bodies once; the static analyzer
    # multiplies through loop trip counts (see launch/hlo_analysis.py)
    mc = hlo_analysis.analyze(compiled.as_text())

    rl = Roofline(
        arch=arch, shape=shape_name, mesh=meta["mesh"], chips=chips,
        hlo_flops=mc.flops * chips, hlo_bytes=mc.traffic_bytes * chips,
        hlo_bytes_lower=mc.dot_traffic_bytes * chips,
        coll_bytes_per_chip=mc.collective_bytes,
        coll_breakdown={k: v for k, v in mc.collective_breakdown.items()},
        model_flops=model_flops_for(cfg, shape),
        peak_mem_bytes=(mem.argument_size_in_bytes +
                        mem.temp_size_in_bytes) if mem else None,
    )
    out = {**meta, "lower_s": t_lower, "compile_s": t_compile,
           "memory_analysis": {
               "argument_bytes": mem.argument_size_in_bytes,
               "output_bytes": mem.output_size_in_bytes,
               "temp_bytes": mem.temp_size_in_bytes,
               "alias_bytes": mem.alias_size_in_bytes,
           } if mem else None,
           "cost_analysis": {
               "xla_flops_per_chip": float(cost.get("flops", 0.0)),
               "xla_bytes_per_chip": float(cost.get("bytes accessed", 0.0))},
           "hlo_static": {
               "flops_per_chip": mc.flops,
               "traffic_per_chip": mc.traffic_bytes,
               "traffic_upper_per_chip": mc.traffic_bytes_upper,
               "dot_traffic_per_chip": mc.dot_traffic_bytes,
               "flops_by_comp": mc.flops_by_comp,
               "coll_by_comp": mc.coll_by_comp},
           "roofline": rl.to_dict()}
    if verbose:
        ma = out["memory_analysis"]
        print(f"[dryrun] {arch} x {shape_name} on {meta['mesh']}: "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s")
        if ma:
            print(f"  memory/chip: args {ma['argument_bytes']/2**30:.2f} GiB"
                  f" (aliased {ma['alias_bytes']/2**30:.2f}) "
                  f"temp {ma['temp_bytes']/2**30:.2f} GiB")
        print(f"  FLOPs/chip {mc.flops:.3e}  traffic/chip "
              f"{mc.traffic_bytes:.3e} (dot-only {mc.dot_traffic_bytes:.3e})"
              f"  coll bytes/chip {mc.collective_bytes:.3e}")
        print(f"  roofline: compute {rl.compute_s*1e3:.1f} ms | memory "
              f"{rl.memory_s*1e3:.1f} ms (lower "
              f"{rl.hlo_bytes_lower/(rl.chips*1e3)/819e6:.1f} ms) | "
              f"collective {rl.collective_s*1e3:.1f} ms -> "
              f"{rl.dominant}-bound, useful {rl.useful_ratio:.2f}, "
              f"roofline-fraction {rl.roofline_fraction:.2f}")
    return out


def _run_all(args) -> int:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    failures = []
    cells = []
    for arch in list_configs():
        cfg = get_config(arch)
        for shp in applicable_shapes(cfg):
            for mp in (False, True):
                cells.append((arch.replace("_", "-"), shp, mp))
    print(f"[dryrun] {len(cells)} cells")
    for arch, shp, mp in cells:
        tag = f"{arch}__{shp}__{'multi' if mp else 'single'}"
        path = os.path.join(RESULTS_DIR, tag + ".json")
        if args.resume and os.path.exists(path):
            print(f"[dryrun] skip {tag} (cached)")
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shp, "--json", path]
        if mp:
            cmd.append("--multi-pod")
        t0 = time.time()
        r = subprocess.run(cmd, capture_output=True, text=True,
                           timeout=args.timeout)
        ok = r.returncode == 0
        print(f"[dryrun] {tag}: {'OK' if ok else 'FAIL'} "
              f"({time.time()-t0:.0f}s)")
        if not ok:
            failures.append(tag)
            sys.stdout.write(r.stdout[-2000:] + "\n" + r.stderr[-4000:])
    print(f"[dryrun] done: {len(cells) - len(failures)}/{len(cells)} OK")
    for f in failures:
        print("  FAILED:", f)
    return 1 if failures else 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--remat", default="full", choices=["full", "none"])
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--json", help="write the cell result to this path")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--resume", action="store_true",
                    help="--all: skip cells with cached results")
    ap.add_argument("--timeout", type=int, default=3000)
    args = ap.parse_args()

    if args.all:
        return _run_all(args)
    if not args.arch or not args.shape:
        ap.error("--arch and --shape are required (or use --all)")

    out = run_cell(args.arch, args.shape, args.multi_pod, args.remat,
                   args.microbatch)
    if args.json:
        os.makedirs(os.path.dirname(os.path.abspath(args.json)),
                    exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
