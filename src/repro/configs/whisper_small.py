"""whisper-small — encoder-decoder audio transformer backbone. The conv
mel-frontend is a STUB: ``input_specs()`` provides precomputed frame
embeddings (batch, 1500, d_model). Learned positional embeddings, GELU MLP,
full MHA, cross-attention in the decoder.

[arXiv:2212.04356; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,              # decoder layers
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    head_dim=64,
    act="gelu",
    is_encoder_decoder=True,
    encoder_layers=12,
    encoder_seq_len=1500,
    learned_pos_emb=True,
    frontend="audio_frames",
    source="arXiv:2212.04356",
)
