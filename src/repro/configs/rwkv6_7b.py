"""rwkv6-7b (Finch) — attention-free linear recurrence with data-dependent
decay; O(1) state per layer, so long_500k decode is natively supported.

[arXiv:2404.05892; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,          # d_model / rwkv_head_dim
    num_kv_heads=64,
    d_ff=14336,            # channel-mix hidden (3.5x)
    vocab_size=65536,
    rwkv_head_dim=64,
    act="relu_sq",         # rwkv channel-mix uses squared relu
    source="arXiv:2404.05892",
)
