"""Architecture + shape configuration system.

Every assigned architecture is a frozen ``ArchConfig`` built from published
numbers; ``SHAPES`` is the assigned input-shape set shared by the LM family.
``get_config(name)`` / ``list_configs()`` are the public registry API used by
the launcher (``--arch <id>``), the dry-run, and the benchmarks.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0            # routed experts
    top_k: int = 1
    num_shared_experts: int = 0     # always-on shared experts
    expert_d_ff: int = 0            # hidden dim of each routed/shared expert
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.001  # load-balance loss weight
    first_layer_dense: bool = False  # deepseek-moe: layer 0 is a dense FFN
    dense_d_ff: int = 0             # hidden dim of that dense layer


@dataclass(frozen=True)
class ArchConfig:
    """A single named architecture (exact published numbers)."""

    name: str
    family: str                     # dense | ssm | moe | audio | hybrid | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads

    # feature flags
    qk_norm: bool = False           # qwen3
    qkv_bias: bool = False          # qwen1.5
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE (per rotary half)
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    act: str = "swiglu"             # swiglu | geglu | gelu

    # MoE
    moe: Optional[MoEConfig] = None

    # hybrid (recurrentgemma / griffin)
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    window: int = 0                 # local-attention window (0 = full causal)
    lru_width: int = 0              # RG-LRU recurrence width
    conv_width: int = 4             # temporal conv width in recurrent block

    # rwkv6
    rwkv_head_dim: int = 64

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq_len: int = 0        # e.g. 1500 audio frames after conv stub
    learned_pos_emb: bool = False

    # modality frontend stub: "none" | "audio_frames" | "vision_patches"
    frontend: str = "none"

    # provenance
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch serve a 500k-token context (long_500k shape)?"""
        if self.family == "ssm":
            return True
        if self.family == "hybrid" and self.window > 0:
            return True
        return False

    def param_count(self) -> int:
        """Analytic parameter count (matches init within rounding)."""
        d, hd = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.num_heads, self.num_kv_heads
        embed = self.vocab_size * d
        head = 0 if self.tie_embeddings else self.vocab_size * d

        def attn_params() -> int:
            p = d * n_q * hd + 2 * d * n_kv * hd + n_q * hd * d
            if self.qkv_bias:
                p += (n_q + 2 * n_kv) * hd
            if self.qk_norm:
                p += 2 * hd
            return p

        def dense_ffn(dff: int) -> int:
            n_in = 2 if self.act in ("swiglu", "geglu") else 1
            return n_in * d * dff + dff * d

        total = embed + head + d  # final norm
        if self.family == "ssm":  # rwkv6
            H = d // self.rwkv_head_dim
            per_layer = (
                5 * d * d            # r,k,v,g,o mats (w is lora only)
                + 6 * d              # mus
                + 5 * (d * 32 + 32 * d)  # ddlerp loras (rank 32)
                + d * 64 + 64 * d    # decay lora (rank 64)
                + d + H * self.rwkv_head_dim  # w0, u(bonus)
                + 2 * d              # ln_x groupnorm
                + dense_ffn(self.d_ff) + 2 * d  # channel mix hidden + mus
                + 4 * d              # 2 layer norms
            )
            return total + self.num_layers * per_layer

        if self.family == "hybrid":
            pattern = self._layer_kinds()
            per_norms = 4 * d
            tot = total
            for kind in pattern:
                if kind == "attn":
                    tot += attn_params() + dense_ffn(self.d_ff) + per_norms
                else:  # recurrent block
                    w = self.lru_width or d
                    rec = (
                        2 * d * w            # two input branches
                        + self.conv_width * w  # temporal conv
                        + 2 * w              # lru input gate + a gate (diag-ish)
                        + 2 * (w * w // 8)   # block-diag gate projections
                        + w                  # lambda
                        + w * d              # out proj
                    )
                    tot += rec + dense_ffn(self.d_ff) + per_norms
            return tot

        per_layer = attn_params() + 2 * d
        if self.moe:
            m = self.moe
            expert = dense_ffn(m.expert_d_ff)
            router = d * m.num_experts
            moe_layer = (
                per_layer + router
                + m.num_experts * expert
                + m.num_shared_experts * expert
            )
            dense_layer = per_layer + dense_ffn(m.dense_d_ff or self.d_ff)
            n_moe = self.num_layers - (1 if m.first_layer_dense else 0)
            n_dense = self.num_layers - n_moe
            total += n_moe * moe_layer + n_dense * dense_layer
        else:
            total += self.num_layers * (per_layer + dense_ffn(self.d_ff))

        if self.is_encoder_decoder:
            # encoder stack + decoder cross-attention
            enc = self.encoder_layers * (attn_params() + dense_ffn(self.d_ff) + 2 * d)
            cross = self.num_layers * (attn_params() + d)
            total += enc + cross
            if self.learned_pos_emb:
                total += (self.encoder_seq_len + 32768) * d
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top_k routed only)."""
        if not self.moe:
            return self.param_count()
        m = self.moe
        d = self.d_model
        n_in = 2 if self.act in ("swiglu", "geglu") else 1
        expert = (n_in + 1) * d * m.expert_d_ff
        inactive = (m.num_experts - m.top_k) * expert
        n_moe = self.num_layers - (1 if m.first_layer_dense else 0)
        return self.param_count() - n_moe * inactive

    def _layer_kinds(self) -> Tuple[str, ...]:
        """Expanded per-layer kind list for hybrid archs."""
        if not self.block_pattern:
            return tuple("attn" for _ in range(self.num_layers))
        kinds = []
        i = 0
        while len(kinds) < self.num_layers:
            kinds.append(self.block_pattern[i % len(self.block_pattern)])
            i += 1
        return tuple(kinds)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        if self.kind == "decode":
            return self.global_batch        # one new token per sequence
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

ARCH_NAMES: Tuple[str, ...] = (
    "phi3_mini_3_8b",
    "glm4_9b",
    "qwen3_4b",
    "qwen1_5_110b",
    "rwkv6_7b",
    "llama4_scout_17b_a16e",
    "deepseek_moe_16b",
    "whisper_small",
    "recurrentgemma_9b",
    "qwen2_vl_72b",
)

# public ids (hyphenated, as assigned) -> module names
_ALIASES = {n.replace("_", "-"): n for n in ARCH_NAMES}
_ALIASES.update({
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "qwen1.5-110b": "qwen1_5_110b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
})


def get_config(name: str) -> ArchConfig:
    mod_name = _ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    if mod_name not in ARCH_NAMES:
        raise KeyError(f"unknown architecture {name!r}; known: {sorted(_ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def list_configs() -> Tuple[str, ...]:
    return ARCH_NAMES


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def applicable_shapes(cfg: ArchConfig) -> Tuple[str, ...]:
    """The assigned shapes this arch actually runs (skips noted in DESIGN.md)."""
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.sub_quadratic:
            continue  # quadratic attention cannot serve 500k ctx
        out.append(s.name)
    return tuple(out)


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests."""
    changes: dict = dict(
        num_layers=min(cfg.num_layers, 2),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 1,
        d_ff=256,
        vocab_size=512,
        head_dim=32,
        lru_width=128 if cfg.lru_width else 0,
        window=min(cfg.window, 64) if cfg.window else 0,
        encoder_layers=min(cfg.encoder_layers, 2),
        encoder_seq_len=min(cfg.encoder_seq_len, 16) if cfg.encoder_seq_len else 0,
    )
    if cfg.mrope_sections:
        changes["mrope_sections"] = (4, 6, 6)   # sums to reduced head_dim//2
    if cfg.family == "ssm":
        changes["rwkv_head_dim"] = 32
        changes["num_heads"] = 4
    if cfg.moe:
        changes["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=4,
            top_k=min(cfg.moe.top_k, 2),
            num_shared_experts=min(cfg.moe.num_shared_experts, 1),
            expert_d_ff=64,
            dense_d_ff=256 if cfg.moe.first_layer_dense else 0,
        )
    if cfg.block_pattern:
        changes["num_layers"] = 3  # one full (rec, rec, attn) unit
    changes.update(overrides)
    return dataclasses.replace(cfg, **changes)
