from repro.configs.base import (
    ARCH_NAMES,
    SHAPES,
    ArchConfig,
    MoEConfig,
    ShapeConfig,
    applicable_shapes,
    get_config,
    get_shape,
    list_configs,
    reduced,
)

__all__ = [
    "ARCH_NAMES",
    "SHAPES",
    "ArchConfig",
    "MoEConfig",
    "ShapeConfig",
    "applicable_shapes",
    "get_config",
    "get_shape",
    "list_configs",
    "reduced",
]
