"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed experts, top-6,
expert hidden 1408; first layer is a dense FFN (hidden 10944); full MHA.

[arXiv:2401.06066; hf]
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,             # routed-expert hidden size
    vocab_size=102400,
    head_dim=128,
    rope_theta=10_000.0,
    act="swiglu",
    moe=MoEConfig(
        num_experts=64,
        top_k=6,
        num_shared_experts=2,
        expert_d_ff=1408,
        capacity_factor=1.25,
        first_layer_dense=True,
        dense_d_ff=10944,
    ),
    source="arXiv:2401.06066",
)
