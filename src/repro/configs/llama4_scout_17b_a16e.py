"""llama4-scout-17b-a16e — MoE: 16 routed experts, top-1, plus one shared
expert per MoE layer; GQA kv=8. Early-fusion multimodal in the original —
the text backbone is what is exercised here.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,             # expert hidden size
    vocab_size=202048,
    head_dim=128,
    rope_theta=500_000.0,
    act="swiglu",
    moe=MoEConfig(
        num_experts=16,
        top_k=1,
        num_shared_experts=1,
        expert_d_ff=8192,
        capacity_factor=1.25,
    ),
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
