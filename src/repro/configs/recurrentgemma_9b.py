"""recurrentgemma-9b (Griffin) — hybrid: RG-LRU recurrent blocks + local
sliding-window attention (window 2048), pattern (rec, rec, attn); MQA kv=1.
Sub-quadratic: runs long_500k with O(window) cache + O(1) recurrent state.

[arXiv:2402.19427; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    act="geglu",
    block_pattern=("rec", "rec", "attn"),
    window=2048,
    lru_width=4096,
    conv_width=4,
    tie_embeddings=True,
    source="arXiv:2402.19427",
)
