"""qwen3-4b — dense, GQA kv=8, per-head RMS qk_norm.

[hf:Qwen/Qwen3-8B; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-4b",
    family="dense",
    num_layers=36,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_ff=9728,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    act="swiglu",
    source="hf:Qwen/Qwen3-8B",
)
