"""qwen2-vl-72b — VLM; the 80-layer text backbone with M-RoPE (multimodal
rotary: temporal/height/width sections). The vision tower is a STUB:
``input_specs()`` provides precomputed patch embeddings and (3, B, S)
M-RoPE position ids.

[arXiv:2409.12191; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),
    act="swiglu",
    frontend="vision_patches",
    source="arXiv:2409.12191",
)
