"""Trainer → Guard adapter: real step timings become telemetry Frames.

``GuardStepHook`` implements the trainer's ``StepHook`` protocol
(``(step, wall_s, metrics) -> bool``). It aggregates the measured
per-step wall times into evaluation windows, builds real ``Frame``s —
this host's window-mean step time alongside its peers' — and feeds them
through the session's monitor → policy → manager pipeline. When the
tiered policy fires an IMMEDIATE restart for this host's node, the hook
returns True and the trainer rewinds to its last checkpoint: the full
Fig.-1 loop, driven by actual training-step measurements instead of the
hand-rolled boolean hooks the examples used before.

On a multi-host deployment each host reports its own barrier time and
the frames are assembled fleet-side; in the single-process setting the
hook synthesizes healthy peer timings around the measured baseline so
the peer-relative detector has a population to score against
(``n_peers``, deterministic via ``seed``).

If the trainer's metrics dict carries hardware telemetry (any key from
``repro.core.telemetry.HARDWARE_METRICS``, e.g. a DCGM-style exporter
feeding ``gpu_temp``/``nic_errors``), the hook aggregates it into the
Frames — so the detector's supporting-signal masks run on the real path
— and derives actionable ``ErrorSignals`` from the accumulated window
telemetry for triage. Without hardware telemetry it falls back to
step-time evidence for a latched node, so triage no longer
early-terminates every hardware-backed host for lack of signals.

``LocalHostControl`` / ``LocalSweepBackend`` are the minimal substrate
implementations for a training process with no cluster control plane:
swaps are bookkeeping, restarts raise the hook's restart flag, and
qualification sweeps trivially pass (there is no hardware to probe).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.ccltrace.watchdog import adaptive_deadline
from repro.core.detector import DetectorConfig
from repro.core.sweep import SweepReference
from repro.core.telemetry import HARDWARE_METRICS, Frame
from repro.core.triage import ErrorSignals
from repro.diagnose import Diagnoser, TimingTrace, Topology, WindowTiming
from repro.guard.events import HangDetected, NodeSwapped, RecoveryEvent
from repro.guard.session import GuardSession, Tier


class LocalHostControl:
    """ClusterControl for a single training process (no real fleet)."""

    def __init__(self, next_provision_id: int = 1000):
        self.t = 0.0
        self.swaps: List[tuple] = []
        self.restarts: List[str] = []
        self._next = next_provision_id
        # per-node evidence source (the step hook registers itself here
        # so triage sees telemetry-derived signals, not empty ones)
        self.signals_provider: \
            Optional[Callable[[int], ErrorSignals]] = None

    def swap_node(self, old: int, new: int) -> None:
        self.swaps.append((old, new))

    def restart_job(self, reason: str) -> None:
        self.restarts.append(reason)

    def provision_node(self) -> int:
        nid = self._next
        self._next += 1
        return nid

    def error_signals(self, node_id: int) -> ErrorSignals:
        if self.signals_provider is not None:
            return self.signals_provider(node_id)
        return ErrorSignals()

    def remediate(self, node_id: int, stage: str) -> None:
        pass

    def now(self) -> float:
        return self.t


class LocalSweepBackend:
    """SweepBackend stub for hosts with nothing to probe offline: every
    probe reports exactly the reference, so qualification passes."""

    def __init__(self, devices: int = 1):
        self._devices = devices
        self._ref = SweepReference(device_tflops=100.0, intra_bw_gbps=100.0,
                                   pair_step_time=1.0)

    def device_count(self, node_id: int) -> int:
        return self._devices

    def compute_probe(self, node_id: int, device: int,
                      seconds: float) -> float:
        return self._ref.device_tflops

    def intra_bw_probe(self, node_id: int, dev_a: int, dev_b: int) -> float:
        return self._ref.intra_bw_gbps

    def multi_node_probe(self, node_ids: Sequence[int],
                         steps: int) -> np.ndarray:
        return np.full(steps, self._ref.pair_step_time)

    def reference(self) -> SweepReference:
        return self._ref


@dataclasses.dataclass
class _Stall:
    """A synthetic fault window: measured wall times are scaled by
    ``factor`` for ``steps`` steps starting at ``at_step`` (simulates a
    stalled/degraded host without burning real wall-clock)."""
    at_step: int
    factor: float
    steps: int


class GuardStepHook:
    """StepHook adapter feeding trainer step timings into a GuardSession."""

    def __init__(self, session: Optional[GuardSession] = None,
                 node_id: int = 0, n_peers: int = 15,
                 window_steps: int = 6, n_spares: int = 2,
                 peer_jitter: float = 0.01, seed: int = 0,
                 warmup_windows: int = 1, baseline_alpha: float = 0.25,
                 detector_cfg: Optional[DetectorConfig] = None,
                 trace: Optional[TimingTrace] = None,
                 diagnose: bool = False,
                 own_split: Sequence[float] = (0.75, 0.15, 0.10),
                 step_deadline_s: Optional[float] = None,
                 step_deadline_mult: float = 8.0):
        owns_session = session is None
        if owns_session:
            control = LocalHostControl()
            diagnoser = None
            if diagnose:
                trace = trace or TimingTrace()
                diagnoser = Diagnoser(trace, Topology.single(1 + n_peers))
            session = GuardSession.from_tier(
                Tier.ONLINE, control, LocalSweepBackend(),
                detector_cfg=detector_cfg, diagnoser=diagnoser)
        elif diagnose:
            # a caller-supplied session owns its own wiring: silently
            # dropping the flag would run WITHOUT victim-holding while
            # the caller believes it is on
            raise ValueError(
                "diagnose=True only applies to a hook-owned session; "
                "build a Diagnoser on your GuardSession and pass its "
                "TimingTrace via trace= instead")
        self.session = session
        self.control = session.control
        self.node_id = node_id
        self.window_steps = window_steps
        self.peer_ids = [node_id + 1 + i for i in range(n_peers)]
        self.peer_jitter = peer_jitter
        # the first window(s) carry JIT compilation / cache-warm spikes;
        # real fleets re-baseline after (re)start for the same reason
        self.warmup_windows = warmup_windows
        # synthetic peers track the host's healthy drift slowly (EMA of
        # unflagged window medians) so benign whole-job slowdown is not
        # mistaken for this one node straggling
        self.baseline_alpha = baseline_alpha
        self.rng = np.random.RandomState(seed)
        # preallocated window buffer: one slot per step of the evaluation
        # window (the hook sits on the trainer's hot path)
        self._walls = np.empty(window_steps)
        self._n_walls = 0
        self._windows_seen = 0
        self._baseline: Optional[float] = None
        self._stalls: List[_Stall] = []
        self._restart_pending = False
        self._ckpt = None        # TieredCheckpointManager, bind_checkpoint
        self.frames_fed = 0
        self.restarts_requested = 0
        # liveness: a rank wedged inside a collective never finishes a
        # step, so it never produces a Frame and the detector never sees
        # it. A watchdog (timer thread, sibling process) calls
        # ``check_liveness`` on wall-clock cadence instead; the deadline
        # adapts to the healthy step baseline via the same rule the
        # ccltrace barrier watchdog uses.
        self.step_deadline_floor_s = (300.0 if step_deadline_s is None
                                      else float(step_deadline_s))
        self.step_deadline_mult = float(step_deadline_mult)
        self._last_step_t = self.control.now()
        self._last_step = 0
        self.hangs_detected = 0
        # timing-trace feed (repro.diagnose): measured wall split into
        # compute/comm/host via trainer-supplied component seconds
        # ("compute_s"/"comm_s"/"host_s" metric keys) or ``own_split``
        self.trace = trace
        self.own_split = tuple(own_split)
        self._comp_sums = np.zeros(3)
        # hardware telemetry accumulated from the trainer's metrics dict
        # (HARDWARE_METRICS keys): window sums + per-metric sample counts
        # (exporters often report at a lower cadence than the step loop)
        # -> means -> Frame columns + triage ErrorSignals
        self._hw_sums: Dict[str, float] = {}
        self._hw_counts: Dict[str, int] = {}
        self._hw_last: Dict[str, float] = {}
        self._hw_base: Dict[str, float] = {}
        # evidence snapshots for node ids this host reported under that
        # were swapped out (offline triage queries them AFTER the swap)
        self._evicted_signals: Dict[int, ErrorSignals] = {}

        # register the synthetic population only on a session we built
        # ourselves: a caller-supplied session already has real node
        # pools, and re-registering in-job ids as spares would corrupt
        # them (the caller must register node_id and the peer ids)
        if owns_session:
            session.register_active([node_id, *self.peer_ids])
            session.register_spares(
                [max(self.peer_ids, default=node_id) + 1 + i
                 for i in range(n_spares)])
        # follow our own replacement: after an immediate swap this host
        # reports under its new node identity
        session.bus.subscribe(NodeSwapped, self._on_swap)
        # triage evidence: the hook is the telemetry accumulator for this
        # host, so it (not an empty stub) answers error_signals queries
        if isinstance(self.control, LocalHostControl) and \
                self.control.signals_provider is None:
            self.control.signals_provider = self.derive_signals

    # -------------------------------------------------------------- faults

    def inject_stall(self, at_step: int, factor: float = 8.0,
                     steps: int = 1) -> None:
        """Scale this host's *measured* wall time for a step range —
        deterministic stand-in for an actual stall/slowdown."""
        self._stalls.append(_Stall(at_step, factor, steps))

    def _stall_factor(self, step: int) -> float:
        f = 1.0
        for s in self._stalls:
            if s.at_step <= step < s.at_step + s.steps:
                f *= s.factor
        return f

    # ------------------------------------------------------------ protocol

    def _reset_window(self) -> None:
        self._n_walls = 0
        self._comp_sums[:] = 0.0
        self._hw_sums.clear()
        self._hw_counts.clear()

    def __call__(self, step: int, wall_s: float,
                 metrics: Dict[str, float]) -> bool:
        if self._restart_pending:
            # deferred swaps landed at the last checkpoint: the manager
            # already replaced the node(s); rewind the job now
            self._restart_pending = False
            self._reset_window()
            self.restarts_requested += 1
            return True
        wall = wall_s * self._stall_factor(step)
        self._walls[self._n_walls] = wall
        self._n_walls += 1
        # hardware telemetry riding on the metrics dict (DCGM-style
        # exporter keys) accumulates into the window
        for m in HARDWARE_METRICS:
            v = metrics.get(m)
            if v is not None:
                self._hw_sums[m] = self._hw_sums.get(m, 0.0) + float(v)
                self._hw_counts[m] = self._hw_counts.get(m, 0) + 1
        # own-time decomposition for the timing trace: measured component
        # seconds when the trainer reports them, the configured split of
        # the (stall-scaled) wall otherwise
        if "compute_s" in metrics:
            self._comp_sums[0] += float(metrics["compute_s"])
            self._comp_sums[1] += float(metrics.get("comm_s", 0.0))
            self._comp_sums[2] += float(metrics.get("host_s", 0.0))
        else:
            self._comp_sums[0] += wall * self.own_split[0]
            self._comp_sums[1] += wall * self.own_split[1]
            self._comp_sums[2] += wall * self.own_split[2]
        if isinstance(self.control, LocalHostControl):
            # the local control has no other clock source; a real
            # substrate (e.g. the simulator) advances its own time
            self.control.t += wall
        # a completed step is proof of liveness
        self._last_step_t = self.control.now()
        self._last_step = step
        if self._n_walls < self.window_steps:
            return False
        self._windows_seen += 1
        if self._windows_seen <= self.warmup_windows:
            self._reset_window()         # compile/warm spikes: re-baseline
            return False
        frame = self._make_frame(step)
        self._reset_window()
        outcome = self.session.observe(frame)
        if outcome.restarts:
            self.restarts_requested += 1
            # the faulty node was swapped out: its injected fault leaves
            # the job with it (future-scheduled stalls stay armed)
            self._stalls = [s for s in self._stalls if s.at_step > step]
            return True
        return False

    def on_restart(self, step: int) -> None:
        """Trainer notification: a rewind happened. Drop the partial
        window and re-enter warmup: the first window(s) after a restore
        carry checkpoint-load / re-JIT spikes exactly like job start, and
        scoring them would flag the freshly swapped-in node and cascade
        into further spurious restarts."""
        self._reset_window()
        self._windows_seen = 0
        # restore/warmup time must not count toward the step deadline
        self._last_step_t = self.control.now()

    def on_checkpoint(self, step: int) -> None:
        """Trainer notification: a checkpoint was saved. Deferred and
        pending-patience mitigations land here (§4.2) — if the manager
        applied swaps, the next step call requests the rewind."""
        ck = self.session.on_checkpoint(step=step)
        if self._ckpt is not None:
            # fast-tier cadence follows the live MTTF estimate
            self._ckpt.update_mttf(
                self.session.mttf.estimate(self.control.now()))
        if ck.applied_swaps:
            self._restart_pending = True

    def bind_checkpoint(self, ckpt) -> None:
        """Attach a ``TieredCheckpointManager`` so its fast-snapshot
        cadence is re-tuned (Young-Daly) from the session's live MTTF
        estimate at every checkpoint boundary."""
        self._ckpt = ckpt
        ckpt.update_mttf(self.session.mttf.estimate(self.control.now()))

    def on_recovery(self, step: int, info: Dict) -> None:
        """Trainer notification: a restore completed. Publishes the
        incident as a ``RecoveryEvent`` with the tier the state came
        from, so the MTTR decomposition covers the real path too."""
        self.session.publish(RecoveryEvent(
            t=self.control.now(), step=step,
            reason=str(info.get("reason", "guard restart")),
            ckpt_tier=str(info.get("ckpt_tier", "cold")),
            hot_spare=bool(info.get("hot_spare", False)),
            restore_s=float(info.get("restore_s", 0.0)),
            detect_s=float(info.get("detect_s", 0.0)),
            drain_s=float(info.get("drain_s", 0.0)),
            warmup_s=float(info.get("warmup_s", 0.0)),
            replay_steps=int(info.get("replay_steps", 0))))

    # ------------------------------------------------------------ liveness

    def step_deadline(self) -> float:
        """Wall-clock budget for one training step before this host is
        presumed hung. Scaled from the rolling healthy step baseline by
        the ccltrace adaptive-deadline rule; before a baseline exists
        (first window after start/restart) the configured floor applies
        alone — better a loose cold deadline than a tight wrong one."""
        if self._baseline is None:
            return self.step_deadline_floor_s
        return adaptive_deadline(self._baseline, self.step_deadline_mult,
                                 floor_s=self.step_deadline_floor_s,
                                 cap_s=3600.0)

    def check_liveness(self, now: Optional[float] = None) -> bool:
        """Called off the step path (watchdog thread / sibling process):
        returns True when the trainer must restart because no step has
        completed within the deadline. The hook can only see its own
        host, so it reports itself as a hang *victim* (op="step", no
        culprit) — fleet-side culprit/victim attribution needs the
        ccltrace barrier watchdog, which sees every rank. Without this
        path a rank wedged in a collective blocks the job forever: it
        never finishes a step, so it never produces a Frame, and the
        frame-driven detector never fires."""
        t = self.control.now() if now is None else float(now)
        waited = t - self._last_step_t
        deadline = self.step_deadline()
        if waited < deadline:
            return False
        self.hangs_detected += 1
        self.restarts_requested += 1
        self.session.publish(HangDetected(
            t=t, step=self._last_step, op="step",
            victims=(self.node_id,),
            roles=((self.node_id, "victim"),),
            waited_s=float(waited), deadline_s=float(deadline)))
        self.session.mttf.observe_failure(t)
        # the wedged step's partial window is garbage; the restart path
        # (on_restart) re-enters warmup as usual
        self._reset_window()
        self._last_step_t = t
        return True

    # ------------------------------------------------------------ internal

    def _make_frame(self, step: int) -> Frame:
        walls = self._walls[:self._n_walls]
        n_steps = self._n_walls
        mine = float(walls.mean())
        med = float(np.median(walls))
        latched = self.session.monitor.detector.is_latched(self.node_id)
        if self._baseline is None:
            self._baseline = med
        elif not latched and med < self._baseline * 1.5:
            a = self.baseline_alpha
            self._baseline = (1 - a) * self._baseline + a * med
        peers = self._baseline * (
            1.0 + self.rng.normal(0.0, self.peer_jitter,
                                  len(self.peer_ids)))
        node_ids = np.asarray([self.node_id, *self.peer_ids], np.int64)
        times = np.concatenate([[mine], peers])
        metrics: Dict[str, np.ndarray] = {"step_time": times}
        # hardware telemetry columns: this host's measured window means,
        # peers synthesized around the rolling healthy baseline (so the
        # detector's supporting-signal masks run on the real path).
        # Every metric EVER seen keeps its column — exporters slower
        # than the window cadence would otherwise flap the frame schema,
        # and a schema change makes the detector's RingHistory restart
        # (wiping the K-of-N persistence history every window)
        for m in sorted(set(self._hw_last) | set(self._hw_sums)):
            if m in self._hw_sums:
                v = self._hw_sums[m] / self._hw_counts[m]  # per-sample
                self._hw_last[m] = v
                base = self._hw_base.get(m)
                if base is None:
                    self._hw_base[m] = base = v
                elif not latched:
                    a = self.baseline_alpha
                    self._hw_base[m] = base = (1 - a) * base + a * v
            else:
                v = self._hw_last[m]       # no sample: carry forward
                base = self._hw_base.get(m, v)
            pv = base * (1.0 + self.rng.normal(0.0, 0.005,
                                               len(self.peer_ids)))
            metrics[m] = np.concatenate([[v], pv])
        if self.trace is not None:
            # own-time decomposition: measured for this host, the
            # baseline scaled by the same split for synthetic peers
            comp = self._comp_sums / n_steps
            split = comp / max(float(comp.sum()), 1e-9)
            self.trace.push(WindowTiming(
                t=self.control.now(), step=step, node_ids=node_ids,
                compute=np.concatenate([[comp[0]], peers * split[0]]),
                comm=np.concatenate([[comp[1]], peers * split[1]]),
                host=np.concatenate([[comp[2]], peers * split[2]]),
                stall=np.zeros(len(node_ids))))
        self.frames_fed += 1
        return Frame(t=self.control.now(), step=step, node_ids=node_ids,
                     metrics=metrics,
                     valid=np.ones(len(node_ids), bool))

    # ------------------------------------------------------------ triage

    def derive_signals(self, node_id: int) -> ErrorSignals:
        """Actionable triage evidence from the accumulated window
        telemetry (registered as the LocalHostControl signals provider).

        Lane evidence comes from hardware metrics when the trainer
        supplies them (temperature rise, frequency/power sag -> GPU
        lane; NIC error counters, throughput sag, link down -> NIC
        lane). With no hardware telemetry at all, a latched node still
        yields GPU-lane evidence from its sustained step-time deviation
        — the paper's early-termination rule is for nodes with NO
        evidence, not for hosts whose exporter is missing."""
        if node_id in self._evicted_signals:
            return self._evicted_signals[node_id]
        if node_id != self.node_id:
            return ErrorSignals()
        hw, base = self._hw_last, self._hw_base
        gpu = nic = False
        notes: List[str] = []

        def sag(metric, tol):
            v, b = hw.get(metric), base.get(metric)
            return v is not None and b is not None and b > 0 and \
                v < b * (1.0 - tol)

        if hw.get("gpu_temp", 0.0) > base.get("gpu_temp", np.inf) + 5.0:
            gpu = True
            notes.append("gpu_temp rise")
        if sag("gpu_freq", 0.03):
            gpu = True
            notes.append("gpu_freq sag")
        if sag("gpu_power", 0.08):
            gpu = True
            notes.append("gpu_power sag")
        if hw.get("nic_errors", 0.0) > 0:
            nic = True
            notes.append("nic error counters")
        if sag("nic_tx_rate", 0.08):
            nic = True
            notes.append("nic_tx_rate sag")
        if hw.get("nic_up", 1.0) < 0.999:
            nic = True
            notes.append("nic link down")
        if not (gpu or nic) and \
                self.session.monitor.detector.is_latched(self.node_id):
            gpu = True
            notes.append("sustained step-time deviation "
                         "(no hardware telemetry available)")
        return ErrorSignals(gpu_errors=gpu, nic_errors=nic,
                            detail="; ".join(notes))

    def _on_swap(self, ev: NodeSwapped) -> None:
        if ev.old == self.node_id:
            # snapshot the accumulated evidence under the departing id —
            # the detector latch is already reset by the swap, but the
            # eviction itself is step-time evidence
            sig = self.derive_signals(ev.old)
            if not sig.actionable:
                sig = ErrorSignals(
                    gpu_errors=True,
                    detail=f"evicted: {ev.reason} "
                           f"(no hardware telemetry available)")
            self._evicted_signals[ev.old] = sig
            self.node_id = ev.new
