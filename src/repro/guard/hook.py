"""Trainer → Guard adapter: real step timings become telemetry Frames.

``GuardStepHook`` implements the trainer's ``StepHook`` protocol
(``(step, wall_s, metrics) -> bool``). It aggregates the measured
per-step wall times into evaluation windows, builds real ``Frame``s —
this host's window-mean step time alongside its peers' — and feeds them
through the session's monitor → policy → manager pipeline. When the
tiered policy fires an IMMEDIATE restart for this host's node, the hook
returns True and the trainer rewinds to its last checkpoint: the full
Fig.-1 loop, driven by actual training-step measurements instead of the
hand-rolled boolean hooks the examples used before.

On a multi-host deployment each host reports its own barrier time and
the frames are assembled fleet-side; in the single-process setting the
hook synthesizes healthy peer timings around the measured baseline so
the peer-relative detector has a population to score against
(``n_peers``, deterministic via ``seed``).

``LocalHostControl`` / ``LocalSweepBackend`` are the minimal substrate
implementations for a training process with no cluster control plane:
swaps are bookkeeping, restarts raise the hook's restart flag, and
qualification sweeps trivially pass (there is no hardware to probe).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.detector import DetectorConfig
from repro.core.sweep import SweepReference
from repro.core.telemetry import Frame
from repro.core.triage import ErrorSignals
from repro.guard.events import NodeSwapped
from repro.guard.session import GuardSession, Tier


class LocalHostControl:
    """ClusterControl for a single training process (no real fleet)."""

    def __init__(self, next_provision_id: int = 1000):
        self.t = 0.0
        self.swaps: List[tuple] = []
        self.restarts: List[str] = []
        self._next = next_provision_id

    def swap_node(self, old: int, new: int) -> None:
        self.swaps.append((old, new))

    def restart_job(self, reason: str) -> None:
        self.restarts.append(reason)

    def provision_node(self) -> int:
        nid = self._next
        self._next += 1
        return nid

    def error_signals(self, node_id: int) -> ErrorSignals:
        return ErrorSignals()

    def remediate(self, node_id: int, stage: str) -> None:
        pass

    def now(self) -> float:
        return self.t


class LocalSweepBackend:
    """SweepBackend stub for hosts with nothing to probe offline: every
    probe reports exactly the reference, so qualification passes."""

    def __init__(self, devices: int = 1):
        self._devices = devices
        self._ref = SweepReference(device_tflops=100.0, intra_bw_gbps=100.0,
                                   pair_step_time=1.0)

    def device_count(self, node_id: int) -> int:
        return self._devices

    def compute_probe(self, node_id: int, device: int,
                      seconds: float) -> float:
        return self._ref.device_tflops

    def intra_bw_probe(self, node_id: int, dev_a: int, dev_b: int) -> float:
        return self._ref.intra_bw_gbps

    def multi_node_probe(self, node_ids: Sequence[int],
                         steps: int) -> np.ndarray:
        return np.full(steps, self._ref.pair_step_time)

    def reference(self) -> SweepReference:
        return self._ref


@dataclasses.dataclass
class _Stall:
    """A synthetic fault window: measured wall times are scaled by
    ``factor`` for ``steps`` steps starting at ``at_step`` (simulates a
    stalled/degraded host without burning real wall-clock)."""
    at_step: int
    factor: float
    steps: int


class GuardStepHook:
    """StepHook adapter feeding trainer step timings into a GuardSession."""

    def __init__(self, session: Optional[GuardSession] = None,
                 node_id: int = 0, n_peers: int = 15,
                 window_steps: int = 6, n_spares: int = 2,
                 peer_jitter: float = 0.01, seed: int = 0,
                 warmup_windows: int = 1, baseline_alpha: float = 0.25,
                 detector_cfg: Optional[DetectorConfig] = None):
        owns_session = session is None
        if owns_session:
            control = LocalHostControl()
            session = GuardSession.from_tier(
                Tier.ONLINE, control, LocalSweepBackend(),
                detector_cfg=detector_cfg)
        self.session = session
        self.control = session.control
        self.node_id = node_id
        self.window_steps = window_steps
        self.peer_ids = [node_id + 1 + i for i in range(n_peers)]
        self.peer_jitter = peer_jitter
        # the first window(s) carry JIT compilation / cache-warm spikes;
        # real fleets re-baseline after (re)start for the same reason
        self.warmup_windows = warmup_windows
        # synthetic peers track the host's healthy drift slowly (EMA of
        # unflagged window medians) so benign whole-job slowdown is not
        # mistaken for this one node straggling
        self.baseline_alpha = baseline_alpha
        self.rng = np.random.RandomState(seed)
        # preallocated window buffer: one slot per step of the evaluation
        # window (the hook sits on the trainer's hot path)
        self._walls = np.empty(window_steps)
        self._n_walls = 0
        self._windows_seen = 0
        self._baseline: Optional[float] = None
        self._stalls: List[_Stall] = []
        self._restart_pending = False
        self.frames_fed = 0
        self.restarts_requested = 0

        # register the synthetic population only on a session we built
        # ourselves: a caller-supplied session already has real node
        # pools, and re-registering in-job ids as spares would corrupt
        # them (the caller must register node_id and the peer ids)
        if owns_session:
            session.register_active([node_id, *self.peer_ids])
            session.register_spares(
                [max(self.peer_ids, default=node_id) + 1 + i
                 for i in range(n_spares)])
        # follow our own replacement: after an immediate swap this host
        # reports under its new node identity
        session.bus.subscribe(NodeSwapped, self._on_swap)

    # -------------------------------------------------------------- faults

    def inject_stall(self, at_step: int, factor: float = 8.0,
                     steps: int = 1) -> None:
        """Scale this host's *measured* wall time for a step range —
        deterministic stand-in for an actual stall/slowdown."""
        self._stalls.append(_Stall(at_step, factor, steps))

    def _stall_factor(self, step: int) -> float:
        f = 1.0
        for s in self._stalls:
            if s.at_step <= step < s.at_step + s.steps:
                f *= s.factor
        return f

    # ------------------------------------------------------------ protocol

    def __call__(self, step: int, wall_s: float,
                 metrics: Dict[str, float]) -> bool:
        if self._restart_pending:
            # deferred swaps landed at the last checkpoint: the manager
            # already replaced the node(s); rewind the job now
            self._restart_pending = False
            self._n_walls = 0
            self.restarts_requested += 1
            return True
        wall = wall_s * self._stall_factor(step)
        self._walls[self._n_walls] = wall
        self._n_walls += 1
        if isinstance(self.control, LocalHostControl):
            # the local control has no other clock source; a real
            # substrate (e.g. the simulator) advances its own time
            self.control.t += wall
        if self._n_walls < self.window_steps:
            return False
        self._windows_seen += 1
        if self._windows_seen <= self.warmup_windows:
            self._n_walls = 0            # compile/warm spikes: re-baseline
            return False
        frame = self._make_frame(step)
        self._n_walls = 0
        outcome = self.session.observe(frame)
        if outcome.restarts:
            self.restarts_requested += 1
            # the faulty node was swapped out: its injected fault leaves
            # the job with it (future-scheduled stalls stay armed)
            self._stalls = [s for s in self._stalls if s.at_step > step]
            return True
        return False

    def on_restart(self, step: int) -> None:
        """Trainer notification: a rewind happened. Drop the partial
        window and re-enter warmup: the first window(s) after a restore
        carry checkpoint-load / re-JIT spikes exactly like job start, and
        scoring them would flag the freshly swapped-in node and cascade
        into further spurious restarts."""
        self._n_walls = 0
        self._windows_seen = 0

    def on_checkpoint(self, step: int) -> None:
        """Trainer notification: a checkpoint was saved. Deferred and
        pending-patience mitigations land here (§4.2) — if the manager
        applied swaps, the next step call requests the rewind."""
        ck = self.session.on_checkpoint(step=step)
        if ck.applied_swaps:
            self._restart_pending = True

    # ------------------------------------------------------------ internal

    def _make_frame(self, step: int) -> Frame:
        walls = self._walls[:self._n_walls]
        mine = float(walls.mean())
        med = float(np.median(walls))
        if self._baseline is None:
            self._baseline = med
        elif not self.session.monitor.detector.is_latched(self.node_id) \
                and med < self._baseline * 1.5:
            a = self.baseline_alpha
            self._baseline = (1 - a) * self._baseline + a * med
        peers = self._baseline * (
            1.0 + self.rng.normal(0.0, self.peer_jitter,
                                  len(self.peer_ids)))
        node_ids = np.asarray([self.node_id, *self.peer_ids], np.int64)
        times = np.concatenate([[mine], peers])
        self.frames_fed += 1
        return Frame(t=self.control.now(), step=step, node_ids=node_ids,
                     metrics={"step_time": times},
                     valid=np.ones(len(node_ids), bool))

    def _on_swap(self, ev: NodeSwapped) -> None:
        if ev.old == self.node_id:
            self.node_id = ev.new
