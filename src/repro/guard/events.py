"""Typed Guard control-plane events and the central event bus.

Every state transition in the closed loop (Fig. 1) — online detection
verdicts, mitigations, crashes, offline qualification progress,
checkpoint boundaries — is published as one ``GuardEvent`` subclass on a
``GuardSession``'s ``EventBus``. Consumers attach *sinks* (an in-memory
trace for analysis, a JSONL file for durable audit logs) or *subscribe*
to specific event types with callbacks; the simulator, the benchmarks
and the trainer adapter all read the same taxonomy instead of the ad-hoc
dict records the pre-session code accumulated.

Events are frozen dataclasses: a ``kind`` string (stable wire name), the
session time ``t`` and global training ``step`` they occurred at, plus
typed payload fields. ``to_dict`` gives the flat JSON form used by the
JSONL sink and by ``RunResult.events``.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Callable, ClassVar, Dict, IO, List, Optional, Tuple, Type


@dataclasses.dataclass(frozen=True)
class GuardEvent:
    """Base class: when (session seconds / global step) something happened."""
    kind: ClassVar[str] = "event"
    t: float
    step: int

    def to_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        d["kind"] = self.kind
        return d


# --------------------------------------------------------------- detection

@dataclasses.dataclass(frozen=True)
class StragglerFlagged(GuardEvent):
    """Online detector latched a node; ``action`` is the policy tier."""
    kind: ClassVar[str] = "straggler_flagged"
    node_id: int = -1
    action: str = ""
    reason: str = ""
    slowdown: float = 0.0


@dataclasses.dataclass(frozen=True)
class StragglerCleared(GuardEvent):
    """A previously flagged node unlatched (hysteresis windows elapsed)."""
    kind: ClassVar[str] = "straggler_cleared"
    node_id: int = -1


@dataclasses.dataclass(frozen=True)
class DiagnosisEvent(GuardEvent):
    """Blame attribution reached a (new) verdict for a flagged node:
    ``root_cause`` is the ``repro.diagnose`` taxonomy value, ``blame``
    the standalone what-if excess in seconds (``blame_rel`` relative to
    the healthy reference), ``marginal`` the leave-one-out fleet
    step-time delta, and ``held`` whether the verdict keeps the node in
    the job (cascade victims / transients are watched, not evicted)."""
    kind: ClassVar[str] = "diagnosis"
    node_id: int = -1
    root_cause: str = ""
    blame: float = 0.0
    blame_rel: float = 0.0
    marginal: float = 0.0
    stall_share: float = 0.0
    held: bool = False
    evidence: Tuple[str, ...] = ()


@dataclasses.dataclass(frozen=True)
class HangDetected(GuardEvent):
    """A blocking collective exceeded its adaptive barrier deadline:
    some ranks of ``group`` are stuck in (or never reached) ``op``.
    ``culprits`` are the ranks the ccltrace watchdog accuses (never
    entered, or entered with independent link evidence), ``victims``
    the ranks that arrived and blocked on the barrier; ``roles`` maps
    each involved rank to its CCL-D classification (``never_entered`` /
    ``entered_stalled`` / ``victim``). ``waited_s`` is how long the
    collective had been pending when ``deadline_s`` tripped, and
    ``latency_windows`` the detection latency in evaluation windows
    from hang onset to verdict. An empty ``culprits`` is a detection
    without attribution (all ranks arrived, no link evidence) — the
    job restarts but nobody is evicted."""
    kind: ClassVar[str] = "hang"
    group: int = -1
    op: str = ""
    culprits: Tuple[int, ...] = ()
    victims: Tuple[int, ...] = ()
    roles: Tuple[Tuple[int, str], ...] = ()
    waited_s: float = 0.0
    deadline_s: float = 0.0
    latency_windows: float = 0.0


# -------------------------------------------------------------- mitigation

@dataclasses.dataclass(frozen=True)
class NodeSwapped(GuardEvent):
    """``old`` left the job, ``new`` (a healthy spare) took its place."""
    kind: ClassVar[str] = "swap"
    old: int = -1
    new: int = -1
    reason: str = ""
    deferred: bool = False


@dataclasses.dataclass(frozen=True)
class NodeQuarantined(GuardEvent):
    kind: ClassVar[str] = "quarantine"
    node_id: int = -1
    reason: str = ""


@dataclasses.dataclass(frozen=True)
class NodeTerminated(GuardEvent):
    kind: ClassVar[str] = "terminate"
    node_id: int = -1
    reason: str = ""


@dataclasses.dataclass(frozen=True)
class NodeProvisioned(GuardEvent):
    """A brand-new node entered the spare pool (after admission checks)."""
    kind: ClassVar[str] = "provision"
    node_id: int = -1


@dataclasses.dataclass(frozen=True)
class CrashDetected(GuardEvent):
    """Fail-stop hardware failure interrupted the job."""
    kind: ClassVar[str] = "crash"
    nodes: Tuple[int, ...] = ()
    lost_steps: int = 0


@dataclasses.dataclass(frozen=True)
class JobRestart(GuardEvent):
    """The job restarted; ``lost_steps`` is the rewind to last checkpoint."""
    kind: ClassVar[str] = "restart"
    reason: str = ""
    lost_steps: int = 0
    rewind: bool = True


@dataclasses.dataclass(frozen=True)
class CheckpointSaved(GuardEvent):
    """Checkpoint boundary; ``applied_swaps`` deferred mitigations landed."""
    kind: ClassVar[str] = "checkpoint"
    applied_swaps: int = 0


@dataclasses.dataclass(frozen=True)
class RecoveryEvent(GuardEvent):
    """One completed recovery incident, decomposed into MTTR phases:
    ``detect_s`` (failure start → detection), ``drain_s`` (triage /
    replacement / provisioning before the restore can begin),
    ``restore_s`` (loading state from ``ckpt_tier``: peer / local /
    cold), ``warmup_s`` (re-shard, compile, rejoin collectives).
    ``hot_spare`` marks a promotion that resumed from a DP peer's
    in-memory replica; ``replay_steps`` is the unique progress lost to
    the rewind (the goodput penalty)."""
    kind: ClassVar[str] = "recovery"
    reason: str = ""
    ckpt_tier: str = "cold"
    hot_spare: bool = False
    detect_s: float = 0.0
    drain_s: float = 0.0
    restore_s: float = 0.0
    warmup_s: float = 0.0
    replay_steps: int = 0


# ----------------------------------------------------- offline qualification

@dataclasses.dataclass(frozen=True)
class SweepStarted(GuardEvent):
    """Offline qualification of a quarantined node began."""
    kind: ClassVar[str] = "sweep_start"
    node_id: int = -1
    enhanced: bool = False


@dataclasses.dataclass(frozen=True)
class SweepFinished(GuardEvent):
    """Offline qualification concluded; ``outcome`` is the NodeState value."""
    kind: ClassVar[str] = "sweep_finish"
    node_id: int = -1
    outcome: str = ""
    duration_s: float = 0.0
    sweeps: int = 0
    failures: Tuple[str, ...] = ()


@dataclasses.dataclass(frozen=True)
class TriageStage(GuardEvent):
    """One remediation workflow ran during qualification (§6 FSM)."""
    kind: ClassVar[str] = "triage"
    node_id: int = -1
    stages: Tuple[str, ...] = ()
    outcome: str = ""
    reason: str = ""


@dataclasses.dataclass(frozen=True)
class CampaignFinished(GuardEvent):
    """A fleet-qualification campaign concluded (§5 at fleet scale):
    every candidate node was swept in one batched pass. ``failed`` lists
    the nodes routed into per-node quarantine/triage, ``node_seconds``
    the summed bench occupancy the campaign represents, ``wall_s`` the
    real compute wall of the batched pass, and ``calibrated`` whether
    the SweepReference was auto-derived from fleet medians."""
    kind: ClassVar[str] = "campaign_finish"
    nodes: int = 0
    passed: int = 0
    failed: Tuple[int, ...] = ()
    calibrated: bool = False
    node_seconds: float = 0.0
    wall_s: float = 0.0


EVENT_TYPES: Tuple[Type[GuardEvent], ...] = (
    StragglerFlagged, StragglerCleared, DiagnosisEvent, HangDetected,
    NodeSwapped, NodeQuarantined, NodeTerminated, NodeProvisioned,
    CrashDetected, JobRestart, CheckpointSaved, RecoveryEvent, SweepStarted,
    SweepFinished, TriageStage, CampaignFinished,
)


# ------------------------------------------------------------------- sinks

class TraceSink:
    """In-memory event trace (the default sink on every session)."""

    def __init__(self):
        self.events: List[GuardEvent] = []

    def emit(self, ev: GuardEvent) -> None:
        self.events.append(ev)

    def of_kind(self, kind: str) -> List[GuardEvent]:
        return [e for e in self.events if e.kind == kind]

    def as_dicts(self) -> List[Dict[str, object]]:
        return [e.to_dict() for e in self.events]

    def clear(self) -> None:
        self.events.clear()

    def __len__(self) -> int:
        return len(self.events)


class JsonlSink:
    """Durable audit log: one JSON object per event, append-only."""

    def __init__(self, path: str):
        self.path = path
        self._fh: Optional[IO[str]] = open(path, "a")

    def emit(self, ev: GuardEvent) -> None:
        if self._fh is None:
            raise ValueError(f"JsonlSink({self.path}) is closed")
        json.dump(ev.to_dict(), self._fh)
        self._fh.write("\n")
        # an audit log must survive the process dying mid-incident — the
        # exact scenario it exists to explain
        self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "JsonlSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class EventBus:
    """Central publish/subscribe fan-out for GuardEvents.

    Sinks receive every event; subscribers receive only the event types
    (including subclasses) they registered for. Publication order is
    sinks first, then subscribers, both in attach order.
    """

    def __init__(self):
        self._sinks: List[object] = []
        self._subs: List[Tuple[Type[GuardEvent],
                               Callable[[GuardEvent], None]]] = []

    def attach(self, sink) -> None:
        """Attach a sink (anything with ``emit(event)``)."""
        self._sinks.append(sink)

    def detach(self, sink) -> None:
        self._sinks.remove(sink)

    def subscribe(self, event_type: Type[GuardEvent],
                  fn: Callable[[GuardEvent], None]) -> None:
        self._subs.append((event_type, fn))

    def publish(self, ev: GuardEvent) -> GuardEvent:
        for sink in self._sinks:
            sink.emit(ev)
        for typ, fn in self._subs:
            if isinstance(ev, typ):
                fn(ev)
        return ev
