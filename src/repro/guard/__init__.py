"""``repro.guard`` — the one public entry point to the Guard closed loop.

  session     GuardSession facade + Tier ablation builders (Fig. 1, §7)
  events      typed GuardEvent hierarchy, EventBus, trace/JSONL sinks
  scheduler   non-blocking offline-qualification queue (§5)
  hook        Trainer StepHook adapter: step timings → Frames → monitor

Everything above the substrate protocols (``ClusterControl``,
``SweepBackend``, telemetry ``Collector``) goes through this package;
consumers should not wire ``OnlineMonitor``/``HealthManager`` by hand.
"""
from repro.guard.events import (EVENT_TYPES, CampaignFinished,
                                CheckpointSaved, CrashDetected,
                                DiagnosisEvent, EventBus, GuardEvent,
                                JobRestart, JsonlSink, NodeProvisioned,
                                NodeQuarantined, NodeSwapped, NodeTerminated,
                                StragglerCleared, StragglerFlagged,
                                SweepFinished, SweepStarted, TraceSink,
                                TriageStage)
from repro.guard.hook import (GuardStepHook, LocalHostControl,
                              LocalSweepBackend)
from repro.guard.scheduler import SweepScheduler
from repro.guard.session import (CheckpointOutcome, GuardSession, Tier,
                                 WindowOutcome)

__all__ = [
    "CampaignFinished", "CheckpointOutcome", "CheckpointSaved",
    "CrashDetected",
    "DiagnosisEvent", "EVENT_TYPES",
    "EventBus", "GuardEvent", "GuardSession", "GuardStepHook", "JobRestart",
    "JsonlSink", "LocalHostControl", "LocalSweepBackend", "NodeProvisioned",
    "NodeQuarantined", "NodeSwapped", "NodeTerminated", "StragglerCleared",
    "StragglerFlagged", "SweepFinished", "SweepScheduler", "SweepStarted",
    "Tier", "TraceSink", "TriageStage", "WindowOutcome",
]
