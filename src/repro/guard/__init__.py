"""``repro.guard`` — the one public entry point to the Guard closed loop.

  session     GuardSession facade + Tier ablation builders (Fig. 1, §7)
  events      typed GuardEvent hierarchy, EventBus, trace/JSONL sinks
  scheduler   non-blocking offline-qualification queue (§5)
  hook        Trainer StepHook adapter: step timings → Frames → monitor
  goodput     recovery accounting: checkpoint tiers, MTTF-tuned snapshot
              cadence, MTTR decomposition, goodput metric

Everything above the substrate protocols (``ClusterControl``,
``SweepBackend``, telemetry ``Collector``) goes through this package;
consumers should not wire ``OnlineMonitor``/``HealthManager`` by hand.
"""
from repro.guard.events import (EVENT_TYPES, CampaignFinished,
                                CheckpointSaved, CrashDetected,
                                DiagnosisEvent, EventBus, GuardEvent,
                                HangDetected, JobRestart, JsonlSink,
                                NodeProvisioned,
                                NodeQuarantined, NodeSwapped, NodeTerminated,
                                RecoveryEvent, StragglerCleared,
                                StragglerFlagged, SweepFinished,
                                SweepStarted, TraceSink, TriageStage)
from repro.guard.goodput import (MTTR_PHASES, CheckpointTier,
                                 MTTFEstimator, RecoveryModel,
                                 goodput_tflop_h, mttr_decomposition,
                                 replica_partner, young_daly_interval)
from repro.guard.hook import (GuardStepHook, LocalHostControl,
                              LocalSweepBackend)
from repro.guard.scheduler import SweepScheduler
from repro.guard.session import (CheckpointOutcome, GuardSession, Tier,
                                 WindowOutcome)

__all__ = [
    "CampaignFinished", "CheckpointOutcome", "CheckpointSaved",
    "CheckpointTier", "CrashDetected",
    "DiagnosisEvent", "EVENT_TYPES",
    "EventBus", "GuardEvent", "GuardSession", "GuardStepHook",
    "HangDetected", "JobRestart",
    "JsonlSink", "LocalHostControl", "LocalSweepBackend", "MTTFEstimator",
    "MTTR_PHASES",
    "NodeProvisioned",
    "NodeQuarantined", "NodeSwapped", "NodeTerminated", "RecoveryEvent",
    "RecoveryModel", "StragglerCleared",
    "StragglerFlagged", "SweepFinished", "SweepScheduler", "SweepStarted",
    "Tier", "TraceSink", "TriageStage", "WindowOutcome",
    "goodput_tflop_h", "mttr_decomposition", "replica_partner",
    "young_daly_interval",
]
