"""Recovery accounting: checkpoint tiers, MTTF-tuned snapshot cadence,
MTTR decomposition, and goodput.

The detection half of the loop optimizes *avoidance* metrics (MFU, step
variance, MTTF); this module carries the *recovery* half ("From
Detection to Recovery"): once detection works, wasted FLOPs are
dominated by how a job gets back to training after an incident.

Three checkpoint tiers (``CheckpointTier``), fastest first:

  PEER    each node's shard mirrored in a DP peer's memory. Restoring is
          a fabric copy: a hot spare promoted into the job pulls the
          evicted/dead node's state from the surviving replica holder
          instead of cold-starting from durable storage.
  LOCAL   per-node local shard on node-local disk. Survives evictions
          (the node is alive, its shard is readable) but dies with the
          node on fail-stop.
  COLD    the durable global checkpoint (the npz/manifest directory the
          seed trainer always had).

Cadence for the fast tiers is auto-tuned from the **live** MTTF estimate
the Guard session tracks (``MTTFEstimator``) with the Young–Daly optimum
``sqrt(2 * snapshot_cost * MTTF)`` — a fleet that starts crashing
snapshots more often; a quiet fleet backs off toward the cap.

``mttr_decomposition`` aggregates the ``RecoveryEvent``s a run published
into the detect → drain → restore → warmup phase split plus per-tier
restore counts, and ``goodput_tflop_h`` is the headline: good (unique,
never-replayed) FLOPs per wall hour.

Everything here is dependency-free on purpose: ``repro.train.checkpoint``
(jax layer) and ``repro.simcluster.runtime`` (numpy layer) both import
it without cycles.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Dict, Iterable, List, Optional, Tuple


class CheckpointTier(enum.Enum):
    """Restore sources, fastest first (see module docstring)."""
    PEER = "peer"
    LOCAL = "local"
    COLD = "cold"


def young_daly_interval(mttf_s: float, snapshot_cost_s: float,
                        lo: float = 60.0, hi: float = 1800.0) -> float:
    """Optimal checkpoint interval ``sqrt(2 * C * MTTF)`` (Young/Daly),
    clamped to [lo, hi]."""
    mttf_s = max(float(mttf_s), 1e-9)
    opt = math.sqrt(2.0 * max(float(snapshot_cost_s), 1e-9) * mttf_s)
    return float(min(max(opt, lo), hi))


def replica_partner(i: int, n: int) -> int:
    """DP-peer replica placement over ``n`` job slots: adjacent pairing
    (slot ``i`` mirrors onto ``i ^ 1``), with the odd tail slot mirroring
    onto slot 0. Symmetric for every even-sized fleet; the only
    asymmetric slots are the odd tail and its holder."""
    if n <= 1:
        return i
    j = i ^ 1
    return j if j < n else 0


@dataclasses.dataclass
class MTTFEstimator:
    """Live mean-time-between-job-interrupts estimate.

    Bayesian-flavored: a ``prior_mttf_s`` prior observation is blended
    with the observed (elapsed time, interrupt count), so the estimate
    is finite from t=0 and converges to the empirical rate as evidence
    accumulates. "Failure" here means any job-interrupting event that
    forces a restore — fail-stop crashes and Guard-driven immediate
    restarts both count, because both cost a replay window (the quantity
    the snapshot cadence is tuned against)."""
    t0: float = 0.0
    prior_mttf_s: float = 6 * 3600.0
    prior_weight: float = 1.0
    failures: int = 0
    last_failure_t: Optional[float] = None

    def observe_failure(self, t: float) -> None:
        self.failures += 1
        self.last_failure_t = float(t)

    def estimate(self, now: float) -> float:
        elapsed = max(float(now) - self.t0, 0.0)
        return (elapsed + self.prior_weight * self.prior_mttf_s) / \
            (self.failures + self.prior_weight)


@dataclasses.dataclass(frozen=True)
class RecoveryModel:
    """Tier-dependent recovery costs + which checkpoint tiers each Guard
    ablation tier has built (the recovery ladder mirrors the detection
    ladder of Table 4):

      BURNIN / NODE_SWEEP   durable checkpoints only (cold restarts)
      ONLINE                + local-shard fast tier
      ENHANCED              + peer-replica tier and hot-spare promotion
    """
    peer_restore_s: float = 30.0      # fabric copy from the replica holder
    local_restore_s: float = 120.0    # node-local shard reload
    cold_restore_s: float = 480.0     # durable storage, full job re-shard
    snapshot_cost_s: float = 2.0      # async fast-tier snapshot stall
    min_interval_s: float = 60.0      # fast-tier cadence clamp
    max_interval_s: float = 1800.0

    def restore_s(self, tier: CheckpointTier) -> float:
        return {CheckpointTier.PEER: self.peer_restore_s,
                CheckpointTier.LOCAL: self.local_restore_s,
                CheckpointTier.COLD: self.cold_restore_s}[tier]

    def tiers_for(self, guard_tier: int) -> Tuple[CheckpointTier, ...]:
        if guard_tier >= 4:
            return (CheckpointTier.PEER, CheckpointTier.LOCAL,
                    CheckpointTier.COLD)
        if guard_tier >= 3:
            return (CheckpointTier.LOCAL, CheckpointTier.COLD)
        return (CheckpointTier.COLD,)

    def fast_tier_enabled(self, guard_tier: int) -> bool:
        return guard_tier >= 3

    def pick(self, guard_tier: int, node_alive: bool,
             replica_lost: bool = False) -> CheckpointTier:
        """Best restore source for one incident.

        ``node_alive``: the leaving node still responds (eviction /
        planned swap) — its LOCAL shard is readable. On fail-stop the
        local shard died with the node, so only the PEER replica (if the
        holder survived) or COLD storage can serve.
        ``replica_lost``: the incident also took out a replica holder
        (both members of a mirror pair died), so the PEER tier cannot
        cover every shard and the restore degrades to COLD."""
        tiers = self.tiers_for(guard_tier)
        if CheckpointTier.PEER in tiers and not replica_lost:
            return CheckpointTier.PEER
        if CheckpointTier.LOCAL in tiers and node_alive:
            return CheckpointTier.LOCAL
        return CheckpointTier.COLD


#: phase keys of the MTTR decomposition, in incident order
MTTR_PHASES = ("detect_s", "drain_s", "restore_s", "warmup_s")


def mttr_decomposition(events: Iterable) -> Dict[str, object]:
    """Aggregate ``RecoveryEvent``s (typed or their ``to_dict`` form)
    into the detect → drain → restore → warmup decomposition.

    Always returns the full schema — zero-filled when the run had no
    incidents — so artifact consumers (and the CI gate) can rely on the
    fields existing."""
    recs: List[dict] = []
    for e in events:
        d = e.to_dict() if hasattr(e, "to_dict") else dict(e)
        if d.get("kind", "recovery") == "recovery":
            recs.append(d)
    n = len(recs)
    out: Dict[str, object] = {"incidents": n}
    totals = {}
    for k in MTTR_PHASES:
        totals[k] = float(sum(r.get(k, 0.0) for r in recs))
        out[f"{k}_total"] = totals[k]
        out[f"{k}_mean"] = totals[k] / n if n else 0.0
    total = sum(totals.values())
    out["mttr_total_s"] = total
    out["mttr_s"] = total / n if n else 0.0
    out["replay_steps_total"] = int(sum(r.get("replay_steps", 0)
                                        for r in recs))
    out["hot_spare_promotions"] = sum(1 for r in recs if r.get("hot_spare"))
    out["by_tier"] = {t.value: sum(1 for r in recs
                                   if r.get("ckpt_tier") == t.value)
                      for t in CheckpointTier}
    return out


def goodput_tflop_h(good_steps: int, step_tflops: float,
                    elapsed_h: float) -> float:
    """Good FLOPs per wall hour: only *unique* forward progress counts —
    a step re-executed after a rewind is wasted work, not goodput."""
    if elapsed_h <= 0.0:
        return 0.0
    return float(step_tflops) * int(good_steps) / float(elapsed_h)


__all__ = [
    "CheckpointTier", "MTTFEstimator", "MTTR_PHASES", "RecoveryModel",
    "goodput_tflop_h", "mttr_decomposition", "replica_partner",
    "young_daly_interval",
]
