"""Non-blocking offline-qualification scheduler with sweep-bench
capacity modeling.

The paper's qualification pipeline (§5) is *event-driven and offline*: a
quarantined node is swept/triaged on the side while the job keeps
training, and only re-enters the healthy pool once it passes. The
pre-session code instead called ``qualify_all_quarantined()`` inline at
checkpoint boundaries — instantaneous in simulated time and serialized
with the job.

``SweepScheduler`` restores the real semantics: quarantined nodes queue
up, at most ``concurrency`` qualifications are in flight, and each one
occupies a sweep-bench slot for the simulated ``duration_s`` its
sweep→triage loop consumed. The bench is modeled as ``concurrency``
slots with explicit free times: dequeued work starts at
``max(slot_free_t, enqueue_t)`` — the moment the freeing slot's
previous occupant actually finished, NOT the next time ``advance()``
happened to be called — so bench occupancy and qualification landing
times are exact regardless of how coarsely the caller drives the clock.
``advance(now)`` is the only clock input — call it whenever job time
moves (the simulator does so every window) and it chains starts and
landings in event order up to ``now``, publishing ``SweepStarted`` /
``TriageStage`` / ``SweepFinished`` events on the session bus at their
TRUE times. ``drain(now, step)`` runs the bench to completion for
end-of-run accounting (event times may lie beyond ``now``).
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Dict, List, Optional, Set, Tuple

from repro.core.health_manager import HealthManager, QualificationTicket
from repro.guard.events import (EventBus, SweepFinished, SweepStarted,
                                TriageStage)


@dataclasses.dataclass
class InFlight:
    ticket: QualificationTicket
    started_t: float
    finish_t: float


class BenchSlots:
    """Sweep-bench capacity as explicit slot free times.

    Extracted from the scheduler so the SAME bench can back several
    schedulers at once: a fleet controller hands one ``BenchSlots`` to
    every concurrent job's ``SweepScheduler`` and their qualification
    campaigns queue on the shared slots (the paper's cluster-service
    deployment — one offline bench, many tenants). Slot accounting is a
    min-heap of free times; ``occupy`` is the direct-occupancy path for
    batched background campaigns (healthscan) that bypass the per-node
    queue."""

    def __init__(self, slots: int):
        assert slots >= 1
        self._free_at: List[float] = [0.0] * slots
        heapq.heapify(self._free_at)

    @property
    def slots(self) -> int:
        return len(self._free_at)

    def earliest(self) -> Optional[float]:
        """Free time of the earliest-available slot, or None while every
        slot token is claimed by in-flight work."""
        return self._free_at[0] if self._free_at else None

    def pop(self) -> float:
        """Claim the earliest slot (caller pushes back its new free time)."""
        return heapq.heappop(self._free_at)

    def push(self, free_t: float) -> None:
        heapq.heappush(self._free_at, free_t)

    def occupy(self, now: float, duration_s: float) -> Tuple[float, float]:
        """Occupy one slot for ``duration_s`` starting no earlier than
        ``now``; returns the (start, finish) times actually booked."""
        free_t = self.pop()
        start = max(free_t, float(now))
        finish = start + float(duration_s)
        self.push(finish)
        return start, finish

    def idle_at(self, now: float) -> bool:
        """True when at least one slot is free at time ``now``."""
        return bool(self._free_at) and self._free_at[0] <= float(now)


class SweepScheduler:
    """Queues quarantined nodes and overlaps qualification with the job."""

    def __init__(self, manager: HealthManager,
                 bus: Optional[EventBus] = None,
                 concurrency: int = 2,
                 bench: Optional[BenchSlots] = None):
        self.manager = manager
        self.bus = bus
        # the bench may be private (default: ``concurrency`` slots) or a
        # shared fleet-level BenchSlots arbitrated across many sessions
        self.bench = bench or BenchSlots(concurrency)
        self.concurrency = self.bench.slots
        self.queue: List[Tuple[int, float]] = []    # (node_id, enqueued_t)
        self.in_flight: List[InFlight] = []
        self._tracked: Set[int] = set()
        # nodes whose last qualification ended buddy_exhausted, keyed to
        # the spare count they exhausted it at: re-running the identical
        # ambiguous sweep against the identical buddy pool would burn
        # the bench for the identical parked verdict, so the periodic
        # quarantine scan skips them until the pool has GROWN (an
        # explicit submit() still overrides)
        self._parked: Dict[int, int] = {}
        self.completed: List[QualificationTicket] = []
        self._step = 0               # last known global step, for events
        self._now = 0.0              # last clock input (submit default)

    def rebind_bench(self, bench: BenchSlots) -> None:
        """Point this scheduler at a (shared) bench. Only legal while no
        qualification is in flight — in-flight work booked slots on the
        old bench and landing it against a different heap would corrupt
        both."""
        assert not self.in_flight, "cannot rebind with work in flight"
        self.bench = bench
        self.concurrency = bench.slots

    # ------------------------------------------------------------- intake

    def submit(self, node_id: int, now: Optional[float] = None) -> bool:
        """Enqueue one quarantined node; no-op if already queued/running.
        ``now`` is the time the node became available for the bench
        (defaults to the last clock input)."""
        if node_id in self._tracked:
            return False
        self._tracked.add(node_id)
        self._parked.pop(node_id, None)
        self.queue.append((node_id, self._now if now is None else
                           float(now)))
        return True

    def submit_quarantined(self, now: Optional[float] = None) -> int:
        """Scan the manager for quarantined nodes and enqueue the new
        ones — except buddy-exhausted parked nodes whose spare pool has
        not grown since they parked (re-sweeping them would repeat the
        same ambiguous verdict)."""
        spares = self.manager.spare_count
        return sum(self.submit(nid, now=now)
                   for nid in self.manager.quarantined()
                   if spares > self._parked.get(nid, -1))

    # ------------------------------------------------------------- clock

    @property
    def busy(self) -> int:
        return len(self.in_flight)

    @property
    def backlog(self) -> int:
        return len(self.queue)

    def next_finish_t(self) -> Optional[float]:
        if not self.in_flight:
            return None
        return min(f.finish_t for f in self.in_flight)

    def next_event_t(self) -> Optional[float]:
        """Earliest pending event (a landing or a possible start) —
        lets a fleet controller interleave several schedulers sharing
        one bench in global event order."""
        nf = self.next_finish_t()
        ns = self._next_start_t()
        if nf is None:
            return ns
        if ns is None:
            return nf
        return min(nf, ns)

    def advance(self, now: float, step: int = -1
                ) -> List[QualificationTicket]:
        """Chain starts and landings in event order up to ``now``;
        returns the tickets that completed at or before ``now``."""
        if step >= 0:
            self._step = step
        now = float(now)
        self._now = max(self._now, now)
        return self._run_until(now)

    def drain(self, now: float, step: Optional[int] = None
              ) -> List[QualificationTicket]:
        """Force-run the bench to completion (end of run). Events are
        stamped at their true start/finish times — which may lie beyond
        ``now`` — and carry ``step`` when given (the caller's final
        global step, so end-of-run events aren't stamped with whatever
        step the last mid-run ``advance`` happened to see)."""
        if step is not None:
            self._step = step
        self._now = max(self._now, float(now))
        return self._run_until(math.inf)

    # ----------------------------------------------------------- internal

    def _next_start_t(self) -> Optional[float]:
        """Earliest moment the queue head could occupy a bench slot."""
        if not self.queue:
            return None
        free_t = self.bench.earliest()
        if free_t is None:          # every slot claimed by in-flight work
            return None
        return max(free_t, self.queue[0][1])

    def _run_until(self, horizon: float) -> List[QualificationTicket]:
        done: List[QualificationTicket] = []
        while True:
            nf = self.next_finish_t()
            ns = self._next_start_t()
            # process the earliest event not beyond the horizon; landings
            # first on ties — a freed slot may let queued work start at
            # that same instant
            if nf is not None and nf <= horizon and \
                    (ns is None or nf <= ns):
                i = min(range(len(self.in_flight)),
                        key=lambda j: self.in_flight[j].finish_t)
                f = self.in_flight.pop(i)
                self._finish(f, f.finish_t)
                self.bench.push(f.finish_t)
                done.append(f.ticket)
                continue
            if ns is not None and ns <= horizon:
                free_t = self.bench.pop()
                nid, enq_t = self.queue.pop(0)
                start = max(free_t, enq_t)
                ticket = self.manager.begin_qualification(nid)
                self._publish(SweepStarted(
                    t=start, step=self._step, node_id=nid,
                    enhanced=self.manager.enhanced_sweep))
                self.in_flight.append(
                    InFlight(ticket, start, start + ticket.duration_s))
                continue
            break
        return done

    def _finish(self, f: InFlight, at: float) -> None:
        ticket = f.ticket
        outcome = self.manager.complete_qualification(ticket)
        self._tracked.discard(ticket.node_id)
        if ticket.buddy_exhausted:
            self._parked[ticket.node_id] = self.manager.spare_count
        self.completed.append(ticket)
        failures: List[str] = []
        for kind, rec in ticket.records:
            if kind == "triage":
                self._publish(TriageStage(
                    t=at, step=self._step, node_id=ticket.node_id,
                    stages=tuple(rec.stages_run), outcome=rec.outcome.value,
                    reason=rec.reason))
            else:
                failures.extend(rec.failures)
        self._publish(SweepFinished(
            t=at, step=self._step, node_id=ticket.node_id,
            outcome=outcome.value, duration_s=ticket.duration_s,
            sweeps=ticket.sweeps, failures=tuple(failures)))

    def _publish(self, ev) -> None:
        if self.bus is not None:
            self.bus.publish(ev)
