"""Non-blocking offline-qualification scheduler.

The paper's qualification pipeline (§5) is *event-driven and offline*: a
quarantined node is swept/triaged on the side while the job keeps
training, and only re-enters the healthy pool once it passes. The
pre-session code instead called ``qualify_all_quarantined()`` inline at
checkpoint boundaries — instantaneous in simulated time and serialized
with the job.

``SweepScheduler`` restores the real semantics: quarantined nodes queue
up, at most ``concurrency`` qualifications are in flight, and each one
occupies the sweep-bench for the simulated ``duration_s`` its
sweep→triage loop consumed. ``advance(now)`` is the only clock input —
call it whenever job time moves (the simulator does so every step) and
it starts queued work and lands finished work, publishing
``SweepStarted`` / ``TriageStage`` / ``SweepFinished`` events on the
session bus. ``drain(now)`` force-completes everything for end-of-run
accounting.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Set

from repro.core.health_manager import HealthManager, QualificationTicket
from repro.guard.events import (EventBus, SweepFinished, SweepStarted,
                                TriageStage)


@dataclasses.dataclass
class InFlight:
    ticket: QualificationTicket
    started_t: float
    finish_t: float


class SweepScheduler:
    """Queues quarantined nodes and overlaps qualification with the job."""

    def __init__(self, manager: HealthManager,
                 bus: Optional[EventBus] = None,
                 concurrency: int = 2):
        assert concurrency >= 1
        self.manager = manager
        self.bus = bus
        self.concurrency = concurrency
        self.queue: List[int] = []
        self.in_flight: List[InFlight] = []
        self._tracked: Set[int] = set()
        self.completed: List[QualificationTicket] = []
        self._step = 0               # last known global step, for events

    # ------------------------------------------------------------- intake

    def submit(self, node_id: int) -> bool:
        """Enqueue one quarantined node; no-op if already queued/running."""
        if node_id in self._tracked:
            return False
        self._tracked.add(node_id)
        self.queue.append(node_id)
        return True

    def submit_quarantined(self) -> int:
        """Scan the manager for quarantined nodes and enqueue the new ones."""
        return sum(self.submit(nid) for nid in self.manager.quarantined())

    # ------------------------------------------------------------- clock

    @property
    def busy(self) -> int:
        return len(self.in_flight)

    @property
    def backlog(self) -> int:
        return len(self.queue)

    def next_finish_t(self) -> Optional[float]:
        if not self.in_flight:
            return None
        return min(f.finish_t for f in self.in_flight)

    def advance(self, now: float, step: int = -1
                ) -> List[QualificationTicket]:
        """Land finished qualifications and start queued ones; returns the
        tickets that completed at or before ``now``."""
        if step >= 0:
            self._step = step
        done: List[QualificationTicket] = []
        still: List[InFlight] = []
        for f in self.in_flight:
            if f.finish_t <= now:
                self._finish(f, f.finish_t)
                done.append(f.ticket)
            else:
                still.append(f)
        self.in_flight = still
        while self.queue and len(self.in_flight) < self.concurrency:
            nid = self.queue.pop(0)
            ticket = self.manager.begin_qualification(nid)
            self._publish(SweepStarted(
                t=now, step=self._step, node_id=nid,
                enhanced=self.manager.enhanced_sweep))
            self.in_flight.append(
                InFlight(ticket, now, now + ticket.duration_s))
        return done

    def drain(self, now: float) -> List[QualificationTicket]:
        """Force-complete all queued and in-flight work (end of run)."""
        done: List[QualificationTicket] = []
        while self.queue or self.in_flight:
            done.extend(self.advance(now))   # start queued work
            for f in self.in_flight:         # then land it immediately
                self._finish(f, max(now, f.finish_t))
                done.append(f.ticket)
            self.in_flight = []
        return done

    # ----------------------------------------------------------- internal

    def _finish(self, f: InFlight, at: float) -> None:
        ticket = f.ticket
        outcome = self.manager.complete_qualification(ticket)
        self._tracked.discard(ticket.node_id)
        self.completed.append(ticket)
        failures: List[str] = []
        for kind, rec in ticket.records:
            if kind == "triage":
                self._publish(TriageStage(
                    t=at, step=self._step, node_id=ticket.node_id,
                    stages=tuple(rec.stages_run), outcome=rec.outcome.value,
                    reason=rec.reason))
            else:
                failures.extend(rec.failures)
        self._publish(SweepFinished(
            t=at, step=self._step, node_id=ticket.node_id,
            outcome=outcome.value, duration_s=ticket.duration_s,
            sweeps=ticket.sweeps, failures=tuple(failures)))

    def _publish(self, ev) -> None:
        if self.bus is not None:
            self.bus.publish(ev)
