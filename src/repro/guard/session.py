"""The Guard control plane behind one facade: ``GuardSession``.

A session owns the whole closed loop of Fig. 1 — detector + tiered
policy (via ``OnlineMonitor``), the pool-owning ``HealthManager``, the
non-blocking ``SweepScheduler`` — and a typed ``EventBus`` every state
transition is published on. Substrates plug in underneath through the
two narrow protocols (``ClusterControl``, ``SweepBackend``); the
simulated fleet implements both, and so does a real control plane.

Construction mirrors the §7 ablation ladder (Table 4)::

    session = GuardSession.from_tier(Tier.ENHANCED, control, backend)
    # or the named builders: .burnin() .node_sweep() .online() .enhanced()

Lifecycle::

    session.register_active(job_nodes); session.register_spares(spares)
    outcome = session.observe(frame)          # one evaluation window
    for reason in outcome.restarts: ...       # job must restart now
    ck = session.on_checkpoint()              # deferred swaps + sweep queue
    session.advance(now)                      # qualification overlaps job
    session.handle_crash(dead_nodes)          # fail-stop batch replacement

Telemetry: ``session.trace`` is the in-memory event trace;
``session.add_sink(JsonlSink(path))`` streams the same events to disk.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Callable, List, Optional, Sequence, Set

import numpy as np

from repro.core.detector import DetectorConfig
from repro.core.health_manager import (ClusterControl, HealthManager,
                                       ManagerStats, NodeState)
from repro.core.monitor import HealthEvent, OnlineMonitor
from repro.core.policy import PolicyConfig
from repro.core.sweep import (CampaignResult, SweepBackend, SweepCampaign,
                              SweepConfig, SweepReference,
                              fleet_qualification)
from repro.core.telemetry import Frame
from repro.core.triage import TriageConfig
from repro.guard.events import (CampaignFinished, CheckpointSaved,
                                CrashDetected,
                                DiagnosisEvent, EventBus, GuardEvent,
                                HangDetected, NodeProvisioned,
                                NodeQuarantined,
                                NodeSwapped, NodeTerminated,
                                StragglerCleared, StragglerFlagged,
                                TraceSink)
from repro.guard.goodput import MTTFEstimator
from repro.guard.scheduler import SweepScheduler


class Tier(enum.IntEnum):
    """The §7 ablation ladder (Table 4), cumulative."""
    BURNIN = 1        # burn-in admission only; greys handled by humans
    NODE_SWEEP = 2    # + offline single-node sweep tooling
    ONLINE = 3        # + Guard online monitoring and tiered mitigation
    ENHANCED = 4      # + enhanced sweep (multi-node stage, long burns)


@dataclasses.dataclass
class WindowOutcome:
    """What one evaluation window changed."""
    events: List[HealthEvent]         # raw monitor events this window
    flagged: List[int]                # nodes newly decided on
    cleared: List[int]                # nodes whose latch released
    restarts: List[str]               # reasons for immediate restarts
    diagnoses: List = dataclasses.field(default_factory=list)
    # ^ new/changed Diagnosis records this window (Diagnoser tiers only)


@dataclasses.dataclass
class CheckpointOutcome:
    applied_swaps: int                # deferred mitigations landed
    submitted: int                    # nodes newly queued for sweeps


class GuardSession:
    """Facade over the full Guard closed loop for one training job."""

    def __init__(self, control: ClusterControl, sweep_backend: SweepBackend,
                 tier: Tier = Tier.ENHANCED,
                 detector_cfg: Optional[DetectorConfig] = None,
                 policy_cfg: Optional[PolicyConfig] = None,
                 sweep_cfg: Optional[SweepConfig] = None,
                 triage_cfg: Optional[TriageConfig] = None,
                 pending_patience_s: float = 1800.0,
                 sweep_concurrency: int = 2,
                 on_provision: Optional[Callable[[int], None]] = None,
                 bus: Optional[EventBus] = None,
                 diagnoser=None):
        self.tier = Tier(tier)
        self.control = control
        self.bus = bus or EventBus()
        self.trace = TraceSink()
        self.bus.attach(self.trace)

        # optional repro.diagnose.Diagnoser: the attribution stage
        # between detector and policy (victims watched, not evicted;
        # triage signals enriched with root causes)
        self.diagnoser = diagnoser
        self.monitor = OnlineMonitor(detector_cfg, policy_cfg,
                                     diagnoser=diagnoser)
        self.manager = HealthManager(
            control, sweep_backend, self.monitor,
            sweep_cfg=sweep_cfg, triage_cfg=triage_cfg,
            enhanced_sweep=self.tier == Tier.ENHANCED,
            pending_patience_s=pending_patience_s,
            on_provision=on_provision,
            notify=self._on_manager_notify)
        if diagnoser is not None:
            self.manager.hold_check = diagnoser.should_hold
            self.manager.signals_for = diagnoser.signals_for
        self.scheduler = SweepScheduler(self.manager, self.bus,
                                        concurrency=sweep_concurrency)
        self._step = 0
        self._flagged: Set[int] = set()
        # live mean-time-between-job-interrupts: tunes the fast-tier
        # snapshot cadence (Young-Daly) of the tiered checkpoint manager
        self.mttf = MTTFEstimator(t0=control.now())

    # ------------------------------------------------------------ builders

    @classmethod
    def from_tier(cls, tier: Tier, control: ClusterControl,
                  sweep_backend: SweepBackend, **kw) -> "GuardSession":
        """Build the session for one Table-4 ablation tier."""
        return cls(control, sweep_backend, tier=Tier(tier), **kw)

    @classmethod
    def burnin(cls, control, sweep_backend, **kw) -> "GuardSession":
        return cls.from_tier(Tier.BURNIN, control, sweep_backend, **kw)

    @classmethod
    def node_sweep(cls, control, sweep_backend, **kw) -> "GuardSession":
        return cls.from_tier(Tier.NODE_SWEEP, control, sweep_backend, **kw)

    @classmethod
    def online(cls, control, sweep_backend, **kw) -> "GuardSession":
        return cls.from_tier(Tier.ONLINE, control, sweep_backend, **kw)

    @classmethod
    def enhanced(cls, control, sweep_backend, **kw) -> "GuardSession":
        return cls.from_tier(Tier.ENHANCED, control, sweep_backend, **kw)

    # ----------------------------------------------------------- properties

    @property
    def online_monitoring(self) -> bool:
        """Tiers 3-4 run the online detection loop."""
        return self.tier >= Tier.ONLINE

    @property
    def sweep_tooling(self) -> bool:
        """Tiers 2-4 have offline sweep tooling available."""
        return self.tier >= Tier.NODE_SWEEP

    @property
    def stats(self) -> ManagerStats:
        return self.manager.stats

    @property
    def spares_free(self) -> int:
        return self.manager.spare_count

    def spare_ids(self) -> List[int]:
        """Current healthy-spare ids (copy; e.g. sweep-buddy candidates).
        Under a fleet pool this is the shared pool's view, not a private
        list."""
        return self.manager.spare_pool_ids()

    def node_state(self, node_id: int) -> Optional[NodeState]:
        return self.manager.state.get(node_id)

    def events(self) -> List[GuardEvent]:
        return list(self.trace.events)

    def add_sink(self, sink) -> None:
        self.bus.attach(sink)

    def drain_human_hours(self) -> float:
        """Hand the operator-attention accumulated since the last call to
        the caller's accounting (sweeps/triage consume human time)."""
        h = self.manager.stats.human_seconds / 3600.0
        self.manager.stats.human_seconds = 0.0
        return h

    # --------------------------------------------------------- registration

    def register_active(self, node_ids: Sequence[int]) -> None:
        for nid in node_ids:
            self.manager.register(int(nid), NodeState.ACTIVE)

    def register_spares(self, node_ids: Sequence[int]) -> None:
        for nid in node_ids:
            self.manager.register(int(nid), NodeState.HEALTHY_SPARE)

    # ----------------------------------------------------------- the loop

    def observe(self, frame: Frame) -> WindowOutcome:
        """Feed one telemetry window through detector → policy → manager.

        Publishes StragglerFlagged / StragglerCleared events and reports
        any immediate restarts the tiered policy demanded (the caller owns
        job-time accounting for those)."""
        self._step = frame.step
        out = WindowOutcome([], [], [], [])
        if not self.online_monitoring:
            return out
        events = self.monitor.observe(frame)
        diag = self.monitor.last_diagnosis
        if diag is not None:
            # attribution verdicts first: the flag/mitigation events that
            # follow are explained by them
            for rec in diag.new_records:
                out.diagnoses.append(rec)
                self.bus.publish(DiagnosisEvent(
                    t=frame.t, step=frame.step, node_id=rec.node_id,
                    root_cause=rec.root_cause.value, blame=rec.blame,
                    blame_rel=rec.blame_rel, marginal=rec.marginal,
                    stall_share=rec.stall_share, held=rec.held,
                    evidence=rec.evidence))
        for ev in events:
            out.events.append(ev)
            out.flagged.append(ev.decision.node_id)
            self._flagged.add(ev.decision.node_id)
            self.bus.publish(StragglerFlagged(
                t=frame.t, step=frame.step, node_id=ev.decision.node_id,
                action=ev.decision.action.value, reason=ev.decision.reason,
                slowdown=ev.decision.slowdown))
            pre = self.manager.stats.immediate_restarts
            self.manager.handle(ev)
            if self.manager.stats.immediate_restarts > pre:
                out.restarts.append(ev.decision.reason)
                self.mttf.observe_failure(frame.t)
        # hysteresis released: report clears for nodes still in the job
        # (one vectorized latch query instead of a fleet scan per id)
        if self._flagged:
            ids = sorted(self._flagged)
            still = self.monitor.detector.latched_many(
                np.asarray(ids, dtype=np.int64))
            for nid, latched in zip(ids, still):
                if latched:
                    continue
                self._flagged.discard(nid)
                if self.manager.state.get(nid) in (NodeState.ACTIVE,
                                                   NodeState.PENDING):
                    out.cleared.append(nid)
                    self.bus.publish(StragglerCleared(
                        t=frame.t, step=frame.step, node_id=nid))
        self.advance(frame.t)
        return out

    def on_checkpoint(self, now: Optional[float] = None,
                      step: Optional[int] = None) -> CheckpointOutcome:
        """Checkpoint boundary: land deferred mitigations (online tiers),
        queue quarantined nodes for offline qualification, and let the
        sweep bench make progress."""
        t = self.control.now() if now is None else now
        self._note_step(step)
        applied = self.manager.on_checkpoint() if self.online_monitoring \
            else 0
        self.bus.publish(CheckpointSaved(t=t, step=self._step,
                                         applied_swaps=applied))
        submitted = 0
        if self.sweep_tooling:
            submitted = self.scheduler.submit_quarantined(now=t)
        self.advance(t)
        return CheckpointOutcome(applied, submitted)

    def advance(self, now: float, step: Optional[int] = None) -> None:
        """Clock input: overlapped offline qualification catches up to
        job time ``now`` (starts queued sweeps, lands finished ones).
        Pass the global training ``step`` when known so published events
        carry it even in tiers without online monitoring."""
        self._note_step(step)
        if self.sweep_tooling:
            self.scheduler.advance(now, step=self._step)

    def handle_crash(self, dead: Sequence[int], lost_steps: int = 0,
                     step: Optional[int] = None) -> List[int]:
        """Fail-stop batch replacement: every dead node is swapped for a
        healthy spare in the same restart; the hardware leaves with the
        node. Returns the replacement ids."""
        now = self.control.now()
        self._note_step(step)
        self.mttf.observe_failure(now)
        self.bus.publish(CrashDetected(t=now, step=self._step,
                                       nodes=tuple(int(n) for n in dead),
                                       lost_steps=lost_steps))
        new_ids: List[int] = []
        for bad in dead:
            bad = int(bad)
            spare = self.manager.take_spare(kind="crash")
            self.control.swap_node(bad, spare)
            self.manager.retire(bad, reason="fail-stop crash", crashed=True)
            self.monitor.node_replaced(bad)
            self.bus.publish(NodeSwapped(t=now, step=self._step, old=bad,
                                         new=spare,
                                         reason="fail-stop crash"))
            new_ids.append(spare)
        return new_ids

    def handle_hang(self, verdict, step: Optional[int] = None,
                    latency_windows: float = 0.0) -> List[int]:
        """Route one ccltrace watchdog ``HangVerdict`` through the loop:
        publish the ``HangDetected`` event, record culprit/victim
        diagnoses (triage lanes + the manager's hold-check), and evict
        the culprit ranks' nodes — victims are watched, never evicted.
        A verdict with no culprits only records/publishes: the caller
        restarts the job blind. Returns the replacement node ids."""
        now = self.control.now()
        self._note_step(step)
        roles = tuple(sorted((int(n), getattr(r, "value", str(r)))
                             for n, r in verdict.roles.items()))
        self.bus.publish(HangDetected(
            t=now, step=self._step, group=int(verdict.group),
            op=verdict.op,
            culprits=tuple(int(c) for c in verdict.culprits),
            victims=tuple(int(v) for v in verdict.victims),
            roles=roles, waited_s=float(verdict.waited_s),
            deadline_s=float(verdict.deadline_s),
            latency_windows=float(latency_windows)))
        if self.diagnoser is not None:
            self.diagnoser.record_hang(verdict, t=now, step=self._step)
        self.mttf.observe_failure(now)
        new_ids: List[int] = []
        role_of = dict(roles)
        for bad in verdict.culprits:
            bad = int(bad)
            if self.manager.state.get(bad) in (NodeState.ACTIVE,
                                               NodeState.PENDING):
                new_ids.append(self.replace_node(
                    bad,
                    reason=f"hang culprit ({role_of.get(bad, 'culprit')})",
                    step=self._step, kind="hang"))
        return new_ids

    def replace_node(self, bad: int, reason: str,
                     quarantine: bool = True,
                     step: Optional[int] = None,
                     kind: str = "swap") -> int:
        """Pull ``bad`` out of the job for a healthy spare (manual-hunt /
        operator path). ``quarantine=True`` routes it to the offline
        qualification queue; ``False`` retires it outright (no tooling to
        verify with — the burn-in-only tier). ``kind`` is the lease
        urgency a fleet pool arbitrates on ("swap" | "crash" | "hang")."""
        now = self.control.now()
        self._note_step(step)
        spare = self.manager.take_spare(kind=kind)
        self.control.swap_node(bad, spare)
        self.monitor.node_replaced(bad)
        self.bus.publish(NodeSwapped(t=now, step=self._step, old=bad,
                                     new=spare, reason=reason))
        if quarantine and self.sweep_tooling:
            self.manager.state[bad] = NodeState.QUARANTINED
            self.bus.publish(NodeQuarantined(t=now, step=self._step,
                                             node_id=bad, reason=reason))
            self.scheduler.submit(bad, now=now)
        else:
            self.manager.retire(bad, reason=reason)
        return spare

    def prequalify_fleet(self, node_ids: Optional[Sequence[int]] = None,
                         reference_pool: Optional[Sequence[int]] = None,
                         enhanced: Optional[bool] = None,
                         reference: Optional[SweepReference] = None,
                         replace: bool = True,
                         step: Optional[int] = None) -> CampaignResult:
        """Offline fleet-qualification phase (§5 at fleet scale): sweep
        every candidate node in one batched campaign BEFORE it serves
        the job, so early-run failures are caught on the bench, not in
        the first thousand steps.

        Defaults: all ACTIVE nodes are candidates and the current
        healthy spares form the known-good reference pool for the
        multi-node buddy stage (round-robin — suspects are never each
        other's buddies). ``reference=None`` auto-calibrates the
        SweepReference from fleet medians. Nodes that fail are pulled:
        active failures are swapped for spares (``replace=True``) and
        every failure is quarantined and routed into the event-driven
        per-node sweep→triage loop. Publishes one ``CampaignFinished``
        summary event (plus the usual swap/quarantine events per
        failing node)."""
        if not self.sweep_tooling:
            raise RuntimeError(
                "fleet qualification needs sweep tooling "
                "(tier >= NODE_SWEEP)")
        self._note_step(step)
        now = self.control.now()
        if node_ids is None:
            node_ids = sorted(n for n, st in self.manager.state.items()
                              if st == NodeState.ACTIVE)
        if reference_pool is None:
            reference_pool = tuple(self.manager.spare_pool_ids())
        campaign = SweepCampaign(
            node_ids=tuple(int(n) for n in node_ids),
            reference_pool=tuple(int(n) for n in reference_pool),
            enhanced=(self.tier == Tier.ENHANCED) if enhanced is None
            else enhanced,
            reference=reference)
        res = fleet_qualification(self.manager.backend, campaign,
                                  self.manager.sweep_cfg)
        self.manager.stats.sweeps_run += res.sweeps
        self.manager.stats.sweeps_failed += len(res.failed)
        for rep in res.reports:
            if rep.passed:
                continue
            nid = rep.node_id
            if replace and self.manager.state.get(nid) == NodeState.ACTIVE:
                self.replace_node(nid, reason="fleet prequalification",
                                  step=self._step)
            else:
                self.manager.state[nid] = NodeState.QUARANTINED
                self.manager.spares = [s for s in self.manager.spares
                                       if s != nid]
                self.bus.publish(NodeQuarantined(
                    t=now, step=self._step, node_id=nid,
                    reason="fleet prequalification"))
                self.scheduler.submit(nid, now=now)
        self.bus.publish(CampaignFinished(
            t=now, step=self._step, nodes=len(res.reports),
            passed=len(res.passed), failed=tuple(res.failed),
            calibrated=res.calibrated, node_seconds=res.node_seconds,
            wall_s=res.wall_s))
        return res

    def take_spare(self, kind: str = "swap") -> int:
        return self.manager.take_spare(kind=kind)

    def return_spare(self, node_id: int) -> None:
        self.manager.return_spare(node_id)

    def top_up_spares(self, target: int) -> int:
        """Background warm-pool maintenance: provision (and admit) new
        nodes until ``target`` healthy spares are available."""
        n = 0
        while self.manager.spare_count < target:
            self.manager.provision_spare()
            n += 1
        return n

    def publish(self, ev: GuardEvent) -> GuardEvent:
        return self.bus.publish(ev)

    # ----------------------------------------------------------- internals

    def _note_step(self, step: Optional[int]) -> None:
        if step is not None:
            self._step = step

    def _on_manager_notify(self, topic: str, payload: dict) -> None:
        """Translate manager-level notifications into typed events."""
        t = self.control.now()
        if topic == "swap":
            self.bus.publish(NodeSwapped(
                t=t, step=self._step, old=payload["old"],
                new=payload["new"], reason=payload.get("reason", ""),
                deferred=payload.get("deferred", False)))
            self.bus.publish(NodeQuarantined(
                t=t, step=self._step, node_id=payload["old"],
                reason=payload.get("reason", "")))
            if self.sweep_tooling:      # event-driven qualification (§5)
                self.scheduler.submit(payload["old"], now=t)
        elif topic == "provision":
            self.bus.publish(NodeProvisioned(
                t=t, step=self._step, node_id=payload["node_id"]))
        elif topic == "terminate":
            self.bus.publish(NodeTerminated(
                t=t, step=self._step, node_id=payload["node_id"],
                reason=payload.get("reason", "")))

    def step_hook(self, **kw):
        """Build a ``GuardStepHook`` bound to this session (see
        ``repro.guard.hook``)."""
        from repro.guard.hook import GuardStepHook
        return GuardStepHook(session=self, **kw)


__all__ = ["CheckpointOutcome", "GuardSession", "Tier", "WindowOutcome"]
