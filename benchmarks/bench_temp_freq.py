"""Table 2: GPU temperature -> core frequency (protective downclocking).

Checks the simulator's throttle curve against the published points and
demonstrates the end-to-end effect: a thermal fault raises device
temperature, the sweep's sustained compute probe sees the throughput drop
that a short burn-in misses."""
from __future__ import annotations

import numpy as np

from benchmarks.common import GUARD_WORKLOAD, Table
from repro.simcluster import FaultKind, FaultRates, SimCluster, freq_at_temp

ZERO_RATES = FaultRates(thermal=0, power=0, mem_ecc=0, nic_down=0, nic_degraded=0, host_cpu=0, congestion=0, fail_stop=0, admission_grey_p=0)


PAPER_POINTS = [(50, 1.93), (60, 1.93), (69, 1.78), (77, 1.38)]


def run() -> Table:
    t = Table("GPU temperature -> clock frequency", "table2")
    for temp, ghz in PAPER_POINTS:
        got = float(freq_at_temp(np.array([temp]))[0])
        t.add(f"{temp}C", f"{ghz:.2f} GHz", f"{got:.2f} GHz")

    # end-to-end: sustained probe vs short burn under a thermal fault
    c = SimCluster(n_active=4, n_spare=0, workload=GUARD_WORKLOAD,
                   rates=ZERO_RATES, seed=0)
    c.injector.inject(FaultKind.THERMAL, node=1, severity=0.85, device=2)
    short = c.compute_probe(1, 2, seconds=10.0)
    long = c.compute_probe(1, 2, seconds=3600.0)
    healthy = c.fleet.hw.base_tflops
    t.add("burn-in (10s) sees", "-", f"{short/healthy:.0%} of peak",
          "thermal lag hides the throttle from short tests")
    t.add("sweep (1h) sees", "-", f"{long/healthy:.0%} of peak",
          "sustained burn reaches the throttled steady state")
    return t


def main() -> Table:
    t = run()
    t.show()
    t.save("table2_temp_freq")
    return t


if __name__ == "__main__":
    main()
