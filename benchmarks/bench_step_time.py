"""Fig. 10: mean training step time before/after Guard (17 s -> 10 s).

'Before' is the inherited state of an unmanaged cluster: a grey population
that accumulated over weeks (burn-in admitted them; nobody evicted them).
'After' is the same fleet under full Guard. The synchronous max-composition
over nodes means a handful of severe greys sets the whole job's pace."""
from __future__ import annotations

import numpy as np

from benchmarks.common import GUARD_WORKLOAD, RATES, Table
from repro.simcluster import (FaultKind, RunConfig, SimCluster, Tier,
                              simulate_run)

# the accumulated grey population of a long-unmanaged cluster
LEGACY_GREYS = [
    (3, FaultKind.THERMAL, 1.0), (11, FaultKind.THERMAL, 0.95),
    (13, FaultKind.THERMAL, 0.9), (17, FaultKind.POWER, 0.9),
    (23, FaultKind.NIC_DOWN, 0.5), (29, FaultKind.MEM_ECC, 0.95),
    (31, FaultKind.HOST_CPU, 0.8), (37, FaultKind.NIC_DEGRADED, 0.8),
    (41, FaultKind.POWER, 0.6), (47, FaultKind.MEM_ECC, 0.7),
]


def _seed_legacy(cluster: SimCluster) -> None:
    for node, kind, sev in LEGACY_GREYS:
        cluster.injector.inject(kind, node, severity=sev)
    cluster.fleet.advance_thermals(3600.0)


def run(duration_h: float = 8.0) -> Table:
    t = Table("Mean step time before/after Guard", "fig10")
    results = {}
    for label, tier in (("before", Tier.BURNIN), ("after", Tier.ENHANCED)):
        cfg = RunConfig(tier=tier, n_nodes=64, n_spare=10,
                        duration_h=duration_h, workload=GUARD_WORKLOAD,
                        rates=RATES, seed=7)
        # pre-seed the same legacy grey population into both runs
        import repro.simcluster.runtime as rt
        orig = rt.SimCluster
        made = {}

        class Seeded(orig):                      # intercept construction
            def __init__(self, *a, **k):
                super().__init__(*a, **k)
                _seed_legacy(self)
                made["c"] = self

        rt.SimCluster = Seeded
        try:
            r = simulate_run(cfg)
        finally:
            rt.SimCluster = orig
        results[label] = r
        warm = int(1800.0 / GUARD_WORKLOAD.healthy_step_s)
        steady = float(np.mean(r.step_times[warm:]))
        t.add(f"step time {label}",
              "17 s" if label == "before" else "10 s",
              f"{steady:.1f} s",
              f"p95 {np.percentile(r.step_times[warm:], 95):.1f}s, "
              f"{r.guard_restarts} guard restarts")
    b = results["before"].step_times
    a = results["after"].step_times
    warm = int(1800.0 / GUARD_WORKLOAD.healthy_step_s)
    gain = np.mean(b[warm:]) / np.mean(a[warm:]) - 1.0
    t.add("training efficiency gain", "~70%", f"{100*gain:.0f}%",
          "steps/hour improvement at steady state")
    return t


def main() -> Table:
    t = run()
    t.show()
    t.save("fig10_step_time")
    return t


if __name__ == "__main__":
    main()
