"""Fig. 9: run-to-run variance of training step time, before (no Guard)
vs after (full Guard).

Each 'run' draws a fresh fleet (its own grey-node population, per the
admission model): without Guard the straggler draw dominates the run's mean
step time, producing the published ~20% run-to-run spread; with Guard the
greys are detected and replaced early, so every run converges to the
healthy step time (~1%)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import GUARD_WORKLOAD, RATES, Table, pct
from repro.simcluster import RunConfig, Tier, simulate_run


def _runs(tier: Tier, n_runs: int, duration_h: float):
    means = []
    for seed in range(n_runs):
        cfg = RunConfig(tier=tier, n_nodes=64, n_spare=8,
                        duration_h=duration_h, workload=GUARD_WORKLOAD,
                        rates=RATES, seed=1000 + seed)
        r = simulate_run(cfg)
        # steady-state mean (skip the first hour: Guard needs a few
        # windows to drain the inherited grey population)
        warm = int(3600.0 / GUARD_WORKLOAD.healthy_step_s)
        means.append(float(np.mean(r.step_times[warm:])))
    return np.asarray(means)


def run(n_runs: int = 6, duration_h: float = 10.0) -> Table:
    t = Table("Run-to-run step-time variance", "fig9")
    before = _runs(Tier.BURNIN, n_runs, duration_h)
    after = _runs(Tier.ENHANCED, n_runs, duration_h)
    cv_b = before.std() / before.mean()
    cv_a = after.std() / after.mean()
    t.add("variance before", "20%", pct(float(cv_b)),
          f"means {np.round(before, 1).tolist()}")
    t.add("variance after", "1%", pct(float(cv_a)),
          f"means {np.round(after, 1).tolist()}")
    return t


def main() -> Table:
    t = run()
    t.show()
    t.save("fig9_variance")
    return t


if __name__ == "__main__":
    main()
