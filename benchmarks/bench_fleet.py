"""Fleet control-plane benchmark: N concurrent jobs over one shared
node inventory, written to ``BENCH_fleet.json``.

Drives ``simulate_fleet``: 16 concurrent simulated jobs (mixed
ENHANCED/ONLINE tiers, mixed priorities) over a 4096-node fleet, all
leasing replacement capacity from one ``FleetController`` — global
home-tagged spare pool, shared sweep bench, periodic healthscan
campaigns, and the cursor-replayable fleet event stream.

Gates (CI runs this in the scale job and fails the build on violation):

  starvation     ZERO starvation events — no lease request ever waits
                 past the starvation bound; the fair-share floor keeps
                 low-priority tenants served under contention
  census         bit-consistent pool census — the sum of every job's
                 node census + the free pool + the transfer-ghost
                 ledger equals the initial inventory + every node ever
                 provisioned, checked after the full run
  overhead       control-plane self-time (pool arbitration, lease
                 bookkeeping, healthscan orchestration, event-log
                 appends) below 5% of total sim wall time

Run:  PYTHONPATH=src python -m benchmarks.bench_fleet [--quick]
          [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from repro.guard import Tier
from repro.simcluster import FleetJobSpec, FleetRunConfig, simulate_fleet

OVERHEAD_GATE = 0.05          # control plane < 5% of sim wall time
N_JOBS = 16
FLEET_NODES = 4096            # summed across the concurrent jobs


def fleet_config(quick: bool) -> FleetRunConfig:
    per_job = FLEET_NODES // N_JOBS
    tiers = [Tier.ENHANCED, Tier.ONLINE]
    jobs = tuple(
        FleetJobSpec(
            name=f"job{i:02d}",
            tier=tiers[i % 2],
            n_nodes=per_job,
            n_spare=2,
            # spread priorities so the fair-share floor is actually
            # exercised: some low-priority tenants under high-priority
            # neighbors
            priority=1 + (i % 4),
            seed=i)
        for i in range(N_JOBS))
    return FleetRunConfig(
        jobs=jobs,
        duration_h=2.0 if quick else 8.0,
        # enough bench capacity that background healthscan campaigns
        # find idle slots between foreground qualifications
        bench_slots=8,
        healthscan_period_s=1800.0,
        healthscan_batch=8,
        starvation_age_s=3600.0,
        floor_frac=0.5,
        spare_target=24,
        home_min=1,
        seed=11)


def run_fleet(quick: bool) -> dict:
    cfg = fleet_config(quick)
    res = simulate_fleet(cfg)
    per_tier: dict = {}
    for j in res.jobs:
        t = per_tier.setdefault(j["tier"], {"jobs": 0, "steps": 0,
                                            "leases": 0, "crashes": 0})
        t["jobs"] += 1
        t["steps"] += j["steps"]
        t["leases"] += j["leases"]
        t["crashes"] += j["crashes"]
    return {
        "n_jobs": len(cfg.jobs),
        "fleet_nodes": sum(j.n_nodes for j in cfg.jobs),
        "duration_h": cfg.duration_h,
        "jobs": res.jobs,
        "per_tier": per_tier,
        "starvation_events": res.starvation_events,
        "max_wait_s": res.max_wait_s,
        "census": {k: v for k, v in res.census.items() if k != "jobs"},
        "census_ok": res.census_ok,
        "pool": res.pool,
        "healthscan": res.healthscan,
        "events_logged": res.events_logged,
        "overhead_s": res.overhead_s,
        "wall_s": res.wall_s,
        "overhead_frac": res.overhead_frac,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizing (shorter fleet horizon)")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_fleet.json"))
    args = ap.parse_args(argv)

    t0 = time.perf_counter()
    fleet = run_fleet(args.quick)
    out = {
        "benchmark": "guard_fleet",
        "mode": "quick" if args.quick else "full",
        **fleet,
        "gates": {"starvation_events": 0,
                  "census_ok": True,
                  "overhead_frac_max": OVERHEAD_GATE},
        "total_wall_s": time.perf_counter() - t0,
    }
    out["ok"] = (fleet["starvation_events"] == 0 and fleet["census_ok"]
                 and fleet["overhead_frac"] < OVERHEAD_GATE)

    print(f"{'job':>8s}{'tier':>6s}{'prio':>6s}{'steps':>9s}"
          f"{'crashes':>9s}{'leases':>8s}{'xfers':>7s}")
    for j in fleet["jobs"]:
        print(f"{j['name']:>8s}{j['tier']:6d}{j['priority']:6d}"
              f"{j['steps']:9d}{j['crashes']:9d}{j['leases']:8d}"
              f"{j['transfers']:7d}")
    cen = fleet["census"]
    print(f"\nfleet: {fleet['n_jobs']} jobs / {fleet['fleet_nodes']} nodes"
          f" / {fleet['duration_h']:.0f}h horizon")
    print(f"pool: {fleet['pool']['grants']} grants "
          f"({fleet['pool']['transfers']} transfers, "
          f"{fleet['pool']['provisions']} provisioned), "
          f"max wait {fleet['max_wait_s']:.0f}s")
    print(f"healthscan: {fleet['healthscan'].get('campaigns', 0)} campaigns,"
          f" {fleet['healthscan'].get('scanned', 0)} scanned,"
          f" {fleet['healthscan'].get('failed', 0)} pulled")
    print(f"census: accounted {cen['accounted']} == expected "
          f"{cen['expected']} (inventory {cen['inventory']} + provisions "
          f"{cen['provisions']}), conserved={fleet['census_ok']}")
    print(f"events: {fleet['events_logged']} streamed; control plane "
          f"{fleet['overhead_s'] * 1e3:.1f} ms / {fleet['wall_s']:.1f} s "
          f"sim wall = {fleet['overhead_frac'] * 100:.2f}% "
          f"(gate {OVERHEAD_GATE * 100:.0f}%)")

    ok = True
    if fleet["starvation_events"]:
        print(f"FAIL: {fleet['starvation_events']} starvation events "
              f"(max wait {fleet['max_wait_s']:.0f}s)", file=sys.stderr)
        ok = False
    if not fleet["census_ok"]:
        print(f"FAIL: census not conserved: accounted {cen['accounted']} "
              f"!= expected {cen['expected']}", file=sys.stderr)
        ok = False
    if fleet["overhead_frac"] >= OVERHEAD_GATE:
        print(f"FAIL: control-plane overhead "
              f"{fleet['overhead_frac'] * 100:.2f}% >= "
              f"{OVERHEAD_GATE * 100:.0f}%", file=sys.stderr)
        ok = False

    with open(args.out, "w") as f:
        json.dump(out, f, indent=1, default=str)
    print(f"wrote {args.out}  ({out['total_wall_s']:.0f}s)")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
