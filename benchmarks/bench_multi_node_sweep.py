"""Fig. 6 + Fig. 7: multi-node sweep detects inter-node communication
degradation; 2-node groups already suffice, and inflation scales
predictably as faulty nodes are added (cluster level).

Single-node sweeps CANNOT see these faults (a NIC reroute looks healthy
from inside the node) — the published motivation for the 2-node default."""
from __future__ import annotations

import numpy as np

from benchmarks.common import GUARD_WORKLOAD, Table, pct
from repro.core.sweep import SweepConfig, multi_node_sweep, single_node_sweep
from repro.simcluster import FaultKind, FaultRates, SimCluster

ZERO_RATES = FaultRates(thermal=0, power=0, mem_ecc=0, nic_down=0, nic_degraded=0, host_cpu=0, congestion=0, fail_stop=0, admission_grey_p=0)



def run() -> Table:
    t = Table("Multi-node sweep: inter-node comm validation", "fig6_fig7")
    c = SimCluster(n_active=16, n_spare=0, workload=GUARD_WORKLOAD,
                   rates=ZERO_RATES, seed=4)
    # node 1: NIC down (rerouted) — single-node sweep passes, 2-node fails
    c.injector.inject(FaultKind.NIC_DOWN, 1, device=3)
    # node 2: degraded link
    c.injector.inject(FaultKind.NIC_DEGRADED, 2, severity=0.7)

    cfg = SweepConfig()
    ref = c.reference().pair_step_time

    for node, kind in ((1, "nic_down"), (2, "nic_degraded")):
        s1 = single_node_sweep(c, node, cfg)
        s2 = multi_node_sweep(c, node, buddies=[0], cfg=cfg)
        med = float(np.median(s2.measurements["step_times"]))
        t.add(f"node{node} ({kind}) 1-node sweep", "passes (blind)",
              "PASS" if s1.passed else "FAIL",
              "intra-node probes can't see inter-node links")
        t.add(f"node{node} ({kind}) 2-node sweep", "step inflation",
              f"{'FAIL' if not s2.passed else 'PASS'} "
              f"(+{pct(med/ref - 1)})",
              f"{ref:.2f}s -> {med:.2f}s")

    # Fig. 6: group sizes 2/4/8 — 2-node already detects
    for g in (2, 4, 8):
        buddies = [n for n in range(3, 3 + g - 1)]
        rep = multi_node_sweep(c, 1, buddies=buddies,
                               cfg=SweepConfig(group_size=g))
        med = float(np.median(rep.measurements["step_times"]))
        t.add(f"{g}-node group w/ faulty node", "detectable at 2",
              f"{'detected' if not rep.passed else 'missed'}",
              f"group step {med:.2f}s vs ref {ref:.2f}s")

    # Fig. 7: cluster-level — inflation grows with faulty-node count
    for nbad in (0, 1, 2, 4):
        cc = SimCluster(n_active=32, n_spare=0, workload=GUARD_WORKLOAD,
                        rates=ZERO_RATES, seed=5)
        for n in range(nbad):
            cc.injector.inject(FaultKind.NIC_DEGRADED, n, severity=0.3 + 0.15 * n)
        times = [cc.run_step()["step_time"] for _ in range(30)]
        t.add(f"cluster w/ {nbad} faulty", "scales predictably",
              f"{np.mean(times):.2f}s",
              "synchronous max-composition over 32 nodes")
    return t


def main() -> Table:
    t = run()
    t.show()
    t.save("fig6_multi_node_sweep")
    return t


if __name__ == "__main__":
    main()
