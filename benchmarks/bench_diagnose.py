"""Attribution benchmark: precision/recall/latency of ``repro.diagnose``
against scenario ground truth, written to ``BENCH_diagnose.json``.

Three labeled correlated-fault scenarios (plus a mixed run with
background Poisson wear) drive a barrier-grouped fleet with realistic
measured-wall telemetry — one degraded node inflates the reported step
time of every peer in its DP gradient-barrier group, so the raw
detector flags whole groups. The diagnoser must separate them:

  rack_thermal       8-node rack inside a 16-node barrier group: 8
                     compute culprits + 8 cascade victims per window
  switch_failure     16 nodes lose/downtrain NICs: comm culprits
  congestion_storm   transient fabric congestion: NOBODY is a culprit

Scoring against the injector's fault log (``RunResult.fault_log``):

  precision   culprit attributions (compute/comm/data-stall verdicts)
              that pointed at a node with a genuinely active fault
  recall      scenario-injected grey nodes that were culprit-attributed
  victims     evictions of nodes with NO active fault — must be ZERO
              (the false-eviction reduction the subsystem exists for)
  overhead    what-if + classification cost per diagnosed window at
              1024 nodes (mean under 1 ms) and at 16384 nodes (p50
              under the same 1 ms — steady-state windows reuse verdict
              records; the first diagnosing window pays the O(flagged)
              materialization by design)

Run:  PYTHONPATH=src python -m benchmarks.bench_diagnose [--quick]
          [--out PATH]

Exit is non-zero if any gate fails (CI runs this in the smoke job).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from repro.core import DetectorConfig, StragglerDetector
from repro.core.telemetry import Frame
from repro.diagnose import Diagnoser, TimingTrace, Topology, WindowTiming
from repro.guard import Tier
from repro.simcluster import (CongestionStorm, FaultRates, RackThermal,
                              RunConfig, SwitchFailure, WorkloadProfile,
                              simulate_run)

PRECISION_GATE = 0.90
RECALL_GATE = 0.80
OVERHEAD_GATE_MS = 1.0

# verdicts that accuse the node itself (vs. held/watched verdicts)
CULPRIT_CAUSES = ("compute_degraded", "comm_degraded", "data_stall")
GREY_KINDS = ("thermal", "power", "mem_ecc", "nic_down", "nic_degraded",
              "host_cpu")
# expected remediation lane per injected fault kind (lane accuracy)
EXPECTED_LANE = {
    "thermal": "compute_degraded", "power": "compute_degraded",
    "mem_ecc": "compute_degraded", "nic_down": "comm_degraded",
    "nic_degraded": "comm_degraded", "host_cpu": "data_stall",
    "congestion": "comm_degraded",
}

QUIET = FaultRates(thermal=0, power=0, mem_ecc=0, nic_down=0,
                   nic_degraded=0, host_cpu=0, congestion=0, fail_stop=0,
                   admission_grey_p=0)
# comm-heavier split than the default pretrain profile so link-level
# faults land above the detector's slowdown floor
WORKLOAD = WorkloadProfile(name="diagnose_bench", compute_s=6.0,
                           comm_exposed_s=2.5, host_s=1.5)


def base_config(duration_h: float, **kw) -> RunConfig:
    kw.setdefault("rates", QUIET)
    kw.setdefault("initial_grey_p", 0.0)
    return RunConfig(tier=Tier.ENHANCED, n_nodes=128, n_spare=16,
                     duration_h=duration_h, dp_group_size=16,
                     diagnose=True, workload=WORKLOAD, seed=7, **kw)


def scenario_suite(quick: bool):
    dur = 2.5 if quick else 4.0
    return {
        # rack rows 24-31 sit inside barrier group 16-31: half the group
        # is genuinely degraded, half is stalled behind the barrier
        "rack_thermal": base_config(dur, scenarios=(
            RackThermal(at_h=0.5, rack_size=8, rack_start=24,
                        severity=0.85, power_fraction=0.0),)),
        "switch_failure": base_config(dur, scenarios=(
            SwitchFailure(at_h=0.5, group_size=16, group_start=48,
                          down_fraction=0.25, severity=0.9),)),
        "congestion_storm": base_config(dur, scenarios=(
            CongestionStorm(at_h=0.5, duration_h=1.0, hit_fraction=0.25,
                            severity=0.7),)),
        "mixed": base_config(dur, rates=FaultRates(),
                             initial_grey_p=0.03, scenarios=(
            RackThermal(at_h=0.6, rack_size=8, rack_start=24,
                        severity=0.85, power_fraction=0.0),
            SwitchFailure(at_h=1.0, group_size=8, group_start=96,
                          down_fraction=0.25, severity=0.9),
            CongestionStorm(at_h=0.4, duration_h=0.8,
                            hit_fraction=0.2, severity=0.7),)),
    }


def _active_fault(fault_log, node: int, t: float, kinds,
                  slack_s: float = 120.0):
    """The first logged fault of ``kinds`` active on ``node`` around
    ``t`` (attribution integrates a trace window, hence the slack)."""
    for f in fault_log:
        if f["node"] != node or f["kind"] not in kinds:
            continue
        cleared = f["t_cleared"]
        if f["t_start"] - slack_s <= t and \
                (cleared is None or t <= cleared + slack_s):
            return f
    return None


def score_run(name: str, result) -> dict:
    """Attribution + eviction scoring for one simulated run."""
    log = result.fault_log
    diag = [e for e in result.events if e["kind"] == "diagnosis"]
    accusations = [e for e in diag if e["root_cause"] in CULPRIT_CAUSES]
    held = [e for e in diag if e["held"]]

    tp = fp = lane_ok = 0
    attributed = set()
    for e in accusations:
        f = _active_fault(log, e["node_id"], e["t"],
                          GREY_KINDS + ("congestion",))
        if f is not None:
            tp += 1
            attributed.add(e["node_id"])
            if EXPECTED_LANE.get(f["kind"]) == e["root_cause"]:
                lane_ok += 1
        else:
            fp += 1

    # recall denominator: scenario/background grey nodes, minus nodes
    # that hard-crashed (fail-stop leaves nothing to attribute)
    crashed = {f["node"] for f in log if f["kind"] == "fail_stop"}
    truth = {f["node"] for f in log if f["kind"] in GREY_KINDS} - crashed

    # the headline false-eviction gate: evictions of nodes that had NO
    # active fault of any perf-affecting kind when they were pulled
    victims_evicted = []
    for e in result.events:
        if e["kind"] != "swap" or "crash" in e["reason"]:
            continue
        if _active_fault(log, e["old"], e["t"],
                         GREY_KINDS + ("congestion",), slack_s=600.0) \
                is None:
            victims_evicted.append(e["old"])

    return {
        "scenario": name,
        "steps": result.steps,
        "diagnosis_events": len(diag),
        "accusations": len(accusations),
        "held_verdicts": len(held),
        "tp": tp,
        "fp": fp,
        "lane_ok": lane_ok,
        "truth_nodes": sorted(truth),
        "attributed_nodes": sorted(attributed & truth),
        "recall_hits": len(attributed & truth),
        "recall_total": len(truth),
        "victims_evicted": sorted(set(victims_evicted)),
    }


def overhead_bench(n: int = 1024, windows: int = 30,
                   group: int = 32) -> dict:
    """ms/window of ``Diagnoser.diagnose`` (what-if + classification) on
    a synthetic fleet with latched stragglers — the steady state where
    attribution actually runs."""
    rng = np.random.RandomState(3)
    topo = Topology.grouped(n, group)
    trace = TimingTrace(depth=8)
    diag = Diagnoser(trace, topo)
    det = StragglerDetector(DetectorConfig())
    stragglers = [(7, 1.4), (n // 2 + 5, 1.3), (n - 9, 1.25)]
    node_ids = np.arange(n, dtype=np.int64)
    costs = []
    for w in range(windows):
        comp = 8.0 * (1.0 + rng.normal(0, 0.004, n))
        comm = 0.6 * (1.0 + rng.normal(0, 0.004, n))
        host = 1.4 * (1.0 + rng.normal(0, 0.004, n))
        for nid, f in stragglers:
            comp[nid] *= f
        own = comp + comm + host
        wall = topo.group_max(own)
        trace.push(WindowTiming(t=60.0 * w, step=6 * w, node_ids=node_ids,
                                compute=comp, comm=comm, host=host,
                                stall=wall - own))
        metrics = {
            "step_time": wall,
            "gpu_temp": 58.0 + rng.normal(0, 0.8, n),
            "gpu_util": np.clip(rng.normal(0.97, 0.01, n), 0, 1),
            "gpu_freq": np.full(n, 1.93) + rng.normal(0, 0.002, n),
            "gpu_power": 350.0 + rng.normal(0, 3.0, n),
            "nic_errors": np.zeros(n),
            "nic_tx_rate": 50.0 + rng.normal(0, 0.5, n),
            "nic_up": np.ones(n),
        }
        frame = Frame(t=60.0 * w, step=6 * w, node_ids=node_ids,
                      metrics=metrics, valid=np.ones(n, bool))
        fleet = det.update(frame)
        t0 = time.perf_counter()
        out = diag.diagnose(frame, fleet)
        dt = (time.perf_counter() - t0) * 1e3
        if out is not None:              # only diagnosing windows count
            costs.append(dt)
    return {
        "n_nodes": n,
        "group_size": group,
        "diagnosed_windows": len(costs),
        "ms_per_window_mean": float(np.mean(costs)) if costs else 0.0,
        "ms_per_window_p50": float(np.median(costs)) if costs else 0.0,
        "ms_per_window_max": float(np.max(costs)) if costs else 0.0,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizing (shorter scenario runs)")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_diagnose.json"))
    args = ap.parse_args(argv)

    t0 = time.perf_counter()
    runs = {}
    for name, cfg in scenario_suite(args.quick).items():
        r = simulate_run(cfg)
        runs[name] = score_run(name, r)

    tp = sum(s["tp"] for s in runs.values())
    fp = sum(s["fp"] for s in runs.values())
    lane_ok = sum(s["lane_ok"] for s in runs.values())
    # recall over the LABELED scenarios (pinned severities, all
    # detectable); the mixed run's background greys span arbitrary
    # severities and score precision/eviction only
    rec_hits = sum(runs[n]["recall_hits"]
                   for n in ("rack_thermal", "switch_failure"))
    rec_total = sum(runs[n]["recall_total"]
                    for n in ("rack_thermal", "switch_failure"))
    victims = sorted({v for s in runs.values()
                      for v in s["victims_evicted"]})
    precision = tp / max(tp + fp, 1)
    recall = rec_hits / max(rec_total, 1)
    lane_accuracy = lane_ok / max(tp, 1)

    overhead = overhead_bench()
    # scaled overhead: same gate at 16k nodes, scored on the p50 —
    # steady-state windows reuse verdict records, so only the first
    # diagnosing window pays the O(flagged) materialization cost
    overhead_16k = overhead_bench(n=16384, windows=30, group=512)
    out = {
        "benchmark": "guard_diagnose",
        "mode": "quick" if args.quick else "full",
        "scenarios": runs,
        "pooled": {
            "precision": precision,
            "recall": recall,
            "lane_accuracy": lane_accuracy,
            "tp": tp, "fp": fp,
            "recall_hits": rec_hits, "recall_total": rec_total,
            "victims_evicted": victims,
        },
        "overhead": overhead,
        "overhead_16k": overhead_16k,
        "gates": {
            "precision_min": PRECISION_GATE,
            "recall_min": RECALL_GATE,
            "overhead_ms_max": OVERHEAD_GATE_MS,
            "overhead_16k_p50_ms_max": OVERHEAD_GATE_MS,
            "victims_evicted_max": 0,
        },
        "total_wall_s": time.perf_counter() - t0,
    }

    print(f"{'scenario':>18s}{'accuse':>8s}{'tp':>5s}{'fp':>5s}"
          f"{'held':>6s}{'recall':>10s}{'victims':>9s}")
    for name, s in runs.items():
        rec = f"{s['recall_hits']}/{s['recall_total']}" \
            if s["recall_total"] else "-"
        print(f"{name:>18s}{s['accusations']:8d}{s['tp']:5d}{s['fp']:5d}"
              f"{s['held_verdicts']:6d}{rec:>10s}"
              f"{len(s['victims_evicted']):9d}")
    print(f"\npooled: precision {precision:.3f} (gate {PRECISION_GATE}), "
          f"recall {recall:.3f} (gate {RECALL_GATE}), "
          f"lane accuracy {lane_accuracy:.3f}")
    print(f"overhead @{overhead['n_nodes']} nodes: "
          f"{overhead['ms_per_window_mean']:.3f} ms/window "
          f"(gate {OVERHEAD_GATE_MS} ms)")
    print(f"overhead @{overhead_16k['n_nodes']} nodes: "
          f"p50 {overhead_16k['ms_per_window_p50']:.3f} / "
          f"mean {overhead_16k['ms_per_window_mean']:.3f} ms/window "
          f"(p50 gate {OVERHEAD_GATE_MS} ms)")

    ok = True
    if precision < PRECISION_GATE:
        print(f"FAIL: precision {precision:.3f} < {PRECISION_GATE}",
              file=sys.stderr)
        ok = False
    if recall < RECALL_GATE:
        print(f"FAIL: recall {recall:.3f} < {RECALL_GATE}",
              file=sys.stderr)
        ok = False
    if victims:
        print(f"FAIL: fault-free nodes evicted: {victims}",
              file=sys.stderr)
        ok = False
    if overhead["ms_per_window_mean"] > OVERHEAD_GATE_MS:
        print(f"FAIL: attribution overhead "
              f"{overhead['ms_per_window_mean']:.3f} ms/window > "
              f"{OVERHEAD_GATE_MS}", file=sys.stderr)
        ok = False
    if overhead_16k["ms_per_window_p50"] > OVERHEAD_GATE_MS:
        print(f"FAIL: 16k attribution overhead p50 "
              f"{overhead_16k['ms_per_window_p50']:.3f} ms/window > "
              f"{OVERHEAD_GATE_MS}", file=sys.stderr)
        ok = False

    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {args.out}  ({out['total_wall_s']:.0f}s)")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
