"""Roofline table: per (arch x shape) single-pod roofline terms from the
dry-run artifacts (EXPERIMENTS.md §Roofline reads this output).

Run ``python -m repro.launch.dryrun --all`` first; this bench aggregates
benchmarks/results/dryrun/*.json."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import RESULTS_DIR, Table

DRYRUN_DIR = os.path.join(RESULTS_DIR, "dryrun")


def load_cells(mesh: str = "single"):
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def run() -> Table:
    t = Table("Roofline terms per (arch x shape), 16x16 mesh", "roofline")
    cells = load_cells("single")
    if not cells:
        t.add("no dry-run artifacts", "-", "-",
              "run: python -m repro.launch.dryrun --all")
        return t
    for c in cells:
        r = c["roofline"]
        name = f"{c['arch']}/{c['shape']}"
        terms = (f"c {r['compute_s']*1e3:7.1f} | m {r['memory_s']*1e3:7.1f}"
                 f" | n {r['collective_s']*1e3:7.1f} ms")
        t.add(name, r["dominant"][:4], terms,
              f"useful {r['useful_ratio']:.2f} "
              f"rf {r['roofline_fraction']:.2f} "
              f"compile {c['compile_s']:.0f}s")
    return t


def main() -> Table:
    t = run()
    t.show()
    t.save("roofline")
    return t


if __name__ == "__main__":
    main()
