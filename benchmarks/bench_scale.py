"""Fleet-scale benchmark: the refactor's speedup, pinned in CI.

Two measurements, written to ``BENCH_scale.json``:

  1. ``detector``: microbenchmark of ``StragglerDetector.update`` on
     synthetic full-metric frames at 1k/4k/16k nodes — µs per evaluation
     window plus the number of per-node Python objects materialized per
     window, which must scale with the FLAGGED population, not the fleet
     (the struct-of-arrays FleetAssessment contract).
  2. ``simulate``: wall-clock of the 2048-node, 72 h ENHANCED
     ``simulate_run`` on the window-granular engine, against the
     pre-refactor step-granular baseline measured interleaved on the
     same config / seed / machine immediately before the refactor
     landed (commit 6c6cb4c): ~8-9x min-to-min on the dev container
     (target 10x; enforced regression gate 6x — see SPEEDUP_GATE).

Run:  PYTHONPATH=src python -m benchmarks.bench_scale [--quick]
          [--out PATH] [--budget-s S]

``--quick`` is the CI smoke sizing: a 1024-node short run under a
wall-time budget (exit non-zero if it blows the budget), with the
speedup gate reported but not enforced (CI machines are not the
baseline machine). Full mode enforces the speedup gate.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from repro.core import DetectorConfig, StragglerDetector
from repro.core.telemetry import Frame
from repro.guard import Tier
from repro.simcluster import RunConfig, simulate_run

# Pre-refactor step-granular baseline, measured on the exact BENCH config
# below at the commit preceding this refactor (simulate_run with the
# per-step loop, list-scan FaultInjector, per-node detector objects).
# wall_s is the MIN over 7 interleaved old/new runs on the same machine
# (the least-interference sample; same convention as the new-path
# measurement), so the recorded speedup is conservative. Kept as the
# fixed reference the CI artifact trends against.
PRE_REFACTOR = {
    "commit": "6c6cb4c",
    "wall_s": 32.37,
    "wall_s_samples": [32.37, 34.30, 37.18, 42.69, 32.67, 42.14, 35.13],
    "steps": 7419,
    "config": "2048 nodes, 72 h, ENHANCED, initial_grey_p=0.02, seed 0",
}

# The refactor's target was >=10x; the measured speedup on the dev
# container is ~8-9x min-to-min (recorded in the artifact). The enforced
# gate sits at 6x so CI machine variance cannot flake the job while a
# genuine engine regression still fails loudly.
SPEEDUP_TARGET = 10.0
SPEEDUP_GATE = 6.0

SCALE_CONFIG = dict(tier=Tier.ENHANCED, n_nodes=2048, n_spare=128,
                    duration_h=72.0, initial_grey_p=0.02, seed=0)
QUICK_CONFIG = dict(tier=Tier.ENHANCED, n_nodes=1024, n_spare=64,
                    duration_h=6.0, initial_grey_p=0.05, seed=0)


def synthetic_frame(w: int, n: int, rng, stragglers) -> Frame:
    t = 10.0 * (1.0 + rng.normal(0, 0.004, n))
    for nid, factor in stragglers:
        t[nid] *= factor
    metrics = {
        "step_time": t,
        "gpu_temp": 58.0 + rng.normal(0, 0.8, n),
        "gpu_util": np.clip(rng.normal(0.97, 0.01, n), 0, 1),
        "gpu_freq": np.full(n, 1.93) + rng.normal(0, 0.002, n),
        "gpu_power": 350.0 + rng.normal(0, 3.0, n),
        "nic_errors": np.zeros(n),
        "nic_tx_rate": 50.0 + rng.normal(0, 0.5, n),
        "nic_up": np.ones(n),
    }
    return Frame(t=w * 60.0, step=w * 6,
                 node_ids=np.arange(n, dtype=np.int64),
                 metrics=metrics, valid=np.ones(n, bool))


def detector_microbench(n: int, windows: int = 24,
                        n_stragglers: int = 4) -> dict:
    """µs/window + materialized-object count for an N-node fleet with a
    handful of genuine stragglers (the realistic steady state)."""
    rng = np.random.RandomState(n)
    stragglers = [(i * (n // max(n_stragglers, 1)) + 7, 1.2)
                  for i in range(n_stragglers)]
    det = StragglerDetector(DetectorConfig())
    frames = [synthetic_frame(w, n, rng, stragglers)
              for w in range(windows)]
    per_window_us = []
    materialized = []
    flagged = []
    for frame in frames:
        t0 = time.perf_counter()
        fa = det.update(frame)
        fa.flagged_assessments()         # what the monitor/policy consume
        per_window_us.append((time.perf_counter() - t0) * 1e6)
        materialized.append(fa.materialized)
        flagged.append(int(fa.flagged.sum()))
    warm = per_window_us[2:]             # skip alloc warmup
    return {
        "n_nodes": n,
        "windows": windows,
        "us_per_window_mean": float(np.mean(warm)),
        "us_per_window_p50": float(np.median(warm)),
        "flagged_steady": flagged[-1],
        "objects_per_window_max": int(max(materialized)),
        "objects_O_flagged": bool(
            max(materialized) <= max(max(flagged), 1) + n_stragglers),
    }


def sim_scale_bench(quick: bool, repeats: int = 1) -> dict:
    cfg = QUICK_CONFIG if quick else SCALE_CONFIG
    walls = []
    r = None
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        r = simulate_run(RunConfig(**cfg))
        walls.append(time.perf_counter() - t0)
    # min over repeats: wall-clock gates need the least-interference
    # sample on shared machines (same convention as the baseline)
    wall = min(walls)
    out = {
        "config": {k: (int(v) if k == "tier" else v)
                   for k, v in cfg.items()},
        "wall_s": wall,
        "wall_s_all": walls,
        "steps": r.steps,
        "crashes": r.crashes,
        "mfu": r.mfu,
        "mttf_h": r.mttf_h,
        "events": len(r.events),
    }
    if not quick:
        out["baseline"] = PRE_REFACTOR
        out["speedup_vs_prerefactor"] = PRE_REFACTOR["wall_s"] / wall
        out["speedup_target"] = SPEEDUP_TARGET
        out["speedup_gate"] = SPEEDUP_GATE
    return out


def scale_summary(quick: bool = True) -> dict:
    """Compact detector-scaling summary for embedding in other
    benchmark artifacts (benchmarks.run_all). Engine wall-clock numbers
    live in BENCH_scale.json only."""
    sizes = (1024, 4096) if quick else (1024, 4096, 16384)
    return {
        "detector": [detector_microbench(n, windows=12) for n in sizes],
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizing (1024-node short run)")
    ap.add_argument("--budget-s", type=float, default=300.0,
                    help="wall-time budget for the quick run (CI gate)")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_scale.json"))
    args = ap.parse_args(argv)

    t0 = time.perf_counter()
    detector = [detector_microbench(n) for n in (1024, 4096, 16384)]
    sim = sim_scale_bench(quick=args.quick, repeats=1 if args.quick else 3)
    out = {
        "benchmark": "guard_scale",
        "mode": "quick" if args.quick else "full",
        "detector": detector,
        "simulate": sim,
        "total_wall_s": time.perf_counter() - t0,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)

    print(f"{'n_nodes':>8s}{'µs/window':>12s}{'objects/win':>13s}"
          f"{'flagged':>9s}")
    for d in detector:
        print(f"{d['n_nodes']:8d}{d['us_per_window_p50']:12.0f}"
              f"{d['objects_per_window_max']:13d}{d['flagged_steady']:9d}")
    print(f"\nsimulate: {sim['config']['n_nodes']} nodes, "
          f"{sim['config']['duration_h']:.0f}h -> {sim['wall_s']:.1f}s "
          f"({sim['steps']} steps, {sim['crashes']} crashes)")

    ok = True
    if not all(d["objects_O_flagged"] for d in detector):
        print("FAIL: detector materialized O(N) objects per window",
              file=sys.stderr)
        ok = False
    if args.quick:
        if sim["wall_s"] > args.budget_s:
            print(f"FAIL: quick scale run {sim['wall_s']:.1f}s over the "
                  f"{args.budget_s:.0f}s budget", file=sys.stderr)
            ok = False
    else:
        speedup = sim["speedup_vs_prerefactor"]
        print(f"speedup vs pre-refactor step-granular path: {speedup:.1f}x "
              f"(baseline {PRE_REFACTOR['wall_s']:.1f}s @ "
              f"{PRE_REFACTOR['commit']}; target {SPEEDUP_TARGET:.0f}x, "
              f"gate {SPEEDUP_GATE:.0f}x)")
        if speedup < SPEEDUP_GATE:
            print(f"FAIL: speedup below the {SPEEDUP_GATE:.0f}x gate",
                  file=sys.stderr)
            ok = False
    print(f"wrote {args.out}  ({out['total_wall_s']:.0f}s)")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
