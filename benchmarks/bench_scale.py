"""Fleet-scale benchmark: detector scaling to 131k nodes, pinned in CI.

Four measurements, written to ``BENCH_scale.json``:

  1. ``detector``: microbenchmark of ``StragglerDetector.update`` on
     synthetic full-metric frames at 1k..131k nodes — ms per evaluation
     window (mean/p50/p95), resident buffer bytes, and the number of
     per-node Python objects materialized per window, which must scale
     with the FLAGGED population, not the fleet (the struct-of-arrays
     FleetAssessment contract). Gates: p50 at 16384 nodes under
     ``GATE_16K_MS``; full mode additionally gates the 131072/16384 p50
     ratio under ``SUBLINEAR_RATIO_GATE`` (8x the nodes must cost less
     than 8.2x the window — batched scoring cannot regress to
     superlinear); quick mode gates the 65536-node p50 under
     ``QUICK_65K_GATE_MS`` (the CI scale job's budget).
  2. ``scorer_agreement``: the pallas fleet-score kernel and the NumPy
     reference, each driving a full detector over the same frame
     sequence, must produce bit-identical verdict arrays (flags,
     slowdowns, stall/step-deviant, support masks) at the gated sizes.
  3. ``sim_feed``: ms/window of the 65536-node ``SimCluster`` feed
     (run_window + collect) under background fault churn — the windowed
     (W, N) composition and row-targeted link-state refresh keep this
     free of per-node Python.
  4. ``simulate``: wall-clock of the 2048-node, 72 h ENHANCED
     ``simulate_run`` on the window-granular engine, against the
     pre-refactor step-granular baseline measured interleaved on the
     same config / seed / machine immediately before the refactor
     landed (commit 6c6cb4c): ~8-9x min-to-min on the dev container
     (target 10x; enforced regression gate 6x — see SPEEDUP_GATE).

Run:  PYTHONPATH=src python -m benchmarks.bench_scale [--quick]
          [--nodes N,N,...] [--out PATH] [--budget-s S]

``--quick`` is the CI smoke sizing: detector sizes up to 65536, a
1024-node short engine run under a wall-time budget (exit non-zero if it
blows the budget), with the speedup gate reported but not enforced (CI
machines are not the baseline machine). Full mode adds 131072, the
sublinearity gate and the enforced speedup gate. ``--nodes`` overrides
the detector size list (per-size gates still apply to whichever gated
sizes are present).
"""
from __future__ import annotations

import argparse
import copy
import json
import os
import sys
import time

import numpy as np

from repro.core import DetectorConfig, StragglerDetector
from repro.core.telemetry import Frame
from repro.guard import Tier
from repro.simcluster import FaultRates, RunConfig, SimCluster, simulate_run

# Pre-refactor step-granular baseline, measured on the exact BENCH config
# below at the commit preceding this refactor (simulate_run with the
# per-step loop, list-scan FaultInjector, per-node detector objects).
# wall_s is the MIN over 7 interleaved old/new runs on the same machine
# (the least-interference sample; same convention as the new-path
# measurement), so the recorded speedup is conservative. Kept as the
# fixed reference the CI artifact trends against.
PRE_REFACTOR = {
    "commit": "6c6cb4c",
    "wall_s": 32.37,
    "wall_s_samples": [32.37, 34.30, 37.18, 42.69, 32.67, 42.14, 35.13],
    "steps": 7419,
    "config": "2048 nodes, 72 h, ENHANCED, initial_grey_p=0.02, seed 0",
}

# The refactor's target was >=10x; the measured speedup on the dev
# container is ~8-9x min-to-min (recorded in the artifact). The enforced
# gate sits at 6x so CI machine variance cannot flake the job while a
# genuine engine regression still fails loudly.
SPEEDUP_TARGET = 10.0
SPEEDUP_GATE = 6.0

# detector per-window budgets (p50 over warm windows). Dev-container
# measurements sit near 2.4 ms at 16k / 8.6 ms at 65k / 17.6 ms at 131k;
# the gates leave ~2.5x headroom for slower CI machines. The ratio gate
# pins sublinear-or-linear scaling: 8x the nodes in under 8.2x the time.
GATE_16K_MS = 6.6
QUICK_65K_GATE_MS = 26.4           # 4 x the 16k budget for 4 x the nodes
SUBLINEAR_RATIO_GATE = 8.2
# sharded scorer="jax" detector pass at 16k on the forced 8-device CPU
# mesh: ~15 ms p50 on the dev container (device round trips + psum across
# node shards dominate); gate leaves ~2.6x headroom for CI machines
SHARDED_16K_GATE_MS = 40.0

FULL_SIZES = (1024, 4096, 16384, 65536, 131072)
QUICK_SIZES = (1024, 4096, 16384, 65536)
# sizes whose pallas-vs-reference verdict agreement is checked/gated
AGREEMENT_SIZES_QUICK = (16384,)
AGREEMENT_SIZES_FULL = (16384, 131072)

SCALE_CONFIG = dict(tier=Tier.ENHANCED, n_nodes=2048, n_spare=128,
                    duration_h=72.0, initial_grey_p=0.02, seed=0)
QUICK_CONFIG = dict(tier=Tier.ENHANCED, n_nodes=1024, n_spare=64,
                    duration_h=6.0, initial_grey_p=0.05, seed=0)


def synthetic_frame(w: int, n: int, rng, stragglers) -> Frame:
    t = 10.0 * (1.0 + rng.normal(0, 0.004, n))
    for nid, factor in stragglers:
        t[nid] *= factor
    metrics = {
        "step_time": t,
        "gpu_temp": 58.0 + rng.normal(0, 0.8, n),
        "gpu_util": np.clip(rng.normal(0.97, 0.01, n), 0, 1),
        "gpu_freq": np.full(n, 1.93) + rng.normal(0, 0.002, n),
        "gpu_power": 350.0 + rng.normal(0, 3.0, n),
        "nic_errors": np.zeros(n),
        "nic_tx_rate": 50.0 + rng.normal(0, 0.5, n),
        "nic_up": np.ones(n),
    }
    return Frame(t=w * 60.0, step=w * 6,
                 node_ids=np.arange(n, dtype=np.int64),
                 metrics=metrics, valid=np.ones(n, bool))


def _stragglers(n: int, n_stragglers: int):
    return [(i * (n // max(n_stragglers, 1)) + 7, 1.2)
            for i in range(n_stragglers)]


def detector_microbench(n: int, windows: int = 24,
                        n_stragglers: int = 4,
                        scorer: str = "numpy") -> dict:
    """ms/window + materialized-object count for an N-node fleet with a
    handful of genuine stragglers (the realistic steady state)."""
    rng = np.random.RandomState(n)
    stragglers = _stragglers(n, n_stragglers)
    det = StragglerDetector(DetectorConfig(scorer=scorer))
    frames = [synthetic_frame(w, n, rng, stragglers)
              for w in range(windows)]
    per_window_ms = []
    materialized = []
    flagged = []
    for frame in frames:
        t0 = time.perf_counter()
        fa = det.update(frame)
        fa.flagged_assessments()         # what the monitor/policy consume
        per_window_ms.append((time.perf_counter() - t0) * 1e3)
        materialized.append(fa.materialized)
        flagged.append(int(fa.flagged.sum()))
    warm = per_window_ms[2:]             # skip alloc warmup
    return {
        "n_nodes": n,
        "windows": windows,
        "scorer": scorer,
        "ms_per_window_mean": float(np.mean(warm)),
        "ms_per_window_p50": float(np.median(warm)),
        "ms_per_window_p95": float(np.percentile(warm, 95)),
        "memory_bytes": det.memory_nbytes(),
        "flagged_steady": flagged[-1],
        "objects_per_window_max": int(max(materialized)),
        "objects_O_flagged": bool(
            max(materialized) <= max(max(flagged), 1) + n_stragglers),
    }


def scorer_agreement(n: int, windows: int = 6,
                     n_stragglers: int = 4) -> dict:
    """Drive two detectors — NumPy reference scorer vs the pallas
    fleet-score kernel — over the same frames; every verdict array must
    agree bit-identically (the kernel's golden contract, checked at
    fleet scale where lane padding and big-N medians actually bite)."""
    rng = np.random.RandomState(n + 1)
    stragglers = _stragglers(n, n_stragglers)
    det_ref = StragglerDetector(DetectorConfig(scorer="numpy"))
    det_pl = StragglerDetector(DetectorConfig(scorer="pallas"))
    agree = True
    for w in range(windows):
        frame = synthetic_frame(w, n, rng, stragglers)
        a = det_ref.update(copy.deepcopy(frame))
        b = det_pl.update(copy.deepcopy(frame))
        agree &= np.array_equal(a.flagged, b.flagged)
        agree &= np.array_equal(a.slowdown, b.slowdown)
        agree &= np.array_equal(a.stalled, b.stalled)
        agree &= np.array_equal(a.step_deviant, b.step_deviant)
        agree &= set(a.support_masks) == set(b.support_masks)
        for m in a.support_masks:
            agree &= np.array_equal(a.support_masks[m],
                                    b.support_masks.get(m))
        if not agree:
            break
    return {"n_nodes": n, "windows": windows, "bit_identical": bool(agree)}


def sharded_detection(n: int = 16384, windows: int = 8,
                      n_stragglers: int = 4) -> dict:
    """Full detector pass with ``scorer="jax"`` under an ACTIVE
    multi-device mesh (``make_cpu_mesh`` over however many host devices
    XLA exposes; CI forces 8). The input is constrained over the
    ``fleet_node`` logical axis, so the peer-median rank counts psum
    across node shards — this is the real sharded production path, not
    the single-device jit. Gated on verdict parity with the NumPy
    reference detector over the same frames and on per-window cost."""
    import jax

    from repro import dist
    from repro.launch.mesh import make_cpu_mesh

    rng = np.random.RandomState(n + 2)
    stragglers = _stragglers(n, n_stragglers)
    frames = [synthetic_frame(w, n, rng, stragglers)
              for w in range(windows)]
    det_ref = StragglerDetector(DetectorConfig(scorer="numpy"))
    ref = [det_ref.update(copy.deepcopy(f)) for f in frames]

    det_jax = StragglerDetector(DetectorConfig(scorer="jax"))
    mesh = make_cpu_mesh()
    per_window_ms = []
    agree = True
    with dist.use_mesh(mesh):
        for frame, a in zip(frames, ref):
            t0 = time.perf_counter()
            b = det_jax.update(copy.deepcopy(frame))
            per_window_ms.append((time.perf_counter() - t0) * 1e3)
            agree &= np.array_equal(a.flagged, b.flagged)
            agree &= np.array_equal(a.stalled, b.stalled)
            agree &= np.array_equal(a.step_deviant, b.step_deviant)
    warm = per_window_ms[2:]             # skip trace/compile warmup
    return {
        "n_nodes": n,
        "windows": windows,
        "n_devices": len(jax.devices()),
        "mesh_shape": {k: int(v) for k, v in mesh.shape.items()},
        "ms_per_window_p50": float(np.median(warm)),
        "ms_per_window_p95": float(np.percentile(warm, 95)),
        "verdict_parity": bool(agree),
    }


def sim_feed_bench(n: int = 65536, windows: int = 10,
                   warmup: int = 2) -> dict:
    """ms/window of the simulated fleet feed (run_window + collect) at
    scale, under background grey-fault churn (no fail-stops: a crashed
    fleet stops stepping and would measure nothing)."""
    rates = FaultRates(fail_stop=0, admission_grey_p=0)
    c = SimCluster(n, 16, reserve=32, rates=rates, seed=9)
    for _ in range(warmup):
        c.run_window(6)
        c.collect()
    ms = []
    steps = 0
    for _ in range(windows):
        t0 = time.perf_counter()
        rec = c.run_window(6)
        c.collect()
        ms.append((time.perf_counter() - t0) * 1e3)
        steps += rec["steps_run"]
    return {
        "n_nodes": n,
        "windows": windows,
        "steps": steps,
        "ms_per_window_mean": float(np.mean(ms)),
        "ms_per_window_p50": float(np.median(ms)),
        "ms_per_window_p95": float(np.percentile(ms, 95)),
        "fleet_memory_bytes": c.fleet.memory_nbytes(),
    }


def sim_scale_bench(quick: bool, repeats: int = 1) -> dict:
    cfg = QUICK_CONFIG if quick else SCALE_CONFIG
    walls = []
    r = None
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        r = simulate_run(RunConfig(**cfg))
        walls.append(time.perf_counter() - t0)
    # min over repeats: wall-clock gates need the least-interference
    # sample on shared machines (same convention as the baseline)
    wall = min(walls)
    out = {
        "config": {k: (int(v) if k == "tier" else v)
                   for k, v in cfg.items()},
        "wall_s": wall,
        "wall_s_all": walls,
        "steps": r.steps,
        "crashes": r.crashes,
        "mfu": r.mfu,
        "mttf_h": r.mttf_h,
        "events": len(r.events),
    }
    if not quick:
        out["baseline"] = PRE_REFACTOR
        out["speedup_vs_prerefactor"] = PRE_REFACTOR["wall_s"] / wall
        out["speedup_target"] = SPEEDUP_TARGET
        out["speedup_gate"] = SPEEDUP_GATE
    return out


def scale_summary(quick: bool = True) -> dict:
    """Compact detector-scaling summary for embedding in other
    benchmark artifacts (benchmarks.run_all). Engine wall-clock numbers
    live in BENCH_scale.json only."""
    sizes = (1024, 4096) if quick else (1024, 4096, 16384)
    return {
        "detector": [detector_microbench(n, windows=12) for n in sizes],
    }


def _fmt_bytes(b: int) -> str:
    return f"{b / 2**20:.1f} MiB"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizing (<=65536 nodes, short run)")
    ap.add_argument("--nodes", default=None,
                    help="comma-separated detector size override, e.g. "
                         "1024,16384,65536")
    ap.add_argument("--budget-s", type=float, default=300.0,
                    help="wall-time budget for the quick run (CI gate)")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_scale.json"))
    args = ap.parse_args(argv)

    if args.nodes:
        sizes = tuple(int(s) for s in args.nodes.split(",") if s.strip())
    else:
        sizes = QUICK_SIZES if args.quick else FULL_SIZES
    agree_sizes = [n for n in (AGREEMENT_SIZES_QUICK if args.quick
                               else AGREEMENT_SIZES_FULL) if n in sizes]

    t0 = time.perf_counter()
    detector = [detector_microbench(n) for n in sizes]
    by_n = {d["n_nodes"]: d for d in detector}
    agreement = [scorer_agreement(n) for n in agree_sizes]
    sharded = sharded_detection() if 16384 in sizes else None
    sim_feed = sim_feed_bench() if 65536 in sizes else None
    sim = sim_scale_bench(quick=args.quick, repeats=1 if args.quick else 3)
    out = {
        "benchmark": "guard_scale",
        "mode": "quick" if args.quick else "full",
        "sizes": list(sizes),
        "detector": detector,
        "scorer_agreement": agreement,
        "sharded_detection": sharded,
        "sim_feed": sim_feed,
        "simulate": sim,
        "gates": {
            "detector_16k_p50_ms_max": GATE_16K_MS,
            "detector_65k_p50_ms_max_quick": QUICK_65K_GATE_MS,
            "detector_131k_over_16k_ratio_max": SUBLINEAR_RATIO_GATE,
            "sharded_jax_16k_p50_ms_max": SHARDED_16K_GATE_MS,
        },
        "total_wall_s": time.perf_counter() - t0,
    }
    if 16384 in by_n and 131072 in by_n:
        out["ratio_131k_over_16k"] = (
            by_n[131072]["ms_per_window_p50"] /
            max(by_n[16384]["ms_per_window_p50"], 1e-9))

    print(f"{'n_nodes':>8s}{'ms p50':>9s}{'ms p95':>9s}{'memory':>11s}"
          f"{'objects/win':>13s}{'flagged':>9s}")
    for d in detector:
        print(f"{d['n_nodes']:8d}{d['ms_per_window_p50']:9.2f}"
              f"{d['ms_per_window_p95']:9.2f}"
              f"{_fmt_bytes(d['memory_bytes']):>11s}"
              f"{d['objects_per_window_max']:13d}{d['flagged_steady']:9d}")
    for a in agreement:
        print(f"pallas-vs-ref verdicts @{a['n_nodes']}: "
              f"{'bit-identical' if a['bit_identical'] else 'DISAGREE'}")
    if sharded:
        print(f"sharded jax @{sharded['n_nodes']} on "
              f"{sharded['n_devices']}-device mesh "
              f"{sharded['mesh_shape']}: "
              f"p50 {sharded['ms_per_window_p50']:.1f} ms/window, "
              f"verdicts {'match numpy' if sharded['verdict_parity'] else 'DISAGREE'}")
    if sim_feed:
        print(f"sim feed @{sim_feed['n_nodes']}: "
              f"p50 {sim_feed['ms_per_window_p50']:.0f} ms/window "
              f"(fleet {_fmt_bytes(sim_feed['fleet_memory_bytes'])})")
    print(f"simulate: {sim['config']['n_nodes']} nodes, "
          f"{sim['config']['duration_h']:.0f}h -> {sim['wall_s']:.1f}s "
          f"({sim['steps']} steps, {sim['crashes']} crashes)")

    ok = True
    if not all(d["objects_O_flagged"] for d in detector):
        print("FAIL: detector materialized O(N) objects per window",
              file=sys.stderr)
        ok = False
    if not all(a["bit_identical"] for a in agreement):
        print("FAIL: pallas scorer disagrees with the reference",
              file=sys.stderr)
        ok = False
    if sharded is not None:
        if not sharded["verdict_parity"]:
            print("FAIL: sharded jax scorer verdicts disagree with numpy",
                  file=sys.stderr)
            ok = False
        if sharded["ms_per_window_p50"] > SHARDED_16K_GATE_MS:
            print(f"FAIL: sharded jax 16k detector p50 "
                  f"{sharded['ms_per_window_p50']:.1f} ms > "
                  f"{SHARDED_16K_GATE_MS}", file=sys.stderr)
            ok = False
    if 16384 in by_n and \
            by_n[16384]["ms_per_window_p50"] > GATE_16K_MS:
        print(f"FAIL: 16k detector p50 "
              f"{by_n[16384]['ms_per_window_p50']:.2f} ms > {GATE_16K_MS}",
              file=sys.stderr)
        ok = False
    if "ratio_131k_over_16k" in out and \
            out["ratio_131k_over_16k"] >= SUBLINEAR_RATIO_GATE:
        print(f"FAIL: 131k/16k per-window ratio "
              f"{out['ratio_131k_over_16k']:.2f} >= "
              f"{SUBLINEAR_RATIO_GATE} (superlinear scaling)",
              file=sys.stderr)
        ok = False
    if args.quick:
        if 65536 in by_n and \
                by_n[65536]["ms_per_window_p50"] > QUICK_65K_GATE_MS:
            print(f"FAIL: 65k detector p50 "
                  f"{by_n[65536]['ms_per_window_p50']:.2f} ms > "
                  f"{QUICK_65K_GATE_MS}", file=sys.stderr)
            ok = False
        if sim["wall_s"] > args.budget_s:
            print(f"FAIL: quick scale run {sim['wall_s']:.1f}s over the "
                  f"{args.budget_s:.0f}s budget", file=sys.stderr)
            ok = False
    else:
        speedup = sim["speedup_vs_prerefactor"]
        print(f"speedup vs pre-refactor step-granular path: {speedup:.1f}x "
              f"(baseline {PRE_REFACTOR['wall_s']:.1f}s @ "
              f"{PRE_REFACTOR['commit']}; target {SPEEDUP_TARGET:.0f}x, "
              f"gate {SPEEDUP_GATE:.0f}x)")
        if speedup < SPEEDUP_GATE:
            print(f"FAIL: speedup below the {SPEEDUP_GATE:.0f}x gate",
                  file=sys.stderr)
            ok = False
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {args.out}  ({out['total_wall_s']:.0f}s)")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
