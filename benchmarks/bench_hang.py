"""Hang-watchdog benchmark: culprit/victim attribution of
``repro.ccltrace`` against scenario ground truth, written to
``BENCH_hang.json``.

Three labeled hang scenarios drive a barrier-grouped fleet with the
collective-granular span trace and barrier-timeout watchdog armed. A
hung collective produces NO step samples, so the z-score detector never
sees it — the watchdog must detect the silence, attribute it, and evict
only the culprits:

  deadlocked_collective      ranks wedge inside (or never reach) a
                             collective: never-entered / entered-stalled
                             culprits, group peers blocked as victims
  partial_nic_brownout       one barrier group's NICs degrade, the worst
                             past the hang threshold: entered-stalled
                             culprits with link evidence
  straggler_timeout_cascade  a thermal straggler degrades, then wedges:
                             the fail-slow -> fail-stop escalation path

Scoring against the injector's fault log (``RunResult.fault_log``):

  precision   culprit accusations that pointed at a node with a
              genuinely active hang-class fault — gate >= 0.90
  recall      injected hang-grade nodes that were culprit-attributed
  victims     hang-reason evictions of nodes with NO active hang-class
              fault — must be ZERO (victims are watched, never evicted)
  latency     median detection latency in evaluation windows from hang
              onset to verdict — gate <= 3 windows (the framework CCL
              abort is ~10 windows of silence)

A no-watchdog baseline run of the deadlock scenario shows what the
subsystem buys: the same fault handled by blind CCL-timeout restarts.

Run:  PYTHONPATH=src python -m benchmarks.bench_hang [--quick]
          [--out PATH]

Exit is non-zero if any gate fails (CI runs this in the scale job).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from repro.guard import Tier
from repro.simcluster import (BROWNOUT_HANG_SEV, DeadlockedCollective,
                              FaultRates, PartialNicBrownout, RunConfig,
                              StragglerTimeoutCascade, WorkloadProfile,
                              simulate_run)

PRECISION_GATE = 0.90
LATENCY_GATE_WINDOWS = 3.0

# fault kinds that wedge a rank (the attribution ground truth)
HANG_TRUTH_KINDS = ("collective_hang", "nic_brownout")

QUIET = FaultRates(thermal=0, power=0, mem_ecc=0, nic_down=0,
                   nic_degraded=0, host_cpu=0, congestion=0, fail_stop=0,
                   admission_grey_p=0)
WORKLOAD = WorkloadProfile(name="hang_bench", compute_s=6.0,
                           comm_exposed_s=2.5, host_s=1.5)


def base_config(duration_h: float, **kw) -> RunConfig:
    kw.setdefault("rates", QUIET)
    kw.setdefault("initial_grey_p", 0.0)
    kw.setdefault("hang_watchdog", True)
    return RunConfig(tier=Tier.ENHANCED, n_nodes=64, n_spare=10,
                     duration_h=duration_h, dp_group_size=16,
                     diagnose=True, workload=WORKLOAD, seed=11, **kw)


def scenario_suite(quick: bool):
    dur = 3.0 if quick else 8.0
    return {
        "deadlocked_collective": base_config(dur, scenarios=(
            DeadlockedCollective(at_h=1.0, count=2, interval_h=0.75),)),
        "partial_nic_brownout": base_config(dur, scenarios=(
            PartialNicBrownout(at_h=1.0, group_size=8),)),
        "straggler_timeout_cascade": base_config(dur, scenarios=(
            StragglerTimeoutCascade(at_h=1.0, count=2,
                                    interval_h=0.75),)),
    }


def _active_fault(fault_log, node: int, t: float, kinds,
                  slack_s: float = 600.0):
    """The first logged fault of ``kinds`` active on ``node`` around
    ``t`` (the verdict lags onset by the poll cadence, hence slack)."""
    for f in fault_log:
        if f["node"] != node or f["kind"] not in kinds:
            continue
        cleared = f["t_cleared"]
        if f["t_start"] - slack_s <= t and \
                (cleared is None or t <= cleared + slack_s):
            return f
    return None


def score_run(name: str, result) -> dict:
    """Attribution + eviction + latency scoring for one simulated run."""
    log = result.fault_log
    # fleet-side watchdog verdicts only (op "step" would be the hook's
    # single-host liveness path, which has no culprit attribution)
    hangs = [e for e in result.events
             if e["kind"] == "hang" and e["op"] != "step"]

    tp = fp = 0
    attributed = set()
    for e in hangs:
        for culprit in e["culprits"]:
            if _active_fault(log, culprit, e["t"],
                             HANG_TRUTH_KINDS) is not None:
                tp += 1
                attributed.add(culprit)
            else:
                fp += 1

    # recall denominator: nodes whose injected fault actually wedges a
    # rank — every collective_hang, plus brownouts past the hang
    # severity (milder brownouts degrade without hanging)
    truth = {f["node"] for f in log
             if f["kind"] == "collective_hang"
             or (f["kind"] == "nic_brownout"
                 and f["severity"] >= BROWNOUT_HANG_SEV)}

    # the headline gate: a hang-reason eviction of a node with no active
    # hang-class fault evicted a VICTIM (blocked on the barrier, healthy)
    victims_evicted = []
    for e in result.events:
        if e["kind"] != "swap" or "hang" not in e["reason"]:
            continue
        if _active_fault(log, e["old"], e["t"],
                         HANG_TRUTH_KINDS) is None:
            victims_evicted.append(e["old"])

    latencies = [e["latency_windows"] for e in hangs]
    return {
        "scenario": name,
        "steps": result.steps,
        "goodput_tflop_h": result.goodput_tflop_h,
        "hang_events": len(hangs),
        "attributed_events": sum(1 for e in hangs if e["culprits"]),
        "tp": tp,
        "fp": fp,
        "truth_nodes": sorted(truth),
        "attributed_nodes": sorted(attributed & truth),
        "recall_hits": len(attributed & truth),
        "recall_total": len(truth),
        "victims_evicted": sorted(set(victims_evicted)),
        "latency_windows_median": float(np.median(latencies))
        if latencies else float("nan"),
        "latency_windows_max": float(np.max(latencies))
        if latencies else float("nan"),
        "pools": result.pools,
    }


def baseline_run(quick: bool) -> dict:
    """The deadlock scenario with NO watchdog: every hang rides out the
    blind framework CCL abort and the wedged rank stays in the job."""
    cfg = base_config(3.0 if quick else 8.0, hang_watchdog=False,
                      scenarios=(DeadlockedCollective(
                          at_h=1.0, count=2, interval_h=0.75),))
    r = simulate_run(cfg)
    restarts = sum(1 for e in r.events
                   if e["kind"] == "restart" and "hang" in e["reason"])
    return {"steps": r.steps, "goodput_tflop_h": r.goodput_tflop_h,
            "blind_restarts": restarts, "mfu": r.mfu}


def hang_summary(quick: bool = True) -> dict:
    """Pooled hang-watchdog metrics + gate verdicts (reused by
    ``benchmarks.run_all`` for the regression gate)."""
    runs = {name: score_run(name, simulate_run(cfg))
            for name, cfg in scenario_suite(quick).items()}
    tp = sum(s["tp"] for s in runs.values())
    fp = sum(s["fp"] for s in runs.values())
    rec_hits = sum(s["recall_hits"] for s in runs.values())
    rec_total = sum(s["recall_total"] for s in runs.values())
    victims = sorted({v for s in runs.values()
                      for v in s["victims_evicted"]})
    medians = [s["latency_windows_median"] for s in runs.values()
               if np.isfinite(s["latency_windows_median"])]
    latency = float(np.median(medians)) if medians else float("inf")
    precision = tp / max(tp + fp, 1)
    return {
        "scenarios": runs,
        "pooled": {
            "precision": precision,
            "recall": rec_hits / max(rec_total, 1),
            "tp": tp, "fp": fp,
            "recall_hits": rec_hits, "recall_total": rec_total,
            "victims_evicted": victims,
            "latency_windows_median": latency,
        },
        "gates": {
            "precision_min": PRECISION_GATE,
            "latency_windows_max": LATENCY_GATE_WINDOWS,
            "victims_evicted_max": 0,
        },
        "ok": (precision >= PRECISION_GATE and not victims
               and latency <= LATENCY_GATE_WINDOWS
               and all(s["hang_events"] > 0 for s in runs.values())),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizing (shorter scenario runs)")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_hang.json"))
    args = ap.parse_args(argv)

    t0 = time.perf_counter()
    summary = hang_summary(args.quick)
    baseline = baseline_run(args.quick)
    pooled = summary["pooled"]
    out = {
        "benchmark": "guard_hang",
        "mode": "quick" if args.quick else "full",
        **summary,
        "baseline_no_watchdog": baseline,
        "total_wall_s": time.perf_counter() - t0,
    }

    print(f"{'scenario':>26s}{'hangs':>7s}{'tp':>5s}{'fp':>5s}"
          f"{'recall':>9s}{'victims':>9s}{'lat(w)':>8s}")
    for name, s in summary["scenarios"].items():
        rec = f"{s['recall_hits']}/{s['recall_total']}" \
            if s["recall_total"] else "-"
        print(f"{name:>26s}{s['hang_events']:7d}{s['tp']:5d}{s['fp']:5d}"
              f"{rec:>9s}{len(s['victims_evicted']):9d}"
              f"{s['latency_windows_median']:8.1f}")
    print(f"\npooled: precision {pooled['precision']:.3f} "
          f"(gate {PRECISION_GATE}), recall {pooled['recall']:.3f}, "
          f"median latency {pooled['latency_windows_median']:.1f} windows "
          f"(gate {LATENCY_GATE_WINDOWS})")
    wd_steps = summary["scenarios"]["deadlocked_collective"]["steps"]
    print(f"baseline (no watchdog, deadlock scenario): "
          f"{baseline['steps']} steps vs {wd_steps} with the watchdog, "
          f"{baseline['blind_restarts']} blind CCL-timeout restarts")

    ok = True
    if pooled["precision"] < PRECISION_GATE:
        print(f"FAIL: precision {pooled['precision']:.3f} < "
              f"{PRECISION_GATE}", file=sys.stderr)
        ok = False
    if pooled["victims_evicted"]:
        print(f"FAIL: hang victims evicted: "
              f"{pooled['victims_evicted']}", file=sys.stderr)
        ok = False
    if pooled["latency_windows_median"] > LATENCY_GATE_WINDOWS:
        print(f"FAIL: median detection latency "
              f"{pooled['latency_windows_median']:.1f} windows > "
              f"{LATENCY_GATE_WINDOWS}", file=sys.stderr)
        ok = False
    for name, s in summary["scenarios"].items():
        if not s["hang_events"]:
            print(f"FAIL: {name} produced no hang events", file=sys.stderr)
            ok = False

    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {args.out}  ({out['total_wall_s']:.0f}s)")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
