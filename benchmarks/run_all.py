"""Guard perf-trajectory benchmark: the tier ablation as a CI artifact.

Runs the Table-4 simulation ladder (burn-in-only tier 1 through the full
enhanced-sweep tier 4) over a common fleet/fault environment and writes
``BENCH_guard.json`` with the metrics the paper optimizes — MFU,
step-time variance, MTTF, human hours per incident — plus the recovery
metrics of the detection-to-recovery loop: per-tier goodput (good FLOPs
per wall hour, replayed steps excluded) and the MTTR decomposition
(detect → drain → restore → warmup) aggregated from each run's
RecoveryEvents. Two ordering verdicts gate CI: the Table-4 MFU ladder
(ENHANCED >= ONLINE >= NODE_SWEEP >= BURNIN, within simulation noise)
and the recovery ladder on goodput — ENHANCED must beat ONLINE under
the same fault load *because recovery improved* (peer-replica hot-spare
resume vs local-shard vs cold restarts). CI uploads the file on every
run so the perf trajectory of the reproduction is tracked over time.

Run:  PYTHONPATH=src python -m benchmarks.run_all [--quick] [--out PATH]
Exit status is non-zero if the headline MFU ordering (tier 4 vs tier 1)
or the goodput recovery ladder breaks, or the MTTR decomposition fields
go missing — the paper's directional claims are regression gates.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from benchmarks.common import GUARD_WORKLOAD, RATES
from repro.guard import MTTR_PHASES, Tier
from repro.simcluster import RunConfig, simulate_run

# Simulation noise floor for the non-headline adjacent-tier comparisons:
# short runs put ONLINE and ENHANCED within a hair of each other (the
# enhanced sweep pays off through escalations-avoided, which need long
# horizons to compound).
ORDERING_TOL = 0.01
# Goodput ladder noise floor (relative): the BURNIN/NODE_SWEEP tiers see
# seed-to-seed swings from how many greys escalate; the gated claims are
# ENHANCED > ONLINE (strict) and the ladder within tolerance.
GOODPUT_TOL = 0.02

# MTTR-decomposition fields every per-tier summary must carry (schema
# gate: a refactor that drops them breaks downstream artifact consumers)
MTTR_FIELDS = tuple(f"{p}_mean" for p in MTTR_PHASES) + (
    "mttr_s", "incidents", "replay_steps_total", "hot_spare_promotions")


def run_tiers(duration_h: float, n_nodes: int, n_spare: int, seeds,
              initial_grey_p: float = 0.2) -> dict:
    per_tier = {}
    for tier in Tier:
        runs = []
        for seed in seeds:
            t0 = time.time()
            r = simulate_run(RunConfig(
                tier=tier, n_nodes=n_nodes, n_spare=n_spare,
                duration_h=duration_h, initial_grey_p=initial_grey_p,
                workload=GUARD_WORKLOAD, rates=RATES, seed=seed))
            runs.append({
                "seed": seed,
                "mfu": r.mfu,
                "goodput_tflop_h": r.goodput_tflop_h,
                "mttf_h": r.mttf_h,
                "step_variance_s2": float(np.var(r.step_times)),
                "mean_step_s": r.mean_step_s,
                "p95_step_s": r.p95_step_s,
                "crashes": r.crashes,
                "guard_restarts": r.guard_restarts,
                "human_h_per_incident": r.human_h_per_incident,
                "events": len(r.events),
                "recovery": {k: v for k, v in r.recovery.items()},
                "wall_s": time.time() - t0,
            })
        agg = {k: float(np.mean([x[k] for x in runs]))
               for k in ("mfu", "goodput_tflop_h", "mttf_h",
                         "step_variance_s2", "mean_step_s",
                         "human_h_per_incident")}
        # MTTR decomposition, seed-averaged (by_tier counts summed)
        mttr = {k: float(np.mean([x["recovery"][k] for x in runs]))
                for k in runs[0]["recovery"]
                if not isinstance(runs[0]["recovery"][k], dict)}
        mttr["by_tier"] = {
            ck: int(sum(x["recovery"]["by_tier"][ck] for x in runs))
            for ck in runs[0]["recovery"]["by_tier"]}
        per_tier[tier.name] = {"tier": int(tier), **agg, "mttr": mttr,
                               "runs": runs}
    return per_tier


def check_ordering(per_tier: dict) -> dict:
    """Table-4 directional claims on MFU + the recovery-ladder claims on
    goodput and the MTTR schema."""
    mfu = {t: per_tier[t]["mfu"] for t in per_tier}
    ladder = ["BURNIN", "NODE_SWEEP", "ONLINE", "ENHANCED"]
    adjacent_ok = all(
        mfu[hi] >= mfu[lo] - ORDERING_TOL
        for lo, hi in zip(ladder, ladder[1:]))
    headline_ok = mfu["ENHANCED"] > mfu["BURNIN"]
    gp = {t: per_tier[t]["goodput_tflop_h"] for t in per_tier}
    # recovery ladder: every checkpoint tier the ablation adds must pay
    # for itself in good FLOPs — strict for the headline ENHANCED vs
    # ONLINE (hot-spare peer-replica resume vs local-shard restarts),
    # tolerance-banded below (grey-escalation noise dominates tiers 1-2)
    goodput_ladder_ok = (
        gp["ENHANCED"] >= gp["ONLINE"] * (1 - GOODPUT_TOL)
        and gp["ONLINE"] >= gp["BURNIN"] * (1 - GOODPUT_TOL))
    goodput_headline_ok = gp["ENHANCED"] > gp["ONLINE"]
    mttr_fields_ok = all(
        f in per_tier[t]["mttr"] for t in per_tier for f in MTTR_FIELDS)
    return {"mfu_by_tier": mfu,
            "adjacent_ordering_ok": bool(adjacent_ok),
            "headline_enhanced_gt_burnin": bool(headline_ok),
            "goodput_by_tier": gp,
            "goodput_ladder_ok": bool(goodput_ladder_ok),
            "goodput_enhanced_gt_online": bool(goodput_headline_ok),
            "mttr_fields_ok": bool(mttr_fields_ok)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizing (shorter runs, fewer seeds)")
    ap.add_argument("--hours", type=float, default=None)
    ap.add_argument("--nodes", type=int, default=None)
    ap.add_argument("--seeds", type=int, default=None)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_guard.json"))
    args = ap.parse_args(argv)

    hours = args.hours or (10.0 if args.quick else 24.0)
    nodes = args.nodes or (48 if args.quick else 96)
    seeds = list(range(args.seeds or (2 if args.quick else 3)))

    t0 = time.time()
    per_tier = run_tiers(hours, nodes, max(nodes // 6, 4), seeds)
    ordering = check_ordering(per_tier)
    # detector-scaling summary rides along so the ablation artifact also
    # tracks the vectorized hot path (full curves live in BENCH_scale.json)
    from benchmarks.bench_scale import scale_summary
    scale = scale_summary(quick=True)
    # hang-watchdog attribution summary (full report in BENCH_hang.json):
    # culprit precision, victim evictions and detection latency are
    # regression gates here too
    from benchmarks.bench_hang import hang_summary
    hang = hang_summary(quick=True)
    out = {
        "benchmark": "guard_tier_ablation",
        "config": {"duration_h": hours, "n_nodes": nodes, "seeds": seeds,
                   "workload": GUARD_WORKLOAD.name},
        "tiers": per_tier,
        "ordering": ordering,
        "scale": scale,
        "hang": hang,
        "total_wall_s": time.time() - t0,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)

    print(f"{'tier':12s}{'MFU':>8s}{'goodput':>12s}{'MTTR':>8s}"
          f"{'hot-spare':>10s}{'MTTF':>9s}{'human/inc':>11s}")
    for name, d in per_tier.items():
        print(f"{name:12s}{d['mfu']:8.1%}"
              f"{d['goodput_tflop_h']:10.0f}TF"
              f"{d['mttr']['mttr_s']:7.0f}s"
              f"{d['mttr']['hot_spare_promotions']:10.1f}"
              f"{d['mttf_h']:8.1f}h"
              f"{d['human_h_per_incident']:10.2f}h")
    print(f"\nordering: {ordering}")
    for d in scale["detector"]:
        print(f"detector @{d['n_nodes']:>6d} nodes: "
              f"{d['ms_per_window_p50']:.2f}ms/window, "
              f"{d['objects_per_window_max']} objects")
    hp = hang["pooled"]
    print(f"hang watchdog: precision {hp['precision']:.3f}, "
          f"victims evicted {len(hp['victims_evicted'])}, "
          f"median latency {hp['latency_windows_median']:.1f} windows")
    print(f"wrote {args.out}  ({out['total_wall_s']:.0f}s)")
    fail = False
    if not hang["ok"]:
        print("FAIL: hang-watchdog gates broke (culprit precision, "
              "victim evictions or detection latency — see the 'hang' "
              "section of the artifact)", file=sys.stderr)
        fail = True
    if not ordering["headline_enhanced_gt_burnin"]:
        print("FAIL: ENHANCED did not beat BURNIN on MFU", file=sys.stderr)
        fail = True
    if not ordering["goodput_enhanced_gt_online"]:
        print("FAIL: ENHANCED goodput did not beat ONLINE (recovery "
              "regression: hot-spare peer-replica resume should win)",
              file=sys.stderr)
        fail = True
    if not ordering["goodput_ladder_ok"]:
        print("FAIL: goodput ladder ENHANCED >= ONLINE >= BURNIN broke "
              f"beyond {GOODPUT_TOL:.0%} tolerance", file=sys.stderr)
        fail = True
    if not ordering["mttr_fields_ok"]:
        print("FAIL: MTTR decomposition fields missing from per-tier "
              "summaries", file=sys.stderr)
        fail = True
    if fail:
        return 1
    if not ordering["adjacent_ordering_ok"]:
        print("WARN: adjacent tier ordering outside tolerance",
              file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
