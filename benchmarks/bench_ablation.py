"""Table 4: ablation of system components over multi-day simulated runs.

Four tiers, cumulative:
  1 NCCL/burn-in only          2 + offline node sweep
  3 + online monitoring        4 + enhanced (multi-node) sweep

Reported: average MTTF (active hours between job-interrupting hardware
failures — proactive Guard restarts are not failures), average human hours
per incident, and MFU. The MTTF gain comes from escalation prevention:
unmitigated grey faults eventually hard-fail (§ fault model), so pulling
them early prevents the crash."""
from __future__ import annotations

from benchmarks.common import Table, pct
from benchmarks.run_all import run_tiers
from repro.simcluster import Tier

PAPER = {
    Tier.BURNIN: (6.6, 5.6, 0.05),
    Tier.NODE_SWEEP: (8.1, 2.0, 0.10),
    Tier.ONLINE: (9.2, 1.2, 0.14),
    Tier.ENHANCED: (16.7, 0.5, 0.17),
}


def run(duration_h: float = 72.0, seeds=(0, 1, 2)) -> Table:
    t = Table("Ablation: MTTF / human time / MFU per tier", "table4")
    # one tier-sweep implementation: run_all.run_tiers is the same loop
    # that produces the BENCH_guard.json CI artifact
    per_tier = run_tiers(duration_h, n_nodes=128, n_spare=14, seeds=seeds)
    for tier in Tier:
        d = per_tier[tier.name]
        p_mttf, p_hum, p_mfu = PAPER[tier]
        t.add(f"T{int(tier)} {tier.name} MTTF", f"{p_mttf:.1f} h",
              f"{d['mttf_h']:.1f} h")
        t.add(f"T{int(tier)} {tier.name} human/incident", f"{p_hum:.1f} h",
              f"{d['human_h_per_incident']:.2f} h")
        t.add(f"T{int(tier)} {tier.name} MFU", pct(p_mfu),
              pct(d["mfu"]), f"mean step {d['mean_step_s']:.1f}s")
    return t


def main() -> Table:
    t = run()
    t.show()
    t.save("table4_ablation")
    return t


if __name__ == "__main__":
    main()
