"""Fig. 3 + Fig. 4 + Table 1: NIC failure -> transparent reroute.

A dead adapter's traffic rides the fallback link (Table 1's GPU7 -> NIC0
misrouting): the job does NOT fail, the fallback link carries 2x traffic
(Fig. 4), and the step time inflates by the exposed-communication slice
(Fig. 3's 8.7 s -> 8.4 s once fixed)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import FIG3_WORKLOAD, Table
from repro.simcluster import FaultKind, FaultRates, SimCluster

ZERO_RATES = FaultRates(thermal=0, power=0, mem_ecc=0, nic_down=0, nic_degraded=0, host_cpu=0, congestion=0, fail_stop=0, admission_grey_p=0)



def run() -> Table:
    t = Table("NIC-down reroute: step inflation + traffic asymmetry",
              "fig3_fig4_table1")
    c = SimCluster(n_active=8, n_spare=0, workload=FIG3_WORKLOAD,
                   rates=ZERO_RATES, seed=2)

    def mean_step(steps=40):
        return float(np.mean([c.run_step()["step_time"]
                              for _ in range(steps)]))

    healthy = mean_step()
    # kill NIC 7 of node 3 (the paper's example: GPU7's adapter down)
    c.injector.inject(FaultKind.NIC_DOWN, node=3, now=c.t, device=7)
    c.fleet.nic_tx_bytes[:] = 0.0
    degraded = mean_step()
    tx = c.fleet.nic_tx_bytes[3].copy()
    tx_ok = c.fleet.nic_tx_bytes[0].copy()
    # repair and re-measure (the Fig. 3 fix)
    c.fleet.nic_up[3, 7] = True
    fixed = mean_step()

    t.add("step healthy", "8.4 s", f"{healthy:.2f} s")
    t.add("step w/ NIC down", "8.7 s", f"{degraded:.2f} s",
          f"+{degraded-healthy:.2f}s (paper: +0.3s)")
    t.add("step after fix", "8.4 s", f"{fixed:.2f} s")
    t.add("expected NIC (GPU7)", "7", "7", "Table 1")
    t.add("actual NIC (GPU7)", "0 (misrouted)",
          "0" if tx[7] == 0 else "7", "dead link carries no traffic")
    t.add("fallback link traffic", "~2x", f"{tx[0]/tx[1]:.2f}x",
          "Fig. 4: NIC0 carries its own + the dead link's share")
    t.add("healthy node links", "1x each",
          f"{tx_ok.max()/tx_ok.min():.2f}x", "uniform shares elsewhere")
    return t


def main() -> Table:
    t = run()
    t.show()
    t.save("fig3_nic_reroute")
    return t


if __name__ == "__main__":
    main()
