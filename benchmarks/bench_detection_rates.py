"""Table 3: grey-node classification rates (FPR / FNR), Monte-Carlo.

Protocol: fleets of 32 nodes run the §7 workload for an observation period
of ~40 evaluation windows. POSITIVE samples carry one grey fault with
severity drawn from the production-fitted Beta(2,3) distribution (§3's
catalogue: thermal / power / memory / degraded link / host-CPU). NEGATIVE
samples are healthy but live in the honest environment: sensor noise,
benign cooling wobble, and transient fabric congestion (which the temporal
filter must ride out). A node counts as classified-positive if the detector
latches it at any tier during the period — i.e. it would be scheduled for
offline verification/remediation (the action whose misfires Table 3
prices)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import GUARD_WORKLOAD, Table, pct
from repro.core import DetectorConfig, OnlineMonitor, PolicyConfig
from repro.simcluster import FaultKind, SimCluster

GREYS = [FaultKind.THERMAL, FaultKind.POWER, FaultKind.MEM_ECC,
         FaultKind.NIC_DEGRADED, FaultKind.HOST_CPU]


def one_trial(seed: int, n_nodes: int = 32, n_pos: int = 8,
              windows: int = 40):
    rng = np.random.RandomState(seed)
    c = SimCluster(n_active=n_nodes, n_spare=0, workload=GUARD_WORKLOAD,
                   seed=seed)
    # the environment: transient congestion bursts (occasionally long) and
    # benign cooling wobble on healthy nodes
    c.fleet.temp_target += rng.uniform(-3.0, 5.0, c.fleet.temp_target.shape)

    positives = rng.choice(n_nodes, n_pos, replace=False)
    for node in positives:
        kind = GREYS[rng.randint(len(GREYS))]
        sev = float(np.clip(rng.beta(2, 3), 0.02, 0.95))
        c.injector._mk(kind, int(node), now=0.0, severity=sev)

    mon = OnlineMonitor(DetectorConfig(), PolicyConfig())
    flagged = set()
    for w in range(windows):
        # sprinkle longer-than-usual congestion spells (the FP pressure:
        # production fabrics see minutes-long transient contention)
        if rng.rand() < 0.15:
            f = c.injector._mk(FaultKind.CONGESTION,
                               int(rng.randint(n_nodes)), now=c.t)
            f.t_end = c.t + rng.uniform(180.0, 720.0)
        for _ in range(c.window_steps):
            c.run_step()
        frame = c.collect()
        if frame is None:
            continue
        for ev in mon.observe(frame):
            flagged.add(ev.decision.node_id)
        flagged.update(mon.detector.latched_nodes())
    pos = set(int(p) for p in positives)
    neg = set(range(n_nodes)) - pos
    fp = len(flagged & neg)
    fn = len(pos - flagged)
    return fp, len(neg), fn, len(pos)


def run(trials: int = 12) -> Table:
    t = Table("Grey-node classification rates", "table3")
    FP = TN = FN = TP = 0
    for s in range(trials):
        fp, nneg, fn, npos = one_trial(seed=100 + s)
        FP += fp
        TN += nneg - fp
        FN += fn
        TP += npos - fn
    fpr = FP / max(FP + TN, 1)
    fnr = FN / max(FN + TP, 1)
    t.add("false positive rate", "12.4%", pct(fpr),
          f"{FP}/{FP+TN} negative samples")
    t.add("false negative rate", "7.8%", pct(fnr),
          f"{FN}/{FN+TP} positive samples")
    return t


def main() -> Table:
    t = run()
    t.show()
    t.save("table3_detection_rates")
    return t


if __name__ == "__main__":
    main()
