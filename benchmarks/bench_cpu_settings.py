"""Fig. 2: training-speed impact of host-CPU settings (allocation +
frequency scaling), up to 15%, model-dependent (MoE > dense).

The host term of the step decomposition models the CPU-side work (data
loading, checkpoint I/O, communication coordination). MoE workloads carry
a larger host share (§3.1: heavier communication patterns need more CPU),
so the same bad CPU configuration costs them more — the published
model-dependence."""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import GUARD_WORKLOAD, Table, pct
from repro.simcluster import SimCluster, WorkloadProfile

DENSE = dataclasses.replace(GUARD_WORKLOAD, name="dense", host_s=0.7,
                            compute_s=8.7)
MOE = dataclasses.replace(GUARD_WORKLOAD, name="moe", host_s=1.5,
                          compute_s=7.9)
# host_factor for: fixed frequency + right core count vs dynamic scaling /
# under-allocated cores
SETTINGS = {"optimal": 1.0, "dynamic_freq": 0.7, "under_allocated": 0.5}


def _mean_step(workload: WorkloadProfile, host_factor: float,
               steps: int = 50) -> float:
    c = SimCluster(n_active=16, n_spare=0, workload=workload, seed=1)
    c.fleet.host_factor[:] = host_factor
    return float(np.mean([c.run_step()["step_time"] for _ in range(steps)]))


def run() -> Table:
    t = Table("Host-CPU settings vs training speed", "fig2")
    for wname, w in (("dense", DENSE), ("moe", MOE)):
        base = _mean_step(w, SETTINGS["optimal"])
        for sname, f in SETTINGS.items():
            if sname == "optimal":
                continue
            slow = _mean_step(w, f)
            delta = slow / base - 1.0
            t.add(f"{wname}/{sname}", "<= +15%", f"+{pct(delta)}",
                  f"step {base:.2f}s -> {slow:.2f}s")
    return t


def main() -> Table:
    t = run()
    t.show()
    t.save("fig2_cpu_settings")
    return t


if __name__ == "__main__":
    main()
