"""Render ROOFLINE.md from the dry-run JSONs (single + multi-pod)."""
from __future__ import annotations

import glob
import json
import os

DRYRUN = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def rows(mesh):
    out = []
    for p in sorted(glob.glob(os.path.join(DRYRUN, f"*__{mesh}.json"))):
        out.append(json.load(open(p)))
    return out


def main():
    lines = ["# Roofline table (generated from the dry-run artifacts)",
             "",
             "Terms in seconds per step on the TPU-v5e-class target "
             "(197 TF/s, 819 GB/s HBM, 50 GB/s/link). `useful` = "
             "MODEL_FLOPS/HLO_FLOPs; `rf` = roofline fraction vs the "
             "max(compute, memory-floor) ideal; `mem/chip` is the CPU-backend "
             "compile-time estimate (args+temp) — TPU executables with "
             "fused kernels are significantly leaner. One-sentence "
             "bottleneck note per the §Roofline requirement.", ""]
    for mesh, title in (("single", "16x16 single-pod (256 chips)"),
                        ("multi", "2x16x16 multi-pod (512 chips)")):
        lines += [f"## {title}", "",
                  "| arch | shape | compute | memory | collective | "
                  "dominant | useful | rf | mem/chip | what would move it |",
                  "|---|---|---|---|---|---|---|---|---|---|"]
        for c in rows(mesh):
            r = c["roofline"]
            ma = c["memory_analysis"]
            mem_gib = (ma["argument_bytes"] + ma["temp_bytes"]) / 2**30 \
                if ma else 0
            note = _note(c)
            lines.append(
                f"| {c['arch']} | {c['shape']} | {r['compute_s']:.2f} | "
                f"{r['memory_s']:.2f} | {r['collective_s']:.2f} | "
                f"{r['dominant']} | {r['useful_ratio']:.2f} | "
                f"{r['roofline_fraction']:.2f} | {mem_gib:.1f} GiB | "
                f"{note} |")
        lines.append("")
    path = os.path.join(os.path.dirname(__file__), "..", "ROOFLINE.md")
    with open(path, "w") as f:
        f.write("\n".join(lines))
    print(f"wrote {os.path.abspath(path)} "
          f"({len(rows('single'))}+{len(rows('multi'))} cells)")


def _note(c):
    r = c["roofline"]
    dom = r["dominant"]
    kind = c["kind"]
    arch = c["arch"]
    if dom == "collective":
        if "moe" in arch or "llama4" in arch or "deepseek" in arch:
            return ("EP dispatch/combine all-to-all + TP boundaries; "
                    "overlap with expert compute moves it")
        return ("TP-boundary all-reduces; async-collective overlap with "
                "compute hides 50-80% on TPU")
    if dom == "memory":
        if kind == "decode":
            return ("KV-cache streaming floor; batch growth or cache "
                    "quantization moves it")
        return ("attention-score materialization in the XLA path; the "
                "Pallas flash kernel removes it on TPU")
    return "MXU-bound; larger per-chip batch raises utilization"


if __name__ == "__main__":
    main()
