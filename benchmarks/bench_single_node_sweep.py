"""Fig. 5: single-node sweep exposes intra-node performance divergence that
burn-in passes.

Injects the §3.3 grey-node catalogue (thermal / power / marginal memory)
into single devices of otherwise-healthy nodes, runs the §5.2 sweep, and
reports per-device sustained throughput + pairwise bandwidth symmetry."""
from __future__ import annotations

import numpy as np

from benchmarks.common import GUARD_WORKLOAD, Table, pct
from repro.core.sweep import SweepConfig, single_node_sweep
from repro.simcluster import FaultKind, FaultRates, SimCluster

ZERO_RATES = FaultRates(thermal=0, power=0, mem_ecc=0, nic_down=0, nic_degraded=0, host_cpu=0, congestion=0, fail_stop=0, admission_grey_p=0)



def run() -> Table:
    t = Table("Single-node sweep: intra-node divergence", "fig5")
    c = SimCluster(n_active=8, n_spare=0, workload=GUARD_WORKLOAD,
                   rates=ZERO_RATES, seed=3)
    cases = [
        (1, FaultKind.THERMAL, 0.8),
        (2, FaultKind.POWER, 0.6),
        (3, FaultKind.MEM_ECC, 0.7),
    ]
    for node, kind, sev in cases:
        c.injector.inject(kind, node, severity=sev)
    # settle thermals to steady state
    c.fleet.advance_thermals(3600.0)

    cfg = SweepConfig(burn_seconds=120.0)
    for node in range(5):
        rep = single_node_sweep(c, node, cfg, enhanced=True)
        tf = rep.measurements["tflops"]
        spread = 1.0 - tf.min() / tf.max()
        verdict = "PASS" if rep.passed else "FAIL"
        kind = next((k.value for n, k, _ in cases if n == node), "healthy")
        t.add(f"node{node} ({kind})",
              "divergence visible" if kind != "healthy" else "uniform",
              f"{verdict}, spread {pct(spread)}",
              rep.failures[0][:60] if rep.failures else
              f"median {np.median(tf):.0f} TF/s")
    return t


def main() -> Table:
    t = run()
    t.show()
    t.save("fig5_single_node_sweep")
    return t


if __name__ == "__main__":
    main()
