"""Shared benchmark infrastructure: calibrated workload profiles, result
tables, and CSV emission.

Calibration notes (DESIGN.md §8): hardware dynamics are fitted to the
paper's published observations — the Table-2 throttle curve, the Fig.-3
0.3 s reroute penalty, the Fig.-2 <=15% host-CPU effect, the §3.3 10-15%
power deficit. Each bench prints PAPER vs REPRODUCED columns so the
correspondence is auditable.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import List

from repro.simcluster import FaultRates, WorkloadProfile

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

# The §7 pretraining workload: healthy step 10 s (Fig. 10 "after"),
# decomposed per §3 so each fault family has its published-size effect.
GUARD_WORKLOAD = WorkloadProfile(
    name="guard_pretrain", compute_s=8.0, comm_exposed_s=0.6, host_s=1.4,
    bytes_per_link_gb=4.0, step_noise=0.01, mfu_at_healthy=0.20)

# The Fig.-3 incident workload: an 8.4 s step whose exposed communication
# slice is 0.3 s, so one NIC-down reroute (2x on the fallback link) costs
# exactly the published +0.3 s.
FIG3_WORKLOAD = WorkloadProfile(
    name="fig3_job", compute_s=7.3, comm_exposed_s=0.3, host_s=0.8,
    bytes_per_link_gb=4.0, step_noise=0.004)

# Default fleet fault environment for §7-style runs.
RATES = FaultRates()


@dataclasses.dataclass
class Row:
    name: str
    paper: str
    repro: str
    detail: str = ""


class Table:
    def __init__(self, title: str, artifact: str):
        self.title = title
        self.artifact = artifact
        self.rows: List[Row] = []
        self.t0 = time.time()

    def add(self, name: str, paper, repro, detail: str = "") -> None:
        self.rows.append(Row(name, str(paper), str(repro), detail))

    def show(self) -> None:
        dur = time.time() - self.t0
        print(f"\n== {self.title}  [{self.artifact}]  ({dur:.1f}s)")
        w = max((len(r.name) for r in self.rows), default=10) + 2
        print(f"  {'metric'.ljust(w)}{'paper'.rjust(14)}{'repro'.rjust(14)}"
              f"  detail")
        for r in self.rows:
            print(f"  {r.name.ljust(w)}{r.paper.rjust(14)}"
                  f"{r.repro.rjust(14)}  {r.detail}")

    def csv_lines(self) -> List[str]:
        out = []
        for r in self.rows:
            out.append(f"{self.artifact}/{r.name},"
                       f"{r.paper},{r.repro},{r.detail}")
        return out

    def save(self, name: str) -> None:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        path = os.path.join(RESULTS_DIR, f"{name}.json")
        with open(path, "w") as f:
            json.dump([dataclasses.asdict(r) for r in self.rows], f,
                      indent=1)


def pct(x: float) -> str:
    return f"{100.0 * x:.1f}%"
