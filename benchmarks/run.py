"""Benchmark driver: one module per paper table/figure + the roofline
aggregation. ``python -m benchmarks.run [--quick]`` runs everything and
emits a CSV block (artifact/metric, paper, repro, detail)."""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (bench_ablation, bench_cpu_settings,
                        bench_detection_rates, bench_multi_node_sweep,
                        bench_nic_reroute, bench_roofline,
                        bench_single_node_sweep, bench_step_time,
                        bench_temp_freq, bench_variance)

MODULES = [
    ("table2", bench_temp_freq),
    ("fig2", bench_cpu_settings),
    ("fig3_fig4_table1", bench_nic_reroute),
    ("fig5", bench_single_node_sweep),
    ("fig6_fig7", bench_multi_node_sweep),
    ("table3", bench_detection_rates),
    ("fig9", bench_variance),
    ("fig10", bench_step_time),
    ("table4", bench_ablation),
    ("roofline", bench_roofline),
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the long multi-run benches (fig9/table4)")
    ap.add_argument("--only", help="comma-separated artifact filter")
    args = ap.parse_args(argv)

    only = set(args.only.split(",")) if args.only else None
    skip_slow = {"fig9", "table4"} if args.quick else set()
    tables = []
    t0 = time.time()
    for name, mod in MODULES:
        if only and name not in only:
            continue
        if name in skip_slow:
            print(f"[bench] skip {name} (--quick)")
            continue
        tables.append(mod.main())
    print(f"\n[bench] total {time.time()-t0:.1f}s")
    print("\n# CSV: artifact/metric,paper,repro,detail")
    for t in tables:
        for line in t.csv_lines():
            print(line)
    return 0


if __name__ == "__main__":
    sys.exit(main())
