"""Fleet-qualification campaign benchmark: the batched sweep pipeline's
scale target, pinned in CI.

Three measurements, written to ``BENCH_sweep.json``:

  1. ``campaign``: wall-clock of a full 4096-node enhanced
     ``fleet_qualification`` (batched compute burns + bandwidth probes +
     round-robin 2-node collective stage with disjoint-buddy retries)
     over a simulated fleet carrying a deterministic grey population.
     Gate: < ``--budget-s`` (default 2.0 s) wall.
  2. ``equivalence``: the same campaign driven through the scalar-compat
     fallback (batch methods hidden, node-by-node probes) on an
     identically-seeded fleet — per-node verdicts, failure strings AND
     raw measurements must be bit-identical to the batched pass. CI
     gates on this.
  3. ``detection``: the injected fault classes the campaign must catch
     (power/thermal/memory via the single-node stage, degraded links via
     the 2-node stage) — zero misses, zero false evictions of healthy
     nodes.

Run:  PYTHONPATH=src python -m benchmarks.bench_sweep_scale [--quick]
          [--nodes N] [--budget-s S] [--out PATH]

``--quick`` is the CI smoke sizing: the scalar-equivalence pass runs at
1024 nodes (the fallback is a Python loop) while the batched wall
measurement stays at the full campaign size.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from repro.core.sweep import SweepCampaign, fleet_qualification
from repro.simcluster import FaultKind, FaultRates, SimCluster

QUIET = FaultRates(thermal=0, power=0, mem_ecc=0, nic_down=0,
                   nic_degraded=0, host_cpu=0, congestion=0, fail_stop=0,
                   admission_grey_p=0)

# deterministic grey population: (stride, kind, severity, device)
FAULT_PLAN = (
    (97, FaultKind.POWER, 0.75, 4),
    (131, FaultKind.MEM_ECC, 0.85, 2),
    (211, FaultKind.THERMAL, 0.9, 0),
    (173, FaultKind.NIC_DEGRADED, 0.7, 1),   # only the 2-node stage sees it
)


class ScalarOnlyBackend:
    """Hides the batched protocol so ``fleet_qualification`` exercises
    the scalar-compat fallback (the golden reference path)."""

    def __init__(self, backend):
        self._b = backend

    def device_count(self, node_id):
        return self._b.device_count(node_id)

    def compute_probe(self, node_id, device, seconds):
        return self._b.compute_probe(node_id, device, seconds)

    def intra_bw_probe(self, node_id, a, b):
        return self._b.intra_bw_probe(node_id, a, b)

    def multi_node_probe(self, node_ids, steps):
        return self._b.multi_node_probe(node_ids, steps)

    def reference(self):
        return self._b.reference()


def build_cluster(n_nodes: int, seed: int = 0) -> SimCluster:
    c = SimCluster(n_active=n_nodes, n_spare=max(16, n_nodes // 64),
                   reserve=0, rates=QUIET, seed=seed)
    for stride, kind, sev, dev in FAULT_PLAN:
        for node in range(stride // 2, n_nodes, stride):
            c.injector.inject(kind, node, severity=sev, device=dev)
    c.fleet.advance_thermals(7200.0)          # let thermal faults settle
    return c


def faulted_nodes(n_nodes: int) -> set:
    return {node for stride, *_ in FAULT_PLAN
            for node in range(stride // 2, n_nodes, stride)}


def campaign_for(c: SimCluster) -> SweepCampaign:
    return SweepCampaign(node_ids=tuple(range(len(c.active))),
                         reference_pool=tuple(c.spares), enhanced=True)


def reports_equal(a, b) -> bool:
    if len(a) != len(b):
        return False
    for ra, rb in zip(a, b):
        if (ra.node_id, ra.passed, ra.failures, ra.duration_s) != \
                (rb.node_id, rb.passed, rb.failures, rb.duration_s):
            return False
        if set(ra.measurements) != set(rb.measurements):
            return False
        for k, va in ra.measurements.items():
            vb = rb.measurements[k]
            if isinstance(va, np.ndarray):
                if not np.array_equal(va, vb):
                    return False
            elif isinstance(va, dict):
                if set(va) != set(vb) or \
                        any(va[p] != vb[p] for p in va):
                    return False
            elif va != vb:
                return False
    return True


def run_campaign(n_nodes: int, repeats: int = 3) -> dict:
    """Batched campaign wall at full size (min over repeats — wall-clock
    gates want the least-interference sample)."""
    walls = []
    res = None
    for _ in range(max(repeats, 1)):
        c = build_cluster(n_nodes)
        t0 = time.perf_counter()
        res = fleet_qualification(c, campaign_for(c))
        walls.append(time.perf_counter() - t0)
    expected = faulted_nodes(n_nodes)
    failed = set(res.failed)
    return {
        "n_nodes": n_nodes,
        "wall_s": min(walls),
        "wall_s_all": walls,
        "passed": len(res.passed),
        "failed": len(res.failed),
        "retried": len(res.retry_buddies),
        "sweeps": res.sweeps,
        "node_seconds": res.node_seconds,
        "calibrated": res.calibrated,
        "missed_faulty": sorted(expected - failed),
        "false_failures": sorted(failed - expected),
    }


def run_equivalence(n_nodes: int) -> dict:
    """Batched vs scalar-fallback campaign on identically-seeded fleets:
    bit-identical verdicts, failure strings and measurements."""
    cb = build_cluster(n_nodes)
    cs = build_cluster(n_nodes)
    t0 = time.perf_counter()
    batched = fleet_qualification(cb, campaign_for(cb))
    t1 = time.perf_counter()
    scalar = fleet_qualification(ScalarOnlyBackend(cs), campaign_for(cs))
    t2 = time.perf_counter()
    return {
        "n_nodes": n_nodes,
        "identical": reports_equal(batched.reports, scalar.reports),
        "batched_wall_s": t1 - t0,
        "scalar_wall_s": t2 - t1,
        "speedup": (t2 - t1) / max(t1 - t0, 1e-9),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizing (equivalence at 1024 nodes)")
    ap.add_argument("--nodes", type=int, default=4096)
    ap.add_argument("--budget-s", type=float, default=2.0,
                    help="wall-time budget for the batched campaign")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_sweep.json"))
    args = ap.parse_args(argv)

    t0 = time.perf_counter()
    campaign = run_campaign(args.nodes, repeats=1 if args.quick else 3)
    equiv = run_equivalence(1024 if args.quick else args.nodes)
    out = {
        "benchmark": "sweep_scale",
        "mode": "quick" if args.quick else "full",
        "campaign": campaign,
        "equivalence": equiv,
        "budget_s": args.budget_s,
        "total_wall_s": time.perf_counter() - t0,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)

    print(f"campaign: {campaign['n_nodes']} nodes qualified in "
          f"{campaign['wall_s']:.2f}s wall "
          f"({campaign['passed']} passed, {campaign['failed']} failed, "
          f"{campaign['retried']} buddy retries, "
          f"{campaign['node_seconds'] / 3600.0:.0f}h bench time)")
    print(f"equivalence @{equiv['n_nodes']}: "
          f"{'IDENTICAL' if equiv['identical'] else 'DIVERGED'} "
          f"(batched {equiv['batched_wall_s']:.2f}s vs scalar "
          f"{equiv['scalar_wall_s']:.2f}s, {equiv['speedup']:.1f}x)")

    ok = True
    if campaign["wall_s"] > args.budget_s:
        print(f"FAIL: campaign {campaign['wall_s']:.2f}s over the "
              f"{args.budget_s:.1f}s budget", file=sys.stderr)
        ok = False
    if not equiv["identical"]:
        print("FAIL: batched campaign verdicts diverge from the scalar "
              "path", file=sys.stderr)
        ok = False
    if campaign["missed_faulty"]:
        print(f"FAIL: campaign missed faulty nodes "
              f"{campaign['missed_faulty'][:8]}...", file=sys.stderr)
        ok = False
    if campaign["false_failures"]:
        print(f"FAIL: campaign failed healthy nodes "
              f"{campaign['false_failures'][:8]}...", file=sys.stderr)
        ok = False
    print(f"wrote {args.out}  ({out['total_wall_s']:.0f}s)")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
