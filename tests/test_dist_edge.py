"""repro.dist edge cases beyond the seed matrix: divisibility fallback on
wide (fake) meshes, context restoration on exception, ZeRO-3 gather
round-trips, and rule-table overrides."""
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import api as dist
from repro.launch.mesh import make_cpu_mesh


def fake_mesh(**shape):
    """A mesh stand-in exposing just what spec() resolution reads, so rule
    logic is testable on topologies the host can't materialize."""
    return types.SimpleNamespace(shape=dict(shape),
                                 axis_names=tuple(shape))


class TestDivisibilityFallback:
    def test_non_divisible_dim_replicates_on_wide_mesh(self):
        ctx = dist.DistContext(fake_mesh(data=2, model=16))
        # whisper's 12 heads on a 16-wide model axis: replicate
        assert ctx.spec(("heads", None), shape=(12, 64)) == P(None, None)
        # 32 heads divide 16: sharded
        assert ctx.spec(("heads", None), shape=(32, 64)) == P("model", None)

    def test_multi_axis_rule_needs_full_product(self):
        ctx = dist.DistContext(fake_mesh(pod=2, data=16, model=16))
        # act_batch -> ("pod", "data"): 32 divides 2*16, 16 does not
        assert ctx.spec(("act_batch", None), shape=(32, 8)) == \
            P(("pod", "data"), None)
        assert ctx.spec(("act_batch", None), shape=(16, 8)) == P(None, None)

    def test_missing_mesh_axis_skipped(self):
        # no "pod" axis: act_batch degrades to plain "data" sharding
        ctx = dist.DistContext(fake_mesh(data=4, model=2))
        assert ctx.spec(("act_batch",), shape=(8,)) == P("data")

    def test_unknown_logical_name_replicates(self):
        ctx = dist.DistContext(fake_mesh(data=4, model=2))
        assert ctx.spec(("not_a_rule", "tp")) == P(None, "model")

    def test_duplicate_after_fallback_still_available(self):
        ctx = dist.DistContext(fake_mesh(data=2, model=16))
        # dim0 ("heads", 12) falls back to replicated, so "model" stays
        # free and dim1 ("ff", 32) can still claim it
        assert ctx.spec(("heads", "ff"), shape=(12, 32)) == P(None, "model")

    def test_axis_size_and_mesh_axes(self):
        ctx = dist.DistContext(fake_mesh(pod=2, data=16, model=16))
        assert ctx.axis_size("act_batch") == 32
        assert ctx.axis_size("act_heads") == 16
        assert ctx.axis_size(None) == 1
        assert ctx.mesh_axes("act_batch") == ("pod", "data")
        assert ctx.mesh_axes("layer") == ()


class TestContextManagement:
    def test_use_mesh_restores_prior_context_on_exception(self):
        dist.set_context(None)
        mesh = make_cpu_mesh()
        outer = dist.DistContext(mesh)
        dist.set_context(outer)
        try:
            with pytest.raises(RuntimeError):
                with dist.use_mesh(mesh):
                    assert dist.current() is not outer
                    raise RuntimeError("boom")
            assert dist.current() is outer
            # nested clean exit restores too
            with dist.use_mesh(mesh) as inner:
                assert dist.current() is inner
            assert dist.current() is outer
        finally:
            dist.set_context(None)

    def test_rules_override_scoped_to_context(self):
        mesh = make_cpu_mesh()
        rules = dict(dist.DEFAULT_RULES)
        rules["act_seq"] = ("model",)
        with dist.use_mesh(mesh, rules) as ctx:
            assert ctx.mesh_axes("act_seq") == ("model",)
        with dist.use_mesh(mesh) as ctx:
            assert ctx.mesh_axes("act_seq") == ()


class TestGatherFsdp:
    def _tree(self):
        params = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                  "b": jnp.arange(8, dtype=jnp.float32),
                  "scale": jnp.ones(())}
        axes = {"w": ("fsdp", "tp"), "b": ("fsdp",), "scale": ()}
        return params, axes

    def test_round_trip_preserves_values(self):
        params, axes = self._tree()
        mesh = make_cpu_mesh()
        with mesh, dist.use_mesh(mesh) as ctx:
            sharded = jax.device_put(
                params, dist.param_sharding(axes, params, ctx))
            gathered = jax.jit(lambda t: dist.gather_fsdp(t, axes))(sharded)
        for k in params:
            np.testing.assert_array_equal(np.asarray(gathered[k]),
                                          np.asarray(params[k]))

    def test_gather_drops_only_fsdp(self):
        params, axes = self._tree()
        mesh = make_cpu_mesh()
        with mesh, dist.use_mesh(mesh) as ctx:
            sharded = jax.device_put(
                params, dist.param_sharding(axes, params, ctx))
            gathered = jax.jit(lambda t: dist.gather_fsdp(t, axes))(sharded)
            w_spec = gathered["w"].sharding.spec
            # fsdp dim replicated; tp dim keeps whatever spec() resolves
            assert len(w_spec) == 0 or w_spec[0] is None
            assert gathered["b"].sharding.is_fully_replicated

    def test_noop_without_context(self):
        params, axes = self._tree()
        dist.set_context(None)
        out = dist.gather_fsdp(params, axes)
        assert out["w"] is params["w"]


class TestParamSharding:
    def test_matches_spec_per_leaf(self):
        mesh = make_cpu_mesh()
        ctx = dist.DistContext(mesh)
        params = {"w": jnp.zeros((8, 4)), "v": jnp.zeros((6,))}
        axes = {"w": ("fsdp", "tp"), "v": ("fsdp",)}
        sh = dist.param_sharding(axes, params, ctx)
        assert sh["w"].spec == ctx.spec(("fsdp", "tp"), shape=(8, 4))
        assert sh["v"].spec == ctx.spec(("fsdp",), shape=(6,))

    def test_requires_context_when_none_passed(self):
        dist.set_context(None)
        with pytest.raises(RuntimeError):
            dist.param_sharding({}, {})
