"""Scenario-layer tests: registry plumbing, each built-in's fleet effect,
window-granular engine equivalence, and the NIC error-baseline regression."""
import numpy as np
import pytest

from repro.core import FleetAssessment, StragglerDetector
from repro.simcluster.node import Fleet
from repro.simcluster import (CongestionStorm, DeadlockedCollective,
                              FaultKind, FaultRates,
                              InitialGreyPopulation, MaintenanceWindow,
                              PartialNicBrownout, RackThermal, RunConfig,
                              SimCluster, StragglerTimeoutCascade,
                              SwitchFailure, Tier, arm_all,
                              builtin_scenarios, scenario, simulate_run)

QUIET = FaultRates(thermal=0, power=0, mem_ecc=0, nic_down=0,
                   nic_degraded=0, host_cpu=0, congestion=0, fail_stop=0,
                   admission_grey_p=0)


def cluster(**kw):
    kw.setdefault("rates", QUIET)
    kw.setdefault("n_active", 32)
    kw.setdefault("n_spare", 4)
    return SimCluster(**kw)


class TestRegistry:
    def test_builtins_registered(self):
        names = set(builtin_scenarios())
        assert {"rack_thermal", "switch_failure", "congestion_storm",
                "maintenance_window", "initial_grey",
                "deadlocked_collective", "partial_nic_brownout",
                "straggler_timeout_cascade"} <= names

    def test_hang_scenarios_by_name_with_overrides(self):
        sc = scenario("deadlocked_collective", at_h=0.25, count=3)
        assert isinstance(sc, DeadlockedCollective)
        assert sc.at_h == 0.25 and sc.count == 3
        assert isinstance(scenario("partial_nic_brownout"),
                          PartialNicBrownout)
        assert isinstance(scenario("straggler_timeout_cascade"),
                          StragglerTimeoutCascade)

    def test_lookup_by_name_with_overrides(self):
        sc = scenario("rack_thermal", at_h=1.0, rack_size=4)
        assert isinstance(sc, RackThermal)
        assert sc.at_h == 1.0 and sc.rack_size == 4

    def test_unknown_name_raises(self):
        with pytest.raises(KeyError, match="unknown scenario"):
            scenario("definitely_not_a_scenario")

    def test_arm_all_accepts_names_and_instances(self):
        c = cluster()
        rng = np.random.RandomState(0)
        faults = arm_all(["initial_grey",
                          InitialGreyPopulation(p=1.0)], c, rng)
        # second scenario hits every active node with p=1
        assert len(faults) >= len(c.active)


class TestBuiltinScenarios:
    def test_rack_thermal_hits_contiguous_rack(self):
        c = cluster()
        rng = np.random.RandomState(1)
        RackThermal(at_h=0.0, rack_size=8, rack_start=4, severity=0.9,
                    power_fraction=0.0, stagger_s=0.0).arm(c, rng)
        targets = c.fleet.temp_target.max(axis=1)
        hot = np.flatnonzero(targets > c.fleet.hw.load_temp_c + 1)
        assert list(hot) == list(range(4, 12))
        # the rack ramps into a correlated compute-straggler group
        c.fleet.advance_thermals(3600.0)
        slow = c.fleet.node_compute_factor()
        assert slow[4:12].max() < slow[12:].min()

    def test_rack_thermal_future_events_fire_during_run(self):
        c = cluster()
        rng = np.random.RandomState(1)
        RackThermal(at_h=0.5, rack_size=8, rack_start=0, severity=0.9,
                    power_fraction=0.0, stagger_s=0.0).arm(c, rng)
        assert not c.injector.active_faults()         # nothing yet
        assert c.injector.next_change_t() == pytest.approx(1800.0)
        while c.t < 2400.0:
            c.run_window()
        fired = [f for f in c.injector.faults
                 if f.kind == FaultKind.THERMAL]
        assert len(fired) == 8
        assert {f.node for f in fired} == set(range(8))
        assert all(f.t_start == pytest.approx(1800.0) for f in fired)

    def test_switch_failure_degrades_many_nics_at_once(self):
        c = cluster()
        rng = np.random.RandomState(2)
        SwitchFailure(at_h=0.0, group_size=16, group_start=8,
                      down_fraction=0.3).arm(c, rng)
        group = np.arange(8, 24)
        nic_bad = (~c.fleet.nic_up[group]).any(axis=1) | \
            (c.fleet.nic_quality[group] < 0.99).any(axis=1)
        assert nic_bad.all()
        others = np.setdiff1d(np.arange(c.fleet.n), group)
        assert c.fleet.nic_up[others].all()
        assert (c.fleet.nic_quality[others] == 1.0).all()
        # comm factor degraded across the whole group
        assert (c.fleet.node_comm_factor()[group] < 1.0).all()

    def test_congestion_storm_transient_and_clears(self):
        c = cluster()
        rng = np.random.RandomState(3)
        CongestionStorm(at_h=0.1, duration_h=0.2, hit_fraction=0.5,
                        bursts_per_node=2.0).arm(c, rng)
        hit_any = False
        while c.t < 0.5 * 3600.0:
            c.run_window()
            if (c.injector.congestion_factor > 1.0).any():
                hit_any = True
        assert hit_any
        # storm is over and every burst expired: factors fully recover
        c.advance_idle(3600.0)
        assert (c.injector.congestion_factor == 1.0).all()
        # congestion is NOT a node fault: nothing stays latched/active
        assert not c.injector.active_faults()

    def test_maintenance_window_reverts_on_its_own(self):
        c = cluster()
        rng = np.random.RandomState(4)
        MaintenanceWindow(at_h=0.0, duration_h=0.5, group_size=8,
                          group_start=0, severity=0.5).arm(c, rng)
        assert (c.fleet.host_factor[:8] < 1.0).all()
        assert (c.fleet.host_factor[8:] == 1.0).all()
        # bounded: no escalation clock on planned maintenance
        assert all(f.escalate_at is None
                   for f in c.injector.active_faults())
        c.advance_idle(0.5 * 3600.0 + 60.0)
        assert (c.fleet.host_factor == 1.0).all()
        assert not c.injector.active_faults()

    def test_initial_grey_population_seeds_active_only(self):
        c = cluster(n_active=32, n_spare=8)
        rng = np.random.RandomState(5)
        faults = InitialGreyPopulation(p=0.5).arm(c, rng)
        assert 5 <= len(faults) <= 27          # ~Binomial(32, .5)
        assert all(f.node in c.active for f in faults)
        assert all(f.kind != FaultKind.FAIL_STOP for f in faults)

    def test_deadlocked_collective_hits_distinct_nodes(self):
        c = cluster()
        rng = np.random.RandomState(6)
        DeadlockedCollective(at_h=0.1, count=3,
                             interval_h=0.25).arm(c, rng)
        c.advance_idle(0.7 * 3600.0)       # past the last scheduled onset
        faults = [f for f in c.injector.faults
                  if f.kind == FaultKind.COLLECTIVE_HANG]
        assert len(faults) == 3
        assert len({f.node for f in faults}) == 3
        # incidents are sequential, not simultaneous
        onsets = sorted(f.t_start for f in faults)
        assert onsets[1] - onsets[0] == pytest.approx(900.0)
        assert (c.fleet.hang_phase[[f.node for f in faults]] > 0).all()

    def test_partial_nic_brownout_first_node_always_wedges(self):
        from repro.simcluster import BROWNOUT_HANG_SEV
        from repro.simcluster.faults import HANG_STALLED
        c = cluster()
        rng = np.random.RandomState(7)
        PartialNicBrownout(at_h=0.0, group_size=8,
                           group_start=4).arm(c, rng)
        c.advance_idle(120.0)              # past the onset stagger
        faults = [f for f in c.injector.faults
                  if f.kind == FaultKind.NIC_BROWNOUT]
        assert len(faults) == 8
        assert {f.node for f in faults} == set(range(4, 12))
        by_node = {f.node: f for f in faults}
        assert by_node[4].severity >= BROWNOUT_HANG_SEV
        assert c.fleet.hang_phase[4] == HANG_STALLED
        # the whole block's links degraded (brownout, not just the wedge)
        assert (c.fleet.node_comm_factor()[4:12] < 1.0).all()

    def test_straggler_timeout_cascade_pairs_thermal_with_wedge(self):
        c = cluster()
        rng = np.random.RandomState(8)
        StragglerTimeoutCascade(at_h=0.0, count=2, interval_h=0.1,
                                lag_h=0.05).arm(c, rng)
        c.advance_idle(900.0)              # past both incidents + wedge lag
        faults = [f for f in c.injector.faults
                  if f.kind in (FaultKind.THERMAL,
                                FaultKind.COLLECTIVE_HANG)]
        kinds = sorted(f.kind.value for f in faults)
        assert kinds == ["collective_hang", "collective_hang",
                         "thermal", "thermal"]
        for node in {f.node for f in faults}:
            mine = sorted((f for f in faults if f.node == node),
                          key=lambda f: f.t_start)
            assert mine[0].kind == FaultKind.THERMAL
            assert mine[1].kind == FaultKind.COLLECTIVE_HANG
            assert mine[1].t_start - mine[0].t_start == \
                pytest.approx(180.0)

    def test_simulate_run_consumes_scenarios(self):
        r = simulate_run(RunConfig(
            tier=Tier.ENHANCED, n_nodes=24, n_spare=6, duration_h=3.0,
            initial_grey_p=0.0, rates=QUIET, seed=3,
            scenarios=(RackThermal(at_h=0.5, rack_size=4, rack_start=2,
                                   severity=0.95, power_fraction=0.0,
                                   stagger_s=0.0),)))
        # the correlated rack event produces real detections
        flagged = [e for e in r.events if e["kind"] == "straggler_flagged"]
        assert flagged
        assert {e["node_id"] for e in flagged} & set(range(2, 6))

    def test_scenarios_by_name_in_runconfig(self):
        r = simulate_run(RunConfig(
            tier=Tier.BURNIN, n_nodes=16, n_spare=4, duration_h=1.0,
            initial_grey_p=0.0, rates=QUIET, seed=0,
            scenarios=("maintenance_window",)))
        assert r.steps > 0


class TestWindowEngine:
    def test_run_window_matches_run_step_quiet_fleet(self):
        """Fixed seed: the batched (W, N) fast path must reproduce the
        per-step path bit for bit (same rng stream, same composition)."""
        a = cluster(seed=9)
        b = cluster(seed=9)
        for _ in range(10):
            win = a.run_window(6)
            singles = [b.run_step()["step_time"] for _ in range(6)]
            np.testing.assert_array_equal(win["step_times"],
                                          np.asarray(singles))
        assert a.t == b.t
        assert a.step == b.step
        fa, fb = a.collect(), b.collect()
        np.testing.assert_array_equal(fa.metrics["step_time"],
                                      fb.metrics["step_time"])

    def test_run_window_matches_run_step_with_faults(self):
        """Events landing mid-window cut the batch and replay the rng, so
        the trajectories stay bit-identical through instant-effect fault
        activity (congestion storms, power faults, host faults...)."""
        rates = FaultRates(congestion=0.5, power=0.05, host_cpu=0.03,
                          thermal=0, fail_stop=0, admission_grey_p=0)
        a = cluster(rates=rates, seed=13)
        b = cluster(rates=rates, seed=13)
        win_steps, single_steps = [], []
        for _ in range(40):
            win = a.run_window(6)
            assert not win["crashed"]
            win_steps.append(win["step_times"])
            for _ in range(6):
                single_steps.append(b.run_step()["step_time"])
        np.testing.assert_array_equal(np.concatenate(win_steps),
                                      np.asarray(single_steps))
        assert a.t == b.t
        assert len(a.injector.faults) == len(b.injector.faults)

    def test_run_window_thermal_ramp_close_to_run_step(self):
        """Thermal ramps integrate at batch granularity: the window path
        tracks the per-step path within a tight tolerance through the
        transient and reaches the identical throttle equilibrium."""
        a = cluster(seed=7)
        b = cluster(seed=7)
        for c in (a, b):
            c.injector.inject(FaultKind.THERMAL, 3, severity=0.9, device=0)
        win_steps, single_steps = [], []
        for _ in range(120):                       # ~20 min: full ramp
            win_steps.append(a.run_window(6)["step_times"])
            for _ in range(6):
                single_steps.append(b.run_step()["step_time"])
        wa = np.concatenate(win_steps)
        wb = np.asarray(single_steps)
        # transiently coarser throttle sampling: bounded pointwise even
        # on the steepest part of the ramp, tight in aggregate
        np.testing.assert_allclose(wa, wb, rtol=0.15)
        rel = np.abs(wa - wb) / wb
        assert rel.mean() < 0.015
        assert (rel > 0.03).mean() < 0.05      # <5% of steps off by >3%
        # same equilibrium temperature and compute factor
        np.testing.assert_allclose(a.fleet.temp_c[3], b.fleet.temp_c[3],
                                   atol=Fleet.TEMP_SNAP_C)
        np.testing.assert_allclose(a.fleet.node_compute_factor()[3],
                                   b.fleet.node_compute_factor()[3],
                                   rtol=1e-3)

    def test_run_window_stops_on_crash(self):
        c = cluster(seed=1)
        c.injector.schedule(FaultKind.FAIL_STOP, 3, at=25.0, severity=1.0)
        win = c.run_window(6)
        assert win["crashed"]
        assert win["steps_run"] < 6
        assert c.crashed_nodes() == [3]

    def test_simulate_run_deterministic_with_scenarios(self):
        cfg = RunConfig(tier=Tier.ENHANCED, n_nodes=24, n_spare=4,
                        duration_h=3.0, initial_grey_p=0.1, seed=11,
                        scenarios=(CongestionStorm(at_h=0.5),
                                   "maintenance_window"))
        a, b = simulate_run(cfg), simulate_run(cfg)
        assert a.steps == b.steps and a.crashes == b.crashes
        np.testing.assert_array_equal(a.step_times, b.step_times)
        assert a.events == b.events


class TestNicErrorBaseline:
    def test_swapped_in_spare_reports_no_idle_error_spike(self):
        """Regression (issue satellite): a spare that accrued NIC error
        counts while idle must not dump them into its first in-job
        window's delta after a swap."""
        c = cluster(n_active=16, n_spare=4, seed=2)
        spare = c.spares[0]
        # errors accrued while idle (e.g. link flaps during qualification)
        c.fleet.nic_err_count[spare, :] += 5000.0
        for _ in range(6):
            c.run_step()
        c.collect()                      # establish everyone's baseline
        c.swap_node(2, spare)
        for _ in range(6):
            c.run_step()
        frame = c.collect()
        col = int(np.flatnonzero(frame.node_ids == spare)[0])
        assert frame.metrics["nic_errors"][col] == 0.0

    def test_in_job_errors_still_reported(self):
        """The swap-time baseline must not mask errors that happen while
        the node is actually serving the job."""
        c = cluster(n_active=16, n_spare=4, seed=2)
        spare = c.spares[0]
        for _ in range(6):
            c.run_step()
        c.collect()
        c.swap_node(2, spare)
        c.injector.inject(FaultKind.NIC_DOWN, spare, now=c.t, device=1)
        for _ in range(6):
            c.run_step()
        frame = c.collect()
        col = int(np.flatnonzero(frame.node_ids == spare)[0])
        assert frame.metrics["nic_errors"][col] == 1000.0


@pytest.mark.scale
class TestDetectorObjectBudget:
    def test_update_materializes_no_objects_on_healthy_fleet(self):
        det = StragglerDetector()
        rng = np.random.RandomState(0)
        n = 4096
        ids = np.arange(n, dtype=np.int64)
        for w in range(8):
            frame_metrics = {"step_time": 10 + rng.normal(0, 0.05, n)}
            from repro.core.telemetry import Frame
            out = det.update(Frame(t=w * 60.0, step=w * 6, node_ids=ids,
                                   metrics=frame_metrics,
                                   valid=np.ones(n, bool)))
        assert isinstance(out, FleetAssessment)
        assert out.materialized == 0
        assert not out.flagged.any()
