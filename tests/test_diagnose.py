"""Tests for ``repro.diagnose``: the timing trace, the what-if engine,
root-cause classification, the Diagnoser stage inside the Guard loop
(victims watched, not evicted), and the trainer-hook telemetry path."""
import numpy as np

from repro.core import DetectorConfig, StragglerDetector
from repro.core.detector import FleetAssessment
from repro.core.telemetry import Frame
from repro.diagnose import (Diagnoser, RootCause, RootCauseConfig,
                            TimingTrace, Topology, WindowTiming, whatif)
from repro.guard import (DiagnosisEvent, GuardSession, GuardStepHook,
                         NodeSwapped, Tier)
from repro.simcluster import FaultKind, FaultRates, RunConfig, SimCluster, \
    simulate_run

QUIET = FaultRates(thermal=0, power=0, mem_ecc=0, nic_down=0,
                   nic_degraded=0, host_cpu=0, congestion=0, fail_stop=0,
                   admission_grey_p=0)


def wt(node_ids, compute, comm, host, stall=None, t=0.0, step=0):
    n = len(node_ids)
    z = np.zeros(n)
    return WindowTiming(
        t=t, step=step, node_ids=np.asarray(node_ids, np.int64),
        compute=np.asarray(compute, float), comm=np.asarray(comm, float),
        host=np.asarray(host, float),
        stall=z if stall is None else np.asarray(stall, float))


# ------------------------------------------------------------------ trace

class TestTimingTrace:
    def test_circular_depth_and_means(self):
        tr = TimingTrace(depth=3)
        ids = [0, 1]
        for k in range(5):
            tr.push(wt(ids, [k, k], [1, 1], [0, 0], t=float(k), step=k))
        assert len(tr) == 3 and tr.full
        # windows kept: k = 2, 3, 4 -> mean compute 3
        assert np.allclose(tr.mean("compute"), [3.0, 3.0])
        assert np.allclose(tr.own_mean(), [4.0, 4.0])
        assert tr.last().step == 4

    def test_swap_backfills_only_changed_column(self):
        tr = TimingTrace(depth=4)
        for k in range(4):
            tr.push(wt([0, 1, 2], [9, 1, 1], [0, 0, 0], [0, 0, 0]))
        # node 0 replaced by node 7 reporting healthy 1.0
        tr.push(wt([7, 1, 2], [1, 1, 1], [0, 0, 0], [0, 0, 0]))
        assert np.array_equal(tr.node_ids, [7, 1, 2])
        # the new node must NOT inherit its predecessor's 9.0 history
        assert np.allclose(tr.rows("compute")[:, 0], 1.0)
        # peers keep their window
        assert np.allclose(tr.mean("compute")[1:], 1.0)

    def test_resize_reallocates(self):
        tr = TimingTrace(depth=4)
        tr.push(wt([0, 1], [1, 1], [0, 0], [0, 0]))
        g = tr.generation
        tr.push(wt([0, 1, 2], [1, 1, 1], [0, 0, 0], [0, 0, 0]))
        assert tr.generation == g + 1 and len(tr) == 1


# --------------------------------------------------------------- topology

class TestTopology:
    def test_group_max_matches_naive(self):
        rng = np.random.RandomState(0)
        stage_of = rng.randint(0, 5, size=37)
        topo = Topology(stage_of)
        x = rng.rand(4, 37)
        got = topo.group_max(x)
        for g in np.unique(stage_of):
            cols = stage_of == g
            expect = x[:, cols].max(axis=1, keepdims=True)
            assert np.allclose(got[:, cols], expect)

    def test_single_is_global_barrier(self):
        topo = Topology.single(6)
        x = np.asarray([1.0, 5.0, 2.0, 3.0, 4.0, 0.5])
        assert np.allclose(topo.group_max(x), 5.0)

    def test_grouped_and_pipeline(self):
        t = Topology.grouped(10, 4)
        assert t.n_groups == 3            # 4 + 4 + 2
        p = Topology.pipeline(12, 3)
        assert p.n_groups == 3 and np.all(p.counts == 4)

    def test_from_dist_uses_model_ways(self):
        class Ctx:
            def axis_size(self, name):
                return {"tp": 4}.get(name, 1)
        t = Topology.from_dist(Ctx(), 16)
        assert t.n_groups == 4


# ----------------------------------------------------------------- whatif

class TestWhatIf:
    def test_culprit_gets_blame_victims_get_none(self):
        topo = Topology.grouped(8, 4)
        own = np.full(8, 10.0)
        own[2] = 14.0                     # culprit in group 0
        rep = whatif(own, topo)
        assert rep.blame[2] > 3.9
        assert np.all(rep.blame[np.arange(8) != 2] == 0.0)
        # leave-one-out: fixing node 2 returns the fleet to ~10s
        assert abs(rep.marginal[2] - 4.0) < 0.2
        assert np.all(rep.marginal[np.arange(8) != 2] == 0.0)

    def test_shadowed_culprit_still_blamed(self):
        topo = Topology.grouped(8, 4)
        own = np.full(8, 10.0)
        own[1] = 13.0                     # both in group 0
        own[2] = 14.0
        rep = whatif(own, topo)
        assert rep.blame[1] > 2.5 and rep.blame[2] > 3.5
        # marginal: only the group argmax wins fleet time back, and only
        # down to the runner-up culprit
        assert rep.marginal[1] == 0.0
        assert abs(rep.marginal[2] - 1.0) < 0.1

    def test_marginal_zero_for_non_critical_group(self):
        topo = Topology.grouped(8, 4)
        own = np.full(8, 10.0)
        own[1] = 12.0                     # group 0 max
        own[6] = 15.0                     # group 1 max -> fleet critical
        rep = whatif(own, topo)
        assert rep.marginal[1] == 0.0     # fleet still waits on node 6
        assert abs(rep.marginal[6] - 3.0) < 0.2
        assert rep.blame[1] > 1.5         # standalone blame survives


# ------------------------------------------------------- classification

def _assess(node_ids, flagged_ids, support=None):
    n = len(node_ids)
    flagged = np.isin(node_ids, flagged_ids)
    masks = {}
    for name, ids in (support or {}).items():
        masks[name] = np.isin(node_ids, ids)
    return FleetAssessment(
        node_ids=np.asarray(node_ids, np.int64),
        slowdown=np.where(flagged, 0.3, 0.0), stalled=np.zeros(n, bool),
        step_deviant=flagged.copy(), support_masks=masks,
        flagged=flagged)


def _frame(node_ids, t=0.0, step=0):
    n = len(node_ids)
    return Frame(t=t, step=step,
                 node_ids=np.asarray(node_ids, np.int64),
                 metrics={"step_time": np.full(n, 10.0)},
                 valid=np.ones(n, bool))


class TestRootCause:
    N = 16

    def mk(self, **cfg):
        trace = TimingTrace(depth=4)
        topo = Topology.grouped(self.N, 8)
        return trace, Diagnoser(trace, topo, cfg=RootCauseConfig(**cfg))

    def push_windows(self, trace, compute, comm, host, stall=None, k=4):
        ids = list(range(self.N))
        for w in range(k):
            trace.push(wt(ids, compute, comm, host, stall,
                          t=60.0 * w, step=6 * w))

    def test_compute_culprit(self):
        trace, diag = self.mk()
        comp = np.full(self.N, 8.0)
        comp[3] = 11.0
        self.push_windows(trace, comp, np.full(self.N, 0.6),
                          np.full(self.N, 1.4))
        d = diag.diagnose(_frame(range(self.N)),
                          _assess(range(self.N), [3]))
        assert d.cause_of(3) == RootCause.COMPUTE_DEGRADED
        assert not d.records[3].held
        sig = diag.signals_for(3)
        assert sig.gpu_errors and not sig.nic_errors
        assert sig.root_cause == "compute_degraded"

    def test_sustained_comm_culprit(self):
        trace, diag = self.mk()
        comm = np.full(self.N, 2.0)
        comm[5] = 4.5
        self.push_windows(trace, np.full(self.N, 8.0), comm,
                          np.full(self.N, 1.4))
        d = diag.diagnose(_frame(range(self.N)),
                          _assess(range(self.N), [5]))
        assert d.cause_of(5) == RootCause.COMM_DEGRADED
        assert diag.signals_for(5).nic_errors

    def test_transient_comm_is_held(self):
        trace, diag = self.mk()
        ids = list(range(self.N))
        comp = np.full(self.N, 8.0)
        host = np.full(self.N, 1.4)
        burst = np.full(self.N, 2.0)
        burst[5] = 6.0
        calm = np.full(self.N, 2.0)
        # burst covered 2 of 4 windows and is OVER in the latest one
        trace.push(wt(ids, comp, burst, host))
        trace.push(wt(ids, comp, burst, host))
        trace.push(wt(ids, comp, calm, host))
        trace.push(wt(ids, comp, calm, host))
        d = diag.diagnose(_frame(ids), _assess(ids, [5]))
        assert d.cause_of(5) == RootCause.UNDIAGNOSED
        assert d.records[5].held and diag.should_hold(5)

    def test_cascade_victim_held(self):
        trace, diag = self.mk()
        stall = np.zeros(self.N)
        stall[np.arange(8)] = 3.0         # group 0 stalled
        stall[3] = 0.0                    # ...behind node 3
        comp = np.full(self.N, 8.0)
        comp[3] = 11.0
        self.push_windows(trace, comp, np.full(self.N, 0.6),
                          np.full(self.N, 1.4), stall)
        d = diag.diagnose(_frame(range(self.N)),
                          _assess(range(self.N), list(range(8))))
        assert d.cause_of(3) == RootCause.COMPUTE_DEGRADED
        for v in range(8):
            if v == 3:
                continue
            assert d.cause_of(v) == RootCause.CASCADE_VICTIM
            assert d.records[v].held
        sig = diag.signals_for(0)
        assert sig.root_cause == "cascade_victim" and not sig.actionable

    def test_data_stall_lane(self):
        trace, diag = self.mk()
        host = np.full(self.N, 1.4)
        host[7] = 4.0
        self.push_windows(trace, np.full(self.N, 8.0),
                          np.full(self.N, 0.6), host)
        d = diag.diagnose(_frame(range(self.N)),
                          _assess(range(self.N), [7]))
        assert d.cause_of(7) == RootCause.DATA_STALL
        assert diag.signals_for(7).host_errors

    def test_presymptomatic_support_lane(self):
        trace, diag = self.mk()
        self.push_windows(trace, np.full(self.N, 8.0),
                          np.full(self.N, 0.6), np.full(self.N, 1.4))
        d = diag.diagnose(
            _frame(range(self.N)),
            _assess(range(self.N), [9],
                    support={"gpu_temp": [9], "gpu_freq": [9]}))
        assert d.cause_of(9) == RootCause.COMPUTE_DEGRADED
        assert not d.records[9].held

    def test_reroute_downgrades_only_held(self):
        from repro.core.policy import Action, Decision
        trace, diag = self.mk()
        stall = np.zeros(self.N)
        stall[0] = 3.0
        self.push_windows(trace, np.full(self.N, 8.0),
                          np.full(self.N, 0.6), np.full(self.N, 1.4),
                          stall)
        d = diag.diagnose(_frame(range(self.N)),
                          _assess(range(self.N), [0]))
        dec = Decision(0, Action.IMMEDIATE_RESTART, "severe", 0.3)
        out = d.reroute(dec)
        assert out.action == Action.PENDING_VERIFICATION
        assert "cascade_victim" in out.reason
        other = Decision(4, Action.IMMEDIATE_RESTART, "severe", 0.3)
        assert d.reroute(other) is other   # not flagged -> untouched

    def test_new_records_dedup_until_cause_changes(self):
        trace, diag = self.mk()
        comp = np.full(self.N, 8.0)
        comp[3] = 11.0
        self.push_windows(trace, comp, np.full(self.N, 0.6),
                          np.full(self.N, 1.4))
        fr, fa = _frame(range(self.N)), _assess(range(self.N), [3])
        d1 = diag.diagnose(fr, fa)
        assert len(d1.new_records) == 1
        d2 = diag.diagnose(fr, fa)
        assert d2.new_records == []        # unchanged verdict: no re-emit
        assert d2.records[3] is d1.records[3]


# ------------------------------------------------------------ integration

class TestGuardLoopIntegration:
    def build(self, n=32, group=8, seed=3):
        topo = Topology.grouped(n, group)
        cluster = SimCluster(n_active=n, n_spare=4, rates=QUIET,
                             topology=topo, seed=seed)
        trace = TimingTrace(depth=8)
        cluster.attach_timing(trace)
        diag = Diagnoser(trace, topo)
        session = GuardSession.from_tier(
            Tier.ENHANCED, control=cluster, sweep_backend=cluster,
            diagnoser=diag)
        session.register_active(cluster.active)
        session.register_spares(cluster.spares)
        return cluster, session, diag

    def run_windows(self, cluster, session, n, ckpt_every=5):
        for w in range(n):
            cluster.run_window()
            frame = cluster.collect()
            if frame is not None:
                session.observe(frame)
            if (w + 1) % ckpt_every == 0:
                session.on_checkpoint()

    def test_victims_watched_culprit_evicted(self):
        cluster, session, diag = self.build()
        # severe compute culprit on node 3: its whole barrier group
        # (rows 0-7) reports the contaminated wall and gets flagged
        cluster.injector.inject(FaultKind.POWER, 3, severity=0.95)
        cluster.injector.inject(FaultKind.MEM_ECC, 3, severity=0.95)
        self.run_windows(cluster, session, 30)
        assert 3 not in cluster.active            # culprit pulled
        swapped = [e.old for e in session.trace.events
                   if isinstance(e, NodeSwapped)]
        assert swapped == [3]                     # ...and ONLY the culprit
        diags = [e for e in session.trace.events
                 if isinstance(e, DiagnosisEvent)]
        causes = {e.node_id: e.root_cause for e in diags}
        assert causes[3] == "compute_degraded"
        victims = [e for e in diags
                   if e.root_cause == "cascade_victim"]
        assert {e.node_id for e in victims} <= set(range(8)) - {3}
        assert len(victims) >= 3 and all(e.held for e in victims)
        # after the culprit left, the victims' latch released
        latched = session.monitor.detector.latched_nodes()
        assert all(v not in latched for v in range(8) if v != 3)

    def test_victim_hold_survives_pending_patience(self):
        cluster, session, diag = self.build()
        session.manager.pending_patience_s = 0.0   # pull ASAP
        cluster.injector.inject(FaultKind.POWER, 3, severity=0.95)
        cluster.injector.inject(FaultKind.MEM_ECC, 3, severity=0.95)
        self.run_windows(cluster, session, 30)
        # zero-patience pending pulls must still not evict held victims
        swapped = [e.old for e in session.trace.events
                   if isinstance(e, NodeSwapped)]
        assert swapped == [3]

    def test_simulate_run_diagnose_smoke(self):
        cfg = RunConfig(tier=Tier.ENHANCED, n_nodes=32, n_spare=4,
                        duration_h=1.0, rates=QUIET, initial_grey_p=0.2,
                        dp_group_size=8, diagnose=True, seed=5)
        r1 = simulate_run(cfg)
        r2 = simulate_run(cfg)
        assert r1.steps == r2.steps
        assert [e for e in r1.events] == [e for e in r2.events]
        assert r1.fault_log and \
            all("t_start" in f for f in r1.fault_log)


# ------------------------------------------------------------- step hook

class TestHookSignals:
    def test_hw_telemetry_yields_lane_signals(self):
        hook = GuardStepHook(window_steps=4, warmup_windows=1)
        # healthy baseline windows, then a thermal-throttle signature
        for s in range(12):
            hook(s, 1.0, {"gpu_temp": 58.0, "gpu_freq": 1.93})
        for s in range(12, 20):
            hook(s, 1.0, {"gpu_temp": 78.0, "gpu_freq": 1.40})
        sig = hook.session.control.error_signals(hook.node_id)
        assert sig.gpu_errors and not sig.nic_errors
        assert "gpu" in sig.detail

    def test_intermittent_exporter_keeps_frame_schema_stable(self):
        hook = GuardStepHook(window_steps=4, warmup_windows=1)
        # gpu_temp reported only in every other window: the metric
        # column must persist (carry-forward) so the detector's ring
        # history never reallocates and K-of-N persistence accumulates
        for s in range(16):
            window = s // 4
            m = {"gpu_temp": 58.0} if window % 2 == 0 else {}
            hook(s, 1.0, m)
        det = hook.session.monitor.detector
        gen = det.history.generation     # one realloc when the metric
        for s in range(16, 48):          # first appeared is inherent...
            window = s // 4
            m = {"gpu_temp": 58.0} if window % 2 == 0 else {}
            hook(s, 1.0, m)
        # ...but absent windows must NOT flap the schema afterwards
        assert det.history.generation == gen
        assert "gpu_temp" in det.history.metric_names()
        assert det.history.full          # history was never wiped

    def test_stale_cascade_verdict_loses_to_real_counters(self):
        from repro.core.triage import ErrorSignals as ES
        trace = TimingTrace(depth=4)
        topo = Topology.grouped(8, 4)
        cluster = SimCluster(n_active=8, n_spare=2, rates=QUIET,
                             topology=topo, seed=9)
        cluster.attach_timing(trace)
        diag = Diagnoser(trace, topo)
        session = GuardSession.from_tier(
            Tier.ENHANCED, control=cluster, sweep_backend=cluster,
            diagnoser=diag)
        # fake a stale victim verdict for node 1, then give the node a
        # real GPU-lane fault: the substrate counters must win
        from repro.diagnose.rootcause import Diagnosis
        diag.last[1] = Diagnosis(1, RootCause.CASCADE_VICTIM, 0.0, 0.0,
                                 0.0, 0.3, ("stale",), 0.0, 0)
        cluster.injector.inject(FaultKind.THERMAL, 1, severity=0.9)
        sig = session.manager._error_signals(1)
        assert sig.gpu_errors
        assert sig.root_cause != "cascade_victim"
        # with no contradicting counters the victim verdict still holds
        diag.last[2] = Diagnosis(2, RootCause.CASCADE_VICTIM, 0.0, 0.0,
                                 0.0, 0.3, ("stale",), 0.0, 0)
        assert session.manager._error_signals(2).root_cause == \
            "cascade_victim"
        assert isinstance(session.manager._error_signals(2), ES)

    def test_sparse_exporter_cadence_not_diluted(self):
        hook = GuardStepHook(window_steps=6, warmup_windows=1)
        # exporter reports every 3rd step only; means must be
        # per-sample, not per-step (else nic_up reads 1/3 -> link down)
        for s in range(24):
            m = {"nic_up": 1.0, "gpu_temp": 58.0} if s % 3 == 0 else {}
            hook(s, 1.0, m)
        sig = hook.session.control.error_signals(hook.node_id)
        assert not sig.actionable
        assert abs(hook._hw_last["nic_up"] - 1.0) < 1e-9

    def test_step_time_fallback_when_latched(self):
        hook = GuardStepHook(window_steps=4, warmup_windows=1, seed=1)
        hook.inject_stall(at_step=16, factor=1.6, steps=40)
        restarted = False
        for s in range(80):
            if hook(s, 1.0, {}):
                restarted = True
                break
        # latched or evicted either way: the old node id must carry
        # actionable evidence instead of the empty stub
        nid = hook.node_id if not restarted else hook.control.swaps[0][0]
        sig = hook.session.control.error_signals(nid)
        assert sig.actionable
        assert not hook.session.control.error_signals(99999).actionable

    def test_healthy_unlatched_node_has_no_signals(self):
        hook = GuardStepHook(window_steps=4, warmup_windows=1)
        for s in range(20):
            hook(s, 1.0, {})
        assert not hook.session.control.error_signals(
            hook.node_id).actionable

    def test_diagnose_flag_rejected_with_supplied_session(self):
        import pytest
        from repro.guard import LocalHostControl, LocalSweepBackend
        session = GuardSession.from_tier(
            Tier.ONLINE, LocalHostControl(), LocalSweepBackend())
        with pytest.raises(ValueError, match="hook-owned"):
            GuardStepHook(session=session, diagnose=True)

    def test_diagnose_mode_feeds_trace(self):
        hook = GuardStepHook(window_steps=4, warmup_windows=1,
                             diagnose=True)
        for s in range(20):
            hook(s, 1.0, {"compute_s": 0.7, "comm_s": 0.2,
                          "host_s": 0.1})
        assert hook.trace is not None and len(hook.trace) >= 3
        comp = hook.trace.last().compute
        assert abs(comp[0] - 0.7) < 1e-6
        assert hook.session.diagnoser is not None


# --------------------------------------------------- sim decomposition

class TestSimDecomposition:
    def test_trace_matches_fault_decomposition(self):
        topo = Topology.grouped(16, 8)
        cluster = SimCluster(n_active=16, n_spare=2, rates=QUIET,
                             topology=topo, seed=11)
        trace = TimingTrace(depth=4)
        cluster.attach_timing(trace)
        cluster.injector.inject(FaultKind.HOST_CPU, 5, severity=0.9)
        cluster.injector.inject(FaultKind.NIC_DEGRADED, 12, severity=0.9,
                                device=1)
        for _ in range(4):
            cluster.run_window()
            cluster.collect()
        last = trace.last()
        w = cluster.workload
        # host fault shows up in the host channel of node 5 only
        assert last.host[5] > 2.0 * w.host_s
        assert abs(last.host[4] - w.host_s) < 0.1
        # NIC fault shows up in the comm channel of node 12 only
        assert last.comm[12] > 1.5 * w.comm_exposed_s
        assert abs(last.comm[5] - w.comm_exposed_s) < 0.1
        # victims in group 1 carry stall, their own channels stay clean
        assert last.stall[8] > 0.1
        assert abs(last.compute[8] - w.compute_s) < 0.2

    def test_window_engine_decomposition_matches_per_step(self):
        def build():
            topo = Topology.grouped(8, 4)
            c = SimCluster(n_active=8, n_spare=2, rates=QUIET,
                           topology=topo, seed=4)
            tr = TimingTrace(depth=6)
            c.attach_timing(tr)
            c.injector.inject(FaultKind.POWER, 2, severity=0.8)
            return c, tr

        c1, t1 = build()
        for _ in range(12):
            c1.run_step()
        f1 = c1.collect()
        c2, t2 = build()
        c2.run_window(12)
        f2 = c2.collect()
        assert np.array_equal(f1.metrics["step_time"],
                              f2.metrics["step_time"])
        # trace channels: the batched path sums k noise factors in one
        # reduction instead of k accumulations -> ULP-level association
        # differences only
        for ch in ("compute", "comm", "host", "stall"):
            assert np.allclose(getattr(t1.last(), ch),
                               getattr(t2.last(), ch),
                               rtol=1e-12, atol=1e-12), ch

    def test_wall_telemetry_contaminates_group(self):
        topo = Topology.grouped(8, 4)
        c = SimCluster(n_active=8, n_spare=2, rates=QUIET,
                       topology=topo, seed=4)
        c.injector.inject(FaultKind.POWER, 2, severity=0.9)
        c.run_window()
        f = c.collect()
        st = f.metrics["step_time"]
        # everyone in group 0 reports the culprit's wall; group 1 clean
        assert np.allclose(st[:4], st[2])
        assert st[0] > st[4] * 1.05


class TestDetectorGoldenWithTopology:
    def test_detector_flags_whole_group_without_diagnoser(self):
        """The failure mode the subsystem exists for: with measured-wall
        telemetry and no diagnoser, the detector cannot separate the
        culprit from its barrier group."""
        topo = Topology.grouped(32, 8)
        cluster = SimCluster(n_active=32, n_spare=2, rates=QUIET,
                             topology=topo, seed=2)
        det = StragglerDetector(DetectorConfig())
        cluster.injector.inject(FaultKind.POWER, 3, severity=0.95)
        cluster.injector.inject(FaultKind.MEM_ECC, 3, severity=0.95)
        flagged = set()
        for _ in range(8):
            cluster.run_window()
            frame = cluster.collect()
            fa = det.update(frame)
            flagged |= set(fa.flagged_ids().tolist())
        assert set(range(8)) <= flagged
