"""Shared test configuration: hypothesis settings profiles.

The property suite (test_properties.py) runs wherever hypothesis is
installed — locally that may be nowhere (it importorskips), in CI the
``[test]`` extra provides it. CI selects the "ci" profile via
``HYPOTHESIS_PROFILE=ci``: capped examples, no deadline (shared runners
have noisy clocks), and derandomized so a red run is reproducible from
the log instead of depending on the runner's entropy.
"""
import os

try:
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "ci", max_examples=20, deadline=None, derandomize=True,
        suppress_health_check=[HealthCheck.too_slow])
    settings.register_profile("dev", deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
except ImportError:
    pass
