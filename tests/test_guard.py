"""Tests for the ``repro.guard`` control plane: session wiring, typed
event bus, pooled spare accounting, the non-blocking sweep scheduler,
the pending-patience / buddy-retry manager branches, the trainer step
hook, and simulate_run determinism."""
import json

import numpy as np
import pytest

from repro.core import ErrorSignals, NodeState, SweepConfig, SweepReference
from repro.core.telemetry import Frame
from repro.guard import (EventBus, GuardSession, GuardStepHook, JsonlSink,
                         NodeSwapped, StragglerFlagged, Tier, TraceSink)
from repro.simcluster import FaultKind, FaultRates, RunConfig, SimCluster, \
    simulate_run

QUIET = FaultRates(thermal=0, power=0, mem_ecc=0, nic_down=0,
                   nic_degraded=0, host_cpu=0, congestion=0, fail_stop=0,
                   admission_grey_p=0)


def quiet_cluster(**kw):
    kw.setdefault("rates", QUIET)
    kw.setdefault("n_active", 16)
    kw.setdefault("n_spare", 4)
    return SimCluster(**kw)


def mk_session(cluster, tier=Tier.ENHANCED, **kw):
    s = GuardSession.from_tier(tier, control=cluster, sweep_backend=cluster,
                               **kw)
    s.register_active(cluster.active)
    s.register_spares(cluster.spares)
    return s


# ---------------------------------------------------------------- session

class TestSessionWiring:
    def test_tier_builders_set_capabilities(self):
        c = quiet_cluster()
        for builder, tier in ((GuardSession.burnin, Tier.BURNIN),
                              (GuardSession.node_sweep, Tier.NODE_SWEEP),
                              (GuardSession.online, Tier.ONLINE),
                              (GuardSession.enhanced, Tier.ENHANCED)):
            s = builder(c, c)
            assert s.tier == tier
            assert s.online_monitoring == (tier >= Tier.ONLINE)
            assert s.sweep_tooling == (tier >= Tier.NODE_SWEEP)
            assert s.manager.enhanced_sweep == (tier == Tier.ENHANCED)

    def test_observe_noop_below_online(self):
        c = quiet_cluster()
        s = mk_session(c, tier=Tier.NODE_SWEEP)
        for _ in range(12):
            c.run_step()
        frame = c.collect()
        out = s.observe(frame)
        assert out.events == [] and out.restarts == []

    def test_severe_straggler_swapped_through_session(self):
        c = quiet_cluster(seed=11)
        s = mk_session(c, tier=Tier.ENHANCED)
        c.injector.inject(FaultKind.POWER, 7, severity=0.95)
        for step in range(1, 400):
            c.run_step()
            if step % c.window_steps == 0:
                frame = c.collect()
                if frame is not None:
                    s.observe(frame)
            if step % 60 == 0:
                s.on_checkpoint()
            if 7 not in c.active:
                break
        assert 7 not in c.active
        assert s.manager.state[7] == NodeState.QUARANTINED
        kinds = [e.kind for e in s.events()]
        assert "straggler_flagged" in kinds
        assert "swap" in kinds and "quarantine" in kinds
        # event-driven qualification was queued for the quarantined node
        assert s.scheduler.busy + s.scheduler.backlog >= 1


# -------------------------------------------------------------- event bus

class TestEventBus:
    def test_typed_subscription_and_trace(self):
        bus = EventBus()
        trace = TraceSink()
        bus.attach(trace)
        got = []
        bus.subscribe(StragglerFlagged, got.append)
        bus.publish(StragglerFlagged(t=1.0, step=5, node_id=3,
                                     action="immediate_restart", reason="x"))
        bus.publish(NodeSwapped(t=2.0, step=6, old=3, new=9))
        assert len(got) == 1 and got[0].node_id == 3
        assert len(trace) == 2
        assert trace.of_kind("swap")[0].new == 9

    def test_jsonl_sink_round_trip(self, tmp_path):
        path = str(tmp_path / "events.jsonl")
        bus = EventBus()
        with JsonlSink(path) as sink:
            bus.attach(sink)
            bus.publish(StragglerFlagged(t=1.0, step=2, node_id=4,
                                         action="defer", reason="slow",
                                         slowdown=0.12))
            bus.publish(NodeSwapped(t=3.0, step=4, old=4, new=8,
                                    reason="deferred", deferred=True))
        rows = [json.loads(line) for line in open(path)]
        assert [r["kind"] for r in rows] == ["straggler_flagged", "swap"]
        assert rows[0]["slowdown"] == pytest.approx(0.12)
        assert rows[1]["deferred"] is True

    def test_session_events_serializable(self):
        c = quiet_cluster(seed=3)
        s = mk_session(c)
        c.injector.inject(FaultKind.POWER, 2, severity=0.95)
        for step in range(1, 200):
            c.run_step()
            if step % c.window_steps == 0:
                frame = c.collect()
                if frame is not None:
                    s.observe(frame)
        for d in s.trace.as_dicts():
            json.dumps(d)          # every event must be JSON-clean
            assert "kind" in d and "t" in d and "step" in d


# ------------------------------------------------- pooled spare accounting

class TestSparePool:
    def _assert_no_leak(self, cluster, session):
        """A node is never simultaneously a spare and ACTIVE (the old
        runtime's crash path leaked cluster.spares[0] this way)."""
        mgr = session.manager
        active = set(cluster.active)
        assert not (set(mgr.spares) & active), (mgr.spares, cluster.active)
        assert not (set(cluster.spares) & active)
        for nid in mgr.spares:
            assert mgr.state[nid] == NodeState.HEALTHY_SPARE

    def test_crash_replacement_does_not_leak_spares(self):
        c = quiet_cluster(seed=2)
        s = mk_session(c)
        c.injector.inject(FaultKind.FAIL_STOP, 4, severity=1.0)
        rec = c.run_step()
        assert rec["crashed"]
        dead = c.crashed_nodes()
        replacements = s.handle_crash(dead, lost_steps=3)
        self._assert_no_leak(c, s)
        assert s.manager.state[4] == NodeState.TERMINATED
        assert 4 not in c.active
        for nid in replacements:
            assert nid in c.active
            assert s.manager.state[nid] == NodeState.ACTIVE
        crash = s.trace.of_kind("crash")[0]
        assert crash.nodes == (4,) and crash.lost_steps == 3
        # fail-stop deaths are not Guard terminations (separate stat)
        assert s.stats.nodes_lost == len(dead)
        assert s.stats.nodes_terminated == 0

    def test_take_spare_provisions_when_dry(self):
        c = quiet_cluster(n_spare=1, seed=9)
        s = mk_session(c)
        first = s.take_spare()
        assert s.spares_free == 0
        second = s.take_spare()       # pool dry -> provisioned + admitted
        assert second != first
        assert s.manager.state[second] == NodeState.ACTIVE
        assert s.stats.nodes_provisioned >= 1
        self_ids = {first, second}
        assert not (self_ids & set(s.manager.spares))

    def test_return_spare_round_trip(self):
        c = quiet_cluster()
        s = mk_session(c)
        nid = s.take_spare()
        s.return_spare(nid)
        assert nid in s.manager.spares
        assert s.manager.state[nid] == NodeState.HEALTHY_SPARE

    def test_top_up_spares(self):
        c = quiet_cluster(n_spare=2)
        s = mk_session(c)
        s.take_spare()
        s.take_spare()
        added = s.top_up_spares(4)
        assert added == 4 and s.spares_free == 4


# -------------------------------------------------------- manager branches

class FakeControl:
    def __init__(self):
        self.t = 0.0
        self.swaps = []
        self.restarts = []
        self._next = 500
        self.signals = ErrorSignals()

    def swap_node(self, old, new):
        self.swaps.append((old, new))

    def restart_job(self, reason):
        self.restarts.append(reason)

    def provision_node(self):
        self._next += 1
        return self._next

    def error_signals(self, node_id):
        return self.signals

    def remediate(self, node_id, stage):
        pass

    def now(self):
        return self.t


def hw_frame(w, n=8, bad=None):
    """Frame with healthy step times; ``bad`` node deviates on two
    hardware signals only (the PENDING_VERIFICATION tier)."""
    temps = np.full(n, 58.0)
    freqs = np.full(n, 1.93)
    if bad is not None:
        temps[bad] = 90.0
        freqs[bad] = 1.3
    metrics = {
        "step_time": np.full(n, 10.0) + np.linspace(0, 0.01, n),
        "gpu_temp": temps,
        "gpu_freq": freqs,
    }
    return Frame(t=w * 60.0, step=w * 6,
                 node_ids=np.arange(n, dtype=np.int64),
                 metrics=metrics, valid=np.ones(n, bool))


class TestPendingPatience:
    def _session(self, patience_s):
        ctl = FakeControl()
        s = GuardSession.from_tier(Tier.ONLINE, ctl, None,
                                   pending_patience_s=patience_s)
        s.register_active(range(8))
        s.register_spares([100, 101])
        return ctl, s

    def test_pending_past_patience_is_pulled_at_checkpoint(self):
        ctl, s = self._session(patience_s=300.0)
        for w in range(6):
            ctl.t = w * 60.0
            out = s.observe(hw_frame(w, bad=3))
        assert s.manager.state[3] == NodeState.PENDING
        assert 3 in s.monitor.pending
        # patience not yet exceeded: checkpoint leaves the node in the job
        ctl.t = s.manager.pending_since[3] + 100.0
        assert s.manager.on_checkpoint() == 0
        assert s.manager.state[3] == NodeState.PENDING
        # keep deviating past the patience window -> pulled for offline
        # verification at the next checkpoint
        for w in range(6, 14):
            ctl.t = w * 60.0
            out = s.observe(hw_frame(w, bad=3))
        assert out is not None
        ctl.t = s.manager.pending_since[3] + 301.0
        applied = s.manager.on_checkpoint()
        assert applied == 1
        assert s.manager.state[3] == NodeState.QUARANTINED
        assert (3, 100) in ctl.swaps
        assert any("deferred" in r for r in ctl.restarts)

    def test_pending_that_clears_returns_to_active(self):
        ctl, s = self._session(patience_s=300.0)
        for w in range(6):
            ctl.t = w * 60.0
            s.observe(hw_frame(w, bad=3))
        assert s.manager.state[3] == NodeState.PENDING
        # deviation stops; hysteresis clears the latch after clean windows
        for w in range(6, 14):
            ctl.t = w * 60.0
            s.observe(hw_frame(w, bad=None))
        ctl.t += 10_000.0            # way past patience — but it cleared
        assert s.manager.on_checkpoint() == 0
        assert s.manager.state[3] == NodeState.ACTIVE
        assert 3 not in s.manager.pending_since
        assert not ctl.swaps
        cleared = s.trace.of_kind("straggler_cleared")
        assert [e.node_id for e in cleared] == [3]


class RetryBackend:
    """Single-node stage healthy; the 2-node stage fails whenever the
    contaminated buddy is in the group."""

    def __init__(self, bad_buddies=(10,)):
        self.bad = set(bad_buddies)
        self.groups = []
        self._ref = SweepReference(device_tflops=100.0, intra_bw_gbps=100.0,
                                   pair_step_time=1.0)

    def device_count(self, node_id):
        return 2

    def compute_probe(self, node_id, device, seconds):
        return 100.0

    def intra_bw_probe(self, node_id, a, b):
        return 100.0

    def multi_node_probe(self, node_ids, steps):
        self.groups.append(tuple(node_ids))
        bad = bool(self.bad & set(node_ids))
        return np.full(steps, 2.0 if bad else 1.0)

    def reference(self):
        return self._ref


class TestBuddyRetry:
    def _manager(self, backend):
        ctl = FakeControl()
        s = GuardSession.from_tier(Tier.ENHANCED, ctl, backend,
                                   sweep_cfg=SweepConfig())
        s.register_spares([10, 11])
        return ctl, s.manager

    def test_contaminated_buddy_retried_before_verdict(self):
        backend = RetryBackend(bad_buddies=(10,))
        ctl, mgr = self._manager(backend)
        mgr.state[5] = NodeState.QUARANTINED
        pre = mgr.stats.sweeps_run
        assert mgr.qualify(5) == NodeState.HEALTHY_SPARE
        # single-node stage passed, 2-node vs buddy 10 failed, disjoint
        # retry vs 11 passed: three sweep executions
        assert mgr.stats.sweeps_run - pre == 3
        assert backend.groups[0] == (5, 10)
        assert backend.groups[1] == (5, 11)
        assert 5 in mgr.spares
        assert mgr.stats.nodes_requalified == 1

    def test_failure_with_both_buddies_goes_to_triage(self):
        backend = RetryBackend(bad_buddies=(10, 11))
        ctl, mgr = self._manager(backend)
        mgr.state[5] = NodeState.QUARANTINED
        # no actionable error signals -> triage early-terminates (§6)
        assert mgr.qualify(5) == NodeState.TERMINATED
        assert mgr.stats.triages_run == 1
        assert mgr.stats.nodes_terminated == 1


# ------------------------------------------------------------- scheduler

class TestSweepScheduler:
    def test_qualification_overlaps_job_time(self):
        c = quiet_cluster(seed=4)
        s = mk_session(c, tier=Tier.ENHANCED)
        # healthy node wrongly quarantined (a false positive)
        s.manager.state[3] = NodeState.QUARANTINED
        c.active.remove(3)
        s.scheduler.submit(3)
        t0 = c.t
        s.advance(t0)
        assert s.scheduler.busy == 1
        assert s.manager.state[3] == NodeState.QUARANTINED   # still on bench
        finish = s.scheduler.next_finish_t()
        assert finish > t0                       # sweeps take simulated time
        s.advance(finish - 1.0)
        assert s.manager.state[3] == NodeState.QUARANTINED
        s.advance(finish + 1.0)
        assert s.manager.state[3] == NodeState.HEALTHY_SPARE
        assert 3 in s.manager.spares
        fin = s.trace.of_kind("sweep_finish")
        assert fin and fin[0].node_id == 3
        assert fin[0].outcome == "healthy_spare"
        assert fin[0].duration_s > 0

    def test_concurrency_cap_and_drain(self):
        c = quiet_cluster(n_active=12, seed=4)
        s = mk_session(c, tier=Tier.ENHANCED, sweep_concurrency=1)
        for nid in (1, 2, 3):
            s.manager.state[nid] = NodeState.QUARANTINED
            c.active.remove(nid)
        assert s.scheduler.submit_quarantined() == 3
        assert s.scheduler.submit_quarantined() == 0   # no double-enqueue
        s.advance(c.t)
        assert s.scheduler.busy == 1 and s.scheduler.backlog == 2
        s.scheduler.drain(c.t)
        assert s.scheduler.busy == 0 and s.scheduler.backlog == 0
        for nid in (1, 2, 3):
            assert s.manager.state[nid] in (NodeState.HEALTHY_SPARE,
                                            NodeState.TERMINATED)


# ------------------------------------------------------------- step hook

class TestGuardStepHook:
    def test_stall_triggers_restart_and_swap(self):
        hook = GuardStepHook(window_steps=4, n_peers=8, seed=1)
        hook.inject_stall(at_step=16, factor=10.0, steps=4)
        restart_steps = []
        for step in range(1, 40):
            if hook(step, 0.1, {}):
                restart_steps.append(step)
                hook.on_restart(step - 8)
        assert restart_steps, "stall was not detected"
        assert restart_steps[0] <= 24
        assert hook.restarts_requested == 1
        assert hook.node_id != 0                 # follows its replacement
        ctl = hook.control
        assert ctl.swaps and ctl.swaps[0][0] == 0
        assert ctl.restarts
        flagged = hook.session.trace.of_kind("straggler_flagged")
        assert any(e.node_id == 0 for e in flagged)

    def test_post_restart_spike_absorbed_by_warmup(self):
        """After a rewind the hook re-enters warmup: the restore/re-JIT
        spike in the first window must not flag the replacement node and
        cascade into more restarts."""
        hook = GuardStepHook(window_steps=4, n_peers=8, seed=1)
        hook.inject_stall(at_step=16, factor=10.0, steps=4)
        restarted_at = None
        for step in range(1, 30):
            if hook(step, 0.1, {}):
                restarted_at = step
                hook.on_restart(8)
                break
        assert restarted_at is not None
        # replay from the checkpoint: restore + re-JIT inflate the first
        # window's measured walls by 5x
        for i, wall in enumerate([0.5, 0.5, 0.1, 0.1]):
            assert not hook(9 + i, wall, {})
        for step in range(13, 41):
            assert not hook(step, 0.1, {})
        assert hook.restarts_requested == 1

    def test_deferred_swap_lands_via_trainer_checkpoint(self):
        """Moderate (10-20%) sustained slowdown takes the DEFER tier:
        nothing happens until the checkpoint notification, then the swap
        is applied and the next step call requests the rewind."""
        hook = GuardStepHook(window_steps=4, n_peers=8, seed=1,
                             baseline_alpha=0.0)   # frozen peer baseline
        for step in range(1, 9):                   # establish baseline
            assert not hook(step, 0.1, {})
        hook.inject_stall(at_step=9, factor=1.15, steps=100)
        restarted = False
        for step in range(9, 41):
            if hook(step, 0.1, {}):
                restarted = True
                break
        assert not restarted                       # deferred, not immediate
        flagged = hook.session.trace.of_kind("straggler_flagged")
        assert any(e.action == "defer_to_checkpoint" for e in flagged)
        assert not hook.control.swaps
        hook.on_checkpoint(step=40)                # trainer saved a ckpt
        assert hook.control.swaps                  # swap landed here
        assert hook(41, 0.1, {})                   # rewind requested
        assert hook.restarts_requested == 1
        swaps = hook.session.trace.of_kind("swap")
        assert swaps and swaps[0].deferred

    def test_supplied_session_pools_left_untouched(self):
        """Binding a hook to an existing session must not re-register
        its synthetic population over the caller's real pools."""
        c = quiet_cluster(n_active=16, n_spare=4)
        s = mk_session(c, tier=Tier.ONLINE)
        before = dict(s.manager.state)
        spares_before = list(s.manager.spares)
        hook = s.step_hook(window_steps=4, n_peers=8)
        assert hook.session is s
        assert s.manager.state == before
        assert s.manager.spares == spares_before

    def test_healthy_run_stays_quiet(self):
        hook = GuardStepHook(window_steps=4, n_peers=8, seed=1)
        assert not any(hook(step, 0.1, {}) for step in range(1, 60))
        assert hook.restarts_requested == 0
        assert not hook.session.trace.of_kind("straggler_flagged")
        assert hook.frames_fed > 10


# ---------------------------------------------------------- simulate_run

class TestSimulateRunGuardAPI:
    def test_determinism_across_invocations(self):
        cfg = RunConfig(tier=Tier.ENHANCED, n_nodes=24, n_spare=4,
                        duration_h=4.0, initial_grey_p=0.15, seed=7)
        a = simulate_run(cfg)
        b = simulate_run(cfg)
        assert a.steps == b.steps
        assert a.crashes == b.crashes
        assert a.mfu == pytest.approx(b.mfu, abs=0)
        assert a.mttf_h == pytest.approx(b.mttf_h, abs=0)
        assert a.human_hours == pytest.approx(b.human_hours, abs=0)
        assert a.incidents == b.incidents
        np.testing.assert_array_equal(a.step_times, b.step_times)
        assert a.events == b.events

    def test_restart_events_report_lost_steps(self):
        cfg = RunConfig(tier=Tier.ENHANCED, n_nodes=24, n_spare=4,
                        duration_h=4.0, initial_grey_p=0.15,
                        rates=FaultRates(fail_stop=3e-2), seed=2)
        r = simulate_run(cfg)
        assert r.crashes > 0
        crashes = [e for e in r.events if e["kind"] == "crash"]
        restarts = [e for e in r.events if e["kind"] == "restart"]
        assert crashes and restarts
        for e in crashes:
            assert e["nodes"], e
        rewinds = [e for e in restarts if e["rewind"]]
        assert rewinds
        assert all(e["lost_steps"] >= 0 for e in rewinds)
        assert any(e["lost_steps"] > 0 for e in rewinds)

    def test_events_carry_global_step_without_online_monitoring(self):
        """Manager-path events must report the training step even in the
        tiers that never call observe() (regression: step froze at 0)."""
        cfg = RunConfig(tier=Tier.BURNIN, n_nodes=24, n_spare=4,
                        duration_h=4.0, initial_grey_p=0.1,
                        rates=FaultRates(fail_stop=3e-2), seed=1)
        r = simulate_run(cfg)
        crashes = [e for e in r.events if e["kind"] == "crash"]
        assert crashes
        assert any(e["step"] > 0 for e in crashes)
        swaps = [e for e in r.events if e["kind"] == "swap"]
        assert any(e["step"] > 0 for e in swaps)
