"""Unit tests for the offline sweeps (§5) and the triage FSM (§6)."""

from repro.core import (ErrorSignals, SweepConfig, TriageConfig,
                        TriageOutcome, TriageWorkflow, multi_node_sweep,
                        qualification_sweep, single_node_sweep)
from repro.simcluster import FaultKind, FaultRates, SimCluster

QUIET = FaultRates(thermal=0, power=0, mem_ecc=0, nic_down=0,
                   nic_degraded=0, host_cpu=0, congestion=0, fail_stop=0,
                   admission_grey_p=0)


def cluster(seed=0, n=8):
    return SimCluster(n_active=n, n_spare=0, rates=QUIET, seed=seed)


class TestSingleNodeSweep:
    def test_healthy_node_passes(self):
        c = cluster()
        rep = single_node_sweep(c, 0, SweepConfig())
        assert rep.passed, rep.failures

    def test_power_fault_fails_compute(self):
        c = cluster()
        c.injector.inject(FaultKind.POWER, 1, severity=0.8, device=4)
        rep = single_node_sweep(c, 1, SweepConfig())
        assert not rep.passed
        assert any("dev4" in f for f in rep.failures)

    def test_slow_thermal_needs_sustained_burn(self):
        c = cluster()
        c.injector.inject(FaultKind.THERMAL, 2, severity=0.9, device=0)
        # temp hasn't ramped yet: short burn passes, enhanced catches it
        short = single_node_sweep(c, 2, SweepConfig(burn_seconds=5.0))
        long = single_node_sweep(c, 2, SweepConfig(), enhanced=True)
        assert short.passed
        assert not long.passed

    def test_mem_fault_breaks_bw_symmetry(self):
        c = cluster()
        c.injector.inject(FaultKind.MEM_ECC, 3, severity=0.9, device=2)
        rep = single_node_sweep(c, 3, SweepConfig())
        assert not rep.passed


class TestMultiNodeSweep:
    def test_nic_fault_invisible_to_single_node(self):
        c = cluster()
        c.injector.inject(FaultKind.NIC_DOWN, 1, device=5)
        assert single_node_sweep(c, 1, SweepConfig()).passed
        rep = multi_node_sweep(c, 1, buddies=[0], cfg=SweepConfig())
        assert not rep.passed

    def test_healthy_pair_passes(self):
        c = cluster()
        rep = multi_node_sweep(c, 0, buddies=[4], cfg=SweepConfig())
        assert rep.passed, rep.failures

    def test_qualification_is_conservative(self):
        c = cluster()
        c.injector.inject(FaultKind.NIC_DEGRADED, 2, severity=0.8, device=1)
        basic = qualification_sweep(c, 2, buddies=[0], enhanced=False)
        full = qualification_sweep(c, 2, buddies=[0], enhanced=True)
        assert basic.passed        # single-node only: blind to the link
        assert not full.passed     # enhanced adds the 2-node stage


class TestTriage:
    def test_no_actionable_errors_early_terminates(self):
        tw = TriageWorkflow()
        res = tw.run(7, ErrorSignals(), now=0.0,
                     remediate=lambda n, s: None, verify=lambda n: True)
        assert res.outcome == TriageOutcome.TERMINATED
        assert res.stages_run == []

    def test_gpu_path_escalates_until_verified(self):
        tw = TriageWorkflow()
        fixed_at = {"count": 0}

        def remediate(node, stage):
            fixed_at["count"] += 1

        def verify(node):
            return fixed_at["count"] >= 2    # healthy after second stage

        res = tw.run(1, ErrorSignals(gpu_errors=True), now=0.0,
                     remediate=remediate, verify=verify)
        assert res.outcome == TriageOutcome.RETURNED_TO_SWEEP
        assert res.stages_run == ["gpu_reset", "reboot"]
        assert res.elapsed_s > 0 and res.human_s > 0

    def test_exhausted_stages_terminate(self):
        tw = TriageWorkflow()
        res = tw.run(2, ErrorSignals(nic_errors=True), now=0.0,
                     remediate=lambda n, s: None, verify=lambda n: False)
        assert res.outcome == TriageOutcome.TERMINATED
        assert res.stages_run == ["nic_reset", "reboot", "reimage"]

    def test_three_strikes_in_week(self):
        tw = TriageWorkflow(TriageConfig(strike_limit=3))
        day = 86_400.0
        r1 = tw.run(5, ErrorSignals(gpu_errors=True), now=0.0,
                    remediate=lambda n, s: None, verify=lambda n: True)
        r2 = tw.run(5, ErrorSignals(gpu_errors=True), now=2 * day,
                    remediate=lambda n, s: None, verify=lambda n: True)
        assert r1.outcome == r2.outcome == TriageOutcome.RETURNED_TO_SWEEP
        r3 = tw.run(5, ErrorSignals(gpu_errors=True), now=4 * day,
                    remediate=lambda n, s: None, verify=lambda n: True)
        assert r3.outcome == TriageOutcome.TERMINATED
        assert "strikes" in r3.reason

    def test_strikes_expire_outside_window(self):
        tw = TriageWorkflow(TriageConfig(strike_limit=3))
        week = 7 * 86_400.0
        for i in range(4):    # one strike every 4 days: never 3 in a week
            res = tw.run(6, ErrorSignals(gpu_errors=True), now=i * week / 1.6,
                         remediate=lambda n, s: None, verify=lambda n: True)
        assert res.outcome == TriageOutcome.RETURNED_TO_SWEEP

    def test_strike_window_expiry_resets_count(self):
        tw = TriageWorkflow(TriageConfig(strike_limit=3))
        week = 7 * 86_400.0
        for i in range(2):
            tw.run(4, ErrorSignals(gpu_errors=True), now=i * 3600.0,
                   remediate=lambda n, s: None, verify=lambda n: True)
        assert tw.strike_count(4, now=3600.0) == 2
        # both strikes age out of the window: the count RESETS, so a
        # fresh pair of incidents later does not terminate the node
        assert tw.strike_count(4, now=2 * week) == 0
        for i in range(2):
            res = tw.run(4, ErrorSignals(gpu_errors=True),
                         now=2 * week + i * 3600.0,
                         remediate=lambda n, s: None,
                         verify=lambda n: True)
        assert res.outcome == TriageOutcome.RETURNED_TO_SWEEP
        assert tw.strike_count(4, now=2 * week + 3600.0) == 2

    def test_cascade_victim_consumes_no_strike(self):
        tw = TriageWorkflow(TriageConfig(strike_limit=3))
        gpu = ErrorSignals(gpu_errors=True)
        victim = ErrorSignals(root_cause="cascade_victim")
        tw.run(8, gpu, now=0.0, remediate=lambda n, s: None,
               verify=lambda n: True)
        tw.run(8, gpu, now=3600.0, remediate=lambda n, s: None,
               verify=lambda n: True)
        # a cascade-victim verdict between strikes: returned to sweep,
        # no remediation stages, and crucially NO third strike
        res = tw.run(8, victim, now=7200.0, remediate=lambda n, s: None,
                     verify=lambda n: True)
        assert res.outcome == TriageOutcome.RETURNED_TO_SWEEP
        assert res.stages_run == [] and res.human_s == 0.0
        assert tw.strike_count(8, now=7200.0) == 2
        # the next REAL incident is strike 3 and does terminate
        res = tw.run(8, gpu, now=10_800.0, remediate=lambda n, s: None,
                     verify=lambda n: True)
        assert res.outcome == TriageOutcome.TERMINATED
        assert "strikes" in res.reason

    def test_host_errors_route_to_host_lane(self):
        tw = TriageWorkflow()
        res = tw.run(9, ErrorSignals(host_errors=True), now=0.0,
                     remediate=lambda n, s: None, verify=lambda n: False)
        assert res.stages_run == ["reboot", "reimage"]

    def test_root_cause_rich_signals_merge(self):
        diag = ErrorSignals(gpu_errors=True, root_cause="compute_degraded",
                            detail="blame +20%")
        counters = ErrorSignals(nic_errors=True)
        merged = diag.merged(counters)
        assert merged.gpu_errors and merged.nic_errors
        assert merged.root_cause == "compute_degraded"
        assert merged.detail == "blame +20%"
        assert ErrorSignals().merged(counters).nic_errors


class TestRemediationModel:
    def test_reimage_clears_host_fault(self):
        c = cluster(seed=3)
        c.injector.inject(FaultKind.HOST_CPU, 1, severity=0.8)
        assert c.fleet.host_factor[1] < 1.0
        for _ in range(10):                # p=0.8 per attempt
            c.injector.remediate(1, "reimage")
        assert c.fleet.host_factor[1] == 1.0

    def test_gpu_reset_does_not_fix_nic(self):
        c = cluster(seed=4)
        c.injector.inject(FaultKind.NIC_DOWN, 2, device=3)
        for _ in range(10):
            c.injector.remediate(2, "gpu_reset")
        assert not c.fleet.nic_up[2, 3]
