"""guardlint: per-rule caught/pass fixtures, pragma grammar, self-lint.

Each GLxxx rule gets at least one deliberately-seeded violation fixture
(must be caught) and one allowlisted/clean fixture (must pass), plus the
meta-policy tests: suppressions without reasons are themselves
violations, and the repo's own tree lints clean with all 8 rules active.
"""
import json
import textwrap
from pathlib import Path

import pytest

from repro.analysis.guardlint import RULES, lint_paths
from repro.analysis.guardlint.__main__ import main as guardlint_main
from repro.analysis.guardlint.pragmas import parse_pragmas

REPO_ROOT = Path(__file__).resolve().parents[1]
KNOWN = set(RULES)


def make_project(tmp_path, files, readme=None, gates=None):
    """Write a fixture repo and lint its src/ tree."""
    (tmp_path / "pyproject.toml").write_text("[project]\nname = 'fx'\n")
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    if readme is not None:
        (tmp_path / "README.md").write_text(readme)
    if gates is not None:
        bdir = tmp_path / "benchmarks"
        bdir.mkdir(exist_ok=True)
        (bdir / "gates.json").write_text(json.dumps(gates))
    src_dir = tmp_path / "src"
    src_dir.mkdir(exist_ok=True)
    return lint_paths([str(src_dir)], root=str(tmp_path))


def hits(result, rule):
    return [v for v in result.violations if v.rule == rule]


# ------------------------------------------------------------ GL001


class TestGL001Determinism:
    def test_catches_unseeded_and_wallclock(self, tmp_path):
        res = make_project(tmp_path, {"src/repro/simcluster/x.py": """
            import time
            import numpy as np
            from time import time as wall

            def f():
                a = np.random.rand(4)           # module stream
                g = np.random.default_rng()     # unseeded ctor
                t = time.time()                 # wall clock
                u = wall()                      # aliased wall clock
                return a, g, t, u
        """})
        lines = sorted(v.line for v in hits(res, "GL001"))
        assert len(lines) == 4

    def test_seeded_and_perf_counter_pass(self, tmp_path):
        res = make_project(tmp_path, {"src/repro/diagnose/x.py": """
            import time
            import numpy as np

            RNG = np.random.default_rng(1234)
            LEGACY = np.random.RandomState(7)

            def f():
                t0 = time.perf_counter()
                return RNG.normal(), LEGACY.rand(3), t0
        """})
        assert not hits(res, "GL001")

    def test_non_replay_packages_exempt(self, tmp_path):
        res = make_project(tmp_path, {"src/repro/train/x.py": """
            import time
            STAMP = time.time()
        """})
        assert not hits(res, "GL001")


# ------------------------------------------------------------ GL002


class TestGL002DtypeDiscipline:
    def test_catches_f64_in_hot_module(self, tmp_path):
        res = make_project(tmp_path, {"src/repro/core/x.py": """
            # guardlint: hot
            import numpy as np

            def f(x):
                a = np.zeros(100)               # dtype-defaulting
                b = x.astype(np.float64)        # explicit f64
                c = x.astype(float)             # builtin float == f64
                return a, b, c
        """})
        assert len(hits(res, "GL002")) == 3

    def test_explicit_f32_passes(self, tmp_path):
        res = make_project(tmp_path, {"src/repro/core/x.py": """
            # guardlint: hot
            import numpy as np

            def f(x):
                a = np.zeros(100, np.float32)
                b = np.full((2, 3), np.nan, dtype=np.float32)
                return a, b, x.astype(np.float32)
        """})
        assert not hits(res, "GL002")

    def test_cold_modules_exempt(self, tmp_path):
        res = make_project(tmp_path, {"src/repro/core/x.py": """
            import numpy as np
            SCRATCH = np.zeros(8)
        """})
        assert not hits(res, "GL002")

    def test_pragma_with_reason_suppresses(self, tmp_path):
        res = make_project(tmp_path, {"src/repro/core/x.py": """
            # guardlint: hot
            import numpy as np
            # guardlint: disable=GL002 reason=rolling f64 accumulator
            ACC = np.zeros(8, np.float64)
        """})
        assert not hits(res, "GL002")
        assert any(v.rule == "GL002" for v, _ in res.suppressed)


# ------------------------------------------------------------ GL003


class TestGL003HotLoops:
    def test_catches_per_node_loops(self, tmp_path):
        res = make_project(tmp_path, {"src/repro/core/x.py": """
            # guardlint: hot
            def f(self, nodes, n):
                out = []
                for node in self.nodes:
                    out.append(node)
                for i in range(len(nodes)):
                    out.append(i)
                vals = [i * 2 for i in range(self.n_nodes)]
                return out, vals
        """})
        assert len(hits(res, "GL003")) == 3

    def test_flagged_sized_loops_pass(self, tmp_path):
        res = make_project(tmp_path, {"src/repro/core/x.py": """
            # guardlint: hot
            def f(flagged, changed):
                out = [x for x in flagged]      # O(flagged), fine
                for c in changed:
                    out.append(c)
                return out
        """})
        assert not hits(res, "GL003")


# ------------------------------------------------------------ GL004


_EVENT_BASE = textwrap.dedent("""
    import dataclasses
    from typing import ClassVar, Tuple

    @dataclasses.dataclass(frozen=True)
    class GuardEvent:
        kind: ClassVar[str] = "base"
        t: float = 0.0
""")


def ev_file(extra):
    """Flush-left event-module fixture: shared base + test-specific part."""
    return _EVENT_BASE + textwrap.dedent(extra)


class TestGL004EventTaxonomy:
    def test_complete_event_passes(self, tmp_path):
        res = make_project(tmp_path, {"src/repro/guard/ev.py": ev_file("""
            @dataclasses.dataclass(frozen=True)
            class NodeZapped(GuardEvent):
                kind: ClassVar[str] = "node_zapped"
                node_id: int = -1

            EVENT_TYPES: Tuple[type, ...] = (NodeZapped,)
        """)}, readme="| `node_zapped` | a node was zapped |\n")
        assert not res.violations       # parseable AND taxonomy-complete

    def test_catches_missing_kind_registry_and_readme(self, tmp_path):
        res = make_project(tmp_path, {"src/repro/guard/ev.py": ev_file("""
            @dataclasses.dataclass(frozen=True)
            class Unkinded(GuardEvent):
                node_id: int = -1

            @dataclasses.dataclass(frozen=True)
            class Undocumented(GuardEvent):
                kind: ClassVar[str] = "undocumented"
                node_id: int = -1

            EVENT_TYPES: Tuple[type, ...] = (Unkinded,)
        """)}, readme="nothing here\n")
        msgs = " ".join(v.message for v in hits(res, "GL004"))
        assert "does not declare" in msgs          # Unkinded: no kind
        assert "README" in msgs                    # Undocumented: no row
        assert "registry" in msgs                  # Undocumented: no entry

    def test_catches_unserializable_payload(self, tmp_path):
        res = make_project(tmp_path, {"src/repro/guard/ev.py": ev_file("""
            import numpy as np

            @dataclasses.dataclass(frozen=True)
            class BadPayload(GuardEvent):
                kind: ClassVar[str] = "bad_payload"
                arr: np.ndarray = None

            EVENT_TYPES: Tuple[type, ...] = (BadPayload,)
        """)}, readme="| `bad_payload` | row |\n")
        assert any("JSONL" in v.message for v in hits(res, "GL004"))


# ------------------------------------------------------------ GL005


class TestGL005CensusDiscipline:
    def test_catches_unasserted_mutation(self, tmp_path):
        res = make_project(tmp_path, {"src/repro/fleet/p.py": """
            class GlobalSparePool:
                def __init__(self):
                    self._free = {}

                def add(self, key, rec):
                    self._free[key] = rec       # no census assert
        """})
        assert len(hits(res, "GL005")) == 1

    def test_asserted_mutation_and_readonly_pass(self, tmp_path):
        res = make_project(tmp_path, {"src/repro/fleet/p.py": """
            class GlobalSparePool:
                def __init__(self):
                    self._free = {}

                def _assert_census(self):
                    assert isinstance(self._free, dict)

                def add(self, key, rec):
                    self._free[key] = rec
                    self._assert_census()

                def free_count(self):
                    return len(self._free)      # read-only: exempt
        """})
        assert not hits(res, "GL005")

    def test_other_classes_exempt(self, tmp_path):
        res = make_project(tmp_path, {"src/repro/fleet/p.py": """
            class SomethingElse:
                def add(self, key, rec):
                    self._free[key] = rec
        """})
        assert not hits(res, "GL005")


# ------------------------------------------------------------ GL006


class TestGL006SwallowedExceptions:
    def test_catches_bare_and_swallowed(self, tmp_path):
        res = make_project(tmp_path, {"src/repro/train/w.py": """
            def f(x):
                try:
                    x()
                except:
                    x = None
                try:
                    x()
                except ValueError:
                    pass
        """})
        assert len(hits(res, "GL006")) == 2

    def test_surfaced_handler_passes(self, tmp_path):
        res = make_project(tmp_path, {"src/repro/train/w.py": """
            import logging

            def f(x, payload):
                try:
                    x()
                except ValueError as e:
                    logging.error("write failed for %r: %s", payload, e)
                    raise
        """})
        assert not hits(res, "GL006")


# ------------------------------------------------------------ GL007


_BENCH = """
    FOO_GATE = 1.5
    def run():
        return FOO_GATE
"""


class TestGL007GateManifest:
    def test_missing_manifest_caught(self, tmp_path):
        res = make_project(tmp_path, {"benchmarks/bench_a.py": _BENCH})
        assert any("missing" in v.message for v in hits(res, "GL007"))

    def test_value_drift_caught(self, tmp_path):
        res = make_project(tmp_path, {"benchmarks/bench_a.py": _BENCH},
                           gates={"bench_a.py": {"FOO_GATE": 2.0}})
        assert any("drifted" in v.message for v in hits(res, "GL007"))

    def test_vanished_gate_caught(self, tmp_path):
        res = make_project(
            tmp_path, {"benchmarks/bench_a.py": _BENCH},
            gates={"bench_a.py": {"FOO_GATE": 1.5, "GONE_GATE": 3.0}})
        assert any("GONE_GATE" in v.message for v in hits(res, "GL007"))

    def test_exact_manifest_passes(self, tmp_path):
        res = make_project(tmp_path, {"benchmarks/bench_a.py": _BENCH},
                           gates={"bench_a.py": {"FOO_GATE": 1.5}})
        assert not hits(res, "GL007")


# ------------------------------------------------------------ GL008


class TestGL008KernelParity:
    def test_missing_ref_caught(self, tmp_path):
        res = make_project(tmp_path, {
            "src/repro/kernels/mykern/ops.py": "def op(x):\n    return x\n"})
        assert any("no ref.py" in v.message for v in hits(res, "GL008"))

    def test_untested_ref_caught(self, tmp_path):
        res = make_project(tmp_path, {
            "src/repro/kernels/mykern/ops.py": "def op(x):\n    return x\n",
            "src/repro/kernels/mykern/ref.py":
                "def op_ref(x):\n    return x\n"})
        assert any("golden parity" in v.message for v in hits(res, "GL008"))

    def test_ref_with_golden_test_passes(self, tmp_path):
        res = make_project(tmp_path, {
            "src/repro/kernels/mykern/ops.py": "def op(x):\n    return x\n",
            "src/repro/kernels/mykern/ref.py":
                "def op_ref(x):\n    return x\n",
            "tests/test_mykern.py": """
                from repro.kernels.mykern import op, op_ref

                def test_parity():
                    assert op(1) == op_ref(1)
            """})
        assert not hits(res, "GL008")


# ------------------------------------------------- pragma grammar / GL000


class TestPragmas:
    def test_hot_tag_with_annotation(self):
        p = parse_pragmas("# guardlint: hot  (detector window)\nx = 1\n",
                          KNOWN)
        assert p.hot and not p.errors

    def test_trailing_disable_applies_to_its_line(self):
        src = "import numpy as np\nx = 1  " \
              "# guardlint: disable=GL002 reason=scratch\n"
        p = parse_pragmas(src, KNOWN)
        assert p.suppresses("GL002", 2) == "scratch"
        assert p.suppresses("GL002", 1) is None
        assert p.suppresses("GL003", 2) is None

    def test_own_line_disable_applies_to_next_code_line(self):
        src = ("# guardlint: disable=GL002,GL003 reason=compat shim\n"
               "# more prose\n"
               "x = 1\n")
        p = parse_pragmas(src, KNOWN)
        assert p.suppresses("GL002", 3) == "compat shim"
        assert p.suppresses("GL003", 3) == "compat shim"

    def test_disable_file_scope(self):
        src = "# guardlint: disable-file=GL006 reason=generated code\nx=1\n"
        p = parse_pragmas(src, KNOWN)
        assert p.suppresses("GL006", 999) == "generated code"

    def test_missing_reason_is_meta_violation(self):
        p = parse_pragmas("# guardlint: disable=GL006\nx = 1\n", KNOWN)
        assert p.errors and "reason" in p.errors[0].message
        assert p.suppresses("GL006", 2) is None

    def test_unknown_rule_is_meta_violation(self):
        p = parse_pragmas("# guardlint: disable=GL999 reason=x\n", KNOWN)
        assert p.errors and "GL999" in p.errors[0].message

    def test_pragma_in_string_literal_ignored(self):
        p = parse_pragmas('s = "# guardlint: disable=GL006 reason=no"\n',
                          KNOWN)
        assert not p.errors and p.suppresses("GL006", 1) is None

    def test_reasonless_suppression_fails_the_lint(self, tmp_path):
        res = make_project(tmp_path, {"src/repro/train/w.py": """
            def f(x):
                try:
                    x()
                except ValueError:  # guardlint: disable=GL006
                    pass
        """})
        assert any(v.rule == "GL000" for v in res.violations)
        assert hits(res, "GL006")      # and the suppression did NOT apply

    def test_gl000_is_never_suppressible(self, tmp_path):
        res = make_project(tmp_path, {"src/repro/train/w.py": """
            # guardlint: disable-file=GL000 reason=nice try
            x = 1
        """})
        assert any(v.rule == "GL000" for v in res.violations)


# ------------------------------------------------------------ CLI + self


class TestCLI:
    def test_exit_codes_and_json_report(self, tmp_path, capsys):
        (tmp_path / "pyproject.toml").write_text("[project]\nname='x'\n")
        bad = tmp_path / "src" / "repro" / "train" / "w.py"
        bad.parent.mkdir(parents=True)
        bad.write_text("try:\n    pass\nexcept:\n    pass\n")
        report = tmp_path / "report.json"
        rc = guardlint_main([str(tmp_path / "src"),
                             "--json", str(report)])
        assert rc == 1
        data = json.loads(report.read_text())
        assert data["ok"] is False and data["counts"]["GL006"] >= 1
        capsys.readouterr()

        bad.write_text("x = 1\n")
        rc = guardlint_main([str(tmp_path / "src")])
        assert rc == 0
        capsys.readouterr()

    def test_unknown_only_rule_is_usage_error(self, tmp_path, capsys):
        rc = guardlint_main([str(tmp_path), "--only", "GL042"])
        assert rc == 2
        capsys.readouterr()

    def test_list_rules(self, capsys):
        assert guardlint_main(["--list-rules"]) == 0
        out = capsys.readouterr().out
        for rid in sorted(RULES):
            assert rid in out


class TestSelfLint:
    def test_eight_rules_registered(self):
        assert len(RULES) == 8
        assert sorted(RULES) == [f"GL00{i}" for i in range(1, 9)]

    def test_repo_lints_clean(self):
        res = lint_paths([str(REPO_ROOT / "src")], root=str(REPO_ROOT))
        assert res.ok, "self-lint violations:\n" + "\n".join(
            v.render() for v in res.violations)
        # the mandatory-reason policy: every live suppression documents why
        for v, reason in res.suppressed:
            assert reason.strip(), f"reason-less suppression for {v}"
