"""Recovery-path coverage: tier selection, MTTF-driven cadence, hot-spare
promotion economics in the simulator, and RecoveryEvent stamping."""
import math

import pytest

from repro.guard import Tier
from repro.guard.goodput import (MTTR_PHASES, CheckpointTier, MTTFEstimator,
                                 RecoveryModel, goodput_tflop_h,
                                 mttr_decomposition, replica_partner,
                                 young_daly_interval)
from repro.simcluster import RunConfig, simulate_run
from repro.simcluster.faults import FaultRates

# pure fail-stop fault load: no grey faults, no admission greys — every
# incident is a crash, so the recovery path is the only thing under test
CRASH_ONLY = FaultRates(thermal=0.0, power=0.0, mem_ecc=0.0, nic_down=0.0,
                        nic_degraded=0.0, host_cpu=0.0, congestion=0.0,
                        fail_stop=4.0e-2, admission_grey_p=0.0)
QUIET = FaultRates(thermal=0.0, power=0.0, mem_ecc=0.0, nic_down=0.0,
                   nic_degraded=0.0, host_cpu=0.0, congestion=0.0,
                   fail_stop=0.0, admission_grey_p=0.0)


def crash_run(tier, rates=CRASH_ONLY, hours=10.0, seed=0):
    return simulate_run(RunConfig(tier=tier, n_nodes=24, n_spare=8,
                                  duration_h=hours, initial_grey_p=0.0,
                                  rates=rates, seed=seed))


class TestGoodputPrimitives:
    def test_young_daly_monotone_and_clamped(self):
        rm = RecoveryModel()
        a = young_daly_interval(1 * 3600.0, rm.snapshot_cost_s)
        b = young_daly_interval(9 * 3600.0, rm.snapshot_cost_s)
        assert a < b
        # sqrt(2*C*M) scaling between the clamps
        assert b == pytest.approx(a * 3.0)
        assert young_daly_interval(1.0, rm.snapshot_cost_s) == 60.0
        assert young_daly_interval(1e9, rm.snapshot_cost_s) == 1800.0

    def test_replica_partner_pairs(self):
        # buddies are symmetric within each pair...
        for n in (2, 4, 8, 48):
            for i in range(n):
                j = replica_partner(i, n)
                assert j != i
                if i % 2 == 0 and i + 1 < n:
                    assert replica_partner(j, n) == i
        # ...the odd tail mirrors onto rank 0, and n<=1 has no partner
        assert replica_partner(4, 5) == 0
        assert replica_partner(0, 1) == 0

    def test_mttf_estimator_shrinks_with_failures(self):
        est = MTTFEstimator(t0=0.0)
        quiet = est.estimate(8 * 3600.0)
        for t in (3600.0, 7200.0, 10800.0):
            est.observe_failure(t)
        noisy = est.estimate(8 * 3600.0)
        assert noisy < quiet
        assert est.failures == 3
        # Bayesian blend: (elapsed + prior) / (failures + 1)
        expect = (8 * 3600.0 + est.prior_mttf_s) / 4.0
        assert noisy == pytest.approx(expect)

    def test_pick_matrix(self):
        rm = RecoveryModel()
        # ENHANCED: peer replica unless the whole mirror pair is gone
        assert rm.pick(4, node_alive=False, replica_lost=False) \
            is CheckpointTier.PEER
        assert rm.pick(4, node_alive=False, replica_lost=True) \
            is CheckpointTier.COLD
        # ONLINE: local shard survives eviction but not a dead node
        assert rm.pick(3, node_alive=True, replica_lost=False) \
            is CheckpointTier.LOCAL
        assert rm.pick(3, node_alive=False, replica_lost=False) \
            is CheckpointTier.COLD
        # untooled tiers are always cold
        for t in (1, 2):
            assert rm.pick(t, node_alive=True, replica_lost=False) \
                is CheckpointTier.COLD

    def test_mttr_decomposition_schema(self):
        empty = mttr_decomposition([])
        assert empty["incidents"] == 0
        for p in MTTR_PHASES:
            assert f"{p}_mean" in empty and f"{p}_total" in empty
        evs = [{"kind": "recovery", "detect_s": 10.0, "drain_s": 20.0,
                "restore_s": 30.0, "warmup_s": 40.0, "replay_steps": 5,
                "ckpt_tier": "peer", "hot_spare": True},
               {"kind": "step", "t": 0.0},   # ignored
               {"kind": "recovery", "detect_s": 10.0, "drain_s": 20.0,
                "restore_s": 480.0, "warmup_s": 40.0, "replay_steps": 45,
                "ckpt_tier": "cold", "hot_spare": False}]
        d = mttr_decomposition(evs)
        assert d["incidents"] == 2
        assert d["restore_s_mean"] == pytest.approx(255.0)
        assert d["mttr_s"] == pytest.approx((100.0 + 550.0) / 2.0)
        assert d["replay_steps_total"] == 50
        assert d["hot_spare_promotions"] == 1
        assert d["by_tier"] == {"peer": 1, "local": 0, "cold": 1}

    def test_goodput_units(self):
        assert goodput_tflop_h(100, 4500.0, 2.0) == pytest.approx(225000.0)
        assert goodput_tflop_h(100, 4500.0, 0.0) == 0.0


class TestSimRecovery:
    def test_tier_routes_to_expected_checkpoint_tier(self):
        burnin = crash_run(Tier.BURNIN)
        enhanced = crash_run(Tier.ENHANCED)
        assert burnin.recovery["incidents"] > 0
        assert enhanced.recovery["incidents"] > 0
        # untooled crashes always restore cold from the durable checkpoint
        assert burnin.recovery["by_tier"]["cold"] == burnin.recovery["incidents"]
        assert burnin.recovery["by_tier"]["peer"] == 0
        assert burnin.recovery["hot_spare_promotions"] == 0
        # ENHANCED promotes the DP peer's in-memory replica
        assert enhanced.recovery["by_tier"].get("peer", 0) > 0
        assert enhanced.recovery["hot_spare_promotions"] > 0

    def test_hot_spare_charges_fewer_lost_steps_than_cold(self):
        burnin = crash_run(Tier.BURNIN)
        enhanced = crash_run(Tier.ENHANCED)
        # restore is the in-memory replica (30 s) vs durable reload (480 s)
        assert enhanced.recovery["restore_s_mean"] \
            < burnin.recovery["restore_s_mean"]
        # replay from the last FAST snapshot, not the 90-step durable one
        mean_replay = lambda r: (r.recovery["replay_steps_total"]
                                 / r.recovery["incidents"])
        assert mean_replay(enhanced) < mean_replay(burnin)
        # end to end the automated tier turns the same fault load into
        # more unique progress per wall hour
        assert enhanced.recovery["mttr_s"] < burnin.recovery["mttr_s"]
        assert enhanced.goodput_tflop_h > burnin.goodput_tflop_h

    def test_mttr_decomposition_present_per_tier(self):
        for tier in (Tier.BURNIN, Tier.ONLINE, Tier.ENHANCED):
            r = crash_run(tier, hours=6.0)
            for p in MTTR_PHASES:
                assert f"{p}_mean" in r.recovery
            assert r.recovery["mttr_s"] >= 0.0
            assert r.recovery["good_steps"] <= r.steps
            assert r.goodput_tflop_h > 0.0

    def test_cadence_tightens_under_fault_load(self):
        quiet = crash_run(Tier.ENHANCED, rates=QUIET)
        crashy = crash_run(Tier.ENHANCED)
        assert quiet.recovery["incidents"] == 0
        assert quiet.recovery["snap_interval_s"] > 0.0
        # failures pull the MTTF estimate down -> Young-Daly shortens the
        # snapshot cadence
        assert crashy.recovery["snap_interval_s"] \
            < quiet.recovery["snap_interval_s"]
        # untooled tiers have no fast-snapshot machinery at all
        assert crash_run(Tier.BURNIN).recovery["snap_interval_s"] == 0.0

    def test_recovery_events_step_stamped(self):
        r = crash_run(Tier.ENHANCED)
        events = r.events
        recs = [e for e in events if e.get("kind") == "recovery"]
        assert len(recs) == r.recovery["incidents"]
        ts = [e["t"] for e in events]
        assert ts == sorted(ts)
        for e in recs:
            assert 0 <= e["step"] <= r.steps
            assert e["ckpt_tier"] in {"peer", "local", "cold"}
            assert e["restore_s"] > 0.0 and e["warmup_s"] > 0.0
            assert math.isfinite(e["t"])
        # every recovery rides on the restart that triggered it: same
        # timestamp, same post-rewind step
        restarts = [e for e in events if e.get("kind") == "restart"]
        by_t = {e["t"]: e for e in restarts}
        for e in recs:
            assert e["t"] in by_t
            assert by_t[e["t"]]["step"] == e["step"]
